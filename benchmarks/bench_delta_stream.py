"""Delta subscription vs snapshot re-serve: the pub/sub append economics.

The delta protocol's whole claim is economic: a subscribed tenant whose
dataset grows by <=5% per append should pay O(suffix) end-to-end — the
tracker merge + rotation gate, the suffix transform, and the rectangular
suffix-x-all analytics scans — while a snapshot client re-submits the grown
dataset and pays the full O(m^2) downstream recompute every time even when
the served map did not move. This bench drives the SAME drift-free append
stream through both contracts and measures per-append latency:

* **delta_subscribe** — one ``DropService`` subscription
  (``serve_drop.delta``): each append is ``svc.append`` + a scheduler drain
  + ``poll_deltas``; the client folds the pushed delta into
  ``SubscriberState``. Everything the subscriber pays is in the timing,
  including scheduler overhead.
* **snapshot_rerun** — the pre-subscription client: after every append,
  re-transform ALL rows under the served basis and re-run the full
  kNN + DBSCAN + KDE pairwise scans on the grown matrix (the cheapest
  honest baseline — it is not even charged for a basis refit or for the
  service's queueing, only for the downstream work the deltas avoid).

Parity is asserted, not assumed: after the final append the subscriber's
kNN indices/distances and DBSCAN labels must be BIT-IDENTICAL to the
snapshot client's, and KDE densities equal to compensated-sum tolerance —
the speedup is only meaningful if both sides hold the same answer.

Both legs get the harness's two warm passes (compile exclusion) before the
timed one, and the record carries a ``cores=`` caveat: the pairwise engine
is data-parallel inside one dispatch, so single-core hosts understate the
baseline's absolute cost but the RATIO (what this bench tracks) is shape-
driven, O(s*m) vs O(m^2), and survives.

    python benchmarks/bench_delta_stream.py
    python benchmarks/bench_delta_stream.py --rows 4000 --steps 5
    python benchmarks/bench_delta_stream.py --json rows.json  # nightly
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

KDE_ATOL = 1e-5  # densities: compensated f64 fold vs one-pass recompute


def measure(
    rows0: int = 4000,
    dim: int = 128,
    rank: int = 3,
    steps: int = 5,
    grow_frac: float = 0.05,
    target: float = 0.97,
    eps: float = 1.0,
    min_samples: int = 5,
    bandwidth: float = 1.0,
    seed: int = 0,
) -> dict:
    """One drift-free append stream through both serving contracts.

    ``target`` must leave the served rank a real margin over the stream's
    intrinsic rank: a target sitting exactly at the rank boundary (e.g.
    0.98 on this rank-3 process) makes the revalidation gate a coin flip
    and the suffix update pads the rank with degenerate noise directions
    that the next merge freely rotates — every append then correctly
    escalates to a rollback, which is the LADDER's regime (tested in
    test_delta_serve.py), not the steady-append economics this bench
    tracks."""
    import numpy as np

    from benchmarks.harness import warm
    from repro.analytics import dbscan, pairwise_kde, pairwise_knn
    from repro.core import DropConfig
    from repro.data import sinusoid_mixture
    from repro.serve_drop import DropService, SubscribeQuery, SubscriberState

    append = max(1, int(rows0 * grow_frac))
    m_total = rows0 + steps * append
    # one generative process; every append is a genuine extension of the
    # same structured tenant (the regime the rotation gate is tuned for)
    x_full = sinusoid_mixture(m_total, dim, rank=rank, seed=seed)[0]
    cfg = DropConfig(target_tlb=target, seed=seed, min_iterations=99)

    def drive_delta():
        """Subscribe once, then time each append end-to-end: enqueue ->
        scheduler drain -> delta popped and folded by the client."""
        svc = DropService()
        sid = svc.subscribe(SubscribeQuery(
            x=x_full[:rows0], cfg=cfg, eps=eps, min_samples=min_samples,
            bandwidth=bandwidth,
        ))
        while svc.poll():
            pass
        client = SubscriberState()
        for d in svc.poll_deltas(sid):
            client.apply(d)  # bootstrap rollback
        walls = []
        for i in range(steps):
            lo = rows0 + i * append
            t0 = time.perf_counter()
            svc.append(sid, x_full[lo: lo + append])
            while svc.poll():
                pass
            for d in svc.poll_deltas(sid):
                client.apply(d)
            walls.append(time.perf_counter() - t0)
        return walls, client, svc

    def drive_snapshot(basis):
        """The snapshot client on the SAME stream: every append pays a
        full re-transform + full pairwise kNN/DBSCAN/KDE recompute."""
        walls, out = [], None
        for i in range(steps):
            grown = x_full[: rows0 + (i + 1) * append]
            t0 = time.perf_counter()
            xt = basis.transform(grown)
            idx, d2 = pairwise_knn(xt)
            labels = dbscan(xt, eps, min_samples)
            dens = pairwise_kde(xt, None, bandwidth)
            out = (np.asarray(idx), np.asarray(d2), np.asarray(labels),
                   np.asarray(dens))
            walls.append(time.perf_counter() - t0)
        return walls, out

    # harness convention: two warm passes pin the compiled-shape set, the
    # third pass is the timed one
    _, warm_client, _ = warm(lambda: drive_delta())
    basis = warm_client.basis
    warm(lambda: drive_snapshot(basis))
    delta_walls, client, svc = drive_delta()
    snap_walls, (s_idx, s_d2, s_labels, s_dens) = drive_snapshot(client.basis)

    # parity: the speedup only counts if both contracts hold the same
    # answer on the final grown dataset
    assert client.appends == steps and client.rollbacks == 1, (
        client.appends, client.rollbacks,
    )  # drift-free stream: every post-bootstrap delta stayed on the
    #    O(suffix) append path
    # bit layer: the incremental analytics state must be BIT-identical to
    # a cold recompute over the rows the subscriber actually holds
    b_idx, b_d2 = pairwise_knn(client.rows)
    assert np.array_equal(client.knn_idx, np.asarray(b_idx)), "kNN idx drift"
    assert np.array_equal(client.knn_d2, np.asarray(b_d2)), "kNN d2 drift"
    assert np.array_equal(
        client.labels, np.asarray(dbscan(client.rows, eps, min_samples))
    ), "DBSCAN label drift"
    # value layer vs the snapshot client: its transform of the full grown
    # matrix differs from the suffix-assembled rows by f32 ulps (BLAS picks
    # size-dependent kernels), so distances/densities compare to tolerance
    # — indices and labels still agree on this stream
    assert np.array_equal(client.knn_idx, s_idx), "kNN index drift vs snap"
    assert np.array_equal(client.labels, s_labels), "label drift vs snapshot"
    assert np.allclose(client.knn_d2, s_d2, rtol=1e-4, atol=1e-5)
    assert np.allclose(client.densities, s_dens, atol=KDE_ATOL), (
        float(np.max(np.abs(client.densities - s_dens)))
    )

    cores = (
        len(os.sched_getaffinity(0))
        if hasattr(os, "sched_getaffinity")
        else (os.cpu_count() or 1)
    )
    mean_delta = sum(delta_walls) / len(delta_walls)
    mean_snap = sum(snap_walls) / len(snap_walls)
    return {
        "rows0": rows0,
        "dim": dim,
        "rank": rank,
        "steps": steps,
        "grow_frac": grow_frac,
        "append_rows": append,
        "target_tlb": target,
        "k": client.basis.k,
        "cores": cores,
        "note": (
            f"speedup is shape-driven (O(s*m) rectangular scans vs O(m^2) "
            f"full recompute); cores={cores} scales both legs' absolute "
            f"times together"
        ),
        "speedup_delta_vs_snapshot": round(mean_snap / mean_delta, 2),
        "legs": {
            "delta_subscribe": {
                "per_append_ms": [round(w * 1e3, 2) for w in delta_walls],
                "mean_append_ms": round(mean_delta * 1e3, 2),
                "steady_qps": round(1.0 / mean_delta, 2),
                "delta_serves": svc.stats.delta_serves,
                "rollbacks": svc.stats.rollbacks,
            },
            "snapshot_rerun": {
                "per_append_ms": [round(w * 1e3, 2) for w in snap_walls],
                "mean_append_ms": round(mean_snap * 1e3, 2),
                "steady_qps": round(1.0 / mean_snap, 2),
            },
        },
    }


def run(full: bool = False) -> list:
    """Harness rows (benchmarks/run.py integration)."""
    from benchmarks.harness import Row

    rec = measure(
        rows0=4000 if full else 1500,
        dim=128 if full else 96,
        steps=5 if full else 3,
        grow_frac=0.05,
    )
    label = (
        f"delta_stream/m{rec['rows0']}"
        f"+{int(rec['grow_frac'] * 100)}%x{rec['steps']}"
    )
    rows = []
    for name, leg in rec["legs"].items():
        derived = f"qps={leg['steady_qps']};k={rec['k']}"
        if name == "delta_subscribe":
            derived += (
                f";speedup={rec['speedup_delta_vs_snapshot']:.2f}x vs "
                f"snapshot re-serve;cores={rec['cores']} "
                "(O(suffix) deltas replace the O(m^2) downstream recompute "
                "per append)"
            )
        rows.append(Row(f"{label}/{name}", leg["mean_append_ms"] * 1e3,
                        derived))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=4000)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--rank", type=int, default=3)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--grow-frac", type=float, default=0.05,
                    help="per-append row growth as a fraction of the base")
    ap.add_argument("--target", type=float, default=0.97,
                    help="TLB target; keep a margin over the stream's "
                         "intrinsic rank (see measure docstring)")
    ap.add_argument("--eps", type=float, default=1.0)
    ap.add_argument("--min-samples", type=int, default=5)
    ap.add_argument("--bandwidth", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", type=str, default=None,
                    help="write the record as JSON (nightly CI artifact)")
    args = ap.parse_args()

    rec = measure(
        rows0=args.rows, dim=args.dim, rank=args.rank, steps=args.steps,
        grow_frac=args.grow_frac, target=args.target, eps=args.eps,
        min_samples=args.min_samples, bandwidth=args.bandwidth,
        seed=args.seed,
    )
    print(f"delta stream: m0={rec['rows0']} d={rec['dim']} "
          f"rank={rec['rank']} +{rec['append_rows']} rows x "
          f"{rec['steps']} appends (target={rec['target_tlb']}, "
          f"k={rec['k']}, cores={rec['cores']})")
    for name, leg in rec["legs"].items():
        print(f"  {name:16s} mean_append={leg['mean_append_ms']:8.1f}ms "
              f"qps={leg['steady_qps']:6.2f}")
    print(f"  speedup: {rec['speedup_delta_vs_snapshot']:.2f}x "
          f"(delta vs snapshot re-serve, parity-checked)")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
