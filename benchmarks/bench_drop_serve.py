"""DropService throughput: repeat-workload traffic vs sequential cold drop().

The paper's §5 reuse claim, measured at the service layer: a pool of D
distinct datasets is queried Q times (Q > D, so later submissions repeat).
Sequential baseline pays a full cold DROP per query; the service pays DROP
once per distinct dataset and a sampled-TLB validation per repeat. Expected:
>=1.5x on repeat-heavy traffic.
"""

from __future__ import annotations

import numpy as np

from benchmarks.harness import Row, timed
from repro.core import DropConfig, drop
from repro.core.cost import knn_cost
from repro.data import sinusoid_mixture
from repro.serve_drop import DropService


def _workload(n_queries: int, n_datasets: int, rows: int, dim: int):
    pool = [
        sinusoid_mixture(rows, dim, rank=5 + i, seed=i)[0]
        for i in range(n_datasets)
    ]
    return [pool[i % n_datasets] for i in range(n_queries)]


def _serve(datasets, cfg, cost) -> DropService:
    svc = DropService()
    for x in datasets:
        svc.submit(x, cfg, cost)
    svc.run()
    return svc


def run(full: bool = False) -> list[Row]:
    rows_n = 4000 if full else 1200
    dim = 128 if full else 64
    n_queries = 16 if full else 8
    n_datasets = 2
    cfg = DropConfig(target_tlb=0.98, seed=0)
    cost = knn_cost(rows_n)
    datasets = _workload(n_queries, n_datasets, rows_n, dim)

    # warmup=1 runs each side once un-timed (harness convention: timing
    # excludes jit compilation), so the comparison isolates basis reuse —
    # each timed _serve() builds a FRESH service, so its cache starts cold
    t_seq, _ = timed(
        lambda: [drop(x, cfg, cost=cost) for x in datasets], warmup=1
    )
    t_srv, svc = timed(lambda: _serve(datasets, cfg, cost), warmup=1)

    speedup = t_seq / t_srv
    out = [
        Row(
            f"drop_serve/q{n_queries}_d{n_datasets}/sequential",
            t_seq * 1e6 / n_queries,
            f"qps={n_queries/t_seq:.2f}",
        ),
        Row(
            f"drop_serve/q{n_queries}_d{n_datasets}/service",
            t_srv * 1e6 / n_queries,
            f"qps={n_queries/t_srv:.2f};hits={svc.stats.cache_hits};"
            f"fits={svc.stats.fit_calls};speedup={speedup:.2f}x "
            "(paper §5: reuse amortizes fitting across repeat workloads)",
        ),
    ]
    return out


if __name__ == "__main__":
    for row in run():
        print(row.csv())
