"""DropService throughput: repeat-workload reuse and multi-device scaling.

Two claims, measured at the service layer:

* **§5 reuse** — a pool of D distinct datasets is queried Q times (Q > D, so
  later submissions repeat). Sequential baseline pays a full cold DROP per
  query; the service pays DROP once per distinct dataset and a sampled-TLB
  validation per repeat. Expected: >=1.5x on repeat-heavy traffic.
* **multi-device scaling** — a multi-tenant cache-COLD workload
  (heterogeneous tenants, each with its own shapes, zero reuse: every query
  pays a full DROP fit) served by a 1- vs N-worker process fleet. Following
  the harness convention, jit compilation is excluded: two warm drains land
  the compiles in the workers before the clock starts. Expected: >=1.5x at
  2 workers GIVEN >=2 host cores (workers split the core set; a single-core
  container can only measure the fleet's supervision overhead, ~0.9x).

  Measurement note: the XLA *CPU* host platform serializes execution across
  forced host devices inside one client (one execution pool per client), so
  in-process placement cannot scale on CPU no matter the scheduler — real
  CPU scale-out is one worker PROCESS (one XLA client) per device slot,
  which is also how a production CPU deployment shards. That mode is now a
  library feature (``repro.serve_drop.FleetSupervisor``: supervised
  core-pinned workers, framed-pickle pipe protocol, restart-on-death); this
  bench drives the library instead of carrying its own worker protocol.
  On accelerator backends each device executes independently, so there the
  in-process ``ShardedDropService`` threaded drain provides the overlap and
  the fleet's sticky round-robin mirrors its placement (tenant i ->
  worker i mod N).

    python benchmarks/bench_drop_serve.py                # harness rows
    python benchmarks/bench_drop_serve.py --devices 2    # scaling comparison
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

# runnable both as `python -m benchmarks.bench_drop_serve` and as a script
# without PYTHONPATH: the repo root provides `benchmarks.`, src/ provides
# `repro.` (fleet worker subprocesses receive PYTHONPATH=src from the
# supervisor itself)
_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))


def _tenant_args(n_tenants: int) -> list[tuple[int, int, int, int]]:
    """Heterogeneous tenants: every tenant has its own (rows, dim), so a cold
    drain fits per-tenant shapes — the multi-tenant case placement spreads."""
    return [
        (800 + 200 * i, 48 + 16 * i, 4 + i, i)  # rows, dim, rank, seed
        for i in range(n_tenants)
    ]


def _run_scale_leg(workers: int, tenants: int) -> dict:
    """One leg: a ``FleetSupervisor`` of ``workers`` core-pinned processes
    serves all ``tenants`` concurrently. The fleet IS the library serving
    mode (``serve_drop.fleet``) — this bench no longer carries its own
    worker protocol. Sticky round-robin placement (tenant i -> worker
    i mod N, the uniform-arrival assignment), worker caches off so every
    query pays a full cold DROP fit, two warm drains so compiles land in
    the workers outside the clock, then best-of-3 timed drains."""
    from benchmarks.harness import warm
    from repro.core import DropConfig
    from repro.core.cost import zero_cost
    from repro.data import sinusoid_mixture
    from repro.serve_drop import FleetSupervisor

    # min_iterations pins every tenant to the full progressive schedule:
    # Eq. 2 termination is wall-clock-adaptive, so unpinned iteration counts
    # (and with them per-query k and the shape set compiled during warmup)
    # would vary run-to-run and across legs
    datasets = [
        (sinusoid_mixture(rows, dim, rank=rank, seed=seed)[0],
         DropConfig(target_tlb=0.98, seed=seed, min_iterations=99))
        for rows, dim, rank, seed in _tenant_args(tenants)
    ]
    with FleetSupervisor(
        workers=workers,
        enable_worker_cache=False,  # cache-cold: the claim under test
        placement="rr",  # sticky homes keep warmed executables valid
        profile=False,  # rr ignores measured cost; skip the probe time
    ) as fleet:

        def drain():
            qids = {
                fleet.submit(x, cfg, zero_cost()): i
                for i, (x, cfg) in enumerate(datasets)
            }
            return {
                qids[r.query_id]: r.result.k
                for r in fleet.run(timeout=1800)
            }

        # two warm drains (harness convention for DROP's adaptive schedule)
        warm(drain)
        wall, ks = float("inf"), {}
        for _ in range(3):
            t0 = time.perf_counter()
            ks = drain()
            wall = min(wall, time.perf_counter() - t0)
    return {
        "devices": workers,
        "wall_s": wall,
        "qps": tenants / wall,
        "ks": [ks[i] for i in range(tenants)],
    }


def scaling_rows(max_devices: int = 2, tenants: int = 6) -> list:
    """Cache-cold multi-tenant throughput at 1 vs ``max_devices`` workers.

    The speedup is core-bound: N workers split the host's cores, so the
    >=1.5x-at-2-workers claim needs >=2 cores — on a single-core container
    the comparison measures supervision+transport overhead instead (~0.9x,
    i.e. the fleet machinery costs <10%), and the row says so."""
    from benchmarks.harness import Row

    cores = (
        len(os.sched_getaffinity(0))
        if hasattr(os, "sched_getaffinity")
        else (os.cpu_count() or 1)
    )
    legs = [_run_scale_leg(d, tenants) for d in (1, max_devices)]
    base, multi = legs[0], legs[-1]
    speedup = multi["qps"] / base["qps"]
    if base["ks"] != multi["ks"]:  # placement must never change results
        raise AssertionError(
            f"per-query k diverged across legs: {base['ks']} vs {multi['ks']}"
        )
    rows = [
        Row(
            f"drop_serve/scale_cold_t{tenants}/d{leg['devices']}",
            leg["wall_s"] * 1e6 / tenants,
            f"qps={leg['qps']:.2f}",
        )
        for leg in legs
    ]
    rows[-1].derived += (
        f";speedup={speedup:.2f}x vs 1 worker;cores={cores} "
        "(multi-tenant cache-cold: every query pays a full fit; one XLA "
        "client per worker; speedup is core-bound — expect >=1.5x only "
        f"with >={max_devices} cores)"
    )
    return rows


def run(full: bool = False) -> list:
    from benchmarks.harness import Row, timed, warm
    from repro.core import DropConfig, drop
    from repro.core.cost import knn_cost
    from repro.data import sinusoid_mixture
    from repro.serve_drop import DropService

    rows_n = 4000 if full else 1200
    dim = 128 if full else 64
    n_queries = 16 if full else 8
    n_datasets = 2
    cfg = DropConfig(target_tlb=0.98, seed=0)
    cost = knn_cost(rows_n)
    pool = [
        sinusoid_mixture(rows_n, dim, rank=5 + i, seed=i)[0]
        for i in range(n_datasets)
    ]
    datasets = [pool[i % n_datasets] for i in range(n_queries)]

    def _serve():
        svc = DropService()
        for x in datasets:
            svc.submit(x, cfg, cost)
        svc.run()
        return svc

    # two warm runs per side un-timed (harness convention: DROP's adaptive
    # schedule needs two to pin its compiled-shape set), so the comparison
    # isolates basis reuse — each timed _serve() builds a FRESH service, so
    # its cache starts cold
    seq = lambda: [drop(x, cfg, cost=cost) for x in datasets]  # noqa: E731
    warm(seq)
    t_seq, _ = timed(seq, warmup=0)
    warm(_serve)
    t_srv, svc = timed(_serve, warmup=0)

    speedup = t_seq / t_srv
    out = [
        Row(
            f"drop_serve/q{n_queries}_d{n_datasets}/sequential",
            t_seq * 1e6 / n_queries,
            f"qps={n_queries/t_seq:.2f}",
        ),
        Row(
            f"drop_serve/q{n_queries}_d{n_datasets}/service",
            t_srv * 1e6 / n_queries,
            f"qps={n_queries/t_srv:.2f};hits={svc.stats.cache_hits};"
            f"fits={svc.stats.fit_calls};speedup={speedup:.2f}x "
            "(paper §5: reuse amortizes fitting across repeat workloads)",
        ),
    ]
    if full:
        # fleet legs: minutes of cold compile each, full mode only
        out += scaling_rows()
    return out


def _emit(rows, json_path: str | None) -> None:
    """Print harness CSV; optionally also write a JSON artifact (nightly CI
    throughput tracking — regressions in these numbers are silent in a
    correctness-only suite)."""
    for row in rows:
        print(row.csv())
    if json_path:
        with open(json_path, "w") as f:
            json.dump(
                [
                    {"name": r.name, "us_per_call": r.us_per_call,
                     "derived": r.derived}
                    for r in rows
                ],
                f, indent=2,
            )
        print(f"wrote {json_path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--tenants", type=int, default=6)
    ap.add_argument("--json", type=str, default=None,
                    help="also write rows as a JSON artifact")
    args = ap.parse_args()
    if args.devices is not None:
        rows = scaling_rows(args.devices, args.tenants)
    else:
        rows = run()
    _emit(rows, args.json)
