"""DropService throughput: repeat-workload reuse and multi-device scaling.

Two claims, measured at the service layer:

* **§5 reuse** — a pool of D distinct datasets is queried Q times (Q > D, so
  later submissions repeat). Sequential baseline pays a full cold DROP per
  query; the service pays DROP once per distinct dataset and a sampled-TLB
  validation per repeat. Expected: >=1.5x on repeat-heavy traffic.
* **multi-device scaling** — a multi-tenant cache-COLD workload
  (heterogeneous tenants, each with its own shapes, zero reuse: every query
  pays a full DROP fit) served by 1 vs N device workers. Following the
  harness convention, jit compilation is excluded: each worker warms its
  executables before the clock starts. Expected: >=1.5x at 2 devices.

  Measurement note: the XLA *CPU* host platform serializes execution across
  forced host devices inside one client (one execution pool per client), so
  in-process placement cannot scale on CPU no matter the scheduler — the
  bench therefore isolates each device in its own worker process (one XLA
  client per device), which is also how a production CPU deployment shards.
  On accelerator backends each device executes independently, so there the
  in-process ``ShardedDropService`` threaded drain provides the overlap and
  this bench's worker split simply mirrors its placement policy (tenant i ->
  device i mod N).

    python benchmarks/bench_drop_serve.py                # harness rows
    python benchmarks/bench_drop_serve.py --devices 2    # scaling comparison
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

# runnable both as `python -m benchmarks.bench_drop_serve` and as a script
# without PYTHONPATH: the repo root provides `benchmarks.`, src/ provides
# `repro.` (worker subprocesses still receive PYTHONPATH=src explicitly)
_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))


def _tenant_args(n_tenants: int) -> list[tuple[int, int, int, int]]:
    """Heterogeneous tenants: every tenant has its own (rows, dim), so a cold
    drain fits per-tenant shapes — the multi-tenant case placement spreads."""
    return [
        (800 + 200 * i, 48 + 16 * i, 4 + i, i)  # rows, dim, rank, seed
        for i in range(n_tenants)
    ]


def _scale_worker_main(argv: list[str]) -> None:
    """Device-worker entry: serve this worker's tenant shard through a
    single-device service. Warm first, handshake READY/GO on stdio so the
    parent's clock excludes startup and compilation."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale-worker", type=int, required=True)  # shard index
    ap.add_argument("--of", type=int, required=True)  # worker count
    ap.add_argument("--tenants", type=int, default=6)
    args = ap.parse_args(argv)

    # partition host cores across device workers (multi-worker legs only):
    # each worker's XLA client otherwise spawns an nproc-wide compute pool
    # and N workers x nproc threads thrash — a production shard sizes each
    # replica to cores/replicas, so the bench does too
    if args.of > 1 and hasattr(os, "sched_setaffinity"):
        cores = sorted(os.sched_getaffinity(0))
        mine_cores = {
            c for i, c in enumerate(cores) if i % args.of == args.scale_worker
        }
        os.sched_setaffinity(0, mine_cores or set(cores))

    from repro.core import DropConfig
    from repro.core.cost import zero_cost
    from repro.data import sinusoid_mixture
    from repro.serve_drop import DropService

    # tenant i -> worker i mod N: same round-robin the sharded scheduler's
    # least-loaded admission produces for a uniform arrival order
    mine = [
        (i, spec)
        for i, spec in enumerate(_tenant_args(args.tenants))
        if i % args.of == args.scale_worker
    ]
    # min_iterations pins every tenant to the full progressive schedule:
    # Eq. 2 termination is wall-clock-adaptive, so unpinned iteration counts
    # (and with them per-query k and the shape set compiled during warmup)
    # would vary run-to-run and across legs
    datasets = [
        (i, sinusoid_mixture(rows, dim, rank=rank, seed=seed)[0],
         DropConfig(target_tlb=0.98, seed=seed, min_iterations=99))
        for i, (rows, dim, rank, seed) in mine
    ]

    def drain():
        svc = DropService(max_inflight=len(datasets), enable_cache=False)
        qids = {svc.submit(x, cfg, zero_cost()): i for i, x, cfg in datasets}
        return {qids[r.query_id]: r.result.k for r in svc.run()}

    from benchmarks.harness import warm

    # two warm drains (harness convention for DROP's adaptive schedule):
    # compiles land here, outside the parent's clock
    warm(drain)
    print("READY", flush=True)
    sys.stdin.readline()  # GO
    # best-of-3 (harness convention): all workers keep draining concurrently,
    # so contention stays realistic while container noise is filtered
    wall, ks = float("inf"), {}
    for _ in range(3):
        t0 = time.perf_counter()
        ks = drain()
        wall = min(wall, time.perf_counter() - t0)
    print(json.dumps({"shard": args.scale_worker, "wall_s": wall,
                      "ks": {str(i): k for i, k in ks.items()}}), flush=True)


def _run_scale_leg(workers: int, tenants: int) -> dict:
    """One leg: ``workers`` device processes serve ``tenants`` concurrently.
    Leg wall = GO -> last worker done (startup/compile excluded)."""
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    procs = [
        subprocess.Popen(
            [sys.executable, os.path.abspath(__file__),
             "--scale-worker", str(w), "--of", str(workers),
             "--tenants", str(tenants)],
            env=env, stdin=subprocess.PIPE, stdout=subprocess.PIPE, text=True,
        )
        for w in range(workers)
    ]
    for p in procs:  # all workers warm before any clock starts
        assert p.stdout.readline().strip() == "READY"
    for p in procs:
        p.stdin.write("GO\n")
        p.stdin.flush()
    outs = [json.loads(p.stdout.readline()) for p in procs]
    for p in procs:
        p.wait()
    # leg wall = the slowest worker's best round: the service is only as
    # fast as its most loaded device
    wall = max(o["wall_s"] for o in outs)
    ks: dict[str, int] = {}
    for o in outs:
        ks.update(o["ks"])
    return {
        "devices": workers,
        "wall_s": wall,
        "qps": tenants / wall,
        "ks": [ks[str(i)] for i in range(tenants)],
    }


def scaling_rows(max_devices: int = 2, tenants: int = 6) -> list:
    """Cache-cold multi-tenant throughput at 1 vs ``max_devices`` devices."""
    from benchmarks.harness import Row

    legs = [_run_scale_leg(d, tenants) for d in (1, max_devices)]
    base, multi = legs[0], legs[-1]
    speedup = multi["qps"] / base["qps"]
    if base["ks"] != multi["ks"]:  # placement must never change results
        raise AssertionError(
            f"per-query k diverged across legs: {base['ks']} vs {multi['ks']}"
        )
    rows = [
        Row(
            f"drop_serve/scale_cold_t{tenants}/d{leg['devices']}",
            leg["wall_s"] * 1e6 / tenants,
            f"qps={leg['qps']:.2f}",
        )
        for leg in legs
    ]
    rows[-1].derived += (
        f";speedup={speedup:.2f}x vs 1 device (multi-tenant cache-cold: "
        "every query pays a full fit; one XLA client per device)"
    )
    return rows


def run(full: bool = False) -> list:
    from benchmarks.harness import Row, timed, warm
    from repro.core import DropConfig, drop
    from repro.core.cost import knn_cost
    from repro.data import sinusoid_mixture
    from repro.serve_drop import DropService

    rows_n = 4000 if full else 1200
    dim = 128 if full else 64
    n_queries = 16 if full else 8
    n_datasets = 2
    cfg = DropConfig(target_tlb=0.98, seed=0)
    cost = knn_cost(rows_n)
    pool = [
        sinusoid_mixture(rows_n, dim, rank=5 + i, seed=i)[0]
        for i in range(n_datasets)
    ]
    datasets = [pool[i % n_datasets] for i in range(n_queries)]

    def _serve():
        svc = DropService()
        for x in datasets:
            svc.submit(x, cfg, cost)
        svc.run()
        return svc

    # two warm runs per side un-timed (harness convention: DROP's adaptive
    # schedule needs two to pin its compiled-shape set), so the comparison
    # isolates basis reuse — each timed _serve() builds a FRESH service, so
    # its cache starts cold
    seq = lambda: [drop(x, cfg, cost=cost) for x in datasets]  # noqa: E731
    warm(seq)
    t_seq, _ = timed(seq, warmup=0)
    warm(_serve)
    t_srv, svc = timed(_serve, warmup=0)

    speedup = t_seq / t_srv
    out = [
        Row(
            f"drop_serve/q{n_queries}_d{n_datasets}/sequential",
            t_seq * 1e6 / n_queries,
            f"qps={n_queries/t_seq:.2f}",
        ),
        Row(
            f"drop_serve/q{n_queries}_d{n_datasets}/service",
            t_srv * 1e6 / n_queries,
            f"qps={n_queries/t_srv:.2f};hits={svc.stats.cache_hits};"
            f"fits={svc.stats.fit_calls};speedup={speedup:.2f}x "
            "(paper §5: reuse amortizes fitting across repeat workloads)",
        ),
    ]
    if full:
        # subprocess legs: minutes of cold compile each, full mode only
        out += scaling_rows()
    return out


def _emit(rows, json_path: str | None) -> None:
    """Print harness CSV; optionally also write a JSON artifact (nightly CI
    throughput tracking — regressions in these numbers are silent in a
    correctness-only suite)."""
    for row in rows:
        print(row.csv())
    if json_path:
        with open(json_path, "w") as f:
            json.dump(
                [
                    {"name": r.name, "us_per_call": r.us_per_call,
                     "derived": r.derived}
                    for r in rows
                ],
                f, indent=2,
            )
        print(f"wrote {json_path}")


if __name__ == "__main__":
    if any(a == "--scale-worker" or a.startswith("--scale-worker=")
           for a in sys.argv):
        _scale_worker_main(sys.argv[1:])
    else:
        ap = argparse.ArgumentParser()
        ap.add_argument("--devices", type=int, default=None)
        ap.add_argument("--tenants", type=int, default=6)
        ap.add_argument("--json", type=str, default=None,
                        help="also write rows as a JSON artifact")
        args = ap.parse_args()
        if args.devices is not None:
            rows = scaling_rows(args.devices, args.tenants)
        else:
            rows = run()
        _emit(rows, args.json)
