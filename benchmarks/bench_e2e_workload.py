"""End-to-end workload comparison (paper §4.4): DROP vs forced FFT/PAA.

The paper's headline figure is not DR runtime but TOTAL pipeline time:
reduce, then run the analytics on the reduced data. FFT/PAA fit faster, but
their larger k makes every downstream distance computation proportionally
more expensive — on structured series DROP's smaller basis wins end-to-end.
This bench measures exactly that, via the first-class
``pipeline.WorkloadOptimizer`` API instead of ad-hoc timing:

* per method: measured DR wall (R), achieved k/TLB, priced C_m(k),
  objective R + C_m(k), and measured downstream + end-to-end wall;
* the optimizer's pick (argmin objective among TLB-satisfying methods).

Following the harness convention, jit compilation is excluded: DR and the
downstream kernels are warmed per shape before the clock starts.

    python benchmarks/bench_e2e_workload.py
    python benchmarks/bench_e2e_workload.py --rows 8000 --dim 256
    python benchmarks/bench_e2e_workload.py --json e2e.json   # CI artifact
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))


def measure(
    rows: int = 6000,
    dim: int = 192,
    rank: int = 3,
    target: float = 0.98,
    downstream: str = "knn",
    methods: tuple = ("pca", "fft", "paa"),
    seed: int = 0,
) -> dict:
    """One workload's full comparison; returns a JSON-ready record."""
    from repro.core import DropConfig, reduce
    from repro.core.cost import downstream_cost
    from repro.data import sinusoid_mixture
    from repro.pipeline import WorkloadOptimizer, run_downstream

    x, _ = sinusoid_mixture(rows, dim, rank=rank, seed=seed)
    cfg = DropConfig(target_tlb=target, seed=seed)
    cost = downstream_cost(downstream, rows)

    # warm every method's DR path AND the downstream kernel at its k (the
    # analytics kernels compile per reduced shape). DROP's wall-clock-
    # adaptive schedule needs the harness's two warm runs; the single-shot
    # baselines stabilize in one.
    from benchmarks.harness import warm

    for m in methods:
        res = warm(
            lambda m=m: reduce(x, m, cfg, cost),
            runs=2 if m == "pca" else 1,
        )
        run_downstream(downstream, res.transform(x))

    opt = WorkloadOptimizer(methods=methods, cfg=cfg)
    report = opt.optimize(x, downstream, execute="all")

    # best-of-3 on the warm downstream and best-of-2 on warm DR (container
    # noise filter, harness convention); the optimizer's decision record
    # keeps its own single-pass measurement semantics
    for m, o in report.outcomes.items():
        t0 = time.perf_counter()
        res = reduce(x, m, cfg, cost)
        o.reduce_s = min(o.reduce_s, time.perf_counter() - t0)
        o.objective = o.reduce_s + o.downstream_est_s
        xt = o.result.transform(x)
        for _ in range(2):
            t0 = time.perf_counter()
            run_downstream(downstream, xt)
            o.downstream_s = min(o.downstream_s, time.perf_counter() - t0)
        o.end_to_end_s = o.reduce_s + o.downstream_s

    # re-pick on the refined (best-of-N) objectives
    sat = [
        m for m, o in report.outcomes.items() if o.result.satisfied
    ] or list(report.outcomes)
    report.chosen = min(sat, key=lambda m: report.outcomes[m].objective)

    return {
        "rows": rows,
        "dim": dim,
        "rank": rank,
        "target_tlb": target,
        "downstream": downstream,
        "chosen": report.chosen,
        "methods": {
            m: {
                "k": o.result.k,
                "tlb": round(o.result.tlb_estimate, 4),
                "satisfied": o.result.satisfied,
                "reduce_ms": round(o.reduce_s * 1e3, 1),
                "cost_model_ms": round(o.downstream_est_s * 1e3, 1),
                "objective_ms": round(o.objective * 1e3, 1),
                "downstream_ms": round(o.downstream_s * 1e3, 1),
                "e2e_ms": round(o.end_to_end_s * 1e3, 1),
            }
            for m, o in report.outcomes.items()
        },
    }


def run(full: bool = False) -> list:
    """Harness rows (benchmarks/run.py integration)."""
    from benchmarks.harness import Row

    rec = measure(
        rows=8000 if full else 4000, dim=256 if full else 128, rank=3
    )
    rows = []
    for m, o in sorted(
        rec["methods"].items(), key=lambda kv: kv[1]["e2e_ms"]
    ):
        tag = " <- chosen" if m == rec["chosen"] else ""
        rows.append(
            Row(
                f"e2e_workload/{rec['downstream']}"
                f"/m{rec['rows']}_d{rec['dim']}/{m}",
                o["e2e_ms"] * 1e3,
                f"k={o['k']};tlb={o['tlb']};reduce_ms={o['reduce_ms']};"
                f"downstream_ms={o['downstream_ms']};"
                f"objective_ms={o['objective_ms']}{tag}",
            )
        )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=6000)
    ap.add_argument("--dim", type=int, default=192)
    ap.add_argument("--rank", type=int, default=3)
    ap.add_argument("--target", type=float, default=0.98)
    ap.add_argument("--downstream", type=str, default="knn",
                    choices=("knn", "dbscan", "kde"))
    ap.add_argument("--methods", type=str, default="pca,fft,paa")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", type=str, default=None,
                    help="write the record as JSON (nightly CI artifact)")
    args = ap.parse_args()

    rec = measure(
        rows=args.rows, dim=args.dim, rank=args.rank, target=args.target,
        downstream=args.downstream,
        methods=tuple(m.strip() for m in args.methods.split(",")),
        seed=args.seed,
    )
    print(f"workload: m={rec['rows']} d={rec['dim']} rank={rec['rank']} "
          f"downstream={rec['downstream']} target={rec['target_tlb']}")
    print(f"optimizer chose: {rec['chosen']}")
    for m, o in sorted(rec["methods"].items(),
                       key=lambda kv: kv[1]["e2e_ms"]):
        tag = "  <- chosen" if m == rec["chosen"] else ""
        print(f"  {m:4s} k={o['k']:4d} tlb={o['tlb']:.4f} "
              f"reduce={o['reduce_ms']:8.1f}ms "
              f"downstream={o['downstream_ms']:8.1f}ms "
              f"e2e={o['e2e_ms']:8.1f}ms "
              f"objective={o['objective_ms']:8.1f}ms{tag}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
