"""Paper Figure 10 + Tables 2/3/4: END-TO-END k-NN pipelines.
Pipeline = dimensionality reduction (None/SVD/SVD-Halko/DROP) -> 1-NN
retrieval. Claims: DROP end-to-end up to 33x faster than no-DR (avg 2.7x),
avg ~5.9x faster than SVD; retrieval accuracy within ~1% of baselines."""

from __future__ import annotations

import numpy as np

from benchmarks.harness import Row, suite, timed
from repro.analytics import knn_retrieval_accuracy
from repro.baselines.svd_pca import svd_binary_search, svd_halko_binary_search
from repro.core import DropConfig, drop
from repro.core.cost import knn_cost


def run(full: bool = False) -> list[Row]:
    rows: list[Row] = []
    sp_raw, sp_svd, accs = [], [], []
    cfg = DropConfig(target_tlb=0.98, seed=0)
    for name, (x, y) in suite(full).items():
        cost = knn_cost(x.shape[0])
        # no dimensionality reduction
        t_raw, acc_raw = timed(lambda: knn_retrieval_accuracy(x, y))

        def pipeline(reducer):
            r = reducer()
            xt = r.transform(x) if hasattr(r, "transform") else r
            return knn_retrieval_accuracy(np.ascontiguousarray(xt), y)

        t_drop, acc_drop = timed(lambda: pipeline(lambda: drop(x, cfg, cost=cost)))
        t_svd, acc_svd = timed(lambda: pipeline(lambda: svd_binary_search(x, cfg)))
        t_halko, acc_halko = timed(
            lambda: pipeline(lambda: svd_halko_binary_search(x, cfg))
        )
        sp_raw.append(t_raw / t_drop)
        sp_svd.append(t_svd / t_drop)
        accs.append(acc_drop - acc_raw)
        rows.append(
            Row(
                f"fig10/{name}",
                t_drop * 1e6,
                f"speedup_vs_raw={t_raw/t_drop:.2f}x;"
                f"speedup_vs_svd={t_svd/t_drop:.2f}x;"
                f"speedup_vs_halko={t_halko/t_drop:.2f}x;"
                f"acc_raw={acc_raw:.3f};acc_drop={acc_drop:.3f};"
                f"acc_svd={acc_svd:.3f};acc_halko={acc_halko:.3f}",
            )
        )
    rows.append(
        Row(
            "fig10/AVG",
            0.0,
            f"speedup_vs_raw={np.mean(sp_raw):.2f}x(max {np.max(sp_raw):.1f}x);"
            f"speedup_vs_svd={np.mean(sp_svd):.2f}x;"
            f"acc_delta_vs_raw={np.mean(accs):+.4f}"
            " (paper: 2.7x avg/33x max vs raw, ~5.9x vs svd, acc within 1%)",
        )
    )
    return rows
