"""Paper Figure 12: cost-function sensitivity — the SAME k-NN-tuned cost
function driving a DBSCAN pipeline. Claim: speedups smaller (avg ~1.25x vs
raw) but DROP still beats SVD (~5.6x) and Halko (~2.5x) end-to-end."""

from __future__ import annotations

import numpy as np

from benchmarks.harness import Row, suite, timed
from repro.analytics import dbscan
from repro.baselines.svd_pca import svd_binary_search, svd_halko_binary_search
from repro.core import DropConfig, drop
from repro.core.cost import knn_cost  # deliberately the k-NN cost (the claim)


def run(full: bool = False) -> list[Row]:
    rows: list[Row] = []
    sp_raw, sp_svd, sp_halko = [], [], []
    cfg = DropConfig(target_tlb=0.98, seed=0)
    items = list(suite(full).items())[: (None if full else 4)]
    for name, (x, y) in items:
        x = x[:1500] if not None else x  # DBSCAN BFS is host-side: keep modest
        cost = knn_cost(x.shape[0])
        eps = 0.35 * np.sqrt(x.shape[1])  # scale-aware radius
        t_raw, _ = timed(lambda: dbscan(x, eps=eps, min_samples=4))

        def pipeline(reducer):
            r = reducer()
            xt = np.ascontiguousarray(r.transform(x))
            return dbscan(xt, eps=eps, min_samples=4)

        t_drop, _ = timed(lambda: pipeline(lambda: drop(x, cfg, cost=cost)))
        t_svd, _ = timed(lambda: pipeline(lambda: svd_binary_search(x, cfg)))
        t_halko, _ = timed(
            lambda: pipeline(lambda: svd_halko_binary_search(x, cfg))
        )
        sp_raw.append(t_raw / t_drop)
        sp_svd.append(t_svd / t_drop)
        sp_halko.append(t_halko / t_drop)
        rows.append(
            Row(f"fig12/{name}", t_drop * 1e6,
                f"speedup_vs_raw={t_raw/t_drop:.2f}x;"
                f"speedup_vs_svd={t_svd/t_drop:.2f}x;"
                f"speedup_vs_halko={t_halko/t_drop:.2f}x")
        )
    rows.append(
        Row("fig12/AVG", 0.0,
            f"speedup_vs_raw={np.mean(sp_raw):.2f}x;"
            f"speedup_vs_svd={np.mean(sp_svd):.2f}x;"
            f"speedup_vs_halko={np.mean(sp_halko):.2f}x"
            " (paper: 1.25x raw, 5.63x svd, 2.5x halko)")
    )
    return rows
