"""Paper Figure 2: runtime of PAA / FFT / PCA-via-SVD, normalized to PAA.
Claim: PCA is ~50x slower than PAA, ~8x slower than FFT (motivates DROP)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.harness import Row, suite, timed
from repro.baselines.fft import fft_real_expansion
from repro.baselines.paa import paa_transform
from repro.core.pca import pca_fit_svd


def run(full: bool = False) -> list[Row]:
    rows: list[Row] = []
    ratios_pca, ratios_fft = [], []
    for name, (x, _) in suite(full).items():
        t_paa, _ = timed(paa_transform, x, max(x.shape[1] // 8, 1))
        t_fft, _ = timed(fft_real_expansion, x)
        xs = jnp.asarray(x)
        t_pca, _ = timed(
            lambda a: pca_fit_svd(a)[1].block_until_ready(), xs
        )
        ratios_pca.append(t_pca / t_paa)
        ratios_fft.append(t_fft / t_paa)
        rows.append(
            Row(
                f"fig2/{name}",
                t_pca * 1e6,
                f"pca_over_paa={t_pca/t_paa:.1f}x;fft_over_paa={t_fft/t_paa:.1f}x",
            )
        )
    rows.append(
        Row(
            "fig2/AVG",
            0.0,
            f"pca_over_paa={np.mean(ratios_pca):.1f}x;"
            f"fft_over_paa={np.mean(ratios_fft):.1f}x"
            " (paper: pca ~52x paa, ~8x fft)",
        )
    )
    return rows
