"""Paper Figure 3: normalized eigenvalue spectra — structured time series show
rapid falloff (low intrinsic dimensionality); noise does not."""

from __future__ import annotations

import numpy as np

from benchmarks.harness import Row, suite
from repro.core.pca import explained_spectrum


def run(full: bool = False) -> list[Row]:
    rows: list[Row] = []
    frac_to_90 = []
    for name, (x, _) in suite(full).items():
        spec = explained_spectrum(x[: min(len(x), 2000)])
        cum = np.cumsum(spec)
        k90 = int(np.searchsorted(cum, 0.90)) + 1
        frac = k90 / x.shape[1]
        frac_to_90.append(frac)
        rows.append(
            Row(f"fig3/{name}", 0.0,
                f"k_for_90pct_var={k90};frac_of_d={frac:.4f}")
        )
    pcts = np.percentile(frac_to_90, [25, 50, 75])
    rows.append(
        Row("fig3/PERCENTILES", 0.0,
            f"frac_d_for_90pct_var p25={pcts[0]:.4f} p50={pcts[1]:.4f} "
            f"p75={pcts[2]:.4f} (paper: majority capture most variance in "
            "few PCs)")
    )
    return rows
