"""Paper Figure 5 / Table 5: minimum PROPORTION of rows whose PCA basis (at
k=d, isolating sampling from truncation) already meets the TLB target.
Claim: tiny samples suffice (avg 0.64% @0.75 ... 4.15% @0.99 on big sets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.harness import Row, suite
from repro.core.pca import center
from repro.core.halko import svd_halko
from repro.core.tlb import TLBEstimator

TARGETS = (0.75, 0.90, 0.99)
GRID = (0.005, 0.01, 0.02, 0.04, 0.08, 0.15, 0.3, 0.6, 1.0)


def _min_proportion(x: np.ndarray, target: float, seed: int = 0) -> float:
    m, d = x.shape
    rng = np.random.default_rng(seed)
    pair_rng = np.random.default_rng(seed + 1)
    for frac in GRID:
        n = max(4, int(frac * m))
        idx = rng.choice(m, size=min(n, m), replace=False)
        xs = jnp.asarray(x[idx])
        _, c = center(xs)
        cap = min(n, d)
        v, _ = svd_halko(c, cap, jax.random.PRNGKey(seed), power_iters=1)
        est = TLBEstimator(x, v, pair_rng)
        mean = est.table(400)[:, -1].mean()
        if mean >= target:
            return frac
    return 1.0


def run(full: bool = False) -> list[Row]:
    rows: list[Row] = []
    agg = {t: [] for t in TARGETS}
    for name, (x, _) in suite(full).items():
        fracs = [_min_proportion(x, t) for t in TARGETS]
        for t, f in zip(TARGETS, fracs):
            agg[t].append(f)
        rows.append(
            Row(f"fig5/{name}", 0.0,
                ";".join(f"p@{t}={f:.3f}" for t, f in zip(TARGETS, fracs)))
        )
    rows.append(
        Row("fig5/AVG", 0.0,
            ";".join(f"p@{t}={np.mean(agg[t]):.4f}" for t in TARGETS)
            + " (paper avg: 0.0064@0.75, 0.0415@0.99 on 18 largest)")
    )
    return rows
