"""Paper Figures 6+7: DROP vs SVD / SVD-Halko / Oracle — dimensionality
reduction runtime (normalized to SVD) and output dimension (normalized to d).
Claims: DROP avg ~4.8x faster than SVD (up to 50x), ~1.2x larger k than
SVD/Oracle, ~1.17x slower than Oracle."""

from __future__ import annotations

import numpy as np

from benchmarks.harness import Row, suite, timed
from repro.baselines.svd_pca import oracle, svd_binary_search, svd_halko_binary_search
from repro.core import DropConfig, drop
from repro.core.cost import knn_cost

TLB = 0.98


def run(full: bool = False) -> list[Row]:
    rows: list[Row] = []
    speedups, k_ratios, halko_speedups = [], [], []
    cfg = DropConfig(target_tlb=TLB, seed=0)
    for name, (x, _) in suite(full).items():
        cost = knn_cost(x.shape[0])
        t_drop, r_drop = timed(lambda: drop(x, cfg, cost=cost))
        t_svd, r_svd = timed(lambda: svd_binary_search(x, cfg))
        t_halko, r_halko = timed(lambda: svd_halko_binary_search(x, cfg))
        # oracle: the offline-known minimal proportion (approximated by the
        # proportion DROP's final iteration used)
        prop = r_drop.iterations[-1].sample_size / x.shape[0]
        t_oracle, r_oracle = timed(lambda: oracle(x, prop, cfg))
        speedups.append(t_svd / t_drop)
        halko_speedups.append(t_halko / t_drop)
        k_ratios.append(r_drop.k / max(r_svd.k, 1))
        rows.append(
            Row(
                f"fig6_7/{name}",
                t_drop * 1e6,
                f"speedup_vs_svd={t_svd/t_drop:.2f}x;"
                f"speedup_vs_halko={t_halko/t_drop:.2f}x;"
                f"t_oracle_over_drop={t_oracle/t_drop:.2f};"
                f"k_drop={r_drop.k};k_svd={r_svd.k};k_halko={r_halko.k};"
                f"k_oracle={r_oracle.k};d={x.shape[1]};"
                f"tlb_drop={r_drop.tlb_estimate:.4f}",
            )
        )
    rows.append(
        Row(
            "fig6_7/AVG",
            0.0,
            f"speedup_vs_svd={np.mean(speedups):.2f}x(max {np.max(speedups):.1f}x);"
            f"speedup_vs_halko={np.mean(halko_speedups):.2f}x;"
            f"k_drop_over_svd={np.mean(k_ratios):.2f}x"
            " (paper: 4.8x/2.9x faster, k 1.23x larger)",
        )
    )
    return rows
