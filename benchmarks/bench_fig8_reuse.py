"""Paper Figure 8: effect of work-reuse (importance sampling) percentage on
DROP runtime and output dimension. Claim: ~10% reuse helps slightly; heavy
reuse hurts (worst-fit points get oversampled)."""

from __future__ import annotations

import numpy as np

from benchmarks.harness import Row, suite, timed
from repro.core import DropConfig, drop
from repro.core.cost import knn_cost

FRACTIONS = (0.0, 0.1, 0.3, 0.6)


def run(full: bool = False) -> list[Row]:
    rows: list[Row] = []
    per_frac: dict[float, list[float]] = {f: [] for f in FRACTIONS}
    names = list(suite(full).items())[:4]  # a few datasets suffice here
    for name, (x, _) in names:
        cost = knn_cost(x.shape[0])
        base = None
        for frac in FRACTIONS:
            cfg = DropConfig(target_tlb=0.98, reuse_fraction=frac, seed=0)
            t, r = timed(lambda c=cfg: drop(x, c, cost=cost))
            if base is None:
                base = t
            per_frac[frac].append(t / base)
            rows.append(
                Row(f"fig8/{name}/reuse{int(frac*100)}", t * 1e6,
                    f"k={r.k};rel_time={t/base:.3f}")
            )
    for f in FRACTIONS:
        rows.append(
            Row(f"fig8/AVG/reuse{int(f*100)}", 0.0,
                f"rel_time={np.mean(per_frac[f]):.3f} (paper: ~10% reuse "
                "mildly helps; excessive reuse slows)")
        )
    return rows
