"""Paper Figure 9: runtime vs dataset size at FIXED intrinsic dimensionality
(rank-8 sinusoid mixtures). Claim: DROP's runtime is ~constant in m (it
samples only what the intrinsic dimension needs); SVD baselines scale with m."""

from __future__ import annotations

import numpy as np

from benchmarks.harness import Row, timed
from repro.baselines.svd_pca import svd_halko_binary_search
from repro.core import DropConfig, drop
from repro.core.cost import zero_cost
from repro.data.timeseries import sinusoid_mixture

SIZES_SMALL = (2_000, 8_000, 32_000)
SIZES_FULL = (2_000, 8_000, 32_000, 135_000)
D = 512


def run(full: bool = False) -> list[Row]:
    rows: list[Row] = []
    drop_times, halko_times = [], []
    # fixed-size absolute schedule, like the paper's +500-rows-per-iteration
    for m in SIZES_FULL if full else SIZES_SMALL:
        x, _ = sinusoid_mixture(m, D, rank=8, seed=0)
        sched = tuple(min(1.0, 500.0 * (i + 1) / m) for i in range(10))
        cfg = DropConfig(target_tlb=0.98, schedule=sched, seed=0)
        t_drop, r = timed(lambda: drop(x, cfg, cost=zero_cost()))
        t_halko, rh = timed(lambda: svd_halko_binary_search(x, cfg, rank=64))
        drop_times.append(t_drop)
        halko_times.append(t_halko)
        rows.append(
            Row(f"fig9/m{m}", t_drop * 1e6,
                f"k={r.k};halko_ms={t_halko*1e3:.0f};drop_ms={t_drop*1e3:.0f};"
                f"halko_over_drop={t_halko/t_drop:.1f}x")
        )
    growth_drop = drop_times[-1] / drop_times[0]
    growth_halko = halko_times[-1] / halko_times[0]
    m_growth = (SIZES_FULL if full else SIZES_SMALL)[-1] / 2000
    rows.append(
        Row("fig9/GROWTH", 0.0,
            f"m_grew={m_growth:.0f}x;drop_time_grew={growth_drop:.2f}x;"
            f"halko_time_grew={growth_halko:.2f}x (paper: DROP ~constant, "
            "95x faster than Halko at 135K rows)")
    )
    return rows
