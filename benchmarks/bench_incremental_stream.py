"""Steady-append serving: suffix update vs prefix revalidation vs cold refit.

The append-only tenant is the serving path's worst repeat customer: every
1-10% row growth changes the dataset fingerprint, and before incremental
subspace tracking the service's only answers were "revalidate the cached
map" (cheap, but useless once the data drifts) or "refit cold over the full
grown dataset" (the most expensive operation it can run). This bench drives
the same steady-append stream — repeated ``grow_frac`` growth of a
structured rank-3 tenant — through all three paths and measures per-append
latency and steady-state throughput:

* **suffix_update** — ``DropService(suffix_budget=0.0)``: every append is
  folded in by the O(suffix) ``core.subspace`` merge, TLB-gated on the
  grown data (``stats.suffix_updates`` must equal the append count);
* **prefix_revalidate** — ``enable_suffix_update=False``: PR 3 behavior;
  on this drift-free stream every append revalidates and serves (the
  cheapest possible outcome for that policy — its refit cost when
  validation fails is exactly the cold leg below);
* **cold_refit** — ``enable_cache=False``: every append pays a full DROP
  run over all rows, the pre-prefix-matching baseline and the fallback
  the other two legs escalate to.

Determinism: ``min_iterations`` pins the full progressive schedule (Eq. 2
termination is wall-clock-adaptive) and every leg gets the harness's two
warm passes before the timed one. The bench asserts the suffix-update path
loses at most 0.005 TLB to the cold refit on the final snapshot (one-sided:
the update being the BETTER map is success, not failure) — the incremental
path must not trade quality for speed.

    python benchmarks/bench_incremental_stream.py
    python benchmarks/bench_incremental_stream.py --rows 4000 --steps 8
    python benchmarks/bench_incremental_stream.py --json rows.json  # nightly
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

TLB_PARITY = 0.005  # acceptance: update quality must match a full refit


def measure(
    rows0: int = 2000,
    dim: int = 128,
    rank: int = 3,
    steps: int = 5,
    grow_frac: float = 0.05,
    target: float = 0.98,
    seed: int = 0,
) -> dict:
    """One steady-append stream through the three serving policies."""
    import numpy as np

    from benchmarks.harness import warm
    from repro.core import DropConfig
    from repro.core.cost import zero_cost
    from repro.core.tlb import sample_pairs, transform_tlb_sampled
    from repro.data import sinusoid_mixture
    from repro.serve_drop import DropService

    append = max(1, int(rows0 * grow_frac))
    m_total = rows0 + steps * append
    # one generative process; snapshots are prefixes, so every append is a
    # genuine extension (the prefix-fingerprint machinery sees it as such)
    x_full = sinusoid_mixture(m_total, dim, rank=rank, seed=seed)[0]
    snapshots = [
        np.ascontiguousarray(x_full[: rows0 + i * append])
        for i in range(steps + 1)
    ]
    # pin the full progressive schedule: Eq. 2 termination is wall-clock-
    # adaptive, so unpinned iteration counts would vary run-to-run and
    # across legs (the repo's determinism convention)
    cfg = DropConfig(target_tlb=target, seed=seed, min_iterations=99)

    def drive(make_svc):
        """Cold-fit the base snapshot, then time each append's serve."""
        svc = make_svc()
        svc.submit(snapshots[0], cfg, zero_cost())
        svc.run()
        walls, last = [], None
        for snap in snapshots[1:]:
            t0 = time.perf_counter()
            svc.submit(snap, cfg, zero_cost())
            last = svc.run()[0]
            walls.append(time.perf_counter() - t0)
        return walls, last, svc

    legs = {
        "suffix_update": lambda: DropService(suffix_budget=0.0),
        "prefix_revalidate": lambda: DropService(enable_suffix_update=False),
        "cold_refit": lambda: DropService(enable_cache=False),
    }
    # shared TLB evaluation sample for the parity check: the internal CI
    # estimates stop sampling as soon as the target decision is stable, so
    # comparing THEM compares stopping points, not map quality — every
    # leg's final map is instead scored on one fixed 4000-pair sample
    eval_pairs = sample_pairs(
        snapshots[-1].shape[0], 4000, np.random.default_rng(seed + 7)
    )

    out: dict[str, dict] = {}
    for name, make_svc in legs.items():
        warm(lambda: drive(make_svc))  # two warm passes (harness convention)
        walls, last, svc = drive(make_svc)
        final_tlb, _, _ = transform_tlb_sampled(
            snapshots[-1], last.result.transform(snapshots[-1]), eval_pairs
        )
        out[name] = {
            "per_append_ms": [round(w * 1e3, 2) for w in walls],
            "mean_append_ms": round(sum(walls) / len(walls) * 1e3, 2),
            "steady_qps": round(len(walls) / sum(walls), 2),
            "final_k": last.result.k,
            "final_tlb": round(float(final_tlb), 4),
            "final_tlb_ci_estimate": round(last.result.tlb_estimate, 4),
            "suffix_updates": svc.stats.suffix_updates,
            "suffix_update_failures": svc.stats.suffix_update_failures,
            "prefix_hits": svc.stats.prefix_hits,
            "fit_calls": svc.stats.fit_calls,
        }
    # wiring sanity (deterministic): each leg exercised its intended path
    assert out["suffix_update"]["suffix_updates"] == steps, out
    assert out["prefix_revalidate"]["prefix_hits"] == steps, out
    assert out["cold_refit"]["fit_calls"] > steps, out
    # acceptance: the incremental map's quality matches a full refit. The
    # bound is ONE-sided (may not LOSE more than 0.005 to the refit): at
    # degenerate rank boundaries the refit's CI-gated search can itself be
    # the worse map by more than the budget, and being better must not
    # fail the nightly job (see test_properties_serve's sweep-validated
    # property of the same shape)
    tlb_delta = round(
        out["cold_refit"]["final_tlb"] - out["suffix_update"]["final_tlb"], 4
    )
    assert tlb_delta <= TLB_PARITY, (
        f"suffix-update TLB lost {tlb_delta} to the cold refit "
        f"(budget {TLB_PARITY}): {out}"
    )
    speedup = (
        out["cold_refit"]["mean_append_ms"]
        / out["suffix_update"]["mean_append_ms"]
    )
    return {
        "rows0": rows0,
        "dim": dim,
        "rank": rank,
        "steps": steps,
        "grow_frac": grow_frac,
        "append_rows": append,
        "target_tlb": target,
        # positive = update lost that much TLB to the refit; negative = the
        # update was the better map
        "tlb_delta_update_vs_refit": tlb_delta,
        "speedup_update_vs_cold": round(speedup, 2),
        "legs": out,
    }


def run(full: bool = False) -> list:
    """Harness rows (benchmarks/run.py integration)."""
    from benchmarks.harness import Row

    rec = measure(
        rows0=4000 if full else 1500,
        dim=256 if full else 96,
        steps=6 if full else 4,
        grow_frac=0.05,
    )
    label = (
        f"incremental_stream/m{rec['rows0']}"
        f"+{int(rec['grow_frac'] * 100)}%x{rec['steps']}"
    )
    rows = []
    for name, leg in rec["legs"].items():
        derived = (
            f"qps={leg['steady_qps']};k={leg['final_k']};"
            f"tlb={leg['final_tlb']}"
        )
        if name == "suffix_update":
            derived += (
                f";speedup={rec['speedup_update_vs_cold']:.2f}x vs cold refit"
                f";tlb_delta={rec['tlb_delta_update_vs_refit']}"
                " (O(suffix) merge replaces the O(full) refit per append)"
            )
        rows.append(Row(f"{label}/{name}", leg["mean_append_ms"] * 1e3,
                        derived))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=2000)
    ap.add_argument("--dim", type=int, default=128)
    ap.add_argument("--rank", type=int, default=3)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--grow-frac", type=float, default=0.05,
                    help="per-append row growth as a fraction of the base")
    ap.add_argument("--target", type=float, default=0.98)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", type=str, default=None,
                    help="write the record as JSON (nightly CI artifact)")
    args = ap.parse_args()

    rec = measure(
        rows0=args.rows, dim=args.dim, rank=args.rank, steps=args.steps,
        grow_frac=args.grow_frac, target=args.target, seed=args.seed,
    )
    print(f"stream: m0={rec['rows0']} d={rec['dim']} rank={rec['rank']} "
          f"+{rec['append_rows']} rows x {rec['steps']} appends "
          f"(target={rec['target_tlb']})")
    for name, leg in rec["legs"].items():
        print(f"  {name:18s} mean_append={leg['mean_append_ms']:8.1f}ms "
              f"qps={leg['steady_qps']:6.2f} k={leg['final_k']:3d} "
              f"tlb={leg['final_tlb']:.4f} fits={leg['fit_calls']}")
    print(f"suffix-update speedup vs cold refit: "
          f"{rec['speedup_update_vs_cold']:.2f}x "
          f"(tlb delta {rec['tlb_delta_update_vs_refit']})")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
