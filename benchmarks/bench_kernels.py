"""Kernel-layer microbenchmarks: DROP's hot operators.

On this CPU container the production path is the jnp oracle (the Pallas
kernels target TPU; they are validated in interpret mode by tests/). This
bench times the jitted oracle path at DROP-realistic shapes and reports the
arithmetic intensity each kernel achieves (the quantity the Pallas BlockSpec
tiling is designed around)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.harness import Row, timed
from repro.kernels.center_gram.ref import center_gram_ref
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.pairwise_tlb.ref import pairwise_tlb_ref


def run(full: bool = False) -> list[Row]:
    rows = []
    key = jax.random.PRNGKey(0)

    # Halko power-iteration matmul: (m, d) x (d, k+p)
    m, d, k = (16384, 1024, 69) if full else (4096, 512, 37)
    a = jax.random.normal(key, (m, d), jnp.float32)
    b = jax.random.normal(key, (d, k), jnp.float32)
    f = jax.jit(matmul_ref)
    t, _ = timed(lambda: f(a, b).block_until_ready(), iters=3)
    flops = 2 * m * d * k
    rows.append(Row("kernel/matmul_halko", t * 1e6,
                    f"gflops={flops/t/1e9:.1f};shape={m}x{d}x{k}"))

    # pairwise TLB: P pairs x (d -> kmax prefix table)
    p, kmax = (1024, 512) if full else (512, 256)
    xi = jax.random.normal(key, (p, d), jnp.float32)
    xj = jax.random.normal(key, (p, d), jnp.float32)
    v = jnp.linalg.qr(jax.random.normal(key, (d, d)))[0][:, :kmax]
    g = jax.jit(pairwise_tlb_ref)
    t, _ = timed(lambda: g(xi, xj, v).block_until_ready(), iters=3)
    rows.append(Row("kernel/pairwise_tlb", t * 1e6,
                    f"pairs={p};d={d};kmax={kmax};"
                    f"gflops={2*p*d*kmax/t/1e9:.1f}"))

    # fused center+gram: (m, d) -> (d, d)
    x = jax.random.normal(key, (m, d), jnp.float32)
    h = jax.jit(center_gram_ref)
    t, _ = timed(lambda: h(x).block_until_ready(), iters=3)
    rows.append(Row("kernel/center_gram", t * 1e6,
                    f"gflops={2*m*d*d/t/1e9:.1f};shape={m}x{d}"))
    return rows
