"""Paper §4.5 (beyond time series): DROP on structured image data (MNIST
stand-in, 784-dim flattened digits). Claim: sampling-based reduction works on
regularly structured non-time-series data; DROP examines ~1.4% of rows."""

from __future__ import annotations

from benchmarks.harness import Row, timed
from repro.analytics import knn_retrieval_accuracy
from repro.baselines.svd_pca import svd_halko_binary_search
from repro.core import DropConfig, drop
from repro.core.cost import knn_cost
from repro.data.timeseries import mnist_like


def run(full: bool = False) -> list[Row]:
    m = 20_000 if full else 4_000
    x, y = mnist_like(m=m, side=28, seed=0)
    cfg = DropConfig(target_tlb=0.98, seed=0)
    cost = knn_cost(m)
    t_drop, r = timed(lambda: drop(x, cfg, cost=cost))
    t_halko, rh = timed(lambda: svd_halko_binary_search(x, cfg, rank=128))
    frac = r.total_rows_processed / m
    acc_raw = knn_retrieval_accuracy(x, y)
    acc_drop = knn_retrieval_accuracy(r.transform(x), y)
    return [
        Row(
            "mnist_like/drop",
            t_drop * 1e6,
            f"k={r.k};rows_frac={frac:.4f};speedup_vs_halko={t_halko/t_drop:.1f}x;"
            f"acc_raw={acc_raw:.3f};acc_drop={acc_drop:.3f}"
            " (paper: ~1.4% of rows, 28x vs halko, acc parity)",
        )
    ]
