"""Fused pairwise-analytics engine vs the legacy host-loop paths.

Per task (kNN / DBSCAN / KDE) and per reduced dimensionality, times the
fused single-dispatch engine (``analytics.pairwise``) against the legacy
blocked host loop it replaced (one dispatch + one device->host sync per
(block, m) distance tile). The dims {3, 25, 95} are the k's PCA/FFT/PAA
produce at target TLB 0.98 on the structured ``bench_e2e_workload`` data —
i.e. exactly the downstream shapes the §4.4 end-to-end comparison pays for.

Timing follows the harness convention: ``warm()`` x2 before the clock (the
analytics paths are deterministic single-shot jits, but two runs also
settle allocator/cache state), then best-of-N. DROP itself is never
invoked here, so no ``min_iterations`` pinning applies — the inputs are
seeded raw matrices shared bit-for-bit by both legs.

``--split`` adds the flash-decoding-style split-scan legs
(``analytics.split``): the same tasks at 1 vs N dataset shards, via the
same public wrappers (``split=s``). The merges are exact, so the legs
measure pure decomposition overhead/benefit; like the fleet-scaling bench,
any speedup is core-bound (the shard axis is data-parallel inside one XLA
dispatch) and the record carries a ``cores=`` caveat — on a single-core
container the comparison measures split overhead only.

    python benchmarks/bench_pairwise_analytics.py
    python benchmarks/bench_pairwise_analytics.py --rows 8000 --dims 3,25,95
    python benchmarks/bench_pairwise_analytics.py --split 1,2
    python benchmarks/bench_pairwise_analytics.py --json pairwise.json  # CI
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

_ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

TASKS = ("knn", "dbscan", "kde")


def _eps_for(x, quantile: float = 0.005, probe: int = 512, seed: int = 0):
    """An eps giving ~quantile of pairs as neighbors (sampled): keeps the
    DBSCAN legs comparable across dims — neighbor sets small but non-empty,
    so the host side (BFS + decode vs eager np.nonzero) is exercised too."""
    import numpy as np

    rng = np.random.default_rng(seed)
    s = x[rng.integers(0, x.shape[0], size=min(probe, x.shape[0]))]
    d2 = (
        (s * s).sum(1)[:, None] + (s * s).sum(1)[None, :] - 2.0 * s @ s.T
    )
    vals = np.sqrt(np.maximum(d2[np.triu_indices(s.shape[0], 1)], 0.0))
    return float(np.quantile(vals, quantile))


def _time_best(fn, iters: int) -> float:
    from benchmarks.harness import warm

    warm(fn, runs=2)
    best = float("inf")
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def measure(
    rows: int = 8000,
    dims: tuple = (3, 25, 95),
    tasks: tuple = TASKS,
    iters: int = 3,
    seed: int = 0,
) -> dict:
    """Fused-vs-legacy legs per (task, d); returns a JSON-ready record."""
    import numpy as np

    from repro.analytics import (
        dbscan,
        dbscan_legacy,
        gaussian_kde,
        gaussian_kde_legacy,
        nearest_neighbors,
        nearest_neighbors_legacy,
    )

    rec = {"rows": rows, "seed": seed, "tasks": {t: {} for t in tasks}}
    rng = np.random.default_rng(seed)
    for d in dims:
        x = rng.normal(size=(rows, d)).astype(np.float32)
        legs = {}
        if "knn" in tasks:
            legs["knn"] = (
                lambda x=x: nearest_neighbors(x),
                lambda x=x: nearest_neighbors_legacy(x),
            )
        if "dbscan" in tasks:
            eps = _eps_for(x, seed=seed)
            legs["dbscan"] = (
                lambda x=x, e=eps: dbscan(x, eps=e, min_samples=5),
                lambda x=x, e=eps: dbscan_legacy(x, eps=e, min_samples=5),
            )
        if "kde" in tasks:
            legs["kde"] = (
                lambda x=x: gaussian_kde(x),
                lambda x=x: gaussian_kde_legacy(x),
            )
        for task, (fused, legacy) in legs.items():
            t_fused = _time_best(fused, iters)
            t_legacy = _time_best(legacy, iters)
            rec["tasks"][task][f"d{d}"] = {
                "fused_ms": round(t_fused * 1e3, 1),
                "legacy_ms": round(t_legacy * 1e3, 1),
                "speedup": round(t_legacy / t_fused, 2),
            }
    return rec


def measure_split(
    rows: int = 8000,
    dims: tuple = (3, 25),
    tasks: tuple = TASKS,
    shards: tuple = (1, 2),
    iters: int = 3,
    seed: int = 0,
) -> dict:
    """Sequential scan vs the split fan-out at each shard count, through
    the public wrappers (``split=s``; exact merges — identical outputs).
    Speedup is core-bound: the shard axis is data-parallel inside one
    dispatch, so a 1-core host can only measure the split's overhead."""
    import numpy as np

    from repro.analytics import dbscan, gaussian_kde, nearest_neighbors

    cores = (
        len(os.sched_getaffinity(0))
        if hasattr(os, "sched_getaffinity")
        else (os.cpu_count() or 1)
    )
    rec = {
        "rows": rows,
        "seed": seed,
        "cores": cores,
        "note": (
            f"split legs are core-bound (data-parallel shard axis); "
            f"cores={cores} — expect sequential-comparable times, not "
            f"speedup, below 2 cores"
        ),
        "tasks": {t: {} for t in tasks},
    }
    rng = np.random.default_rng(seed)
    for d in dims:
        x = rng.normal(size=(rows, d)).astype(np.float32)
        legs = {}
        if "knn" in tasks:
            legs["knn"] = lambda s, x=x: nearest_neighbors(x, split=s)
        if "dbscan" in tasks:
            eps = _eps_for(x, seed=seed)
            legs["dbscan"] = lambda s, x=x, e=eps: dbscan(
                x, eps=e, min_samples=5, split=s
            )
        if "kde" in tasks:
            legs["kde"] = lambda s, x=x: gaussian_kde(x, split=s)
        for task, leg in legs.items():
            entry = {
                "seq_ms": round(
                    _time_best(lambda: leg(None), iters) * 1e3, 1
                )
            }
            for s in shards:
                entry[f"split{s}_ms"] = round(
                    _time_best(lambda s=s: leg(s), iters) * 1e3, 1
                )
            rec["tasks"][task][f"d{d}"] = entry
    return rec


def run(full: bool = False) -> list:
    """Harness rows (benchmarks/run.py integration). The small path keeps
    the whole module CI-sized; --full runs the acceptance shape m=8000."""
    from benchmarks.harness import Row

    rec = measure(
        rows=8000 if full else 2500,
        dims=(3, 25, 95) if full else (3, 25),
        iters=3 if full else 2,
    )
    rows = []
    for task, by_d in rec["tasks"].items():
        for dkey, leg in by_d.items():
            rows.append(
                Row(
                    f"pairwise/{task}/m{rec['rows']}_{dkey}/fused",
                    leg["fused_ms"] * 1e3,
                    f"legacy_ms={leg['legacy_ms']};"
                    f"speedup={leg['speedup']}",
                )
            )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=8000)
    ap.add_argument("--dims", type=str, default="3,25,95")
    ap.add_argument("--tasks", type=str, default="knn,dbscan,kde")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--split", type=str, default=None,
                    help="comma list of shard counts: add split-scan legs "
                         "(sequential vs analytics.split at each count; "
                         "core-bound — see module docstring)")
    ap.add_argument("--json", type=str, default=None,
                    help="write the record as JSON (nightly CI artifact)")
    args = ap.parse_args()

    rec = measure(
        rows=args.rows,
        dims=tuple(int(d) for d in args.dims.split(",")),
        tasks=tuple(t.strip() for t in args.tasks.split(",")),
        iters=args.iters,
        seed=args.seed,
    )
    if args.split:
        rec["split"] = measure_split(
            rows=args.rows,
            dims=tuple(int(d) for d in args.dims.split(",")),
            tasks=tuple(t.strip() for t in args.tasks.split(",")),
            shards=tuple(int(s) for s in args.split.split(",")),
            iters=args.iters,
            seed=args.seed,
        )
    print(f"pairwise analytics: m={rec['rows']} (fused engine vs legacy "
          f"host loop, warm x2, best-of-{args.iters})")
    for task, by_d in rec["tasks"].items():
        for dkey, leg in by_d.items():
            print(f"  {task:6s} {dkey:>4s}  "
                  f"fused={leg['fused_ms']:8.1f}ms  "
                  f"legacy={leg['legacy_ms']:8.1f}ms  "
                  f"speedup={leg['speedup']:5.2f}x")
    if args.split:
        sp = rec["split"]
        print(f"split scan (exact merges; {sp['note']})")
        for task, by_d in sp["tasks"].items():
            for dkey, leg in by_d.items():
                splits = "  ".join(
                    f"{k.removesuffix('_ms')}={v:.1f}ms"
                    for k, v in leg.items()
                    if k.startswith("split")
                )
                print(f"  {task:6s} {dkey:>4s}  "
                      f"seq={leg['seq_ms']:8.1f}ms  {splits}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
