"""LM-framework roofline table: reads the dry-run artifacts (launch/dryrun.py)
and emits the per-(arch x shape x mesh) three-term roofline — the §Roofline
deliverable in tabular form."""

from __future__ import annotations

import json
import os

from benchmarks.harness import Row

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def run(full: bool = False) -> list[Row]:
    rows: list[Row] = []
    if not os.path.isdir(ART):
        return [Row("roofline/NONE", 0.0,
                    "no dry-run artifacts; run python -m repro.launch.dryrun --all")]
    for fname in sorted(os.listdir(ART)):
        if not fname.endswith(".json"):
            continue
        with open(os.path.join(ART, fname)) as f:
            rec = json.load(f)
        name = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec["status"] == "skipped":
            rows.append(Row(name, 0.0, f"SKIP:{rec['skip_reason'][:60]}"))
            continue
        if rec["status"] != "ok":
            rows.append(Row(name, 0.0, f"ERROR:{rec.get('error','')[:80]}"))
            continue
        r = rec["roofline"]
        step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        rows.append(
            Row(
                name,
                step_s * 1e6,
                f"compute={r['compute_s']:.4f}s;memory={r['memory_s']:.4f}s;"
                f"collective={r['collective_s']:.4f}s;dom={r['dominant']};"
                f"useful={r['useful_ratio']:.3f}",
            )
        )
    return rows
