"""Paper Table 6 / Figure 1: output dimension required for target TLB, per
method (PAA, FFT, PCA). Claim under test: PCA needs ~2x fewer dims."""

from __future__ import annotations

import numpy as np

from benchmarks.harness import Row, suite
from repro.baselines import dwt_min_k, fft_min_k, paa_min_k
from repro.baselines.svd_pca import pca_min_k

TARGETS = (0.75, 0.90, 0.99)


def run(full: bool = False) -> list[Row]:
    rows: list[Row] = []
    ratios = {t: [] for t in TARGETS}
    for name, (x, _) in suite(full).items():
        d = x.shape[1]
        for t in TARGETS:
            k_pca = pca_min_k(x, t)
            k_fft = fft_min_k(x, t)
            k_paa = paa_min_k(x, t)
            k_dwt = dwt_min_k(x, t)
            ratios[t].append((k_fft + k_paa + k_dwt) / 3 / max(k_pca, 1))
            rows.append(
                Row(
                    f"table6/{name}/tlb{t}",
                    0.0,
                    f"k_pca={k_pca};k_fft={k_fft};k_paa={k_paa};"
                    f"k_dwt={k_dwt};d={d}",
                )
            )
    for t in TARGETS:
        rows.append(
            Row(
                f"table6/AVG/tlb{t}",
                0.0,
                f"mean_alt_over_pca={np.mean(ratios[t]):.2f}x"
                f" (paper claims >2x at matched TLB)",
            )
        )
    return rows
