"""Shared benchmark harness: timing, CSV emission, dataset sizing.

Conventions:
* every benchmark module exposes ``run(full: bool) -> list[Row]``;
* timing excludes jit compilation (one warm-up call), matching the paper's
  exclusion of data loading/parsing;
* rows print as ``name,us_per_call,derived`` CSV (required by run.py).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable


@dataclass
class Row:
    name: str
    us_per_call: float
    derived: str  # free-form metric, e.g. "k=7;tlb=0.985;speedup=12.3"

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 1, **kw):
    """(best_seconds, result). Warm-up runs compile; best-of-iters timed."""
    out = None
    for _ in range(warmup):
        out = fn(*args, **kw)
    best = float("inf")
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, out


def warm(fn: Callable, runs: int = 2):
    """Run ``fn`` un-timed ``runs`` times; returns the last result.

    TWO warm runs are the repo convention for DROP paths (quickstart.py
    documents it): the progressive schedule terminates on wall-clock, so the
    first run's compile stalls change WHICH iterations execute — and with
    them the compiled-shape set. Only a second, compile-free run pins the
    shapes the timed run will see. One run suffices for the deterministic
    single-shot baselines (pass ``runs=1``)."""
    out = None
    for _ in range(max(int(runs), 1)):
        out = fn()
    return out


def suite(full: bool, n_small: int = 6):
    """UCR-like datasets for benchmarks: a subset by default, all when --full.
    Rows capped on the small path so the whole suite stays CI-sized."""
    from repro.data.timeseries import ucr_like_suite

    if full:
        return ucr_like_suite()
    return ucr_like_suite(max_datasets=n_small, max_m=2500)
