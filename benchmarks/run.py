"""Benchmark runner: one module per paper table/figure + framework rooflines.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig10]

Prints ``name,us_per_call,derived`` CSV per row.
"""

from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

MODULES = [
    "bench_table6_dims",      # Table 6 / Fig 1: dims for target TLB
    "bench_fig2_runtime",     # Fig 2: PAA/FFT/PCA runtime
    "bench_fig3_spectrum",    # Fig 3: spectra falloff
    "bench_fig5_sampling",    # Fig 5 / Table 5: sample proportions
    "bench_fig6_fig7_drop",   # Figs 6+7: DROP vs SVD/Halko/Oracle
    "bench_fig8_reuse",       # Fig 8: work reuse
    "bench_fig9_scalability", # Fig 9: size-independence
    "bench_fig10_knn",        # Fig 10 + Tables 2/3/4: e2e k-NN
    "bench_fig12_dbscan",     # Fig 12: e2e DBSCAN
    "bench_drop_serve",       # §5 reuse at the service layer: qps + cache
                              # (--full adds the FleetSupervisor process-
                              # worker scaling legs, 1 vs 2 workers)
    "bench_e2e_workload",     # §4.4 via WorkloadOptimizer: DR+analytics e2e
    "bench_incremental_stream",  # append-only: suffix update vs reval/refit
    "bench_delta_stream",     # pub/sub deltas vs snapshot re-serve per append
    "bench_pairwise_analytics",  # fused engine vs legacy host loops

    "bench_mnist_like",       # §4.5: beyond time series
    "bench_kernels",          # kernel layer
    "bench_roofline",         # framework §Roofline table (from dry-run)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sizes")
    ap.add_argument("--only", default=None, help="substring filter on module")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = []
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
            for row in mod.run(full=args.full):
                print(row.csv(), flush=True)
            print(f"# {mod_name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001
            failures.append(mod_name)
            print(f"# {mod_name} FAILED:", flush=True)
            traceback.print_exc()
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
