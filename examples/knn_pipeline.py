"""End-to-end analytics pipeline (paper §4.4): DROP as a pre-processor for
1-NN retrieval, with the k-NN-tuned cost function balancing reduction time
against downstream time.

    PYTHONPATH=src python examples/knn_pipeline.py
"""

import time

import numpy as np

from repro.analytics import knn_retrieval_accuracy
from repro.baselines.svd_pca import svd_binary_search
from repro.core import DropConfig, drop
from repro.core.cost import knn_cost
from repro.data import sinusoid_mixture


def main() -> None:
    x, y = sinusoid_mixture(6000, 512, rank=12, n_classes=6, seed=3)
    print(f"dataset: m={x.shape[0]} d={x.shape[1]} classes=6")
    cfg = DropConfig(target_tlb=0.98, seed=0)
    cost = knn_cost(x.shape[0])

    def best_of(fn, n=3):
        best, out = float("inf"), None
        for _ in range(n):
            t0 = time.perf_counter()
            out = fn()
            best = min(best, time.perf_counter() - t0)
        return best, out

    # best-of-N timings: excludes jit compilation (DROP's shape trajectory is
    # runtime-adaptive, so the first runs compile extra shapes)
    t_raw, acc_raw = best_of(lambda: knn_retrieval_accuracy(x, y))
    print(f"\nraw k-NN:            acc={acc_raw:.3f}  total={t_raw*1e3:7.0f} ms")

    t_dr, res = best_of(lambda: drop(x, cfg, cost=cost))
    xt = np.ascontiguousarray(res.transform(x))
    t_knn, acc_drop = best_of(lambda: knn_retrieval_accuracy(xt, y))
    print(f"DROP({res.k:3d}d) + k-NN:  acc={acc_drop:.3f}  "
          f"total={(t_dr+t_knn)*1e3:7.0f} ms  "
          f"(reduce {t_dr*1e3:.0f} + knn {t_knn*1e3:.0f}; DROP aims to "
          "equalize the two)")

    t_svd, base = best_of(lambda: svd_binary_search(x, cfg), n=2)
    xs = np.ascontiguousarray(base.transform(x))
    t_knn_svd, acc_svd = best_of(lambda: knn_retrieval_accuracy(xs, y))
    print(f"SVD ({base.k:3d}d) + k-NN:  acc={acc_svd:.3f}  "
          f"total={(t_svd+t_knn_svd)*1e3:7.0f} ms")

    print(f"\nend-to-end speedup vs raw: {t_raw/(t_dr+t_knn):.2f}x"
          f"   vs SVD pipeline: {(t_svd+t_knn_svd)/(t_dr+t_knn):.2f}x")


if __name__ == "__main__":
    main()
