"""Quickstart: DROP on structured time series vs full-SVD PCA.

Reproduces the paper's core pitch in one page: on an ECG-like dataset, a tiny
progressive sample recovers a TLB-preserving PCA basis orders of magnitude
cheaper than full SVD, and the basis is ~2x smaller than FFT/PAA at the same
distance-preservation target.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import numpy as np

from repro.baselines import fft_min_k, paa_min_k, svd_binary_search
from repro.core import DropConfig, drop
from repro.core.cost import knn_cost
from repro.core.tlb import exact_tlb
from repro.data import ecg_like


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return time.perf_counter() - t0, out


def main() -> None:
    # StarLightCurves-scale data: the regime the paper targets (its study
    # excludes datasets whose full SVD finishes in <1s — at (5000, 140) LAPACK
    # SVD takes ~50 ms and nothing can beat it)
    print("generating 8000 light-curve-like series of dimension 1024")
    x, _ = ecg_like(8000, 1024, seed=0)

    cfg = DropConfig(target_tlb=0.98, seed=0)
    cost = knn_cost(x.shape[0])

    # warm the jit caches: DROP's shape trajectory is runtime-adaptive, so
    # two throwaway runs stabilize the compiled-shape set (the paper's Java
    # baseline pays no compilation; we exclude it the same way the paper
    # excludes data loading)
    t_drop, res = min(
        (_timed(lambda: drop(x, cfg, cost=cost)) for _ in range(4)),
        key=lambda p: p[0],
    )
    t_svd, base = min(
        (_timed(lambda: svd_binary_search(x, cfg)) for _ in range(2)),
        key=lambda p: p[0],
    )

    print(f"\nDROP:     k={res.k:3d}  est. TLB={res.tlb_estimate:.4f}  "
          f"time={t_drop*1e3:7.1f} ms  rows processed="
          f"{res.total_rows_processed}/{x.shape[0]}")
    print(f"full SVD: k={base.k:3d}  est. TLB={base.tlb_mean:.4f}  "
          f"time={t_svd*1e3:7.1f} ms")
    print(f"speedup: {t_svd/t_drop:.1f}x")

    truth = exact_tlb(x[:400], res.v)
    print(f"\nexact TLB of DROP's basis (400-row check): {truth:.4f} "
          f"(target {cfg.target_tlb})")

    k_fft = fft_min_k(x, 0.98)
    k_paa = paa_min_k(x, 0.98)
    print(f"\ndims needed at TLB 0.98:  PCA/DROP={res.k}  FFT={k_fft}  "
          f"PAA={k_paa}  (paper: PCA ~2x smaller)")

    print("\nper-iteration trace (progressive sampling + Eq.2 stopping):")
    for r in res.iterations:
        print(f"  i={r.i}  sample={r.sample_size:5d}  k={r.k:3d}  "
              f"tlb={r.tlb_estimate:.4f}  r_i={r.runtime_s*1e3:6.1f} ms  "
              f"pairs={r.pairs_used}")

    optimizer_demo(x[:3000], cfg)
    serve_demo(x[:2000], cfg)


def optimizer_demo(x, cfg) -> None:
    """End-to-end workload optimization (paper §4.4 as an API): every DR
    operator is a Reducer, and the WorkloadOptimizer races them against the
    objective R + C_m(k) for a named downstream analytics task. Full bench:
    python benchmarks/bench_e2e_workload.py"""
    from repro.pipeline import WorkloadOptimizer

    print("\nWorkloadOptimizer: DROP vs FFT vs PAA for a k-NN workload")
    report = WorkloadOptimizer(methods=("pca", "fft", "paa"), cfg=cfg).optimize(
        x, downstream="knn"
    )
    print(report.summary())


def serve_demo(x, cfg) -> None:
    """Multi-query serving (paper §5 reuse): repeat workloads are served
    from the basis cache after one cold fit — no re-fitting, just a sampled
    TLB revalidation. An append-only grown dataset is folded in by an
    O(suffix) incremental subspace update instead of any refit.
    Full CLI: python -m repro.launch.drop_serve (--grow-steps N for the
    append-stream demo)"""
    from repro.serve_drop import DropService

    print("\nDropService: 4 submissions of the same workload + 1 append")
    svc = DropService(suffix_budget=0.0)  # appends go straight to the update
    cost = knn_cost(x.shape[0])  # C_m for the rows actually served
    for _ in range(4):
        svc.submit(x, cfg, cost)
    grown = np.concatenate([x, x[: max(1, x.shape[0] // 20)]])  # +5% rows
    results = svc.run()
    svc.submit(np.ascontiguousarray(grown), cfg, cost)
    results += svc.run()
    for r in results:
        tag = ("suffix-upd" if r.suffix_update
               else "cache-hit" if r.cache_hit else "cold")
        print(f"  q{r.query_id}  [{tag:10s}]  k={r.result.k:3d}  "
              f"tlb={r.result.tlb_estimate:.4f}  wall={r.wall_s*1e3:7.1f} ms")
    print(f"  stats: {svc.stats.as_dict()}")


if __name__ == "__main__":
    main()
