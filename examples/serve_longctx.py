"""Serving example: batched generation with KV cache + DROP KV compression.

Runs a reduced llama-family model through the Engine (prefill + greedy
decode), then demonstrates the beyond-paper DROP KV-cache compression: a
PCA basis discovered from sampled keys lets decode attention run in r < hd
dims with bounded score distortion.

    PYTHONPATH=src python examples/serve_longctx.py
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_smoke_config
from repro.models.model import init_model
from repro.serve.engine import Engine
from repro.serve.kv_compress import (
    KVCompressConfig,
    compress_cache_layer,
    decode_attention_compressed,
    discover_kv_basis,
)
from repro.models.attention import decode_attention
from repro.sharding.specs import ShardCtx


def main() -> None:
    cfg = get_smoke_config("tinyllama_1_1b")
    ctx = ShardCtx(mesh=None)
    params = init_model(cfg, jax.random.PRNGKey(0))

    # --- batched serving through the engine ---
    b, prompt_len, max_new = 4, 12, 8
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, size=(b, prompt_len))
    eng = Engine(params, cfg, ctx, batch=b, context_len=prompt_len + max_new)
    res = eng.generate(prompts, max_new=max_new)
    print(f"generated {res.tokens.shape[1]} tokens for batch {b}:")
    print(res.tokens)

    # --- DROP KV compression on the accumulated cache ---
    k_cache = np.asarray(eng.cache["attn"]["k"][0], np.float32)  # layer 0
    v_cache = np.asarray(eng.cache["attn"]["v"][0], np.float32)
    hd = cfg.head_dim
    rows_k = k_cache.reshape(-1, hd)
    rows_v = v_cache.reshape(-1, hd)
    kc = KVCompressConfig()  # default 0.98: keys punish sub-rank bases
    basis_k = discover_kv_basis(rows_k, kc, seed=0)
    basis_v = discover_kv_basis(rows_v, kc, seed=1)
    print(f"\nDROP KV bases: head_dim={hd} -> rank_k={basis_k.shape[1]}, "
          f"rank_v={basis_v.shape[1]} "
          f"(cache bytes x{basis_k.shape[1]/hd:.2f})")

    # verify decode attention in the compressed space tracks the exact one
    t = k_cache.shape[1]
    q = jax.random.normal(jax.random.PRNGKey(2),
                          (b, 1, cfg.num_kv_heads,
                           cfg.num_heads // cfg.num_kv_heads, hd))
    valid = jnp.ones((b, t), bool)
    exact = decode_attention(q, jnp.asarray(k_cache), jnp.asarray(v_cache),
                             length_mask=valid)
    ck, cv = compress_cache_layer(
        jnp.asarray(k_cache), jnp.asarray(v_cache),
        jnp.asarray(basis_k), jnp.asarray(basis_v),
    )
    approx = decode_attention_compressed(
        q, ck, cv, jnp.asarray(basis_k), jnp.asarray(basis_v), valid
    )
    err = float(jnp.linalg.norm(exact - approx) / jnp.linalg.norm(exact))
    print(f"compressed-decode relative error: {err:.4f} "
          f"(TLB target {kc.target_tlb})")
    print("note: RANDOM-INIT weights produce nearly isotropic keys "
          "(rank ~= head_dim); trained models' keys are structured — "
          "the regime below:")

    # --- the trained-model regime: structured (low-rank) keys ---
    rng = np.random.default_rng(0)
    b2, t2, kvh = 4, 256, cfg.num_kv_heads
    factors = rng.normal(size=(b2 * t2 * kvh, 4)).astype(np.float32)
    k_s = (factors @ rng.normal(size=(4, hd)).astype(np.float32)
           + 0.02 * rng.normal(size=(b2 * t2 * kvh, hd)).astype(np.float32))
    v_s = (factors @ rng.normal(size=(4, hd)).astype(np.float32)
           + 0.02 * rng.normal(size=(b2 * t2 * kvh, hd)).astype(np.float32))
    bk = discover_kv_basis(k_s, kc, seed=2)
    bv = discover_kv_basis(v_s, kc, seed=3)
    ks4 = jnp.asarray(k_s.reshape(b2, t2, kvh, hd))
    vs4 = jnp.asarray(v_s.reshape(b2, t2, kvh, hd))
    ck2, cv2 = compress_cache_layer(ks4, vs4, jnp.asarray(bk), jnp.asarray(bv))
    q2 = jax.random.normal(jax.random.PRNGKey(5),
                           (b2, 1, kvh, cfg.num_heads // kvh, hd))
    valid2 = jnp.ones((b2, t2), bool)
    exact2 = decode_attention(q2, ks4, vs4, length_mask=valid2)
    approx2 = decode_attention_compressed(
        q2, ck2, cv2, jnp.asarray(bk), jnp.asarray(bv), valid2)
    err2 = float(jnp.linalg.norm(exact2 - approx2) / jnp.linalg.norm(exact2))
    print(f"structured keys: head_dim={hd} -> rank {bk.shape[1]} "
          f"(cache bytes x{bk.shape[1]/hd:.2f}), rel err {err2:.4f}")


if __name__ == "__main__":
    main()
