"""End-to-end driver: train a ~100M-param LM for a few hundred steps with the
full substrate — token pipeline, AdamW, checkpointing, fault injection, and
DROP gradient-compression basis discovery.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch tinyllama_1_1b]

The model is the assigned arch's family scaled to ~100M params (trains on one
CPU core in minutes); the identical code path drives the full configs on the
production meshes (launch/dryrun.py proves those lower+compile).
"""

import argparse
from dataclasses import replace

import numpy as np

from repro.configs.base import get_config
from repro.train.grad_compress import GradCompressConfig
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


from repro.configs.scaled import scaled_100m  # noqa: E402


class LmTrainer(Trainer):
    seq_len = 256
    batch = 8

    def _seq_len(self) -> int:
        return self.seq_len

    def _batch(self) -> int:
        return self.batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="tinyllama_1_1b")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--failure-prob", type=float, default=0.0)
    ap.add_argument("--drop-compress", action="store_true",
                    help="discover low-rank gradient bases with DROP")
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = scaled_100m(args.arch)
    print(f"arch={cfg.name} family={cfg.family} "
          f"params~{cfg.param_count()/1e6:.0f}M")

    tcfg = TrainerConfig(
        total_steps=args.steps,
        ckpt_every=max(args.steps // 5, 1),
        ckpt_dir=args.ckpt_dir,
        log_every=10,
        failure_prob=args.failure_prob,
        grad_compress=GradCompressConfig(refresh_every=100)
        if args.drop_compress
        else None,
    )
    opt = OptimizerConfig(learning_rate=3e-3, warmup_steps=20,
                          total_steps=args.steps)
    trainer = LmTrainer(cfg, opt, tcfg)
    trainer.seq_len = args.seq_len
    trainer.batch = args.batch
    report = trainer.run()

    first = np.mean(report.losses[:10])
    last = np.mean(report.losses[-10:])
    print(f"\nsteps={report.steps_run} restarts={report.restarts} "
          f"ckpts={report.ckpt_steps}")
    print(f"loss: {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    if trainer._bases is not None:
        from repro.train.grad_compress import compressed_bytes_ratio
        print(f"DROP gradient bases: {len(trainer._bases)} matrices")


if __name__ == "__main__":
    main()
