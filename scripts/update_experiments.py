"""Regenerate the §Roofline-table section of EXPERIMENTS.md from the dry-run
artifacts.

    PYTHONPATH=src python scripts/update_experiments.py
"""

import json
import os
import re

ROOT = os.path.join(os.path.dirname(__file__), "..")
ART = os.path.join(ROOT, "artifacts", "dryrun")
EXP = os.path.join(ROOT, "EXPERIMENTS.md")

MARK = "## §Roofline-table (regenerated after optimizations)"


def table() -> str:
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "dominant | useful | MODEL_TF | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    skips = []
    for f in sorted(os.listdir(ART)):
        if not f.endswith(".json"):
            continue
        r = json.load(open(os.path.join(ART, f)))
        if r["status"] == "skipped":
            skips.append(f"{r['arch']} x {r['shape']} x {r['mesh']}: "
                         f"{r['skip_reason']}")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR: {r.get('error','')[:60]} |||||||")
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
            f"| {rf['collective_s']:.4f} | {rf['dominant']} "
            f"| {rf['useful_ratio']:.3f} | {rf['model_flops_total']/1e12:.1f} "
            f"| {r.get('suggestion','')[:80]} |"
        )
    out = "\n".join(lines)
    out += "\n\nSkipped cells (documented, DESIGN.md §6):\n"
    out += "\n".join(f"* {s}" for s in skips)
    return out


def main() -> None:
    with open(EXP) as f:
        text = f.read()
    head = text.split(MARK)[0]
    with open(EXP, "w") as f:
        f.write(head + MARK + "\n\n" + table() + "\n")
    print("EXPERIMENTS.md roofline table regenerated "
          f"({len(os.listdir(ART))} artifacts)")


if __name__ == "__main__":
    main()
