"""repro — production-grade JAX reproduction of DROP (Suri & Bailis, 2017).

DROP: Dimensionality Reduction Optimization for Time Series.

Public API:
    repro.core            -- the DROP optimizer (paper Algorithm 2)
    repro.baselines       -- PAA / FFT / full-SVD PCA / JL baselines
    repro.analytics       -- downstream k-NN / DBSCAN / KDE operators
    repro.data            -- synthetic UCR-like time series + LM token pipeline
    repro.models          -- the 10 assigned LM-family architectures
    repro.train, .serve   -- distributed training & serving substrate
    repro.launch          -- production mesh + multi-pod dry-run
"""

__version__ = "1.0.0"
