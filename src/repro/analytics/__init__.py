"""Downstream analytics operators (pure JAX/numpy): k-NN retrieval, DBSCAN
clustering, kernel density estimation — the pipelines DROP pre-processes.
All three run on the shared fused tiled pairwise engine (``pairwise``);
``*_legacy`` variants keep the pre-engine host loops as parity oracles."""

from repro.analytics.dbscan import dbscan, dbscan_legacy  # noqa: F401
from repro.analytics.incremental import IncrementalAnalytics  # noqa: F401
from repro.analytics.kde import gaussian_kde, gaussian_kde_legacy  # noqa: F401
from repro.analytics.knn import (  # noqa: F401
    knn_retrieval_accuracy,
    nearest_neighbors,
    nearest_neighbors_legacy,
)
from repro.analytics.pairwise import (  # noqa: F401
    NeighborDecoder,
    pairwise_dbscan,
    pairwise_kde,
    pairwise_knn,
    unpack_neighbors,
)
from repro.analytics.split import (  # noqa: F401
    merge_dbscan_partials,
    merge_kde_partials,
    merge_knn_partials,
    split_pairwise_dbscan,
    split_pairwise_kde,
    split_pairwise_knn,
)
