"""Downstream analytics operators (pure JAX/numpy): k-NN retrieval, DBSCAN
clustering, kernel density estimation — the pipelines DROP pre-processes."""

from repro.analytics.dbscan import dbscan  # noqa: F401
from repro.analytics.kde import gaussian_kde  # noqa: F401
from repro.analytics.knn import knn_retrieval_accuracy, nearest_neighbors  # noqa: F401
