"""DBSCAN (Ester et al. 1996) — the paper's second end-to-end task (§4.4).

The device side is one fused tiled scan (``analytics.pairwise``): eps-ball
degree counts + packed uint32 neighbor bitmasks in a single dispatch and a
single device->host transfer. The host BFS consumes the packed bits — core
checks read the precomputed degrees, and a row is only ever decoded
(``unpack_neighbors``) when the expansion actually visits it, replacing the
legacy per-row ``np.nonzero`` over m boolean matrix rows.

``dbscan_legacy`` keeps the pre-engine blocked host loop as the parity
oracle / benchmark baseline. Both paths share ``_bfs``, so fused-vs-legacy
label parity is exact (identical traversal order — DBSCAN border-point
labels are traversal-order dependent).
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

NOISE = -1
UNVISITED = -2


@partial(jax.jit, static_argnames=())
def _radius_block(xq: jax.Array, x: jax.Array, eps2: jax.Array) -> jax.Array:
    sq_q = jnp.sum(xq * xq, axis=1, keepdims=True)
    sq_x = jnp.sum(x * x, axis=1)
    d2 = sq_q + sq_x[None, :] - 2.0 * xq @ x.T
    return d2 <= eps2


def _neighbor_lists(x: np.ndarray, eps: float, block: int = 1024) -> list[np.ndarray]:
    xs = jnp.asarray(x, dtype=jnp.float32)
    eps2 = jnp.float32(eps * eps)
    m = x.shape[0]
    out: list[np.ndarray] = []
    for a in range(0, m, block):
        xq = xs[a : a + block]
        n = xq.shape[0]
        if n < block:
            # pad the remainder to the full block: every tail shape used to
            # mint a fresh XLA executable (one compile per distinct m %
            # block); padded rows are sliced off before the host scan
            xq = jnp.pad(xq, ((0, block - n), (0, 0)))
        mask = np.asarray(_radius_block(xq, xs, eps2))[:n]
        for r in range(n):
            nbrs = np.nonzero(mask[r])[0]
            out.append(nbrs[nbrs != a + r])
    return out


def _bfs(
    m: int,
    min_samples: int,
    degrees: np.ndarray,
    neighbors: Callable[[int], np.ndarray],
) -> np.ndarray:
    """The (host) expansion shared by the fused and legacy paths.

    ``degrees`` INCLUDE the self point (a point is always within eps of
    itself); ``neighbors(p)`` returns p's eps-neighbors sorted ascending,
    self excluded — the exact arrays the legacy path precomputed, so the
    traversal (and with it every border-point label) is identical."""
    labels = np.full(m, UNVISITED, dtype=np.int64)
    cluster = 0
    for p in range(m):
        if labels[p] != UNVISITED:
            continue
        if degrees[p] < min_samples:
            labels[p] = NOISE
            continue
        labels[p] = cluster
        frontier = list(neighbors(p))
        while frontier:
            q = frontier.pop()
            if labels[q] == NOISE:
                labels[q] = cluster
            if labels[q] != UNVISITED:
                continue
            labels[q] = cluster
            if degrees[q] >= min_samples:
                frontier.extend(neighbors(q))
        cluster += 1
    return labels


def dbscan(
    x: np.ndarray,
    eps: float = 0.5,
    min_samples: int = 5,
    block: int = 1024,
    *,
    use_kernels: bool = False,
    split: int | None = None,
    fanout: str = "xla",
    devices=None,
) -> np.ndarray:
    """Cluster labels per point; -1 = noise. One fused device scan.

    ``split=N`` shards the device scan (``analytics.split``); counts and
    packed bitmasks merge bit-identically, so the BFS — and every
    traversal-order-dependent border label — is unchanged."""
    from repro.analytics.pairwise import NeighborDecoder, pairwise_dbscan

    m = x.shape[0]
    if split is not None or fanout == "mesh":
        from repro.analytics.split import split_pairwise_dbscan

        counts, packed = split_pairwise_dbscan(
            x, eps, shards=split or 1, block_q=block, block_k=block,
            use_kernels=use_kernels, fanout=fanout, devices=devices,
        )
    else:
        counts, packed = pairwise_dbscan(
            x, eps, block, block, use_kernels=use_kernels
        )
    return _bfs(m, min_samples, counts, NeighborDecoder(packed, m))


def dbscan_legacy(
    x: np.ndarray, eps: float = 0.5, min_samples: int = 5, block: int = 1024
) -> np.ndarray:
    """The pre-engine path: blocked radius queries with a host sync per
    block and eager per-row ``np.nonzero``. Parity oracle / benchmark
    baseline."""
    m = x.shape[0]
    nbrs = _neighbor_lists(x, eps, block=block)
    degrees = np.array([n.size + 1 for n in nbrs])
    return _bfs(m, min_samples, degrees, lambda p: nbrs[p])
