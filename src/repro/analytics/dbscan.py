"""DBSCAN (Ester et al. 1996) — the paper's second end-to-end task (§4.4).

Blocked radius queries (O(m^2 k) distance work, jitted) + host BFS expansion.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

NOISE = -1
UNVISITED = -2


@partial(jax.jit, static_argnames=())
def _radius_block(xq: jax.Array, x: jax.Array, eps2: jax.Array) -> jax.Array:
    sq_q = jnp.sum(xq * xq, axis=1, keepdims=True)
    sq_x = jnp.sum(x * x, axis=1)
    d2 = sq_q + sq_x[None, :] - 2.0 * xq @ x.T
    return d2 <= eps2


def _neighbor_lists(x: np.ndarray, eps: float, block: int = 1024) -> list[np.ndarray]:
    xs = jnp.asarray(x, dtype=jnp.float32)
    eps2 = jnp.float32(eps * eps)
    m = x.shape[0]
    out: list[np.ndarray] = []
    for a in range(0, m, block):
        mask = np.asarray(_radius_block(xs[a : a + block], xs, eps2))
        for r in range(mask.shape[0]):
            nbrs = np.nonzero(mask[r])[0]
            out.append(nbrs[nbrs != a + r])
    return out


def dbscan(
    x: np.ndarray, eps: float = 0.5, min_samples: int = 5, block: int = 1024
) -> np.ndarray:
    """Cluster labels per point; -1 = noise."""
    m = x.shape[0]
    nbrs = _neighbor_lists(x, eps, block=block)
    labels = np.full(m, UNVISITED, dtype=np.int64)
    cluster = 0
    for p in range(m):
        if labels[p] != UNVISITED:
            continue
        if nbrs[p].size + 1 < min_samples:
            labels[p] = NOISE
            continue
        labels[p] = cluster
        frontier = list(nbrs[p])
        while frontier:
            q = frontier.pop()
            if labels[q] == NOISE:
                labels[q] = cluster
            if labels[q] != UNVISITED:
                continue
            labels[q] = cluster
            if nbrs[q].size + 1 >= min_samples:
                frontier.extend(nbrs[q])
        cluster += 1
    return labels
