"""Incremental downstream-analytics maintenance for append-only streams.

The delta-serving subsystem (``serve_drop.delta``) promises subscribers
O(suffix) work per append *end to end* — and the downstream analytics are
where that promise is hardest: a cold kNN/DBSCAN/KDE pass over the grown
reduced dataset is the O(m^2 k) scan DROP's cost model prices, re-paid on
every append. This module maintains the three downstream states
incrementally instead, with the new rows' pairwise contributions computed
by the SAME fused tile body the cold path runs (``pairwise._scan_core``),
just over *rectangular* shards:

* **scan A** (old rows x new rows, ``col_offset = m_old``) — how the
  appended suffix changes every existing row's reduction;
* **scan B** (new rows x all rows, ``row_offset = m_old``) — the new rows'
  own full reduction, identical tile layout to the cold scan's.

Per-append device work is O(s * m), not O(m^2). The carried states and why
each merge is exact:

* **kNN** — carried (nn_idx, nn_d2). A d2 element is a function of its two
  rows only (same d-length contraction regardless of tile position — the
  same invariant ``analytics.split``'s shard merges rely on), and the
  engine's tie-break (per-tile first-occurrence argmin + strict-``<``
  carry) composes associatively over ordered column groups, so folding
  scan A into the carry with strict ``<`` (old state, lower columns, wins
  ties) reproduces the cold scan's lowest-column-argmin bit-for-bit.
* **DBSCAN** — degrees are exact integer sums; the adjacency bitmask is
  kept as packed SEGMENTS (one row-block per append, one column-patch per
  append) so arbitrary — non-tile-aligned — append boundaries never need
  bit shifting. Labels are NOT re-grown by BFS: ``_bfs``'s output is a
  pure function of (core set, core adjacency) — cluster ids are components
  of the core subgraph ranked by minimal core index, border labels the
  minimum id over adjacent components (see ``_DbscanLabeler``) — and on an
  append-only stream degrees are monotone, so the core set only grows and
  components only merge. A union-find over core points repaired only in
  the eps-neighborhood of appended/promoted points therefore yields labels
  bit-identical to a cold ``dbscan()``.
* **KDE** — per-row compensated (sum, comp) f32 pairs from each scan are
  folded into a float64 running total (exactly the shard-merge semantics
  of ``kde_from_compensated``), so densities match a cold scan to ~f32 ulp
  — the same split-point independence the split engine guarantees.

``rebuild()`` resets everything from a cold scan — the rollback path when
the serving basis rotates and old reduced coordinates become invalid.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.analytics.dbscan import NOISE, _bfs  # noqa: F401  (parity oracle)
from repro.analytics.pairwise import (
    DEFAULT_BLOCK,
    NeighborDecoder,
    _clamp_block,
    _default_top_k,
    _pad_rows,
    _scan_core,
    pairwise_dbscan,
    pairwise_kde,
    pairwise_knn,
)
from repro.core.bucketing import DEFAULT_BUCKETS, ShapeBucketCache

__all__ = ["IncrementalAnalytics", "AnalyticsSnapshot"]


@partial(jax.jit, static_argnames=("task", "bq", "bk", "use_top_k"))
def _rect_scan(xq, x, m, scalar, col_offset, row_offset, task, bq, bk, use_top_k):
    """One rectangular (query shard x dataset shard) pass of the fused tile
    body — the sequential engine with nonzero global offsets."""
    return _scan_core(
        xq, x, m, scalar, col_offset, row_offset,
        task=task, bq=bq, bk=bk, use_top_k=use_top_k,
    )


def _run_rect(
    queries: np.ndarray,
    data: np.ndarray,
    m_total: int,
    scalar: float,
    col_offset: int,
    row_offset: int,
    task: str,
    block: int,
    use_top_k: bool,
    bucket: ShapeBucketCache,
):
    """Host wrapper: pad both shards through the shared buckets, run the
    jitted rectangular scan, slice the true rows back out."""
    nq, nk = queries.shape[0], data.shape[0]
    bq = _clamp_block(block, nq)
    bk = _clamp_block(block, nk)
    xq_pad = _pad_rows(np.ascontiguousarray(queries, np.float32),
                       bucket.bucket_tile_rows(nq, bq))
    xk_pad = _pad_rows(np.ascontiguousarray(data, np.float32),
                       bucket.bucket_tile_rows(nk, bk))
    a, b = jax.device_get(
        _rect_scan(
            jnp.asarray(xq_pad), jnp.asarray(xk_pad),
            jnp.int32(m_total), jnp.float32(scalar),
            jnp.int32(col_offset), jnp.int32(row_offset),
            task=task, bq=bq, bk=bk, use_top_k=use_top_k,
        )
    )
    return np.asarray(a)[:nq], np.asarray(b)[:nq]


# ------------------------------------------------------------ DBSCAN labels


class _SegmentedAdjacency:
    """Packed eps-ball adjacency stored as append segments.

    * ``row_blocks[t] = (row0, ncols, packed)`` — the rows appended at step
      t, with their full adjacency over columns [0, ncols) (scan B output;
      t = 0 is the bootstrap full scan).
    * ``col_patches[u] = (base, ncols, packed)`` — ALL rows that existed
      before append u (rows [0, base)) against the appended columns
      [base, base + ncols) (scan A output; local bit c maps to global
      column base + c).

    ``neighbors(r)`` decodes r's block row (self excluded) plus every later
    patch row, each ascending, concatenated ascending — the exact neighbor
    sets a cold ``NeighborDecoder`` would produce, at O(words of row r)."""

    def __init__(self) -> None:
        self.row_blocks: list[tuple[int, int, np.ndarray]] = []
        self.col_patches: list[tuple[int, int, np.ndarray]] = []

    def add_block(self, row0: int, ncols: int, packed: np.ndarray) -> None:
        self.row_blocks.append((row0, ncols, packed))

    def add_patch(self, base: int, ncols: int, packed: np.ndarray) -> None:
        self.col_patches.append((base, ncols, packed))

    @staticmethod
    def _decode(words: np.ndarray, ncols: int) -> np.ndarray:
        bits = np.unpackbits(
            np.ascontiguousarray(words).view(np.uint8), bitorder="little"
        )[:ncols]
        return np.flatnonzero(bits)

    def neighbors(self, r: int) -> np.ndarray:
        pieces = []
        for row0, ncols, packed in self.row_blocks:
            if row0 <= r < row0 + packed.shape[0]:
                own = self._decode(packed[r - row0], ncols)
                pieces.append(own[own != r])
                break
        for base, ncols, packed in self.col_patches:
            if r < base:
                pieces.append(self._decode(packed[r], ncols) + base)
        if not pieces:
            return np.empty(0, dtype=np.int64)
        return np.concatenate(pieces)


class _DbscanLabeler:
    """Union-find over core points + support sets for border points.

    ``_bfs`` labels are a pure function of the eps-graph (the BFS docstring
    semantics, restated order-free): a point is *core* iff its degree
    (self included) clears ``min_samples``; clusters are the connected
    components of the core-core adjacency, numbered by the rank of each
    component's minimal core index; a core point takes its component's id;
    a non-core point takes the MINIMUM id over components it is eps-
    adjacent to (the lowest-numbered cluster expands first and claims it),
    else NOISE. The parity suite pins this equivalence against ``_bfs``
    directly.

    Append-only monotonicity: degrees never decrease, so the core set only
    grows and components only merge — both are union-find-friendly. Per
    append only the NEWLY core points (appended or promoted) need their
    neighborhoods walked."""

    def __init__(self, min_samples: int) -> None:
        self.min_samples = int(min_samples)
        self.parent = np.empty(0, dtype=np.int64)
        self.is_core = np.empty(0, dtype=bool)
        self.min_core: dict[int, int] = {}  # root -> minimal core index
        self.support: dict[int, set[int]] = {}  # non-core -> adjacent cores

    def _find(self, a: int) -> int:
        p = self.parent
        root = a
        while p[root] != root:
            root = p[root]
        while p[a] != root:  # path compression
            p[a], a = root, int(p[a])
        return root

    def _union(self, a: int, b: int) -> None:
        ra, rb = self._find(a), self._find(b)
        if ra == rb:
            return
        if ra > rb:  # keep the lower root: min_core stays cheap to track
            ra, rb = rb, ra
        self.parent[rb] = ra
        self.min_core[ra] = min(self.min_core[ra], self.min_core.pop(rb))

    def grow(
        self,
        degrees: np.ndarray,
        prev_degrees_old: np.ndarray | None,
        adj: _SegmentedAdjacency,
        m_old: int,
    ) -> None:
        """Fold one append in: ``degrees`` are the grown exact degrees,
        ``prev_degrees_old`` the pre-append degrees of the old rows (None
        on bootstrap, when every point is 'new')."""
        m = degrees.shape[0]
        grown_parent = np.arange(m, dtype=np.int64)
        grown_parent[: self.parent.shape[0]] = self.parent
        self.parent = grown_parent
        was_core = np.zeros(m, dtype=bool)
        was_core[: self.is_core.shape[0]] = self.is_core
        self.is_core = degrees >= self.min_samples
        newly_core = np.flatnonzero(self.is_core & ~was_core)
        # mark every newly-core point before walking any neighborhood, so a
        # pair of simultaneously promoted neighbors unions from either side
        for p in newly_core:
            p = int(p)
            self.min_core[p] = p
            self.support.pop(p, None)
        for p in newly_core:
            p = int(p)
            for q in adj.neighbors(p):
                q = int(q)
                if self.is_core[q]:
                    self._union(p, q)
                else:
                    self.support.setdefault(q, set()).add(p)
        # appended non-core rows: their support is their core neighborhood
        # (promoted cores above already pushed themselves into old rows'
        # support sets — only the brand-new rows still need a walk)
        for r in range(m_old, m):
            if not self.is_core[r]:
                sup = {int(q) for q in adj.neighbors(r) if self.is_core[q]}
                if sup:
                    self.support[r] = sup

    def labels(self) -> np.ndarray:
        m = self.parent.shape[0]
        out = np.full(m, NOISE, dtype=np.int64)
        core_idx = np.flatnonzero(self.is_core)
        if core_idx.size == 0:
            return out
        roots = np.fromiter(
            (self._find(int(p)) for p in core_idx), dtype=np.int64,
            count=core_idx.size,
        )
        order = sorted(set(roots.tolist()), key=lambda r: self.min_core[r])
        cid = {r: i for i, r in enumerate(order)}
        out[core_idx] = np.fromiter(
            (cid[int(r)] for r in roots), dtype=np.int64, count=roots.size
        )
        for q, sup in self.support.items():
            if sup and not self.is_core[q]:
                out[q] = min(cid[self._find(a)] for a in sup)
        return out


# --------------------------------------------------------------- the engine


class AnalyticsSnapshot:
    """One consistent view of the three maintained downstream outputs."""

    __slots__ = ("knn_idx", "knn_d2", "labels", "densities")

    def __init__(self, knn_idx, knn_d2, labels, densities) -> None:
        self.knn_idx = knn_idx
        self.knn_d2 = knn_d2
        self.labels = labels
        self.densities = densities


class IncrementalAnalytics:
    """Per-subscription downstream state with O(s * m) appends.

    Bootstrap (and ``rebuild()``) run the COLD fused scans — the same calls
    a ``run_downstream`` leg makes — so the initial state is the cold state
    by construction; every ``append()`` then folds the suffix in via two
    rectangular ``_scan_core`` passes per task and the exact merges
    described in the module docstring."""

    def __init__(
        self,
        y: np.ndarray,
        *,
        eps: float,
        min_samples: int = 5,
        bandwidth: float = 1.0,
        block: int = DEFAULT_BLOCK,
        use_top_k: bool | None = None,
        bucket: ShapeBucketCache | None = None,
    ) -> None:
        self.eps = float(eps)
        self.min_samples = int(min_samples)
        self.bandwidth = float(bandwidth)
        self.block = int(block)
        self.bucket = bucket or DEFAULT_BUCKETS
        self._use_top_k = use_top_k
        self.rebuild(y)

    # float32(eps * eps): ONE rounding, matching pairwise_dbscan exactly —
    # eps-boundary parity with the cold path depends on it
    @property
    def _eps2(self) -> np.float32:
        return np.float32(self.eps * self.eps)

    @property
    def _inv2h2(self) -> np.float32:
        return np.float32(1.0 / (2.0 * self.bandwidth * self.bandwidth))

    @property
    def rows(self) -> int:
        return int(self._y.shape[0])

    def _top_k(self, m: int) -> bool:
        return _default_top_k(m) if self._use_top_k is None else self._use_top_k

    # ----------------------------------------------------------- rebuild

    def rebuild(self, y: np.ndarray) -> AnalyticsSnapshot:
        """Cold bootstrap over ``y`` (reduced coordinates) — the rollback
        path: the basis rotated, every cached pairwise quantity is void."""
        y = np.ascontiguousarray(np.asarray(y), dtype=np.float32)
        if y.ndim != 2:
            raise ValueError(f"expected (m, k) reduced rows, got {y.shape}")
        self._y = y
        m = y.shape[0]
        self.nn_idx, self.nn_d2 = pairwise_knn(
            y, self.block, self.block,
            use_top_k=self._use_top_k, bucket=self.bucket,
        )
        counts, packed = pairwise_dbscan(
            y, self.eps, self.block, self.block, bucket=self.bucket
        )
        self.degrees = counts.astype(np.int64)
        self._adj = _SegmentedAdjacency()
        self._adj.add_block(0, m, packed)
        self._labeler = _DbscanLabeler(self.min_samples)
        self._labeler.grow(self.degrees, None, self._adj, m_old=0)
        self.labels = self._labeler.labels()
        # KDE: keep the compensated pairs' exact float64 value per row; the
        # density divides by the CURRENT row count at snapshot time
        scan = _run_rect(
            y, y, m, self._inv2h2, 0, 0, "kde", self.block, False, self.bucket
        )
        self._kde64 = scan[0].astype(np.float64) + scan[1].astype(np.float64)
        return self.snapshot()

    # ------------------------------------------------------------ append

    def append(self, y_new: np.ndarray) -> dict:
        """Fold appended reduced rows in; returns the O(suffix) patch:
        ``changed`` (old rows whose nearest neighbor moved) plus the new
        rows' values. Labels and densities are returned whole from
        ``snapshot()`` — every append can renumber clusters and rescales
        every density by 1/m, so their *values* are O(m) even though the
        compute is O(s * m)."""
        y_new = np.ascontiguousarray(np.asarray(y_new), dtype=np.float32)
        s = y_new.shape[0]
        m_old = self.rows
        if s == 0:
            return {"changed": np.empty(0, np.int64)}
        if y_new.ndim != 2 or y_new.shape[1] != self._y.shape[1]:
            raise ValueError(
                f"append shape {y_new.shape} does not extend "
                f"{self._y.shape}"
            )
        grown = np.concatenate([self._y, y_new], axis=0)
        m = m_old + s
        top_k = self._top_k(m)

        # kNN: scan A folds new columns into the old carry (strict <: the
        # old state, holding lower column indices, keeps ties — the cold
        # scan's first-occurrence argmin); scan B is the new rows' full
        # reduction in the cold scan's own tile layout
        idx_a, d2_a = _run_rect(
            self._y, y_new, m, 0.0, m_old, 0,
            "knn", self.block, top_k, self.bucket,
        )
        idx_b, d2_b = _run_rect(
            y_new, grown, m, 0.0, 0, m_old,
            "knn", self.block, top_k, self.bucket,
        )
        better = d2_a < self.nn_d2
        changed = np.flatnonzero(better)
        self.nn_idx = np.concatenate(
            [np.where(better, idx_a, self.nn_idx).astype(np.int32), idx_b]
        )
        self.nn_d2 = np.concatenate([np.where(better, d2_a, self.nn_d2), d2_b])

        # DBSCAN: exact integer degree folds + adjacency segments, then
        # label repair confined to appended/promoted neighborhoods
        cnt_a, packed_a = _run_rect(
            self._y, y_new, m, self._eps2, m_old, 0,
            "dbscan", self.block, False, self.bucket,
        )
        cnt_b, packed_b = _run_rect(
            y_new, grown, m, self._eps2, 0, m_old,
            "dbscan", self.block, False, self.bucket,
        )
        prev_degrees = self.degrees
        self.degrees = np.concatenate(
            [prev_degrees + cnt_a, cnt_b.astype(np.int64)]
        )
        self._adj.add_patch(m_old, s, packed_a)
        self._adj.add_block(m_old, m, packed_b)
        self._labeler.grow(self.degrees, prev_degrees, self._adj, m_old)
        self.labels = self._labeler.labels()

        # KDE: compensated pairs folded in float64 (shard-merge semantics)
        sum_a, comp_a = _run_rect(
            self._y, y_new, m, self._inv2h2, m_old, 0,
            "kde", self.block, False, self.bucket,
        )
        sum_b, comp_b = _run_rect(
            y_new, grown, m, self._inv2h2, 0, m_old,
            "kde", self.block, False, self.bucket,
        )
        self._kde64 = np.concatenate([
            self._kde64 + (sum_a.astype(np.float64) + comp_a.astype(np.float64)),
            sum_b.astype(np.float64) + comp_b.astype(np.float64),
        ])

        self._y = grown
        return {
            "changed": changed,
            "idx": self.nn_idx[changed],
            "d2": self.nn_d2[changed],
            "append_idx": idx_b,
            "append_d2": d2_b,
        }

    # ---------------------------------------------------------- snapshot

    def snapshot(self) -> AnalyticsSnapshot:
        return AnalyticsSnapshot(
            knn_idx=self.nn_idx.copy(),
            knn_d2=self.nn_d2.copy(),
            labels=self.labels.copy(),
            densities=(self._kde64 / float(self.rows)).astype(np.float32),
        )
