"""Gaussian kernel density estimation — a pairwise-distance downstream task
(mentioned in §1 alongside k-NN/k-Means as TLB-sensitive analytics).

``gaussian_kde`` is a thin adapter over the fused tiled engine
(``analytics.pairwise``): the exp-sum reduction runs inside the tile loop,
one device dispatch, one transfer. ``gaussian_kde_legacy`` keeps the
pre-engine per-block host loop as the parity oracle / benchmark baseline
(same math, so parity is tight — only the summation tree differs)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.jit, static_argnames=())
def _kde_block(xq: jax.Array, x: jax.Array, inv_two_h2: jax.Array) -> jax.Array:
    sq_q = jnp.sum(xq * xq, axis=1, keepdims=True)
    sq_x = jnp.sum(x * x, axis=1)
    d2 = jnp.maximum(sq_q + sq_x[None, :] - 2.0 * xq @ x.T, 0.0)
    return jnp.mean(jnp.exp(-d2 * inv_two_h2), axis=1)


def gaussian_kde_legacy(
    x: np.ndarray, queries: np.ndarray | None = None, bandwidth: float = 1.0,
    block: int = 1024,
) -> np.ndarray:
    """The pre-engine host loop (one dispatch + one sync per query block)."""
    xs = jnp.asarray(x, dtype=jnp.float32)
    qs = xs if queries is None else jnp.asarray(queries, dtype=jnp.float32)
    inv = jnp.float32(1.0 / (2.0 * bandwidth * bandwidth))
    out = []
    for a in range(0, qs.shape[0], block):
        out.append(np.asarray(_kde_block(qs[a : a + block], xs, inv)))
    return np.concatenate(out)


def gaussian_kde(
    x: np.ndarray,
    queries: np.ndarray | None = None,
    bandwidth: float = 1.0,
    block: int = 1024,
    *,
    use_kernels: bool = False,
    split: int | None = None,
    fanout: str = "xla",
    devices=None,
) -> np.ndarray:
    """Mean Gaussian kernel density at each query point (unnormalized).

    ``split=N`` shards the exp-sum (``analytics.split``); compensated
    partials folded in float64 keep densities split-point independent."""
    if split is not None or fanout == "mesh":
        from repro.analytics.split import split_pairwise_kde

        return split_pairwise_kde(
            x, queries, bandwidth, shards=split or 1,
            block_q=block, block_k=block,
            use_kernels=use_kernels, fanout=fanout, devices=devices,
        )
    from repro.analytics.pairwise import pairwise_kde

    return pairwise_kde(
        x, queries, bandwidth, block, block, use_kernels=use_kernels
    )
