"""Brute-force k-NN retrieval (the paper's end-to-end downstream task, §4.4).

The paper's "2-NN retrieval" = for every point, retrieve its single nearest
OTHER point (self excluded) and check label agreement. Runtime O(m^2 k) —
exactly the shape of DROP's default cost model.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.jit, static_argnames=("block",))
def _nn_block(xq: jax.Array, x: jax.Array, start: jax.Array, block: int):
    """Nearest neighbor of each row of xq among rows of x, self excluded."""
    sq_q = jnp.sum(xq * xq, axis=1, keepdims=True)
    sq_x = jnp.sum(x * x, axis=1)
    d2 = sq_q + sq_x[None, :] - 2.0 * xq @ x.T  # (b, m)
    rows = start + jnp.arange(xq.shape[0])
    cols = jnp.arange(x.shape[0])
    d2 = jnp.where(rows[:, None] == cols[None, :], jnp.inf, d2)
    idx = jnp.argmin(d2, axis=1)
    return idx, jnp.take_along_axis(d2, idx[:, None], axis=1)[:, 0]


def nearest_neighbors(x: np.ndarray, block: int = 1024) -> np.ndarray:
    """Index of the nearest other point for every row (blocked, jitted)."""
    x = jnp.asarray(x, dtype=jnp.float32)
    m = x.shape[0]
    out = []
    for a in range(0, m, block):
        b = min(a + block, m)
        xq = x[a:b]
        if xq.shape[0] < block:  # pad to keep a single compiled shape
            pad = block - xq.shape[0]
            xq = jnp.pad(xq, ((0, pad), (0, 0)))
            idx, _ = _nn_block(xq, x, jnp.int32(a), block)
            out.append(np.asarray(idx)[: b - a])
        else:
            idx, _ = _nn_block(xq, x, jnp.int32(a), block)
            out.append(np.asarray(idx))
    return np.concatenate(out)


def knn_retrieval_accuracy(
    x: np.ndarray, labels: np.ndarray, block: int = 1024
) -> float:
    """Label agreement rate of 1-NN retrieval (paper Table 2/4 metric)."""
    nn = nearest_neighbors(x, block=block)
    return float((labels[nn] == labels).mean())
