"""Brute-force k-NN retrieval (the paper's end-to-end downstream task, §4.4).

The paper's "2-NN retrieval" = for every point, retrieve its single nearest
OTHER point (self excluded) and check label agreement. Runtime O(m^2 k) —
exactly the shape of DROP's default cost model.

``nearest_neighbors`` is a thin adapter over the fused tiled engine
(``analytics.pairwise``): one jitted scan, one device dispatch, one
device->host transfer, distance tiles never materialized at (block, m).
The pre-engine host-loop path survives as ``nearest_neighbors_legacy`` —
it is the parity oracle and the benchmark baseline
(``benchmarks/bench_pairwise_analytics.py`` tracks the fused speedup).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.jit, static_argnames=("block", "use_top_k"))
def _nn_block(
    xq: jax.Array,
    x: jax.Array,
    start: jax.Array,
    block: int,
    use_top_k: bool = False,
):
    """Nearest neighbor of each row of xq among rows of x, self excluded.

    Two self-exclusion strategies, picked per backend by the caller:

    * ``use_top_k`` — one ``top_k(2)`` partial-sort pass over the negated
      distances: reads d2 once, no (b, m) index-grid compare, no rewritten
      distance matrix. If the query's own row is the closest hit the
      runner-up is the neighbor, otherwise the top hit already is. This is
      the accelerator path: on TPU/GPU the mask+argmin+take pipeline is
      three k-independent O(b·m) memory passes, while sort units make
      top_k(2) effectively one.
    * mask+argmin — the CPU path. Measured on XLA:CPU, ``lax.top_k`` is a
      20-40x PESSIMIZATION at these shapes (it lowers to a slow generic
      sort loop), while the where+argmin fuses into a single pass anyway —
      so the O(m²) distance-matrix build is the only remaining
      k-independent term there (see the e2e test's slack comment)."""
    sq_q = jnp.sum(xq * xq, axis=1, keepdims=True)
    sq_x = jnp.sum(x * x, axis=1)
    d2 = sq_q + sq_x[None, :] - 2.0 * xq @ x.T  # (b, m)
    rows = start + jnp.arange(xq.shape[0])
    if use_top_k:
        neg_vals, idx = jax.lax.top_k(-d2, 2)  # two smallest per row
        self_first = idx[:, 0] == rows
        nn = jnp.where(self_first, idx[:, 1], idx[:, 0])
        d2_nn = jnp.where(self_first, -neg_vals[:, 1], -neg_vals[:, 0])
        return nn, d2_nn
    cols = jnp.arange(x.shape[0])
    d2 = jnp.where(rows[:, None] == cols[None, :], jnp.inf, d2)
    idx = jnp.argmin(d2, axis=1)
    return idx, jnp.take_along_axis(d2, idx[:, None], axis=1)[:, 0]


def _use_top_k() -> bool:
    """top_k(2) wins on accelerators; on XLA:CPU it is measurably (20-40x)
    slower than the fused mask+argmin at kNN block shapes."""
    return jax.default_backend() != "cpu"


def nearest_neighbors_legacy(x: np.ndarray, block: int = 1024) -> np.ndarray:
    """The pre-engine host loop: one device dispatch AND one blocking
    device->host sync per (block, m) distance tile. Kept as the parity
    oracle / benchmark baseline for the fused engine."""
    x = jnp.asarray(x, dtype=jnp.float32)
    m = x.shape[0]
    # top_k(2) needs 2 candidates; the degenerate m=1 input keeps the mask
    # path (which returns the self index, as before) on every backend
    use_top_k = _use_top_k() and m >= 2
    out = []
    for a in range(0, m, block):
        b = min(a + block, m)
        xq = x[a:b]
        if xq.shape[0] < block:  # pad to keep a single compiled shape
            pad = block - xq.shape[0]
            xq = jnp.pad(xq, ((0, pad), (0, 0)))
            idx, _ = _nn_block(xq, x, jnp.int32(a), block, use_top_k)
            out.append(np.asarray(idx)[: b - a])
        else:
            idx, _ = _nn_block(xq, x, jnp.int32(a), block, use_top_k)
            out.append(np.asarray(idx))
    return np.concatenate(out)


def nearest_neighbors(
    x: np.ndarray,
    block: int = 1024,
    *,
    use_kernels: bool = False,
    split: int | None = None,
    fanout: str = "xla",
    devices=None,
) -> np.ndarray:
    """Index of the nearest other point for every row — one fused scan.

    ``split=N`` runs the dataset axis as N flash-decoding-style shards
    (``fanout="mesh"`` fans them across devices); results are bit-identical
    to the sequential scan for every shard count (``analytics.split``)."""
    if split is not None or fanout == "mesh":
        from repro.analytics.split import split_pairwise_knn

        idx, _ = split_pairwise_knn(
            x, shards=split or 1, block_q=block, block_k=block,
            use_kernels=use_kernels, fanout=fanout, devices=devices,
        )
        return idx
    from repro.analytics.pairwise import pairwise_knn

    idx, _ = pairwise_knn(x, block, block, use_kernels=use_kernels)
    return idx


def knn_retrieval_accuracy(
    x: np.ndarray,
    labels: np.ndarray,
    block: int = 1024,
    *,
    use_kernels: bool = False,
    split: int | None = None,
    fanout: str = "xla",
    devices=None,
) -> float:
    """Label agreement rate of 1-NN retrieval (paper Table 2/4 metric)."""
    nn = nearest_neighbors(
        x, block=block, use_kernels=use_kernels,
        split=split, fanout=fanout, devices=devices,
    )
    return float((labels[nn] == labels).mean())
