"""Fused tiled pairwise-reduction engine for the downstream analytics.

Every analytics task the paper prices with the O(m^2 k) cost model — kNN
retrieval, DBSCAN radius queries, Gaussian KDE — is the same computation: a
row-reduction over the (m_q, m) pairwise squared-distance matrix. The legacy
modules each ran a Python host loop that materialized a ``(block, m)``
distance tile and synced it to host per block; at m=8000 that is a 32 MB
tile written to and re-read from RAM once per block, plus one blocking
device->host transfer per block — a k-INDEPENDENT O(m^2) memory-bound cost
that flattens the paper's §4.4 end-to-end margins on CPU.

This engine runs the ENTIRE scan as one jitted ``lax.fori_loop`` over query
tiles, with an inner ``fori_loop`` over dataset tiles and the per-task
reduction fused into the tile body (flash-attention-style online reduction,
Dao et al.: the row-reduction is carried across dataset tiles so the m x m
matrix never materializes — distance tiles live only in registers/cache):

* ``knn``    — running (min-d2, argmin) per query row, self excluded;
* ``dbscan`` — eps-ball degree counts + packed uint32 neighbor bitmasks
               (the host BFS consumes packed bits instead of re-running
               ``np.nonzero`` on boolean rows);
* ``kde``    — running sum of ``exp(-d2 / 2h^2)`` per query row.

Invariants (see ``analytics/README.md``):

* **one device dispatch** per call — the tile loops live inside a single
  jitted computation, never in Python;
* **one device->host transfer** per call — outputs come back together via a
  single ``jax.device_get`` at the end;
* **single compiled shape per bucket** — query and dataset row counts are
  padded to tile multiples through ``ShapeBucketCache.bucket_tile_rows``
  (the ``rows`` family), so remainder tiles never mint fresh executables,
  and the true row count ``m`` is a traced scalar (datasets landing in the
  same bucket share one executable).

Backend gating (measured, see ``knn._use_top_k``): the per-tile kNN
reduction uses ``lax.top_k(2)`` only off-CPU — on XLA:CPU ``top_k`` is a
20-40x pessimization at these shapes while where+argmin fuses into a single
pass.  ``use_kernels=True`` routes the scan through the
``kernels/pairwise_reduce`` Pallas kernel where a kernel backend is live
(TPU native, or interpret mode under ``REPRO_PALLAS_INTERPRET=1``); on a
plain CPU backend it falls back to this fused jnp scan, which IS the
optimized CPU path — the flag is always safe to set.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.bucketing import DEFAULT_BUCKETS, ShapeBucketCache

TASKS = ("knn", "dbscan", "kde")

# tuned on the container CPU (see benchmarks/bench_pairwise_analytics.py):
# 1024x1024 f32 distance tiles are 4 MB — L2/L3-resident, where the legacy
# (1024, m) tiles spill to RAM at serving sizes
DEFAULT_BLOCK = 1024


def _kernel_backend_live() -> bool:
    """Where ``use_kernels=True`` routes: a live kernel backend (TPU native
    or interpret mode — the shared ``repro.kernels`` gating rule), else the
    fused jnp scan here IS the optimized CPU path."""
    from repro.kernels import kernel_backend_live

    return kernel_backend_live()


def _pad_rows(x: np.ndarray, rows: int) -> np.ndarray:
    """Zero-pad ``x`` to ``rows`` rows on the host (padding happens before
    the single device transfer, so the device only ever sees bucket shapes)."""
    if x.shape[0] == rows:
        return x
    out = np.zeros((rows, x.shape[1]), dtype=x.dtype)
    out[: x.shape[0]] = x
    return out


# below this width the (bq, d) x (d, bk) gemm degenerates on XLA:CPU (the
# tiny contraction defeats the gemm micro-kernels; measured ~1.3x slower
# than unrolled elementwise at d=3, while gemm wins from d~8 up) — exactly
# the regime DROP's small-k reductions land in. CAVEAT: the unrolled
# (q-x)^2 form rounds differently from the gemm expansion the legacy/
# kernel/ref paths use, so at d <= DIRECT_D_MAX cross-path parity is
# exact-on-the-tested-seeds, not guaranteed at last-ulp ties (a pair
# straddling an eps boundary or an argmin near-tie by <1 ulp may
# legitimately resolve either way; exact duplicates still give d2 = 0 in
# every form). The parity suites run seeded data through this regime
# (DBSCAN blobs at d=3) and are deterministic.
DIRECT_D_MAX = 4


def _tile_d2(xqt, sq_q, x, sq_x, j, bk, m, col_offset):
    """One (bq, bk) squared-distance tile with padded dataset columns masked
    to +inf. Returns (d2, cols) — cols are GLOBAL dataset indices
    (``col_offset`` shifts local tile columns to global when ``x`` is one
    shard of a split dataset; the sequential scan passes 0)."""
    d = x.shape[1]
    xt = lax.dynamic_slice(x, (j * bk, 0), (bk, d))
    cols = col_offset + j * bk + jnp.arange(bk)
    if d <= DIRECT_D_MAX:
        # unrolled sum_j (q_j - x_j)^2: pure VPU, no degenerate gemm
        d2 = jnp.zeros((xqt.shape[0], bk), jnp.float32)
        for jj in range(d):
            diff = xqt[:, jj][:, None] - xt[None, :, jj]
            d2 = d2 + diff * diff
    else:
        sq_t = lax.dynamic_slice(sq_x, (j * bk,), (bk,))
        d2 = sq_q + sq_t[None, :] - 2.0 * xqt @ xt.T
    d2 = jnp.where(cols[None, :] >= m, jnp.inf, d2)
    return d2, cols


def _knn_tile(carry, d2, cols, rows, use_top_k):
    """Fold one distance tile into the running (min-d2, argmin) carry.

    Strict ``<`` keeps the earlier tile on ties, and both per-tile
    reductions keep the first occurrence — together that reproduces the
    global-argmin first-occurrence tie-break of the legacy path exactly."""
    best_d2, best_idx = carry
    if use_top_k:
        # accelerator reduction: one top_k(2) partial-sort pass — if the
        # query's own row is the top hit the runner-up is the neighbor
        neg_vals, loc = lax.top_k(-d2, 2)
        cand = cols[loc]  # (bq, 2) global indices
        self_first = cand[:, 0] == rows
        t_d2 = jnp.where(self_first, -neg_vals[:, 1], -neg_vals[:, 0])
        t_idx = jnp.where(self_first, cand[:, 1], cand[:, 0])
    else:
        # CPU reduction: mask+argmin fuses into a single pass over the tile
        d2 = jnp.where(rows[:, None] == cols[None, :], jnp.inf, d2)
        t_d2 = jnp.min(d2, axis=1)
        t_idx = cols[jnp.argmin(d2, axis=1)]
    better = t_d2 < best_d2
    return (
        jnp.where(better, t_d2, best_d2),
        jnp.where(better, t_idx, best_idx),
    )


def _pack_bits(mask: jax.Array) -> jax.Array:
    """(bq, bk) bool -> (bq, bk//32) uint32, little-endian bit order (bit j
    of word w flags dataset column w*32 + j within the tile). Mirrors
    ``kernels.pairwise_reduce.pairwise_reduce.pack_bits_u32`` — THE layout
    definition; cross-path agreement is pinned by the parity sweeps. (Kept
    as a local copy so analytics never imports pallas at module level.)"""
    bq, bk = mask.shape
    u = mask.astype(jnp.uint32).reshape(bq, bk // 32, 32)
    weights = jnp.left_shift(
        jnp.uint32(1), jnp.arange(32, dtype=jnp.uint32)
    )
    return jnp.sum(u * weights[None, None, :], axis=-1, dtype=jnp.uint32)


def _scan_core(
    xq: jax.Array,  # (nq*bq, d) padded queries (or one query shard)
    x: jax.Array,  # (nk*bk, d) padded dataset (or one dataset shard)
    m: jax.Array,  # true GLOBAL dataset row count (traced)
    scalar: jax.Array,  # task scalar: eps^2 (dbscan) / 1/(2h^2) (kde) / 0
    col_offset: jax.Array,  # global index of x's first row (traced int32)
    row_offset: jax.Array,  # global index of xq's first row (traced int32)
    *,
    task: str,
    bq: int,
    bk: int,
    use_top_k: bool,
):
    """The tiled pairwise scan over one (query shard, dataset shard) pair.

    This is the shared body behind the sequential ``_fused_scan`` (offsets
    0) and the split/mesh paths in ``analytics.split`` (each shard passes
    its global offsets, producing a PARTIAL carry that merges exactly —
    see the split-scan contract in ``analytics/README.md``).

    Returns per task:
      knn    -> (nn_idx  (nq*bq,) int32,  nn_d2  (nq*bq,) float32)
      dbscan -> (counts  (nq*bq,) int32,  packed (nq*bq, nk*bk/32) uint32)
      kde    -> (sums (nq*bq,) f32, comps (nq*bq,) f32)  [compensated pair;
                caller folds ``sums + comps`` in float64 and divides by m]
    """
    mq_pad, d = xq.shape
    nk = x.shape[0] // bk
    sq_x = jnp.sum(x * x, axis=1)

    def q_body(i, out):
        a = i * bq
        xqt = lax.dynamic_slice(xq, (a, 0), (bq, d))
        sq_q = jnp.sum(xqt * xqt, axis=1, keepdims=True)
        # kNN queries ARE the dataset rows, so the global query index doubles
        # as the self column to exclude (kde/dbscan never read `rows`)
        rows = row_offset + a + jnp.arange(bq)

        if task == "knn":

            def k_body(j, carry):
                d2, cols = _tile_d2(xqt, sq_q, x, sq_x, j, bk, m, col_offset)
                return _knn_tile(carry, d2, cols, rows, use_top_k)

            init = (
                jnp.full((bq,), jnp.inf, jnp.float32),
                jnp.zeros((bq,), jnp.int32),
            )
            best_d2, best_idx = lax.fori_loop(0, nk, k_body, init)
            idx_out, d2_out = out
            return (
                lax.dynamic_update_slice(idx_out, best_idx, (a,)),
                lax.dynamic_update_slice(d2_out, best_d2, (a,)),
            )

        if task == "dbscan":

            def k_body(j, carry):
                counts, packed_row = carry
                d2, _cols = _tile_d2(
                    xqt, sq_q, x, sq_x, j, bk, m, col_offset
                )
                mask = d2 <= scalar  # self included (d2=0); host drops it
                counts = counts + jnp.sum(mask, axis=1, dtype=jnp.int32)
                packed_row = lax.dynamic_update_slice(
                    packed_row, _pack_bits(mask), (0, j * (bk // 32))
                )
                return counts, packed_row

            init = (
                jnp.zeros((bq,), jnp.int32),
                jnp.zeros((bq, nk * (bk // 32)), jnp.uint32),
            )
            counts, packed_row = lax.fori_loop(0, nk, k_body, init)
            counts_out, packed_out = out
            return (
                lax.dynamic_update_slice(counts_out, counts, (a,)),
                lax.dynamic_update_slice(packed_out, packed_row, (a, 0)),
            )

        # kde: compensated (Neumaier) running exp-sum. A plain f32 running
        # sum swallows low-order tile contributions once the accumulator
        # grows (and makes per-shard partials depend on the split point);
        # carrying the rounding error in a second f32 keeps ~f64 accuracy
        # while staying in the backend's native width (jax x64 is off, so a
        # float64 carry would silently degrade back to f32 anyway). Padded
        # columns are masked, not exp(-inf), so a zero bandwidth scalar can
        # never produce inf*0 = nan.
        def k_body(j, carry):
            acc, comp = carry
            d2, cols = _tile_d2(xqt, sq_q, x, sq_x, j, bk, m, col_offset)
            e = jnp.exp(-jnp.maximum(d2, 0.0) * scalar)
            e = jnp.where(cols[None, :] < m, e, 0.0)
            t = jnp.sum(e, axis=1)
            s = acc + t
            comp = comp + jnp.where(
                jnp.abs(acc) >= jnp.abs(t),
                (acc - s) + t,  # low-order bits of t lost in the add
                (t - s) + acc,  # (tile sum larger: symmetric form)
            )
            return s, comp

        kinit = (
            jnp.zeros((bq,), jnp.float32),
            jnp.zeros((bq,), jnp.float32),
        )
        sums, comps = lax.fori_loop(0, nk, k_body, kinit)
        sums_out, comps_out = out
        return (
            lax.dynamic_update_slice(sums_out, sums, (a,)),
            lax.dynamic_update_slice(comps_out, comps, (a,)),
        )

    if task == "knn":
        init = (
            jnp.zeros((mq_pad,), jnp.int32),
            jnp.zeros((mq_pad,), jnp.float32),
        )
    elif task == "dbscan":
        init = (
            jnp.zeros((mq_pad,), jnp.int32),
            jnp.zeros((mq_pad, (x.shape[0] // bk) * (bk // 32)), jnp.uint32),
        )
    else:
        init = (
            jnp.zeros((mq_pad,), jnp.float32),
            jnp.zeros((mq_pad,), jnp.float32),
        )
    return lax.fori_loop(0, mq_pad // bq, q_body, init)


@partial(
    jax.jit,
    static_argnames=("task", "bq", "bk", "use_top_k"),
)
def _fused_scan(
    xq: jax.Array,
    x: jax.Array,
    m: jax.Array,
    scalar: jax.Array,
    task: str,
    bq: int,
    bk: int,
    use_top_k: bool,
):
    """The whole SEQUENTIAL pairwise scan as one device computation (the
    split/mesh variants live in ``analytics.split``; output contract is
    ``_scan_core``'s with both offsets zero)."""
    zero = jnp.int32(0)
    return _scan_core(
        xq, x, m, scalar, zero, zero,
        task=task, bq=bq, bk=bk, use_top_k=use_top_k,
    )


def _clamp_block(block: int, rows: int, word: int = 64) -> int:
    """Validate and shrink a tile to the data: a 300-row input under the
    default 1024 block would otherwise pad to (and scan) 1024 rows.

    EVERY accepted block is quantized to a multiple of ``word`` — including
    caller-supplied ones, which are rounded UP. The bitmask packer reshapes
    dataset tiles to ``(bq, bk // 32, 32)``, so a bk like 100 used to crash
    with an opaque reshape error deep inside jit; now it runs at 128, and a
    non-positive/non-integral block fails here with a clear message."""
    from repro.core.bucketing import round_up

    if block != int(block) or int(block) < 1:
        raise ValueError(
            f"block size must be a positive integer, got {block!r}; "
            f"pairwise tiles are quantized to multiples of {word} "
            "(the packed-bitmask word granularity)"
        )
    return max(word, min(round_up(int(block), word), round_up(rows, word)))


def _prepare(
    x: np.ndarray,
    queries: np.ndarray | None,
    bq: int,
    bk: int,
    bucket: ShapeBucketCache,
):
    """Host-side f32 conversion + tile padding through the shared buckets."""
    x = np.ascontiguousarray(x, dtype=np.float32)
    q = x if queries is None else np.ascontiguousarray(
        queries, dtype=np.float32
    )
    mq_pad = bucket.bucket_tile_rows(q.shape[0], bq)
    mk_pad = bucket.bucket_tile_rows(x.shape[0], bk)
    xk_pad = _pad_rows(x, mk_pad)
    # self-scan with matching pads: ONE padded copy serves both jit args
    # (no second host copy or device transfer of the same bytes)
    xq_pad = xk_pad if queries is None and mq_pad == mk_pad else _pad_rows(
        q, mq_pad
    )
    return x, q, xq_pad, xk_pad


def _default_top_k(m: int) -> bool:
    from repro.analytics.knn import _use_top_k

    return _use_top_k() and m >= 2


def pairwise_knn(
    x: np.ndarray,
    block_q: int = DEFAULT_BLOCK,
    block_k: int = DEFAULT_BLOCK,
    *,
    use_kernels: bool = False,
    use_top_k: bool | None = None,
    bucket: ShapeBucketCache | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Nearest OTHER row per row of ``x``: (indices int32, squared dists).

    ``use_top_k=None`` picks the measured per-backend reduction (top_k(2)
    off-CPU, mask+argmin on CPU); tests pass an explicit bool to exercise
    both on one backend."""
    bucket = bucket or DEFAULT_BUCKETS
    m = x.shape[0]
    if use_top_k is None:
        use_top_k = _default_top_k(m)
    block_q = _clamp_block(block_q, m)
    block_k = _clamp_block(block_k, m)
    x, _q, xq_pad, xk_pad = _prepare(x, None, block_q, block_k, bucket)
    if use_kernels and _kernel_backend_live():
        from repro.kernels.pairwise_reduce.ops import pairwise_knn_reduce

        idx, d2 = pairwise_knn_reduce(xq_pad, xk_pad, m)
    else:
        idx, d2 = _fused_scan(
            jnp.asarray(xq_pad),
            jnp.asarray(xk_pad),
            jnp.int32(m),
            jnp.float32(0.0),
            task="knn",
            bq=block_q,
            bk=block_k,
            use_top_k=use_top_k,
        )
    idx, d2 = jax.device_get((idx, d2))  # the single transfer
    return np.asarray(idx)[:m], np.asarray(d2)[:m]


def pairwise_dbscan(
    x: np.ndarray,
    eps: float,
    block_q: int = DEFAULT_BLOCK,
    block_k: int = DEFAULT_BLOCK,
    *,
    use_kernels: bool = False,
    bucket: ShapeBucketCache | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Eps-ball scan: (degree counts int32 (m,), packed uint32 (m, w)).

    Counts and bits INCLUDE the self column (d2=0 is always within eps);
    ``unpack_neighbors`` drops self when decoding. Bit layout is
    little-endian: dataset column c lives at word c//32, bit c%32."""
    bucket = bucket or DEFAULT_BUCKETS
    m = x.shape[0]
    # clamped tiles are 64-quantized, so packed words always divide the
    # dataset tile (bk % 32 == 0)
    block_q = _clamp_block(block_q, m)
    block_k = _clamp_block(block_k, m)
    x, _q, xq_pad, xk_pad = _prepare(x, None, block_q, block_k, bucket)
    # float32(eps * eps) — double-precision square, then ONE rounding —
    # matches the legacy path's jnp.float32(eps * eps) exactly;
    # float32(eps)**2 rounds twice and lands 1 ulp off for ~half of all
    # eps values, silently breaking eps-boundary parity
    eps2 = np.float32(float(eps) * float(eps))
    if use_kernels and _kernel_backend_live():
        from repro.kernels.pairwise_reduce.ops import pairwise_dbscan_reduce

        counts, packed = pairwise_dbscan_reduce(xq_pad, xk_pad, m, eps2)
    else:
        counts, packed = _fused_scan(
            jnp.asarray(xq_pad),
            jnp.asarray(xk_pad),
            jnp.int32(m),
            jnp.float32(eps2),
            task="dbscan",
            bq=block_q,
            bk=block_k,
            use_top_k=False,
        )
    counts, packed = jax.device_get((counts, packed))
    return np.asarray(counts)[:m], np.asarray(packed)[:m]


def pairwise_kde(
    x: np.ndarray,
    queries: np.ndarray | None = None,
    bandwidth: float = 1.0,
    block_q: int = DEFAULT_BLOCK,
    block_k: int = DEFAULT_BLOCK,
    *,
    use_kernels: bool = False,
    bucket: ShapeBucketCache | None = None,
) -> np.ndarray:
    """Mean Gaussian kernel density of ``x`` at each query row (unnormalized,
    matching the legacy operator: mean over the m reference points)."""
    bucket = bucket or DEFAULT_BUCKETS
    m = x.shape[0]
    mq = x.shape[0] if queries is None else queries.shape[0]
    block_q = _clamp_block(block_q, mq)
    block_k = _clamp_block(block_k, m)
    x, _q, xq_pad, xk_pad = _prepare(x, queries, block_q, block_k, bucket)
    inv = np.float32(1.0 / (2.0 * bandwidth * bandwidth))
    if use_kernels and _kernel_backend_live():
        from repro.kernels.pairwise_reduce.ops import pairwise_kde_reduce

        sums, comps = pairwise_kde_reduce(xq_pad, xk_pad, m, inv)
    else:
        sums, comps = _fused_scan(
            jnp.asarray(xq_pad),
            jnp.asarray(xk_pad),
            jnp.int32(m),
            jnp.float32(inv),
            task="kde",
            bq=block_q,
            bk=block_k,
            use_top_k=False,
        )
    sums, comps = jax.device_get((sums, comps))
    return kde_from_compensated(
        np.asarray(sums)[None, :mq], np.asarray(comps)[None, :mq], m
    )


def kde_from_compensated(
    sums: np.ndarray, comps: np.ndarray, m: int
) -> np.ndarray:
    """Fold (S, mq) per-shard compensated exp-sum pairs into densities.

    The device carries (sum, comp) in f32; the exact value of each partial
    is ``sum + comp``. Folding shards and the final mean in float64 on the
    host makes the result independent of the split point to ~f32 ulp (the
    shard combine is the associative piece; see analytics/README.md)."""
    total = (sums.astype(np.float64) + comps.astype(np.float64)).sum(axis=0)
    return (total / float(m)).astype(np.float32)


def unpack_neighbors(packed_row: np.ndarray, p: int, m: int) -> np.ndarray:
    """Decode one packed bitmask row into sorted neighbor indices, self
    excluded — the single-row primitive (``NeighborDecoder`` amortizes the
    unpack over row chunks for the BFS)."""
    bits = np.unpackbits(
        np.ascontiguousarray(packed_row).view(np.uint8), bitorder="little"
    )[:m]
    nbrs = np.flatnonzero(bits)
    return nbrs[nbrs != p]


class NeighborDecoder:
    """Lazy chunked two-level decoder for the packed eps-ball bitmasks.

    The DBSCAN BFS asks for one row at a time; decoding per row (one
    ``np.unpackbits`` + ``np.flatnonzero`` call each) pays Python/numpy
    call overhead m times, and unpacking whole chunks to a byte matrix
    re-creates the O(m^2) host scan the packing was meant to kill. Instead,
    the first touch of a row decodes its whole CHUNK sparsely:

    1. clear the chunk's self bits IN THE PACKED DOMAIN (one vectorized
       word update — the self bit is always set, d2 = 0 <= eps^2);
    2. ``np.flatnonzero`` over the packed WORDS — a 32x smaller scan than
       the unpacked matrix;
    3. ``np.unpackbits`` only the nonzero words and turn bit positions
       into global column indices with vectorized shift/mask arithmetic;
    4. one ``np.split`` at the per-row counts (``np.bincount`` over the
       word rows) hands out per-row neighbor arrays, ascending — the exact
       arrays the legacy per-row ``np.nonzero`` produced.

    Cost per chunk: O(words + set bits), not O(m * chunk) — dense
    neighborhoods decode in a few C passes, sparse ones touch almost
    nothing, and untouched chunks are never decoded at all."""

    def __init__(self, packed: np.ndarray, m: int, chunk: int = 1024) -> None:
        self.packed = packed
        self.m = m
        self.chunk = max(int(chunk), 1)
        self._chunks: dict[int, list[np.ndarray]] = {}

    def _decode_chunk(self, c: int) -> list[np.ndarray]:
        a = c * self.chunk
        b = min(a + self.chunk, self.m)
        rows = b - a
        words = np.array(self.packed[a:b])  # copy: self bits cleared below
        wpr = words.shape[1]
        g = np.arange(a, b)
        words[np.arange(rows), g // 32] &= ~np.left_shift(
            np.uint32(1), (g % 32).astype(np.uint32)
        )
        flat = words.ravel()
        wnz = np.flatnonzero(flat)  # the 32x-smaller scan
        bits = np.unpackbits(
            np.ascontiguousarray(flat[wnz]).view(np.uint8),
            bitorder="little",
        )
        pos = np.flatnonzero(bits)
        wloc = pos >> 5  # which nonzero word each set bit belongs to
        cols = (wnz[wloc] % wpr) * 32 + (pos & 31)
        counts = np.bincount(wnz[wloc] // wpr, minlength=rows)
        return np.split(cols, np.cumsum(counts)[:-1])

    def __call__(self, p: int) -> np.ndarray:
        c = p // self.chunk
        got = self._chunks.get(c)
        if got is None:
            got = self._chunks[c] = self._decode_chunk(c)
        return got[p - c * self.chunk]
