"""Split-dataset pairwise reduction: flash-decoding-style fan-out.

The fused engine (``analytics.pairwise``) scans dataset tiles SEQUENTIALLY
inside one dispatch, so single-query-batch latency is O(m) no matter how
many devices exist. This module applies the flash-decoding trick (mirror of
``kernels/flash_decode``: split the KV/dataset axis into parallel partials
with carried merge state, then a small exact combine):

* ``knn``    — per-shard online (min-d2, argmin) with GLOBAL column indices;
               the cross-shard merge is strict-``<`` in shard order, so the
               first-occurrence tie-break of the sequential scan is
               preserved bit-for-bit (``merge_knn_partials``).
* ``dbscan`` — per-shard eps-ball counts (summed: ints are associative) and
               packed uint32 bitmask SEGMENTS concatenated in shard order;
               shard boundaries are tile-aligned (multiples of bk, hence of
               32), so the concatenated words ARE the sequential layout.
* ``kde``    — per-shard compensated (sum, comp) f32 exp-sum pairs, folded
               in float64 on the host, so the result is independent of the
               split point to ~f32 ulp.

Layered twice:

1. **Single-device split** (``fanout="xla"``): shards run as one batched
   XLA computation (``vmap`` over the shard axis — still ONE dispatch and
   ONE device->host transfer, preserving the engine invariants), with a
   grid-parallel ``kernels/pairwise_reduce`` variant behind
   ``use_kernels``. On a multi-core XLA:CPU / accelerator backend the
   shard axis is embarrassingly parallel; on this container's one core it
   is a correctness/abstraction win only (see the bench ``cores=`` caveat).
2. **Mesh fan-out** (``fanout="mesh"``): ``shard_map`` over dataset shards
   x query tiles — every device computes one (query-shard, dataset-shard)
   partial, and the same host merge combines them. Single-query latency
   then scales DOWN with device count, not just throughput.

Both layers produce the SAME partial contract, merged by the same three
``merge_*_partials`` primitives — the associativity property the tests pin
(``tests/test_split_scan.py``).
"""

from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.bucketing import DEFAULT_BUCKETS, ShapeBucketCache, round_up
from repro.analytics.pairwise import (
    DEFAULT_BLOCK,
    _clamp_block,
    _default_top_k,
    _kernel_backend_live,
    _pad_rows,
    _scan_core,
    kde_from_compensated,
)

__all__ = [
    "split_pairwise_knn",
    "split_pairwise_dbscan",
    "split_pairwise_kde",
    "merge_knn_partials",
    "merge_dbscan_partials",
    "merge_kde_partials",
]


# --------------------------------------------------------------- merges
# Host-side, numpy, EXACT (the carries are associative): these three
# functions are the whole combine step, shared by the vmap, kernel, and
# shard_map layers — and exercised directly by the property tests.


def merge_knn_partials(
    idx_parts: np.ndarray, d2_parts: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """(S, mq) per-shard argmin partials -> global (idx, d2).

    ``np.argmin`` over the shard axis keeps the LOWEST shard on d2 ties;
    each shard's own winner is its first-occurrence (lowest-column) min, so
    the composition picks the globally lowest column index among minima —
    exactly the sequential scan's strict-``<`` tie-break, bit-for-bit."""
    sel = np.argmin(d2_parts, axis=0)
    ar = np.arange(d2_parts.shape[1])
    return (
        np.ascontiguousarray(idx_parts[sel, ar]).astype(np.int32),
        np.ascontiguousarray(d2_parts[sel, ar]),
    )


def merge_dbscan_partials(
    count_parts: np.ndarray, packed_parts: np.ndarray, words: int | None = None
) -> tuple[np.ndarray, np.ndarray]:
    """(S, mq) counts + (S, mq, w_s) packed segments -> global (counts, packed).

    Counts are integer sums (associative, exact). Packed segments
    concatenate in shard order along the word axis; because every shard
    holds a whole number of bk-tiles (bk % 32 == 0), word w of shard s is
    global word s*w_s + w — the sequential layout, no bit shifting needed.
    ``words`` trims trailing all-zero padding words to the sequential
    width, so split and sequential outputs compare bit-identical."""
    counts = count_parts.sum(axis=0, dtype=np.int64).astype(np.int32)
    packed = np.ascontiguousarray(
        np.concatenate(list(packed_parts), axis=1)
    )
    if words is not None:
        packed = np.ascontiguousarray(packed[:, :words])
    return counts, packed


def merge_kde_partials(
    sum_parts: np.ndarray, comp_parts: np.ndarray, m: int
) -> np.ndarray:
    """(S, mq) compensated pairs -> densities; float64 fold (see
    ``pairwise.kde_from_compensated``)."""
    return kde_from_compensated(sum_parts, comp_parts, m)


# ------------------------------------------------------- single-device split


@partial(
    jax.jit, static_argnames=("task", "bq", "bk", "use_top_k", "shards")
)
def _split_scan(
    xq: jax.Array,  # (mq_pad, d) padded queries, shared by every shard
    x_sh: jax.Array,  # (shards, shard_rows, d) tile-aligned dataset shards
    m: jax.Array,  # true GLOBAL dataset row count (traced)
    scalar: jax.Array,
    task: str,
    bq: int,
    bk: int,
    use_top_k: bool,
    shards: int,
):
    """All shard partials as ONE batched device computation (vmap over the
    shard axis: the shards are data-parallel inside a single dispatch, so
    the engine's one-dispatch/one-transfer invariants survive the split)."""
    shard_rows = x_sh.shape[1]
    offsets = jnp.arange(shards, dtype=jnp.int32) * shard_rows
    zero = jnp.int32(0)

    def one(xs, off):
        return _scan_core(
            xq, xs, m, scalar, off, zero,
            task=task, bq=bq, bk=bk, use_top_k=use_top_k,
        )

    return jax.vmap(one)(x_sh, offsets)


def _split_prepare(
    x: np.ndarray,
    queries: np.ndarray | None,
    shards: int,
    bq: int,
    bk: int,
    bucket: ShapeBucketCache,
):
    """Pad queries to the sequential bucket and the dataset to ``shards``
    equal tile-aligned shards covering at least the sequential pad.

    Shard size is a whole number of bk-tiles: ties, eps masks, and packed
    words then land on exactly the same tile boundaries as the sequential
    scan, which is what makes the merges bit-exact. Fully-padded trailing
    shards (m < shards * shard_rows) contribute inert partials (+inf d2,
    zero counts/sums) that can never win a merge."""
    x32 = np.ascontiguousarray(x, dtype=np.float32)
    q32 = x32 if queries is None else np.ascontiguousarray(
        queries, dtype=np.float32
    )
    mq_pad = bucket.bucket_tile_rows(q32.shape[0], bq)
    mk_pad = bucket.bucket_tile_rows(x32.shape[0], bk)
    nk = mk_pad // bk
    tiles_per_shard = -(-nk // shards)
    shard_rows = tiles_per_shard * bk
    xq_pad = _pad_rows(q32, mq_pad)
    x_sh = _pad_rows(x32, shards * shard_rows).reshape(
        shards, shard_rows, x32.shape[1]
    )
    return xq_pad, x_sh, mk_pad


# ------------------------------------------------------------ mesh fan-out


@lru_cache(maxsize=64)
def _mesh_fn(
    devices: tuple,
    q_shards: int,
    d_shards: int,
    task: str,
    bq: int,
    bk: int,
    use_top_k: bool,
):
    """Compiled shard_map fan-out over a (q_shards, d_shards) device mesh.

    Every device runs ``_scan_core`` on its (query shard, dataset shard)
    pair with global offsets from its mesh coordinates; outputs reassemble
    so the host sees d_shards partials in shard order — the same contract
    the single-device split produces, merged by the same primitives."""
    mesh = Mesh(
        np.asarray(devices, dtype=object).reshape(q_shards, d_shards),
        ("q", "d"),
    )

    def call(xq_pad, x_pad, m, scalar):
        lq = xq_pad.shape[0] // q_shards
        lk = x_pad.shape[0] // d_shards

        def local(xq_l, x_l, m_l, scalar_l):
            row0 = (lax.axis_index("q") * lq).astype(jnp.int32)
            col0 = (lax.axis_index("d") * lk).astype(jnp.int32)
            outs = _scan_core(
                xq_l, x_l, m_l, scalar_l, col0, row0,
                task=task, bq=bq, bk=bk, use_top_k=use_top_k,
            )
            if task == "dbscan":
                counts, packed = outs
                # counts gain a leading shard axis; packed keeps its word
                # axis on "d" so the global array concatenates segments in
                # dataset-shard order (the sequential word layout)
                return counts[None, :], packed
            return tuple(o[None, :] for o in outs)

        out_specs = (
            (P("d", "q"), P("q", "d"))
            if task == "dbscan"
            else (P("d", "q"), P("d", "q"))
        )
        return shard_map(
            local,
            mesh=mesh,
            in_specs=(P("q", None), P("d", None), P(), P()),
            out_specs=out_specs,
            check_vma=False,
        )(xq_pad, x_pad, m, scalar)

    return jax.jit(call)


def _mesh_prepare(
    x: np.ndarray,
    queries: np.ndarray | None,
    q_shards: int,
    d_shards: int,
    bq: int,
    bk: int,
    bucket: ShapeBucketCache,
):
    """Pad so every mesh coordinate gets whole tiles: queries to a multiple
    of q_shards*bq, dataset to a multiple of d_shards*bk (>= the sequential
    bucket, so trims match the sequential outputs)."""
    x32 = np.ascontiguousarray(x, dtype=np.float32)
    q32 = x32 if queries is None else np.ascontiguousarray(
        queries, dtype=np.float32
    )
    mq_pad = round_up(bucket.bucket_tile_rows(q32.shape[0], bq), q_shards * bq)
    mk_pad_seq = bucket.bucket_tile_rows(x32.shape[0], bk)
    mk_pad = round_up(mk_pad_seq, d_shards * bk)
    return _pad_rows(q32, mq_pad), _pad_rows(x32, mk_pad), mk_pad_seq


def _resolve_fanout(fanout: str, devices) -> tuple[str, list]:
    """``fanout="mesh"`` needs >1 device to mean anything; degrade to the
    single-device split (same results, same merge) instead of failing."""
    if fanout not in ("xla", "mesh"):
        raise ValueError(f"fanout must be 'xla' or 'mesh', got {fanout!r}")
    if fanout == "mesh":
        devs = list(devices) if devices is not None else list(jax.devices())
        if len(devs) > 1:
            return "mesh", devs
    return "xla", []


def _mesh_shape(mesh_shape, n: int) -> tuple[int, int]:
    if mesh_shape is None:
        return 1, n  # default: every device takes a dataset shard
    q_shards, d_shards = (int(mesh_shape[0]), int(mesh_shape[1]))
    if q_shards * d_shards != n or q_shards < 1 or d_shards < 1:
        raise ValueError(
            f"mesh_shape {mesh_shape} must factor the device count {n}"
        )
    return q_shards, d_shards


# ------------------------------------------------------------- public API


def split_pairwise_knn(
    x: np.ndarray,
    shards: int = 2,
    block_q: int = DEFAULT_BLOCK,
    block_k: int = DEFAULT_BLOCK,
    *,
    use_kernels: bool = False,
    use_top_k: bool | None = None,
    fanout: str = "xla",
    devices=None,
    mesh_shape: tuple[int, int] | None = None,
    bucket: ShapeBucketCache | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Split-dataset nearest-OTHER-row scan; bit-identical to
    ``pairwise_knn`` (indices AND squared distances) for every shard count."""
    bucket = bucket or DEFAULT_BUCKETS
    m = x.shape[0]
    shards = max(1, int(shards))
    if use_top_k is None:
        use_top_k = _default_top_k(m)
    bq = _clamp_block(block_q, m)
    bk = _clamp_block(block_k, m)

    fanout, devs = _resolve_fanout(fanout, devices)
    if fanout == "mesh":
        q_shards, d_shards = _mesh_shape(mesh_shape, len(devs))
        xq_pad, x_pad, _ = _mesh_prepare(
            x, None, q_shards, d_shards, bq, bk, bucket
        )
        fn = _mesh_fn(
            tuple(devs), q_shards, d_shards, "knn", bq, bk, bool(use_top_k)
        )
        idx_p, d2_p = jax.device_get(
            fn(xq_pad, x_pad, jnp.int32(m), jnp.float32(0.0))
        )
    elif use_kernels and _kernel_backend_live():
        from repro.kernels.pairwise_reduce.ops import pairwise_knn_split_reduce

        xq_pad, x_sh, _ = _split_prepare(x, None, shards, bq, bk, bucket)
        idx_p, d2_p = jax.device_get(
            pairwise_knn_split_reduce(
                xq_pad, x_sh.reshape(-1, x_sh.shape[2]), m, shards,
                block_q=bq, block_k=bk,
            )
        )
    else:
        xq_pad, x_sh, _ = _split_prepare(x, None, shards, bq, bk, bucket)
        idx_p, d2_p = jax.device_get(
            _split_scan(
                jnp.asarray(xq_pad),
                jnp.asarray(x_sh),
                jnp.int32(m),
                jnp.float32(0.0),
                task="knn",
                bq=bq,
                bk=bk,
                use_top_k=use_top_k,
                shards=shards,
            )
        )
    idx, d2 = merge_knn_partials(np.asarray(idx_p), np.asarray(d2_p))
    return idx[:m], d2[:m]


def split_pairwise_dbscan(
    x: np.ndarray,
    eps: float,
    shards: int = 2,
    block_q: int = DEFAULT_BLOCK,
    block_k: int = DEFAULT_BLOCK,
    *,
    use_kernels: bool = False,
    fanout: str = "xla",
    devices=None,
    mesh_shape: tuple[int, int] | None = None,
    bucket: ShapeBucketCache | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Split-dataset eps-ball scan; counts and packed bitmask rows are
    bit-identical to ``pairwise_dbscan`` (same word layout, same width)."""
    bucket = bucket or DEFAULT_BUCKETS
    m = x.shape[0]
    shards = max(1, int(shards))
    bq = _clamp_block(block_q, m)
    bk = _clamp_block(block_k, m)
    eps2 = np.float32(float(eps) * float(eps))  # ONE rounding — see pairwise

    fanout, devs = _resolve_fanout(fanout, devices)
    if fanout == "mesh":
        q_shards, d_shards = _mesh_shape(mesh_shape, len(devs))
        xq_pad, x_pad, mk_pad_seq = _mesh_prepare(
            x, None, q_shards, d_shards, bq, bk, bucket
        )
        fn = _mesh_fn(tuple(devs), q_shards, d_shards, "dbscan", bq, bk, False)
        counts_p, packed = jax.device_get(
            fn(xq_pad, x_pad, jnp.int32(m), jnp.float32(eps2))
        )
        # the mesh path reassembles the packed words globally already —
        # only the counts still carry a shard axis to fold
        counts = (
            np.asarray(counts_p).sum(axis=0, dtype=np.int64).astype(np.int32)
        )
        packed = np.ascontiguousarray(
            np.asarray(packed)[:, : mk_pad_seq // 32]
        )
        return counts[:m], packed[:m]
    if use_kernels and _kernel_backend_live():
        from repro.kernels.pairwise_reduce.ops import (
            pairwise_dbscan_split_reduce,
        )

        xq_pad, x_sh, mk_pad_seq = _split_prepare(
            x, None, shards, bq, bk, bucket
        )
        counts_p, packed_p = jax.device_get(
            pairwise_dbscan_split_reduce(
                xq_pad, x_sh.reshape(-1, x_sh.shape[2]), m, eps2, shards,
                block_q=bq, block_k=bk,
            )
        )
    else:
        xq_pad, x_sh, mk_pad_seq = _split_prepare(
            x, None, shards, bq, bk, bucket
        )
        counts_p, packed_p = jax.device_get(
            _split_scan(
                jnp.asarray(xq_pad),
                jnp.asarray(x_sh),
                jnp.int32(m),
                jnp.float32(eps2),
                task="dbscan",
                bq=bq,
                bk=bk,
                use_top_k=False,
                shards=shards,
            )
        )
    counts, packed = merge_dbscan_partials(
        np.asarray(counts_p), np.asarray(packed_p), words=mk_pad_seq // 32
    )
    return counts[:m], packed[:m]


def split_pairwise_kde(
    x: np.ndarray,
    queries: np.ndarray | None = None,
    bandwidth: float = 1.0,
    shards: int = 2,
    block_q: int = DEFAULT_BLOCK,
    block_k: int = DEFAULT_BLOCK,
    *,
    use_kernels: bool = False,
    fanout: str = "xla",
    devices=None,
    mesh_shape: tuple[int, int] | None = None,
    bucket: ShapeBucketCache | None = None,
) -> np.ndarray:
    """Split-dataset Gaussian KDE; compensated shard partials folded in
    float64 make the densities split-point independent to ~f32 ulp."""
    bucket = bucket or DEFAULT_BUCKETS
    m = x.shape[0]
    mq = m if queries is None else queries.shape[0]
    shards = max(1, int(shards))
    bq = _clamp_block(block_q, mq)
    bk = _clamp_block(block_k, m)
    inv = np.float32(1.0 / (2.0 * bandwidth * bandwidth))

    fanout, devs = _resolve_fanout(fanout, devices)
    if fanout == "mesh":
        q_shards, d_shards = _mesh_shape(mesh_shape, len(devs))
        xq_pad, x_pad, _ = _mesh_prepare(
            x, queries, q_shards, d_shards, bq, bk, bucket
        )
        fn = _mesh_fn(tuple(devs), q_shards, d_shards, "kde", bq, bk, False)
        sums_p, comps_p = jax.device_get(
            fn(xq_pad, x_pad, jnp.int32(m), jnp.float32(inv))
        )
    elif use_kernels and _kernel_backend_live():
        from repro.kernels.pairwise_reduce.ops import pairwise_kde_split_reduce

        xq_pad, x_sh, _ = _split_prepare(x, queries, shards, bq, bk, bucket)
        sums_p, comps_p = jax.device_get(
            pairwise_kde_split_reduce(
                xq_pad, x_sh.reshape(-1, x_sh.shape[2]), m, inv, shards,
                block_q=bq, block_k=bk,
            )
        )
    else:
        xq_pad, x_sh, _ = _split_prepare(x, queries, shards, bq, bk, bucket)
        sums_p, comps_p = jax.device_get(
            _split_scan(
                jnp.asarray(xq_pad),
                jnp.asarray(x_sh),
                jnp.int32(m),
                jnp.float32(inv),
                task="kde",
                bq=bq,
                bk=bk,
                use_top_k=False,
                shards=shards,
            )
        )
    dens = merge_kde_partials(
        np.asarray(sums_p)[:, :mq], np.asarray(comps_p)[:, :mq], m
    )
    return dens[:mq]
