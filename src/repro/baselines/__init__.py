"""Baseline dimensionality reduction operators from the paper's comparison
(§2.3): PAA, FFT, full-SVD PCA with binary search, JL random projection."""

from repro.baselines.dwt import dwt_transform, dwt_min_k  # noqa: F401
from repro.baselines.fft import fft_transform, fft_min_k  # noqa: F401
from repro.baselines.jl import jl_min_k, jl_transform  # noqa: F401
from repro.baselines.paa import paa_transform, paa_min_k  # noqa: F401
from repro.baselines.svd_pca import (  # noqa: F401
    pca_min_k,
    svd_binary_search,
    svd_halko_binary_search,
)
