"""Haar discrete wavelet transform — another baseline from the paper's
source study (Ding et al. 2008 compared DWT among the eight methods).

The orthonormal Haar transform is an isometry; coefficients ordered
coarse-to-fine give a NESTED representation (like FFT/PCA prefixes), so
truncation is contractive and the min-k search is a single prefix pass.
Inputs are zero-padded to the next power of two (padding preserves L2).
"""

from __future__ import annotations

import numpy as np

from repro.core.tlb import nested_min_k, sample_pairs


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def haar_expansion(x: np.ndarray) -> np.ndarray:
    """(m, d) -> (m, 2^ceil(log2 d)) orthonormal Haar coefficients, ordered
    [approximation | detail levels coarse -> fine]."""
    x = np.asarray(x, dtype=np.float64)
    m, d = x.shape
    n = _next_pow2(d)
    buf = np.zeros((m, n), dtype=np.float64)
    buf[:, :d] = x
    out_details = []
    cur = buf
    while cur.shape[1] > 1:
        even, odd = cur[:, 0::2], cur[:, 1::2]
        approx = (even + odd) / np.sqrt(2.0)
        detail = (even - odd) / np.sqrt(2.0)
        out_details.append(detail)
        cur = approx
    # coarse-to-fine: final approximation, then details from coarsest level
    cols = [cur] + out_details[::-1]
    return np.concatenate(cols, axis=1).astype(np.float32)


def dwt_transform(x: np.ndarray, k: int) -> np.ndarray:
    """First k Haar dims (coarsest first)."""
    return haar_expansion(x)[:, : max(k, 1)]


def dwt_min_k(x: np.ndarray, target: float, n_pairs: int = 800,
              seed: int = 0) -> int:
    """Smallest k achieving the TLB target (single prefix pass)."""
    rng = np.random.default_rng(seed)
    pairs = sample_pairs(x.shape[0], n_pairs, rng)
    return nested_min_k(x, haar_expansion(x), target, pairs)[0]
