"""Fourier (FFT) dimensionality reduction — paper baseline (Faloutsos et al.).

Orthonormal DFT is an isometry (Parseval), so keeping any subset of
coefficients is contractive. We expand the rfft of a real series into a REAL
coefficient vector ordered by frequency:

    [Re X_0, sqrt(2) Re X_1, sqrt(2) Im X_1, sqrt(2) Re X_2, ...,  (Nyquist)]

whose prefix of length k is the k-dim FFT representation; the full expansion
preserves L2 norms exactly, so prefixes lower-bound distances (TLB <= 1).
Runtime O(m d log d).
"""

from __future__ import annotations

import numpy as np

from repro.core.tlb import (
    nested_min_k,
    sample_pairs,
    transform_tlb_sampled,
)


def fft_real_expansion(x: np.ndarray) -> np.ndarray:
    """(m, d) -> (m, d) real orthonormal Fourier coefficient expansion."""
    x = np.asarray(x, dtype=np.float64)
    m, d = x.shape
    cf = np.fft.rfft(x, axis=1, norm="ortho")  # (m, d//2+1)
    cols = [cf[:, 0].real]  # DC term (weight 1)
    n_half = cf.shape[1]
    for f in range(1, n_half):
        if d % 2 == 0 and f == n_half - 1:
            cols.append(cf[:, f].real)  # Nyquist term (weight 1)
        else:
            cols.append(np.sqrt(2.0) * cf[:, f].real)
            cols.append(np.sqrt(2.0) * cf[:, f].imag)
    out = np.stack(cols, axis=1)[:, :d]
    return out.astype(np.float32)


def fft_transform(x: np.ndarray, k: int) -> np.ndarray:
    """First k real Fourier dims (lowest frequencies first)."""
    return fft_real_expansion(x)[:, : max(k, 1)]


def fft_min_k(
    x: np.ndarray, target: float, n_pairs: int = 800, seed: int = 0
) -> int:
    """Smallest k achieving the TLB target. Coefficients are nested, so one
    expansion + prefix cumsum answers every k at once."""
    rng = np.random.default_rng(seed)
    pairs = sample_pairs(x.shape[0], n_pairs, rng)
    return nested_min_k(x, fft_real_expansion(x), target, pairs)[0]


def fft_tlb_sampled(
    x: np.ndarray, k: int, pairs: np.ndarray
) -> tuple[float, float, float]:
    return transform_tlb_sampled(x, fft_transform(x, k), pairs, 0.95)
