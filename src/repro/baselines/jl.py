"""Johnson-Lindenstrauss Gaussian random projection (Achlioptas 2001).

Data-independent baseline from the paper's introduction: preserves pairwise
distances only in expectation (NOT contractive per-pair), and the JL lemma's
worst-case dimension is what PCA beats by 46x on structured data (§1).
"""

from __future__ import annotations

import numpy as np


def jl_transform(x: np.ndarray, k: int, seed: int = 0) -> np.ndarray:
    """(m, d) -> (m, k) Gaussian random projection scaled by 1/sqrt(k)."""
    rng = np.random.default_rng(seed)
    d = x.shape[1]
    t = rng.normal(size=(d, k)).astype(np.float32) / np.sqrt(k)
    return np.asarray(x, dtype=np.float32) @ t


def jl_dimension_bound(m: int, eps: float) -> int:
    """JL lemma worst-case embedding dimension for m points at distortion eps,
    in the k >= ln(m)/eps^2 form the paper quotes (ln(5000)/0.25^2 ~= 137,
    the ECG example of §1)."""
    return int(np.ceil(np.log(m) / eps**2))
