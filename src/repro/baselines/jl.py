"""Johnson-Lindenstrauss Gaussian random projection (Achlioptas 2001).

Data-independent baseline from the paper's introduction: preserves pairwise
distances only in expectation (NOT contractive per-pair), and the JL lemma's
worst-case dimension is what PCA beats by 46x on structured data (§1).
"""

from __future__ import annotations

import numpy as np


def jl_operator(d: int, k: int, seed: int = 0) -> np.ndarray:
    """The (d, k) Gaussian projection matrix scaled by 1/sqrt(k)."""
    rng = np.random.default_rng(seed)
    # divide before the float32 cast: a float32-array / python-float would
    # silently promote the operator (and every transform) back to float64
    return (rng.normal(size=(d, k)) / np.sqrt(k)).astype(np.float32)


def jl_transform(x: np.ndarray, k: int, seed: int = 0) -> np.ndarray:
    """(m, d) -> (m, k) Gaussian random projection scaled by 1/sqrt(k)."""
    return np.asarray(x, dtype=np.float32) @ jl_operator(x.shape[1], k, seed)


def jl_min_k(
    x: np.ndarray, target: float, n_pairs: int = 800, seed: int = 0
) -> int:
    """Smallest k whose sampled mean distance ratio reaches ``target``.

    JL is not contractive (ratios straddle 1), but the mean ratio
    E[chi_k / sqrt(k)] ~= 1 - 1/(4k) grows monotonically toward 1, so the
    same binary search the paper uses for PAA applies. Each probe redraws
    the legacy ``jl_transform`` matrix for that k (JL projections are not
    nested), keeping this exactly the data-independent baseline of §1."""
    from repro.core.tlb import sample_pairs, transform_min_k

    rng = np.random.default_rng(seed)
    pairs = sample_pairs(x.shape[0], n_pairs, rng)
    return transform_min_k(
        x, lambda a, k: jl_transform(a, k, seed), target, pairs, x.shape[1]
    )


def jl_dimension_bound(m: int, eps: float) -> int:
    """JL lemma worst-case embedding dimension for m points at distortion eps,
    in the k >= ln(m)/eps^2 form the paper quotes (ln(5000)/0.25^2 ~= 137,
    the ECG example of §1)."""
    return int(np.ceil(np.log(m) / eps**2))
