"""Piecewise Aggregate Approximation (Keogh et al. 2001) — paper baseline.

PAA splits each length-d series into k contiguous segments and represents each
segment by its mean. With per-segment sqrt(length) scaling the transform is
contractive (Jensen: L * mean^2 <= sum of squares), so TLB <= 1 holds exactly.
Runtime O(md) — the fastest method in the paper's comparison (Fig. 2).
"""

from __future__ import annotations

import numpy as np

from repro.core.tlb import sample_pairs, transform_min_k, transform_tlb_sampled


def _segments(d: int, k: int) -> list[tuple[int, int]]:
    """k near-equal contiguous segments covering [0, d)."""
    bounds = np.linspace(0, d, k + 1).round().astype(int)
    return [(bounds[s], bounds[s + 1]) for s in range(k) if bounds[s + 1] > bounds[s]]


def paa_transform(x: np.ndarray, k: int) -> np.ndarray:
    """(m, d) -> (m, k') lower-bounding PAA representation (k' <= k)."""
    x = np.asarray(x)
    d = x.shape[1]
    segs = _segments(d, min(k, d))
    cols = [
        x[:, a:b].mean(axis=1) * np.sqrt(float(b - a)) for a, b in segs
    ]
    return np.stack(cols, axis=1).astype(np.float32)


def paa_tlb_sampled(
    x: np.ndarray, k: int, pairs: np.ndarray
) -> tuple[float, float, float]:
    return transform_tlb_sampled(x, paa_transform(x, k), pairs, 0.95)


def paa_min_k(
    x: np.ndarray,
    target: float,
    n_pairs: int = 800,
    seed: int = 0,
) -> int:
    """Smallest segment count achieving the TLB target (binary search; PAA
    quality is monotone-ish in k as in the paper's study)."""
    rng = np.random.default_rng(seed)
    pairs = sample_pairs(x.shape[0], n_pairs, rng)
    return transform_min_k(x, paa_transform, target, pairs, x.shape[1])
