"""Non-sampled PCA baselines (paper §4.1 "Baselines"):

* ``svd_binary_search`` — PCA via full SVD over ALL the data, then binary
  search over k with the sampled-TLB evaluation (the paper's "SVD" baseline).
* ``svd_halko_binary_search`` — same but the basis comes from SVD-Halko over
  all the data (the paper's "SVD-Halko" baseline).
* ``oracle`` — PCA over the offline-precomputed minimum sample proportion that
  matches the full-SVD basis size (paper's "Oracle" baseline).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import halko as halko_mod
from repro.core import pca as pca_mod
from repro.core.basis_search import _binary_search
from repro.core.tlb import TLBEstimator
from repro.core.types import DropConfig
from repro.utils import Clock


@dataclass
class BaselineResult:
    v: np.ndarray
    mean: np.ndarray
    k: int
    tlb_mean: float
    runtime_s: float

    def transform(self, y: np.ndarray) -> np.ndarray:
        return (np.asarray(y) - self.mean) @ self.v


def _search_and_pack(
    x: np.ndarray, mean, v, cfg: DropConfig, clock: Clock, rng
) -> BaselineResult:
    est = TLBEstimator(
        x, jnp.asarray(v), rng, confidence=cfg.confidence, use_kernels=cfg.use_kernels
    )
    k, tlb_mean, _, _ = _binary_search(est, cfg.target_tlb, v.shape[1], cfg)
    k = max(k, 1)
    return BaselineResult(
        v=np.asarray(v[:, :k]),
        mean=np.asarray(mean),
        k=k,
        tlb_mean=tlb_mean,
        runtime_s=clock.elapsed(),
    )


def svd_binary_search(x: np.ndarray, cfg: DropConfig | None = None) -> BaselineResult:
    """Full-SVD PCA on all rows + binary search for min k (paper "SVD")."""
    cfg = cfg or DropConfig()
    clock = Clock()
    rng = np.random.default_rng(cfg.seed + 1)
    mean, v, _ = pca_mod.pca_fit_svd(jnp.asarray(x, dtype=jnp.float32))
    v.block_until_ready()
    return _search_and_pack(x, mean, v, cfg, clock, rng)


def svd_halko_binary_search(
    x: np.ndarray, cfg: DropConfig | None = None, rank: int | None = None
) -> BaselineResult:
    """SVD-Halko on all rows + binary search for min k (paper "SVD-Halko")."""
    cfg = cfg or DropConfig()
    clock = Clock()
    rng = np.random.default_rng(cfg.seed + 1)
    xs = jnp.asarray(x, dtype=jnp.float32)
    mean, c = pca_mod.center(xs)
    cap = rank or min(x.shape)
    v, _ = halko_mod.svd_halko(
        c,
        cap,
        jax.random.PRNGKey(cfg.seed),
        oversample=cfg.halko_oversample,
        power_iters=cfg.halko_power_iters,
        use_kernels=cfg.use_kernels,
    )
    v.block_until_ready()
    return _search_and_pack(x, mean, v, cfg, clock, rng)


def oracle(
    x: np.ndarray, proportion: float, cfg: DropConfig | None = None
) -> BaselineResult:
    """PCA over a precomputed minimal sample proportion (paper "Oracle")."""
    cfg = cfg or DropConfig()
    clock = Clock()
    rng = np.random.default_rng(cfg.seed + 1)
    m = x.shape[0]
    n = max(2, int(round(proportion * m)))
    idx = np.random.default_rng(cfg.seed).choice(m, size=n, replace=False)
    xs = jnp.asarray(x[idx], dtype=jnp.float32)
    mean, c = pca_mod.center(xs)
    cap = min(n, x.shape[1])
    v, _ = halko_mod.svd_halko(
        c, cap, jax.random.PRNGKey(cfg.seed),
        oversample=cfg.halko_oversample, power_iters=cfg.halko_power_iters,
        use_kernels=cfg.use_kernels,
    )
    v.block_until_ready()
    return _search_and_pack(x, mean, v, cfg, clock, rng)


def pca_min_k(
    x: np.ndarray, target: float, n_pairs: int = 800, seed: int = 0
) -> int:
    """Min PCA dimension for a TLB target via the all-prefix table (used by
    the measurement-study benchmark, Table 6)."""
    from repro.core.tlb import sample_pairs

    rng = np.random.default_rng(seed)
    pairs = sample_pairs(x.shape[0], n_pairs, rng)
    _, v, _ = pca_mod.pca_fit_svd(jnp.asarray(x, dtype=jnp.float32))
    xi, xj = x[pairs[:, 0]], x[pairs[:, 1]]
    vn = np.asarray(v, dtype=np.float64)
    dx2 = np.maximum(((xi - xj).astype(np.float64) ** 2).sum(-1), 1e-30)
    z = (xi - xj).astype(np.float64) @ vn
    cum = np.cumsum(z * z, axis=1)
    tlb_k = np.sqrt(np.minimum(cum / dx2[:, None], 1.0)).mean(axis=0)
    ok = np.nonzero(tlb_k >= target)[0]
    return int(ok[0]) + 1 if ok.size else x.shape[1]
