"""Sharded, atomic, mesh-agnostic checkpointing (no orbax/tensorstore here).

Layout:  <dir>/step_<N>/
             manifest.json        step, leaf index, shapes/dtypes, config id
             leaf_<i>.npy         one file per pytree leaf

Properties needed at 1000+-node scale, scaled to this container:
* ATOMIC: written to step_<N>.tmp, fsync'd, then renamed — a crash mid-write
  can never corrupt the restore point (restart scans for the newest manifest).
* MESH-AGNOSTIC: leaves are stored unsharded (here) / per-host shards (fleet);
  on restore they are device_put with shardings resolved against the LIVE
  mesh, so restarts may change topology (elastic re-mesh, fault/faults.py).
* SELF-DESCRIBING: the manifest carries the flattened treedef so a restore
  can validate structural compatibility before touching device memory.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import ml_dtypes
import numpy as np

# dtypes numpy can't natively (de)serialize -> stored as same-width uint views
_UINT_VIEW = {2: np.uint16, 1: np.uint8}


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _savable(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = str(arr.dtype)
    try:
        np.dtype(name)
        if arr.dtype.kind != "V":
            return arr, name
    except TypeError:
        pass
    return arr.view(_UINT_VIEW[arr.dtype.itemsize]), name


def _unsavable(arr: np.ndarray, name: str) -> np.ndarray:
    try:
        dt = np.dtype(name)
        if dt.kind != "V":
            return arr
    except TypeError:
        pass
    return arr.view(getattr(ml_dtypes, name))


def save(path: str, step: int, tree: Any, extra: dict | None = None) -> str:
    """Atomically write a checkpoint. Returns the final directory."""
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "leaves": [],
        "extra": extra or {},
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        view, dtype_name = _savable(arr)
        np.save(os.path.join(tmp, f"leaf_{i}.npy"), view)
        manifest["leaves"].append(
            {"shape": list(arr.shape), "dtype": dtype_name}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = []
    for name in os.listdir(path):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(path, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(
    path: str,
    like: Any,
    step: int | None = None,
    shardings: Any = None,
) -> tuple[Any, int]:
    """Restore into the structure of ``like``; place with ``shardings`` when
    given (resolved against the CURRENT mesh — elastic restarts)."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    like_leaves, treedef = _flatten(like)
    if manifest["n_leaves"] != len(like_leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, expected "
            f"{len(like_leaves)} — structure mismatch"
        )
    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None else None
    )
    out = []
    for i, ref in enumerate(like_leaves):
        arr = np.load(os.path.join(d, f"leaf_{i}.npy"))
        arr = _unsavable(arr, manifest["leaves"][i]["dtype"])
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"leaf {i}: shape {arr.shape} != {ref.shape}")
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.device_put(arr.astype(ref.dtype)))
    return treedef.unflatten(out), step


def prune(path: str, keep: int = 3) -> None:
    """Keep only the newest ``keep`` checkpoints."""
    if not os.path.isdir(path):
        return
    steps = sorted(
        int(n.split("_")[1])
        for n in os.listdir(path)
        if n.startswith("step_") and not n.endswith(".tmp")
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(path, f"step_{s:08d}"), ignore_errors=True)
