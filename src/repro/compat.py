"""Cross-version jax API shims (the image bakes a 0.4.x jax).

``shard_map`` was promoted from ``jax.experimental.shard_map`` to the
``jax`` namespace (~0.5), its ``check_rep`` kwarg was renamed to
``check_vma``, and partial-manual mode switched from ``auto`` (axes left
automatic) to ``axis_names`` (axes made manual). The sharded code paths are
written against the new API; this shim translates for the 0.4.x line.
"""

from __future__ import annotations

import functools

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    @functools.wraps(_shard_map)
    def shard_map(*args, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        if "axis_names" in kwargs:
            manual = frozenset(kwargs.pop("axis_names"))
            mesh = kwargs.get("mesh", args[1] if len(args) > 1 else None)
            kwargs["auto"] = frozenset(mesh.axis_names) - manual
        return _shard_map(*args, **kwargs)
