"""Config system: model architecture + input-shape cells + registry.

Every assigned architecture is a frozen ``ModelConfig`` in its own module
(``repro/configs/<id>.py``) with the exact published hyperparameters, plus a
``smoke()`` reduced config of the same family for CPU tests. Input shapes are
the four assigned cells (train_4k / prefill_32k / decode_32k / long_500k).
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass, field, replace
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


def _pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention variants
    qk_norm: bool = False
    sliding_window: int | None = None
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, ...] = ()  # qwen2-vl M-RoPE (t, h, w) half-dims
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    expand: int = 2
    # hybrid block layout: indices of attention blocks among num_layers
    attn_block_positions: tuple[int, ...] = ()
    # §Perf B1 (validated 3.5x compute win; EXPERIMENTS.md): per-stream SSM
    # projections (shard-aligned) instead of the fused in_proj. Set False to
    # reproduce the pre-optimization baseline.
    mamba_split_proj: bool = True
    # §Perf A6: flash-attention KV tile length (VMEM working-set knob)
    kv_chunk: int = 512
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    is_encoder_decoder: bool = False
    encoder_ctx: int = 1500  # whisper n_audio_ctx
    frontend: str | None = None  # "audio" | "vision" — STUB (embeddings given)
    # numerics / training
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # citation (public literature source)
    source: str = ""

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 128 so TP sharding always divides
        (MaxText-style padding; extra logits are never targeted by labels)."""
        return _pad_to(self.vocab_size, 128)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def uses_subquadratic_attention(self) -> bool:
        """True when long-context (500k) decode is feasible: SSM state,
        hybrid with O(1)-dominant state, or bounded sliding-window cache."""
        return self.family in ("ssm", "hybrid") or self.sliding_window is not None

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab_padded
        emb = v * d if self.tie_embeddings else 2 * v * d
        per_attn = d * (self.num_heads * self.head_dim) * 2 + d * (
            self.num_kv_heads * self.head_dim
        ) * 2
        per_mlp = 3 * d * self.d_ff  # SwiGLU
        per_moe = self.num_experts * 3 * d * self.moe_d_ff + d * self.num_experts
        di, n, h = self.d_inner, self.ssm_state, self.ssm_heads
        per_mamba = (
            d * (2 * di + 2 * n + h)  # in_proj -> x, z, B, C, dt
            + di * d  # out_proj
            + (di + 2 * n) * self.conv_width  # conv
            + 3 * h  # A, D, dt_bias
            + 2 * d  # norms
        )
        total = emb
        if self.family == "ssm":
            total += self.num_layers * (per_mamba + d)
        elif self.family == "hybrid":
            n_attn = len(self.attn_block_positions)
            total += (self.num_layers - n_attn) * (per_mamba + d)
            total += n_attn * (per_attn + per_mlp + 2 * d)
        elif self.family == "moe":
            total += self.num_layers * (per_attn + per_moe + 2 * d)
        else:
            layers = self.num_layers + self.encoder_layers
            cross = self.num_layers * per_attn if self.is_encoder_decoder else 0
            total += layers * (per_attn + per_mlp + 2 * d) + cross
        return total

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: only routed experts count)."""
        if self.family != "moe":
            return self.param_count()
        dense = self.param_count() - self.num_layers * (
            self.num_experts * 3 * self.d_model * self.moe_d_ff
        )
        return dense + self.num_layers * (
            self.experts_per_token * 3 * self.d_model * self.moe_d_ff
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS: tuple[str, ...] = (
    "qwen2_vl_2b",
    "qwen3_32b",
    "tinyllama_1_1b",
    "granite_3_8b",
    "deepseek_67b",
    "mixtral_8x7b",
    "granite_moe_3b_a800m",
    "mamba2_2_7b",
    "zamba2_1_2b",
    "whisper_tiny",
)


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_')}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_')}")
    return mod.smoke()


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch x shape) dry-run cell applies (DESIGN.md §6)."""
    if shape.name == "long_500k" and not cfg.uses_subquadratic_attention:
        return False, "full quadratic attention: 500k cache/step infeasible (skip per spec)"
    return True, ""


def all_cells() -> list[tuple[str, str, bool, str]]:
    """(arch, shape, runnable, reason) for every assigned cell (10x4=40)."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = cell_is_runnable(cfg, shape)
            out.append((arch, shape.name, ok, why))
    return out
