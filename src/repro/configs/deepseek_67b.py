"""DeepSeek-67B [arXiv:2401.02954; hf].

Dense (llama-arch): 95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
"""

from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=102400,
    rope_theta=10000.0,
    source="arXiv:2401.02954; hf:deepseek-ai/deepseek-llm-67b-base",
)


def smoke() -> ModelConfig:
    return replace(
        CONFIG,
        name="deepseek-smoke",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=256,
    )
