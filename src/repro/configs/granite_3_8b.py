"""Granite-3.0-8B [hf:ibm-granite/granite-3.0 family; assignment spec].

Dense: 40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
"""

from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    rope_theta=10000.0,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-2b-base (family); assignment spec",
)


def smoke() -> ModelConfig:
    return replace(
        CONFIG,
        name="granite-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=250,  # deliberately not a multiple of 128: tests vocab padding
    )
