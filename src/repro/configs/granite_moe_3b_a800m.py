"""Granite-3.0-3B-A800M MoE [hf:ibm-granite/granite-3.0 family; assignment].

MoE: 32L d_model=1536 24H (GQA kv=8) expert_d_ff=512 vocab=49155,
40 experts top-8 (fine-grained experts).
"""

from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    num_experts=40,
    experts_per_token=8,
    moe_d_ff=512,
    rope_theta=10000.0,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base (family); assignment spec",
)


def smoke() -> ModelConfig:
    return replace(
        CONFIG,
        name="granite-moe-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=64,
        moe_d_ff=64,
        num_experts=8,
        experts_per_token=4,
        vocab_size=256,
    )
