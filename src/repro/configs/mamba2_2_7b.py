"""Mamba2-2.7B [arXiv:2405.21060].

SSM (SSD / state-space duality): 64L d_model=2560, attention-free,
d_state=128, expand=2 (d_inner=5120), headdim=64 -> 80 SSD heads, vocab=50280.
"""

from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_heads=80,  # d_inner / ssm_head_dim = 5120/64
    ssm_head_dim=64,
    ssm_chunk=256,
    conv_width=4,
    expand=2,
    tie_embeddings=True,
    source="arXiv:2405.21060; state-spaces/mamba2-2.7b",
)


def smoke() -> ModelConfig:
    return replace(
        CONFIG,
        name="mamba2-smoke",
        num_layers=2,
        d_model=64,
        ssm_state=16,
        ssm_heads=8,  # d_inner 128 / head_dim 16
        ssm_head_dim=16,
        ssm_chunk=16,
        vocab_size=256,
    )
