"""Qwen2-VL-2B backbone [arXiv:2409.12191; hf].

VLM: 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936, M-RoPE.
The vision frontend (dynamic-resolution ViT) is a STUB per the assignment:
``input_specs()`` provides precomputed patch embeddings.
"""

from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),  # temporal/height/width half-dims (sum=64)
    tie_embeddings=True,
    frontend="vision",
    source="arXiv:2409.12191; hf:Qwen/Qwen2-VL-2B",
)


def smoke() -> ModelConfig:
    return replace(
        CONFIG,
        name="qwen2-vl-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        mrope_sections=(2, 3, 3),
    )
