"""Scaled-down variants of the assigned architectures (same family) for
CPU-trainable end-to-end runs (examples/train_lm.py, launch/train.py)."""

from __future__ import annotations

from dataclasses import replace

from repro.configs.base import ModelConfig, get_config


def scaled_100m(arch: str) -> ModelConfig:
    """The arch's family at ~100M params."""
    cfg = get_config(arch)
    if cfg.family in ("ssm", "hybrid"):
        return replace(
            cfg, name=f"{cfg.name}-100m", num_layers=6, d_model=512,
            ssm_heads=16, ssm_head_dim=64, ssm_state=32, ssm_chunk=64,
            vocab_size=8192,
            attn_block_positions=(3,) if cfg.family == "hybrid" else (),
            num_heads=8 if cfg.family == "hybrid" else 0,
            num_kv_heads=8 if cfg.family == "hybrid" else 0,
            head_dim=64 if cfg.family == "hybrid" else 0,
            d_ff=1536 if cfg.family == "hybrid" else 0,
        )
    return replace(
        cfg, name=f"{cfg.name}-100m", num_layers=8, d_model=768,
        num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=8192,
        moe_d_ff=512 if cfg.family == "moe" else 0,
        num_experts=8 if cfg.family == "moe" else 0,
        experts_per_token=2 if cfg.family == "moe" else 0,
    )
