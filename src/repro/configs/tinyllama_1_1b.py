"""TinyLlama-1.1B [arXiv:2401.02385; hf].

Dense (llama2-arch): 22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
"""

from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=64,
    d_ff=5632,
    vocab_size=32000,
    rope_theta=10000.0,
    source="arXiv:2401.02385; hf:TinyLlama/TinyLlama-1.1B",
)


def smoke() -> ModelConfig:
    return replace(
        CONFIG,
        name="tinyllama-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
    )
