"""Whisper-tiny [arXiv:2212.04356].

Audio encoder-decoder backbone: 4L encoder + 4L decoder, d_model=384, 6H MHA,
d_ff=1536, vocab=51865. The log-mel + conv frontend is a STUB per the
assignment: ``input_specs()`` provides precomputed frame embeddings
(n_audio_ctx=1500 frames at d_model).
"""

from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,  # decoder layers
    encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    is_encoder_decoder=True,
    encoder_ctx=1500,
    frontend="audio",
    tie_embeddings=True,
    source="arXiv:2212.04356; openai/whisper-tiny",
)


def smoke() -> ModelConfig:
    return replace(
        CONFIG,
        name="whisper-smoke",
        num_layers=2,
        encoder_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        encoder_ctx=32,
    )
