"""Zamba2-1.2B [arXiv:2411.15242; hf].

Hybrid: 38 blocks, d_model=2048; Mamba2 backbone (d_state=64) with shared
full-attention transformer blocks applied at two depths (32H MHA, d_ff=8192).
"""

from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,  # shared attn blocks are MHA
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_heads=64,  # d_inner 4096 / head_dim 64
    ssm_head_dim=64,
    ssm_chunk=256,
    conv_width=4,
    expand=2,
    attn_block_positions=(9, 28),  # shared attention applied at 1/4 and 3/4 depth
    rope_theta=10000.0,
    tie_embeddings=True,
    source="arXiv:2411.15242; hf:Zyphra/Zamba2-1.2B",
)


def smoke() -> ModelConfig:
    return replace(
        CONFIG,
        name="zamba2-smoke",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        ssm_state=16,
        ssm_heads=8,
        ssm_head_dim=16,
        ssm_chunk=16,
        vocab_size=256,
        attn_block_positions=(1, 3),
    )
