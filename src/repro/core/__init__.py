"""DROP core: the paper primary contribution (progressive-sampling PCA
optimizer with sampled TLB validation and cost-based termination)."""

from repro.core.bucketing import DEFAULT_BUCKETS, ShapeBucketCache  # noqa: F401
from repro.core.drop import DropRunner, drop  # noqa: F401
from repro.core.types import (  # noqa: F401
    DEFAULT_SCHEDULE,
    DropConfig,
    DropResult,
    IterationRecord,
)
