"""DROP core: the paper primary contribution (progressive-sampling PCA
optimizer with sampled TLB validation and cost-based termination)."""

from repro.core.bucketing import DEFAULT_BUCKETS, ShapeBucketCache  # noqa: F401
from repro.core.drop import DropRunner, PcaDropReducer, drop  # noqa: F401
from repro.core.reducer import (  # noqa: F401
    REDUCER_METHODS,
    DwtReducer,
    FftReducer,
    JlReducer,
    PaaReducer,
    Reducer,
    make_reducer,
    reduce,
)
from repro.core.subspace import (  # noqa: F401
    TRACK_HEADROOM,
    SubspaceTracker,
    suffix_update,
)
from repro.core.types import (  # noqa: F401
    DEFAULT_SCHEDULE,
    DropConfig,
    DropResult,
    IterationRecord,
    ReduceResult,
)
