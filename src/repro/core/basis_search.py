"""Basis fitting + search for the lowest TLB-preserving dimension (paper §3.4).

COMPUTE-BASIS (Alg. 4): fit PCA on the sample (via SVD-Halko or full SVD),
then find the smallest k achieving the TLB target. Two search modes:

* ``binary`` — the paper's Algorithm 4: binary search over k in [0, k_{i-1}],
  with EVALUATE-TLB's CI-driven pair doubling at each probe.
* ``prefix`` — TPU-native (DESIGN.md §2): one fused pass computes the TLB CI at
  every k simultaneously; the smallest satisfying k is an argmax over the
  table. Strictly fewer device round-trips, MXU-shaped work.

Both exploit the PCA prefix property (T_k = first k columns of T_{k'}) and TLB
monotonicity in k.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import halko as halko_mod
from repro.core import pca as pca_mod
from repro.core.bucketing import DEFAULT_BUCKETS, ShapeBucketCache
from repro.core.tlb import TLBEstimator
from repro.core.types import DropConfig


@dataclass
class BasisSearchResult:
    v_full: np.ndarray  # (d, cap) — full fitted basis (cached for prefix reuse)
    mean: np.ndarray  # (d,) sample column means
    k: int
    tlb_mean: float
    satisfied: bool
    pairs_used: int
    estimator: TLBEstimator  # retained for importance-sampling reuse


def fit_basis(
    sample: np.ndarray,
    cap: int,
    cfg: DropConfig,
    key: jax.Array,
    bucket: ShapeBucketCache | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Fit a rank-``cap`` PCA basis on the sample. Returns (mean, V (d, cap)).

    With a ``bucket``, the sample is zero-padded to its row bucket and
    centered with a row mask: padded rows contribute nothing to the mean and
    stay exactly zero, and zero rows never change the right singular vectors
    (C'ᵀC' = CᵀC) — so bucketed fits are exact while the jitted SVD stages
    see only bucket-quantized shapes.
    """
    n, d = sample.shape
    if bucket is not None:
        padded = bucket.bucket_rows(n)
        xs = jnp.asarray(
            np.concatenate(
                [sample, np.zeros((padded - n, d), sample.dtype)], axis=0
            )
            if padded > n
            else sample
        )
        mask = jnp.arange(xs.shape[0]) < n
        mean, c = pca_mod.center_masked(xs, mask)
    else:
        mean, c = pca_mod.center(jnp.asarray(sample))
    if cfg.svd == "full":
        _, _, vt = jnp.linalg.svd(c, full_matrices=False)
        v = vt.T[:, :cap]
    else:
        v, _ = halko_mod.svd_halko(
            c,
            cap,
            key,
            oversample=cfg.halko_oversample,
            power_iters=cfg.halko_power_iters,
            use_kernels=cfg.use_kernels,
        )
    return np.asarray(mean), np.asarray(v)


def _binary_search(
    est: TLBEstimator, target: float, cap: int, cfg: DropConfig
) -> tuple[int, float, bool, int]:
    """Alg. 4 COMPUTE-BASIS lines 2-9."""
    low, high = 0, cap
    pairs_used = 0
    best_mean = 0.0
    while low != high:
        k = (low + high) // 2
        e = est.estimate_at_k(
            k, target, initial_pairs=cfg.initial_pairs, max_pairs=cfg.max_pairs
        )
        pairs_used = max(pairs_used, e.pairs_used)
        if e.mean <= target:  # not good enough: need more components
            low = k + 1
        else:
            high = k
            best_mean = e.mean
    k = low
    final = est.estimate_at_k(
        k, target, initial_pairs=cfg.initial_pairs, max_pairs=cfg.max_pairs
    )
    pairs_used = max(pairs_used, final.pairs_used)
    return k, final.mean, final.mean >= target, pairs_used


def _prefix_search(
    est: TLBEstimator, target: float, cap: int, cfg: DropConfig
) -> tuple[int, float, bool, int]:
    """All-prefix search: smallest k whose mean TLB clears the target."""
    mean_k, _, _, pairs = est.estimate_all_k(
        target, initial_pairs=cfg.initial_pairs, max_pairs=cfg.max_pairs
    )
    ok = np.nonzero(mean_k[:cap] >= target)[0]
    if ok.size:
        k = int(ok[0]) + 1
        return k, float(mean_k[k - 1]), True, pairs
    return cap, float(mean_k[cap - 1]), False, pairs


def compute_basis(
    x: np.ndarray,
    sample: np.ndarray,
    prev_k: int | None,
    cfg: DropConfig,
    key: jax.Array,
    rng: np.random.Generator,
    bucket: ShapeBucketCache | None = None,
) -> BasisSearchResult:
    """COMPUTE-BASIS(X, X_i, B): fit on the sample, evaluate TLB on full-data
    pairs, search for the smallest satisfying k (bounded by k_{i-1}).

    Shape-dependent sizes (fit width, TLB pair batches) quantize through
    ``bucket`` so jitted stages see a bounded, shareable set of shapes;
    defaults to the process-wide ``DEFAULT_BUCKETS``.
    """
    bucket = bucket or DEFAULT_BUCKETS
    m_i, d = sample.shape
    hard_cap = min(d, m_i)
    cap = hard_cap
    if prev_k is not None:
        # §3.4.3: prior satisfying basis of size d' < d bounds the Halko rank
        cap = min(cap, prev_k)
    cap = max(cap, 1)
    # padded shape buckets (DESIGN.md §2): fit the basis at the bucketed width
    # so the jitted Halko/TLB kernels see a bounded set of shapes across
    # iterations (data-dependent k would otherwise force fresh XLA compiles
    # every iteration); the search below still uses the true cap
    cap_pad = bucket.bucket_rank(cap, hard_cap)
    mean, v = fit_basis(sample, max(cap_pad, cap), cfg, key, bucket=bucket)
    est = TLBEstimator(
        x,
        jnp.asarray(v),
        rng,
        confidence=cfg.confidence,
        use_kernels=cfg.use_kernels,
        bucket=bucket,
    )
    search = _binary_search if cfg.search == "binary" else _prefix_search
    k, tlb_mean, satisfied, pairs = search(est, cfg.target_tlb, cap, cfg)
    return BasisSearchResult(
        v_full=v,
        mean=mean,
        k=max(k, 1),
        tlb_mean=tlb_mean,
        satisfied=satisfied,
        pairs_used=pairs,
        estimator=est,
    )
