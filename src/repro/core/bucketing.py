"""Shape-bucket quantization for jitted stages (DESIGN.md §2, extended).

DROP's per-iteration shapes are data-dependent: the Halko rank cap shrinks as
satisfying bases are found, and the TLB pair count doubles until the CI clears
the target. Left raw, every new size forces a fresh XLA compile. The original
``compute_basis`` padded the rank cap to the next multiple of 32 inline; this
module promotes that trick into an explicit, shared ``ShapeBucketCache`` so

* the Halko fit and the pairwise-TLB batches quantize through ONE policy,
* a multi-query service can share one bucket set across tenants (the jit
  cache is keyed by shape, so shared buckets mean shared compiles), and
* the bucket population is observable (hit-rate telemetry for the service).

Quantization is deterministic (pure rounding), so routing through a bucket
cache never changes numerical results — padding rows are zeros that are
sliced away, and rank padding only widens the fitted basis beyond the
searched cap, exactly as the inline pad-to-32 always did.
"""

from __future__ import annotations

from dataclasses import dataclass, field


def round_up(n: int, quantum: int) -> int:
    """Smallest multiple of ``quantum`` that is >= n (n <= 0 maps to quantum)."""
    n = max(int(n), 1)
    q = max(int(quantum), 1)
    return ((n + q - 1) // q) * q


@dataclass
class BucketStats:
    """Per-family telemetry: how often a request landed in an existing bucket."""

    hits: int = 0
    misses: int = 0
    sizes: set = field(default_factory=set)

    @property
    def requests(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.requests if self.requests else 0.0


class ShapeBucketCache:
    """Quantizes data-dependent sizes into a bounded bucket set.

    Families:
      * ``rank``  — Halko/SVD fit width (the old inline pad-to-32 in
        ``compute_basis``), clamped to the hard cap min(m_i, d).
      * ``pairs`` — TLB pair-batch row counts; the estimator zero-pads each
        incremental batch up to the bucket and slices the padding off.
      * ``rows``  — PCA-fit sample rows; the fit zero-pads the sample and
        uses masked centering, so tenants whose progressive schedules land
        in the same bucket share one Halko executable.

    A "hit" means the padded size was already in the family's bucket set, i.e.
    the jitted stage will reuse an existing XLA executable instead of
    compiling a new one.
    """

    def __init__(
        self,
        rank_quantum: int = 32,
        pair_quantum: int = 128,
        row_quantum: int = 64,
    ) -> None:
        self.rank_quantum = rank_quantum
        self.pair_quantum = pair_quantum
        self.row_quantum = row_quantum
        self.stats: dict[str, BucketStats] = {
            "rank": BucketStats(),
            "pairs": BucketStats(),
            "rows": BucketStats(),
        }

    def _record(self, family: str, size: int) -> int:
        st = self.stats[family]
        if size in st.sizes:
            st.hits += 1
        else:
            st.misses += 1
            st.sizes.add(size)
        return size

    def bucket_rank(self, cap: int, hard_cap: int) -> int:
        """Padded fit width for a search cap of ``cap``: next multiple of
        ``rank_quantum``, never beyond ``hard_cap`` = min(m_i, d)."""
        padded = min(max(int(hard_cap), 1), round_up(cap, self.rank_quantum))
        return self._record("rank", max(padded, max(int(cap), 1)))

    def bucket_pairs(self, p: int) -> int:
        """Padded row count for a TLB pair batch of ``p`` pairs."""
        return self._record("pairs", round_up(p, self.pair_quantum))

    def pad_basis(self, v, hard_cap: int):
        """Zero-pad a (d, k) basis to its rank bucket so jitted TLB stages
        keep the bucketed shapes of the fit path — zero columns never change
        the table entries a search or validation reads. ``hard_cap`` mirrors
        the fit path's min(m, d) cap, so fit / validation / suffix-update
        shapes coincide (one compiled executable per bucket)."""
        import numpy as np  # local: keep the module import-light

        pad_w = self.bucket_rank(v.shape[1], hard_cap)
        if pad_w <= v.shape[1]:
            return v
        return np.concatenate(
            [v, np.zeros((v.shape[0], pad_w - v.shape[1]), v.dtype)], axis=1
        )

    def bucket_rows(self, n: int) -> int:
        """Padded sample-row count for the PCA fit (masked centering keeps the
        zero rows out of the mean; zero rows never change right singular
        vectors, so the padded fit is exact for the real rows)."""
        return self._record("rows", round_up(n, self.row_quantum))

    def bucket_tile_rows(self, n: int, tile: int) -> int:
        """Padded row count for a fused tiled scan (``analytics.pairwise``):
        next multiple of the tile size. The tile grid is part of the compiled
        shape, so quantizing to the tile keeps remainder tiles out of the jit
        cache — every m in (q*tile, (q+1)*tile] shares one executable.
        Recorded under the ``rows`` family (same telemetry as the fit pads)."""
        return self._record("rows", round_up(n, max(int(tile), 1)))

    def summary(self) -> str:
        parts = []
        for family, st in self.stats.items():
            parts.append(
                f"{family}: {len(st.sizes)} buckets, "
                f"{st.hits}/{st.requests} hits ({st.hit_rate:.0%})"
            )
        return "; ".join(parts)


# Shared default: single-query drop() and any service that does not bring its
# own cache quantize through the same instance, so their jitted shapes align.
DEFAULT_BUCKETS = ShapeBucketCache()
