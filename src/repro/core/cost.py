"""Downstream cost functions C_m(k) (paper §3.1, §3.5).

C_m maps output dimensionality k to *estimated downstream runtime in seconds*,
so it is directly commensurable with DROP's own runtime R in the objective
R + C_m(k). The paper's default models k-NN: O(m^2 k).

Coefficients are calibrated once per environment with a micro-benchmark
(``calibrate``), mirroring how the paper "tuned [the default] to k-NN".
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

import numpy as np


@dataclass(frozen=True)
class CostModel:
    name: str
    fn: Callable[[int], float]

    def __call__(self, k: int) -> float:
        return float(self.fn(max(int(k), 0)))


# measured on this container via `calibrate_quadratic` (numpy BLAS pairwise
# distances): seconds per (m^2 * k) element-op. Conservative default.
DEFAULT_KNN_COEFF = 2.5e-10
DEFAULT_LINEAR_COEFF = 1.0e-8


def knn_cost(m: int, coeff: float = DEFAULT_KNN_COEFF) -> CostModel:
    """k-NN / DBSCAN-style all-pairs downstream: C(k) = coeff * m^2 * k."""
    return CostModel("knn", lambda k: coeff * float(m) * float(m) * k)


def linear_cost(m: int, coeff: float = DEFAULT_LINEAR_COEFF) -> CostModel:
    """Similarity-search-style downstream linear in dimension: C(k) = c*m*k."""
    return CostModel("linear", lambda k: coeff * float(m) * k)


def quadratic_dim_cost(coeff: float) -> CostModel:
    """Covariance-estimation-style downstream: C(k) = coeff * k^2."""
    return CostModel("quad_dim", lambda k: coeff * float(k) ** 2)


def zero_cost() -> CostModel:
    """Pure-quality mode: never pays for dimension, so DROP runs the whole
    schedule and returns its best basis (oracle-quality reference)."""
    return CostModel("zero", lambda k: 0.0)


# the paper's three end-to-end analytics are all all-pairs distance tasks:
# k-NN retrieval, DBSCAN radius queries, and Gaussian KDE each do O(m^2 k)
# distance work on the reduced data, so they share the quadratic model
DOWNSTREAM_COSTS = ("knn", "dbscan", "kde")


def downstream_cost(
    name: str, m: int, coeff: float = DEFAULT_KNN_COEFF
) -> CostModel:
    """Price a named downstream task from ``analytics/`` as a C_m(k) model —
    the bridge ``ReduceQuery(downstream=...)`` and the workload optimizer
    use to make DR cost and analytics cost commensurable (objective
    R + C_m(k), paper §3.1)."""
    if name not in DOWNSTREAM_COSTS:
        raise KeyError(
            f"unknown downstream {name!r}; know {DOWNSTREAM_COSTS}"
        )
    return CostModel(name, knn_cost(m, coeff).fn)


def calibrate_quadratic(m_probe: int = 512, d_probe: int = 32) -> float:
    """Measure seconds per (m^2*k) element for all-pairs distance on this host."""
    x = np.random.default_rng(0).normal(size=(m_probe, d_probe)).astype(np.float32)
    t0 = time.perf_counter()
    sq = (x * x).sum(1)
    g = x @ x.T
    _ = np.sqrt(np.maximum(sq[:, None] + sq[None, :] - 2 * g, 0.0))
    dt = time.perf_counter() - t0
    return dt / (m_probe * m_probe * d_probe)
