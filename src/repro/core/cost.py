"""Downstream cost functions C_m(k) (paper §3.1, §3.5).

C_m maps output dimensionality k to *estimated downstream runtime in seconds*,
so it is directly commensurable with DROP's own runtime R in the objective
R + C_m(k). The paper's default models k-NN: O(m^2 k).

Coefficients are calibrated once per environment with a micro-benchmark
(``calibrate``), mirroring how the paper "tuned [the default] to k-NN".
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

import numpy as np


@dataclass(frozen=True)
class CostModel:
    name: str
    fn: Callable[[int], float]

    def __call__(self, k: int) -> float:
        return float(self.fn(max(int(k), 0)))


# measured on this container via `calibrate_quadratic` (numpy BLAS pairwise
# distances): seconds per (m^2 * k) element-op. Conservative default.
DEFAULT_KNN_COEFF = 2.5e-10
# k-INDEPENDENT seconds per m^2 pair: the memory-bound part of a fused
# pairwise scan (tile writes + the argmin/threshold reduction pass) that a
# smaller k cannot shrink. Calibrated on this container from
# `benchmarks/bench_pairwise_analytics.py` at m=8000: the fused kNN engine
# measures 8-13 ns per pair across d in {3, 25, 95} with the d-slope lost in
# noise — the intercept IS most of the cost (`calibrate_pairwise_intercept`
# re-measures on a live host). The term is method-independent (same m for
# every candidate), so it never changes which method an optimizer picks —
# it makes the PRICED C_m(k) track measured wall clock instead of
# underpricing small-k downstreams by an order of magnitude.
DEFAULT_KNN_MEM_COEFF = 8.0e-9
DEFAULT_LINEAR_COEFF = 1.0e-8


def knn_cost(
    m: int,
    coeff: float = DEFAULT_KNN_COEFF,
    mem_coeff: float = DEFAULT_KNN_MEM_COEFF,
) -> CostModel:
    """k-NN / DBSCAN-style all-pairs downstream:
    C(k) = coeff * m^2 * k + mem_coeff * m^2 (paper model + measured
    k-independent memory term; pass ``mem_coeff=0`` for the pure paper
    model)."""
    return CostModel(
        "knn",
        lambda k: coeff * float(m) * float(m) * k
        + mem_coeff * float(m) * float(m),
    )


def linear_cost(m: int, coeff: float = DEFAULT_LINEAR_COEFF) -> CostModel:
    """Similarity-search-style downstream linear in dimension: C(k) = c*m*k."""
    return CostModel("linear", lambda k: coeff * float(m) * k)


def quadratic_dim_cost(coeff: float) -> CostModel:
    """Covariance-estimation-style downstream: C(k) = coeff * k^2."""
    return CostModel("quad_dim", lambda k: coeff * float(k) ** 2)


def zero_cost() -> CostModel:
    """Pure-quality mode: never pays for dimension, so DROP runs the whole
    schedule and returns its best basis (oracle-quality reference)."""
    return CostModel("zero", lambda k: 0.0)


# the paper's three end-to-end analytics are all all-pairs distance tasks:
# k-NN retrieval, DBSCAN radius queries, and Gaussian KDE each do O(m^2 k)
# distance work on the reduced data, so they share the quadratic model
DOWNSTREAM_COSTS = ("knn", "dbscan", "kde")


def downstream_cost(
    name: str,
    m: int,
    coeff: float = DEFAULT_KNN_COEFF,
    mem_coeff: float = DEFAULT_KNN_MEM_COEFF,
    legacy_cost: bool = False,
) -> CostModel:
    """Price a named downstream task from ``analytics/`` as a C_m(k) model —
    the bridge ``ReduceQuery(downstream=...)`` and the workload optimizer
    use to make DR cost and analytics cost commensurable (objective
    R + C_m(k), paper §3.1).

    The default model is ``coeff*m^2*k + mem_coeff*m^2``: the paper's
    O(m^2 k) distance work plus the measured k-independent O(m^2)
    memory-bound term of the fused pairwise engine (building/reducing the
    distance tiles costs the same at k=3 and k=95). ``legacy_cost=True``
    restores the pure O(m^2 k) paper model."""
    if name not in DOWNSTREAM_COSTS:
        raise KeyError(
            f"unknown downstream {name!r}; know {DOWNSTREAM_COSTS}"
        )
    if legacy_cost:
        mem_coeff = 0.0
    return CostModel(name, knn_cost(m, coeff, mem_coeff).fn)


def calibrate_quadratic(m_probe: int = 512, d_probe: int = 32) -> float:
    """Measure seconds per (m^2*k) element for all-pairs distance on this host."""
    x = np.random.default_rng(0).normal(size=(m_probe, d_probe)).astype(np.float32)
    t0 = time.perf_counter()
    sq = (x * x).sum(1)
    g = x @ x.T
    _ = np.sqrt(np.maximum(sq[:, None] + sq[None, :] - 2 * g, 0.0))
    dt = time.perf_counter() - t0
    return dt / (m_probe * m_probe * d_probe)


def calibrate_pairwise_intercept(
    m_probe: int = 4000, d_probe: int = 3, iters: int = 3
) -> float:
    """Measure the k-independent seconds-per-m^2 intercept of the fused
    pairwise engine on this host (`DEFAULT_KNN_MEM_COEFF` re-measured):
    at a tiny d the O(m^2 k) matmul term is negligible, so best-of-N warm
    wall clock over m^2 IS the memory term."""
    from repro.analytics.knn import nearest_neighbors

    x = np.random.default_rng(0).normal(size=(m_probe, d_probe))
    x = x.astype(np.float32)
    nearest_neighbors(x)  # compile
    nearest_neighbors(x)  # harness convention: second warm run
    best = float("inf")
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        nearest_neighbors(x)
        best = min(best, time.perf_counter() - t0)
    return max(best / (m_probe * m_probe) - DEFAULT_KNN_COEFF * d_probe, 0.0)
