"""DROP driver — paper Algorithm 2.

    do:
        X_i   = SAMPLE(X, SAMPLE-SCHEDULE(i))          (§3.3)
        T_k_i = COMPUTE-BASIS(X, X_i, B)               (§3.4)
    while CHECK-PROGRESS(C_m, k_i, r_i, i++)           (§3.5)

The loop is host-driven (termination is data-dependent); all heavy per-
iteration compute (centering, SVD-Halko, pairwise TLB) is jitted JAX, with
Pallas kernel routing under ``cfg.use_kernels``.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core import progress as progress_mod
from repro.core import sampling as sampling_mod
from repro.core.basis_search import compute_basis
from repro.core.types import CostFn, DropConfig, DropResult, IterationRecord
from repro.utils import Clock


def drop(
    x: np.ndarray,
    cfg: DropConfig | None = None,
    cost: CostFn | None = None,
) -> DropResult:
    """Run DROP on data matrix ``x`` (m, d). Returns the lowest-dimensional
    TLB-preserving transformation found, per the objective R + C_m(k)."""
    cfg = cfg or DropConfig()
    if cost is None:
        from repro.core.cost import knn_cost

        cost = knn_cost(x.shape[0])
    x = np.ascontiguousarray(x, dtype=np.float32)
    m, d = x.shape

    rng = np.random.default_rng(cfg.seed)
    pair_rng = np.random.default_rng(cfg.seed + 1)
    key = jax.random.PRNGKey(cfg.seed)

    sizes = sampling_mod.schedule_sizes(m, cfg.schedule)
    records: list[IterationRecord] = []
    hard_points: np.ndarray | None = None
    prev_k: int | None = None
    best: dict | None = None
    total_runtime = 0.0
    clock = Clock()

    for i, size in enumerate(sizes):
        clock.restart()
        idx = sampling_mod.draw_sample(
            m, size, rng, hard_points=hard_points, reuse_fraction=cfg.reuse_fraction
        )
        key, subkey = jax.random.split(key)
        res = compute_basis(x, x[idx], prev_k, cfg, subkey, pair_rng)
        r_i = clock.elapsed()
        total_runtime += r_i

        obj_i = total_runtime + cost(res.k)
        records.append(
            IterationRecord(
                i=i,
                sample_size=size,
                k=res.k,
                tlb_estimate=res.tlb_mean,
                runtime_s=r_i,
                objective=obj_i,
                satisfied=res.satisfied,
                pairs_used=res.pairs_used,
            )
        )

        # keep the best basis: among satisfying ones the lowest k wins; when
        # none satisfies yet, the highest-TLB basis wins (k is meaningless
        # until the constraint is met)
        if res.satisfied:
            rank = (0, res.k, -res.tlb_mean)
        else:
            rank = (1, -res.tlb_mean, res.k)
        if best is None or rank < best["rank"]:
            best = {
                "rank": rank,
                "v": res.v_full[:, : res.k],
                "mean": res.mean,
                "k": res.k,
                "tlb": res.tlb_mean,
                "satisfied": res.satisfied,
            }

        # importance sampling state for the next iteration (§3.3.2)
        pts, scores = res.estimator.point_scores(res.k)
        hard_points = sampling_mod.hard_points_from_scores(
            pts, scores, quantile=cfg.reuse_fraction
        )
        if res.satisfied:
            prev_k = res.k  # §3.4.3: shrink the Halko rank for later iterations

        # CHECK-PROGRESS (§3.5): estimate next iteration, Eq. 2 stopping rule
        if i + 1 < len(sizes) and progress_mod.should_terminate(
            records, sizes[i + 1], cost, min_iterations=cfg.min_iterations
        ):
            break

    assert best is not None
    return DropResult(
        v=np.asarray(best["v"]),
        mean=np.asarray(best["mean"]),
        k=int(best["k"]),
        tlb_estimate=float(best["tlb"]),
        satisfied=bool(best["satisfied"]),
        runtime_s=total_runtime,
        iterations=records,
    )
