"""DROP driver — paper Algorithm 2.

    do:
        X_i   = SAMPLE(X, SAMPLE-SCHEDULE(i))          (§3.3)
        T_k_i = COMPUTE-BASIS(X, X_i, B)               (§3.4)
    while CHECK-PROGRESS(C_m, k_i, r_i, i++)           (§3.5)

The loop is host-driven (termination is data-dependent); all heavy per-
iteration compute (centering, SVD-Halko, pairwise TLB) is jitted JAX, with
Pallas kernel routing under ``cfg.use_kernels``.

The loop body lives in ``PcaDropReducer``, a resumable one-iteration-at-a-
time state machine implementing the ``repro.core.reducer.Reducer`` protocol:
``drop()`` drives it to completion for the classic single-query API, and
``repro.serve_drop.DropService`` interleaves ``step()`` calls across many
in-flight queries so early-terminating queries free device time for the
rest. ``DropRunner`` is the deprecated pre-protocol alias.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core import progress as progress_mod
from repro.core import sampling as sampling_mod
from repro.core import subspace as subspace_mod
from repro.core.basis_search import compute_basis
from repro.core.bucketing import ShapeBucketCache
from repro.core.types import CostFn, DropConfig, DropResult, IterationRecord
from repro.utils import Clock


class PcaDropReducer:
    """Resumable DROP optimizer state for one query (Reducer protocol).

    Each ``step()`` runs exactly one Algorithm-2 iteration (sample → fit →
    TLB-search → progress check) and returns True while more iterations
    remain. Numerics are identical to a monolithic loop: all RNG streams are
    owned by the runner, so interleaving steps of different runners cannot
    perturb any individual query's trajectory.

    ``warm_prev_k`` seeds the §3.4.3 rank bound from a previously fitted
    basis (the serve-layer basis-reuse cache, paper §5), shrinking the first
    Halko fit from min(m_1, d) down to the cached satisfying k. Unlike a
    bound earned by this run's own satisfying iteration, the warm bound is
    a hint: if the first iteration under it fails the TLB target (the
    cached basis was stale for this data), the cap is dropped so later
    iterations search the full rank again.
    """

    method = "pca"
    cacheable = True  # a fitted basis is exactly what the §5 cache amortizes
    supports_update = True  # appended rows fold in via subspace tracking

    def __init__(
        self,
        x: np.ndarray,
        cfg: DropConfig | None = None,
        cost: CostFn | None = None,
        *,
        warm_prev_k: int | None = None,
        bucket: ShapeBucketCache | None = None,
    ) -> None:
        self.cfg = cfg or DropConfig()
        if cost is None:
            from repro.core.cost import knn_cost

            cost = knn_cost(x.shape[0])
        self.cost = cost
        self.x = np.ascontiguousarray(x, dtype=np.float32)
        self.bucket = bucket
        m = self.x.shape[0]

        self._rng = np.random.default_rng(self.cfg.seed)
        self._pair_rng = np.random.default_rng(self.cfg.seed + 1)
        self._key = jax.random.PRNGKey(self.cfg.seed)

        self.sizes = sampling_mod.schedule_sizes(m, self.cfg.schedule)
        self.records: list[IterationRecord] = []
        self._hard_points: np.ndarray | None = None
        self.prev_k: int | None = warm_prev_k
        self._warm_cap = warm_prev_k is not None
        self._best: dict | None = None
        self.total_runtime = 0.0
        self.fit_calls = 0
        self._i = 0
        self.done = False
        self._clock = Clock()
        self.device = None  # mesh device this runner is pinned to (optional)
        self._tracker: subspace_mod.SubspaceTracker | None = None

    def place(self, device) -> None:
        """Pin this runner's compute to ``device`` (serve-layer sharding).

        Between steps the runner's state is host numpy except the PRNG key;
        committing the key makes every jitted stage that consumes it (and,
        by input-following, the arrays staged with it) execute on ``device``.
        Calling place() again migrates the runner — work stealing moves
        runners only between steps, so mid-iteration state never spans
        devices."""
        self._key = jax.device_put(self._key, device)
        self.device = device

    def step(self) -> bool:
        """Run one iteration; returns True iff the query still has work."""
        if self.done:
            return False
        i, size = self._i, self.sizes[self._i]
        m = self.x.shape[0]

        self._clock.restart()
        idx = sampling_mod.draw_sample(
            m,
            size,
            self._rng,
            hard_points=self._hard_points,
            reuse_fraction=self.cfg.reuse_fraction,
        )
        self._key, subkey = jax.random.split(self._key)
        res = compute_basis(
            self.x, self.x[idx], self.prev_k, self.cfg, subkey, self._pair_rng,
            bucket=self.bucket,
        )
        self.fit_calls += 1
        r_i = self._clock.elapsed()
        self.total_runtime += r_i

        obj_i = self.total_runtime + self.cost(res.k)
        self.records.append(
            IterationRecord(
                i=i,
                sample_size=size,
                k=res.k,
                tlb_estimate=res.tlb_mean,
                runtime_s=r_i,
                objective=obj_i,
                satisfied=res.satisfied,
                pairs_used=res.pairs_used,
            )
        )

        # keep the best basis: among satisfying ones the lowest k wins; when
        # none satisfies yet, the highest-TLB basis wins (k is meaningless
        # until the constraint is met)
        if res.satisfied:
            rank = (0, res.k, -res.tlb_mean)
        else:
            rank = (1, -res.tlb_mean, res.k)
        if self._best is None or rank < self._best["rank"]:
            self._best = {
                "rank": rank,
                "v": res.v_full[:, : res.k],
                # wider slice for subspace tracking: near-degenerate trailing
                # directions dropped from the served map still carry old-row
                # energy a future suffix merge needs (the suffix alone cannot
                # reconstruct it)
                "v_track": res.v_full[
                    :, : res.k + subspace_mod.TRACK_HEADROOM
                ],
                "mean": res.mean,
                "k": res.k,
                "tlb": res.tlb_mean,
                "satisfied": res.satisfied,
            }

        # importance sampling state for the next iteration (§3.3.2)
        pts, scores = res.estimator.point_scores(res.k)
        self._hard_points = sampling_mod.hard_points_from_scores(
            pts, scores, quantile=self.cfg.reuse_fraction
        )
        if res.satisfied:
            self.prev_k = res.k  # §3.4.3: shrink the Halko rank later on
            self._warm_cap = False  # bound now earned by this run's own data
        elif self._warm_cap:
            # the warm-start cap was stale for this data: un-cap so the next
            # iteration can search beyond the cached k
            self.prev_k = None
            self._warm_cap = False

        # CHECK-PROGRESS (§3.5): estimate next iteration, Eq. 2 stopping rule
        self._i += 1
        if self._i >= len(self.sizes) or progress_mod.should_terminate(
            self.records, self.sizes[self._i], self.cost,
            min_iterations=self.cfg.min_iterations,
        ):
            self.done = True
        return not self.done

    def result(self) -> DropResult:
        """The best basis found so far (valid once at least one step ran)."""
        assert self._best is not None, "result() before any step()"
        return DropResult(
            v=np.asarray(self._best["v"]),
            mean=np.asarray(self._best["mean"]),
            k=int(self._best["k"]),
            tlb_estimate=float(self._best["tlb"]),
            satisfied=bool(self._best["satisfied"]),
            runtime_s=self.total_runtime,
            iterations=self.records,
            method=self.method,
        )

    def tracker(self) -> subspace_mod.SubspaceTracker:
        """Subspace-updater state for the best basis found so far (the
        serve-layer cache stores this next to the fitted map so appended
        rows can be folded in without a refit)."""
        assert self._best is not None, "tracker() before any step()"
        if self._tracker is None:
            self._tracker = subspace_mod.SubspaceTracker.from_fit(
                self.x, np.asarray(self._best["v_track"])
            )
        return self._tracker

    def update(self, suffix: np.ndarray) -> DropResult:
        """Fold appended rows into the fitted basis instead of refitting
        (Reducer protocol's optional incremental path): a mean-aware block
        incremental SVD merge of the suffix, TLB-gated on the grown data.
        O(suffix), not O(total) — the rows already folded in are never
        touched. ``result().satisfied`` False after an update means the
        suffix outgrew the tracked headroom; callers should refit."""
        assert self._best is not None, "update() before any step()"
        suffix = np.ascontiguousarray(np.asarray(suffix), dtype=np.float32)
        grown = np.concatenate([self.x, suffix], axis=0)
        tracker = self.tracker()
        self._tracker, res, pairs = subspace_mod.suffix_update(
            tracker, grown, self.cfg, bucket=self.bucket
        )
        self.x = grown
        self.total_runtime += res.runtime_s
        self.records.append(
            IterationRecord(
                i=len(self.records),
                sample_size=suffix.shape[0],  # only the suffix is processed
                k=res.k,
                tlb_estimate=res.tlb_estimate,
                runtime_s=res.runtime_s,
                objective=self.total_runtime + self.cost(res.k),
                satisfied=res.satisfied,
                pairs_used=pairs,
            )
        )
        rank = (
            (0, res.k, -res.tlb_estimate)
            if res.satisfied
            else (1, -res.tlb_estimate, res.k)
        )
        self._best = {
            "rank": rank,
            "v": res.v,
            "v_track": self._tracker.v,  # merged state carries the headroom
            "mean": res.mean,
            "k": res.k,
            "tlb": res.tlb_estimate,
            "satisfied": res.satisfied,
        }
        return self.result()


DropRunner = PcaDropReducer  # deprecated alias (pre-Reducer-protocol name)


def drop(
    x: np.ndarray,
    cfg: DropConfig | None = None,
    cost: CostFn | None = None,
) -> DropResult:
    """Run DROP on data matrix ``x`` (m, d). Returns the lowest-dimensional
    TLB-preserving transformation found, per the objective R + C_m(k)."""
    runner = DropRunner(x, cfg, cost)
    while runner.step():
        pass
    return runner.result()
