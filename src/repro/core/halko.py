"""SVD-Halko: randomized truncated SVD (paper Algorithm 3; Halko et al. 2011).

Computes an approximate rank-k factorization in O(mdk + k^2(m+d)) by sketching
the column space with a random Gaussian test matrix, optionally sharpening with
power iteration, then factorizing the small projected panel.

The heavy O(mdk) work is three large matmuls — these route through the Pallas
tiled-MXU kernel wrapper (repro.kernels.matmul.ops) when ``use_kernels=True``;
the small (k+p)-sized QR/SVD panels stay on the dense LAPACK path.
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

MatmulFn = Callable[[jax.Array, jax.Array], jax.Array]


def _default_mm(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.matmul(a, b, precision=jax.lax.Precision.HIGHEST)


def _kernel_mm(a: jax.Array, b: jax.Array) -> jax.Array:
    from repro.kernels.matmul import ops as mm_ops

    return mm_ops.matmul(a, b)


@partial(jax.jit, static_argnames=("k", "oversample", "power_iters", "use_kernels"))
def svd_halko(
    c: jax.Array,
    k: int,
    key: jax.Array,
    oversample: int = 5,
    power_iters: int = 1,
    use_kernels: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Algorithm 3. ``c`` must already be centered. Returns (V[:, :k], sigma).

    V is (d, k): the approximate top-k right singular vectors (PCA projection).
    """
    m, d = c.shape
    l = min(k + oversample, m, d)
    mm: MatmulFn = _kernel_mm if use_kernels else _default_mm

    omega = jax.random.normal(key, (d, l), dtype=c.dtype)  # line 2
    y = mm(c, omega)  # (m, l)
    # Power iteration (line 3): Y = (C Cᵀ)^q C Ω, with QR re-orthonormalization
    # between steps for numerical stability (standard Halko practice; without
    # it float32 loses the small singular directions).
    for _ in range(power_iters):
        y, _ = jnp.linalg.qr(y)
        z = mm(c.T, y)  # (d, l)
        z, _ = jnp.linalg.qr(z)
        y = mm(c, z)  # (m, l)
    q, _ = jnp.linalg.qr(y)  # line 4: (m, l)
    b = mm(q.T, c)  # line 5: (l, d)
    _, s, vt = jnp.linalg.svd(b, full_matrices=False)  # line 6
    return vt[:k].T, s[:k]  # line 7


def svd_halko_np(c, k, seed=0, oversample=5, power_iters=1):
    """Numpy oracle for tests (independent of the JAX path)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    m, d = c.shape
    l = min(k + oversample, m, d)
    omega = rng.normal(size=(d, l)).astype(c.dtype)
    y = c @ omega
    for _ in range(power_iters):
        y, _ = np.linalg.qr(y)
        z, _ = np.linalg.qr(c.T @ y)
        y = c @ z
    q, _ = np.linalg.qr(y)
    b = q.T @ c
    _, s, vt = np.linalg.svd(b, full_matrices=False)
    return vt[:k].T, s[:k]
