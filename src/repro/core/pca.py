"""PCA via truncated SVD (paper Algorithm 1) — the exact / baseline operator."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.jit, static_argnames=())
def center(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """FIT step: column means and centered matrix C_X (Alg. 1 lines 2-3)."""
    xbar = jnp.mean(x, axis=0)
    return xbar, x - xbar


@jax.jit
def center_masked(x: jax.Array, row_mask: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Centering for zero-padded sample buckets.

    Rows with ``row_mask == 0`` are padding; they are excluded from the mean and
    re-zeroed after centering. Zero rows do not change the right singular
    vectors (C'ᵀC' = CᵀC), so padded-bucket PCA is exact for the real rows.
    """
    w = row_mask.astype(x.dtype)[:, None]
    denom = jnp.maximum(jnp.sum(w), 1.0)
    xbar = jnp.sum(x * w, axis=0) / denom
    return xbar, (x - xbar) * w


def pca_fit_svd(x: jax.Array, k: int | None = None) -> tuple[jax.Array, jax.Array, jax.Array]:
    """PCA via full (LAPACK) SVD. Returns (mean, V[:, :k], singular values).

    V columns are the principal directions; ``(y - mean) @ V`` transforms.
    """
    xbar, c = center(x)
    _, s, vt = jnp.linalg.svd(c, full_matrices=False)
    v = vt.T
    if k is not None:
        v = v[:, :k]
        s = s[:k]
    return xbar, v, s


def pca_transform(y: jax.Array, mean: jax.Array, v: jax.Array) -> jax.Array:
    """TRANSFORM step (Alg. 1 lines 5-9)."""
    return (y - mean) @ v


def explained_spectrum(x: np.ndarray) -> np.ndarray:
    """Normalized eigenvalue spectrum (paper Fig. 3): eigenvalues of the
    covariance in decreasing order, normalized to sum to 1."""
    x = np.asarray(x, dtype=np.float64)
    c = x - x.mean(axis=0)
    s = np.linalg.svd(c, compute_uv=False)
    ev = s**2
    return ev / max(ev.sum(), 1e-30)
