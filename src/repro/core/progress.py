"""Online progress estimation + convex stopping rule (paper §3.5).

Linear (two-point) extrapolation of the next iteration's basis size and
runtime over sample size, and the greedy termination test of Eq. 2:

    terminate iff  C_m(k_i) - C_m(k_hat_{i+1}) < r_hat_{i+1}

— i.e. stop when the projected next-iteration cost exceeds the projected
downstream saving. Theorem 3.1 (objective convex when C_m convex nondecreasing
and k_i a convex sequence) makes this greedy local test globally optimal.
"""

from __future__ import annotations

from repro.core.types import CostFn, IterationRecord


def extrapolate(prev: float, cur: float, m_prev: int, m_cur: int, m_next: int) -> float:
    """Paper §3.5.1 linear interpolation: v_{i+1} = v_i + dv/dm * (m_{i+1}-m_i)."""
    if m_cur == m_prev:
        return cur
    slope = (cur - prev) / float(m_cur - m_prev)
    return cur + slope * float(m_next - m_cur)


def estimate_next(
    records: list[IterationRecord], m_next: int
) -> tuple[float, float]:
    """Estimate (k_hat, r_hat) for the next sample size from the last two
    iterations. k_hat is floored at 1; r_hat at 0."""
    a, b = records[-2], records[-1]
    k_hat = extrapolate(a.k, b.k, a.sample_size, b.sample_size, m_next)
    r_hat = extrapolate(
        a.runtime_s, b.runtime_s, a.sample_size, b.sample_size, m_next
    )
    return max(k_hat, 1.0), max(r_hat, 0.0)


def should_terminate(
    records: list[IterationRecord],
    m_next: int,
    cost: CostFn,
    min_iterations: int = 2,
) -> bool:
    """Eq. 2 greedy stopping criterion."""
    if len(records) < max(min_iterations, 2):
        return False
    if not records[-1].satisfied:
        # no TLB-preserving basis yet: the constraint is not met, keep going
        return False
    k_hat, r_hat = estimate_next(records, m_next)
    saving = cost(records[-1].k) - cost(int(round(k_hat)))
    return saving < r_hat
