"""Reducer protocol — the method-agnostic face of the DROP optimizer.

The paper's thesis is that dimensionality reduction should be *optimized
end-to-end* against the downstream workload, not hard-wired to one
factorization. This module encodes that as an API: every DR operator in the
comparison (PCA, FFT, PAA, DWT, JL) is a ``Reducer`` — a resumable, steppable
runner with the same three verbs the serving stack schedules:

* ``step() -> bool`` — run one unit of work; True while more remains.
  ``PcaDropReducer`` (the Algorithm-2 loop) takes many data-dependent steps;
  the deterministic baselines are one-step reducers.
* ``result() -> ReduceResult`` — the fitted (d, k) linear map plus TLB
  telemetry. Every method here IS a linear map, so one result type (and one
  cache entry shape, one validation path) serves them all.
* ``place(device)`` — pin the reducer's compute to a mesh device (the
  sharded scheduler migrates reducers between steps).
* ``update(suffix)`` — OPTIONAL incremental path (``supports_update``
  advertises it): fold appended rows into the fitted map without a refit.
  ``PcaDropReducer`` implements it via ``core.subspace`` tracking; the
  single-shot baselines keep refit semantics and raise
  ``NotImplementedError`` (their fits are cheap and non-incremental).

``make_reducer`` is the factory the serving layer uses; ``reduce`` drives
any method to completion for one-shot callers (the generalization of the
classic ``drop()``).
"""

from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.bucketing import ShapeBucketCache
from repro.core.drop import PcaDropReducer
from repro.core.types import CostFn, DropConfig, IterationRecord, ReduceResult
from repro.utils import Clock


@runtime_checkable
class Reducer(Protocol):
    """What the scheduler needs from a DR operator (see module docstring)."""

    method: str
    done: bool
    fit_calls: int
    records: list
    cacheable: bool  # may result() be served from the basis-reuse cache?
    supports_update: bool  # does update(suffix) avoid a refit?

    def step(self) -> bool: ...

    def result(self) -> ReduceResult: ...

    def place(self, device) -> None: ...

    def update(self, suffix: np.ndarray) -> ReduceResult: ...


def method_operator(method: str, d: int, k: int, seed: int = 0) -> np.ndarray:
    """Materialize a baseline's (d, k) operator by applying it to the
    identity. Exact because every method is linear — and it is what lets
    FFT/PAA/DWT/JL results flow through the same TLB-revalidation and
    basis-reuse-cache machinery as a PCA basis."""
    eye = np.eye(d, dtype=np.float32)
    if method == "fft":
        from repro.baselines.fft import fft_real_expansion

        return fft_real_expansion(eye)[:, :k]
    if method == "dwt":
        from repro.baselines.dwt import haar_expansion

        return haar_expansion(eye)[:, :k]
    if method == "paa":
        from repro.baselines.paa import paa_transform

        return paa_transform(eye, k)
    if method == "jl":
        from repro.baselines.jl import jl_operator

        return jl_operator(d, k, seed)
    raise KeyError(f"no materialized operator for method {method!r}")


class SingleShotReducer:
    """Base for the one-step baseline reducers.

    The whole computation (expansion + shared-CI min-k search + operator
    materialization) happens in the single ``step()``; the scheduler treats
    it exactly like a one-iteration DROP run. Numerics match the legacy
    function API bit-for-bit: the min-k search reuses the same shared TLB
    machinery (``core.tlb.nested_min_k`` / ``transform_min_k``) on the same
    seeded pair sample — ``cfg.seed`` and ``cfg.max_pairs`` take the roles
    of the legacy ``seed``/``n_pairs`` arguments (defaults coincide).

    ``warm_prev_k`` and ``bucket`` are accepted for scheduler uniformity and
    ignored: single-shot methods have no rank bound to seed and no jitted
    shapes to quantize.
    """

    method = ""
    cacheable = True
    supports_update = False  # one-shot fits keep refit semantics

    def __init__(
        self,
        x: np.ndarray,
        cfg: DropConfig | None = None,
        cost: CostFn | None = None,
        *,
        warm_prev_k: int | None = None,
        bucket: ShapeBucketCache | None = None,
    ) -> None:
        self.cfg = cfg or DropConfig()
        if cost is None:
            from repro.core.cost import knn_cost

            cost = knn_cost(x.shape[0])
        self.cost = cost
        self.x = np.ascontiguousarray(x, dtype=np.float32)
        self.records: list[IterationRecord] = []
        self.fit_calls = 0
        self.total_runtime = 0.0
        self.done = False
        self.device = None
        self._result: ReduceResult | None = None
        self._clock = Clock()

    def place(self, device) -> None:
        """Host-numpy compute: placement is scheduler bookkeeping only."""
        self.device = device

    def _sample(self) -> np.ndarray:
        from repro.core.tlb import sample_pairs

        rng = np.random.default_rng(self.cfg.seed)
        return sample_pairs(self.x.shape[0], self.cfg.max_pairs, rng)

    def _solve(self) -> tuple[int, float, bool, int]:
        """(k, tlb_mean_at_k, satisfied, pairs_used) — method-specific."""
        raise NotImplementedError

    def step(self) -> bool:
        """The one step: search min-k and materialize the operator."""
        if self.done:
            return False
        self._clock.restart()
        k, tlb_mean, satisfied, pairs = self._solve()
        v = method_operator(self.method, self.x.shape[1], k, self.cfg.seed)
        r_i = self._clock.elapsed()
        self.total_runtime += r_i
        self.fit_calls += 1
        self.records.append(
            IterationRecord(
                i=0,
                sample_size=self.x.shape[0],
                k=k,
                tlb_estimate=tlb_mean,
                runtime_s=r_i,
                objective=r_i + self.cost(k),
                satisfied=satisfied,
                pairs_used=pairs,
            )
        )
        self._result = ReduceResult(
            v=v,
            mean=np.zeros(self.x.shape[1], np.float32),
            k=k,
            tlb_estimate=tlb_mean,
            satisfied=satisfied,
            runtime_s=r_i,
            iterations=self.records,
            method=self.method,
        )
        self.done = True
        return False

    def result(self) -> ReduceResult:
        assert self._result is not None, "result() before any step()"
        return self._result

    def update(self, suffix: np.ndarray) -> ReduceResult:
        """Single-shot methods keep refit semantics: their whole fit is one
        cheap step, so an incremental path has nothing to amortize."""
        raise NotImplementedError(
            f"{type(self).__name__} keeps refit semantics: appended rows "
            "require a fresh fit (supports_update=False)"
        )


class FftReducer(SingleShotReducer):
    """Fourier prefix reducer (nested: one expansion answers every k)."""

    method = "fft"

    def _solve(self) -> tuple[int, float, bool, int]:
        from repro.baselines.fft import fft_real_expansion
        from repro.core.tlb import nested_min_k

        pairs = self._sample()
        k, tlb_k = nested_min_k(
            self.x, fft_real_expansion(self.x), self.cfg.target_tlb, pairs
        )
        tlb = float(tlb_k[k - 1])
        return k, tlb, tlb >= self.cfg.target_tlb, pairs.shape[0]


class DwtReducer(SingleShotReducer):
    """Haar wavelet prefix reducer (nested, coarse-to-fine; k may exceed d
    when the pow2-padded expansion is wider than the input)."""

    method = "dwt"

    def _solve(self) -> tuple[int, float, bool, int]:
        from repro.baselines.dwt import haar_expansion
        from repro.core.tlb import nested_min_k

        pairs = self._sample()
        k, tlb_k = nested_min_k(
            self.x, haar_expansion(self.x), self.cfg.target_tlb, pairs
        )
        tlb = float(tlb_k[k - 1])
        return k, tlb, tlb >= self.cfg.target_tlb, pairs.shape[0]


class PaaReducer(SingleShotReducer):
    """PAA segment-count reducer (non-nested: binary search over k)."""

    method = "paa"

    def _solve(self) -> tuple[int, float, bool, int]:
        from repro.baselines.paa import paa_transform
        from repro.core.tlb import transform_min_k, transform_tlb_sampled

        pairs = self._sample()
        k = transform_min_k(
            self.x, paa_transform, self.cfg.target_tlb, pairs, self.x.shape[1]
        )
        mean, _, _ = transform_tlb_sampled(
            self.x, paa_transform(self.x, k), pairs
        )
        return k, float(mean), mean >= self.cfg.target_tlb, pairs.shape[0]


class JlReducer(SingleShotReducer):
    """JL random-projection reducer (data-independent; mean distance ratio
    is monotone in k, see ``jl_min_k``). Not contractive — ``satisfied``
    means the mean ratio reached the target, not a lower bound.

    Not cacheable: the operator is fully derived from (d, k, seed), so there
    is no fitting to amortize — and the serve-layer revalidation estimator
    clips per-pair ratios at 1 (correct for contractive maps), which would
    systematically under-read JL's unclipped fit-time mean and fail every
    repeat at tight targets."""

    method = "jl"
    cacheable = False

    def _solve(self) -> tuple[int, float, bool, int]:
        from repro.baselines.jl import jl_transform
        from repro.core.tlb import transform_min_k, transform_tlb_sampled

        pairs = self._sample()
        seed = self.cfg.seed
        k = transform_min_k(
            self.x,
            lambda a, kk: jl_transform(a, kk, seed),
            self.cfg.target_tlb,
            pairs,
            self.x.shape[1],
        )
        mean, _, _ = transform_tlb_sampled(
            self.x, jl_transform(self.x, k, seed), pairs
        )
        return k, float(mean), mean >= self.cfg.target_tlb, pairs.shape[0]


_REDUCERS: dict[str, type] = {
    "pca": PcaDropReducer,
    "fft": FftReducer,
    "paa": PaaReducer,
    "dwt": DwtReducer,
    "jl": JlReducer,
}

REDUCER_METHODS: tuple[str, ...] = tuple(_REDUCERS)


def method_cacheable(method: str) -> bool:
    """Whether ``method``'s results may be served from the basis-reuse
    cache (the serving layer also skips repeat-deferral for methods that
    can never be served by it)."""
    cls = _REDUCERS.get(method)
    return bool(getattr(cls, "cacheable", True))


def make_reducer(
    method: str,
    x: np.ndarray,
    cfg: DropConfig | None = None,
    cost: CostFn | None = None,
    *,
    warm_prev_k: int | None = None,
    bucket: ShapeBucketCache | None = None,
) -> Reducer:
    """Build the Reducer for ``method`` — the factory the serving stack and
    the workload optimizer share, so admission/scheduling code never
    branches on the method name."""
    try:
        cls = _REDUCERS[method]
    except KeyError:
        raise KeyError(
            f"unknown reduction method {method!r}; know {REDUCER_METHODS}"
        ) from None
    return cls(x, cfg, cost, warm_prev_k=warm_prev_k, bucket=bucket)


def reduce(
    x: np.ndarray,
    method: str = "pca",
    cfg: DropConfig | None = None,
    cost: CostFn | None = None,
) -> ReduceResult:
    """Run any method's Reducer to completion — the method-agnostic
    generalization of the classic ``drop()`` (which equals
    ``reduce(x, "pca", ...)``)."""
    runner = make_reducer(method, x, cfg, cost)
    while runner.step():
        pass
    return runner.result()
