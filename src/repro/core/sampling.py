"""Progressive + importance sampling (paper §3.3).

Each iteration draws ``ceil(schedule[i] * m)`` rows: the worst-fit points from
the previous iteration's TLB evaluation are carried forward (importance
sampling, bounded by ``reuse_fraction`` of the sample), and the remainder is
drawn uniformly without replacement from the rest of the population.
"""

from __future__ import annotations

import math

import numpy as np


def schedule_sizes(m: int, schedule) -> list[int]:
    """Absolute sample sizes for the progressive schedule (deduplicated,
    nondecreasing, capped at m)."""
    sizes: list[int] = []
    for frac in schedule:
        s = min(m, max(2, math.ceil(frac * m)))
        if not sizes or s > sizes[-1]:
            sizes.append(s)
    return sizes


def draw_sample(
    m: int,
    size: int,
    rng: np.random.Generator,
    hard_points: np.ndarray | None = None,
    reuse_fraction: float = 0.10,
) -> np.ndarray:
    """Compose the iteration's sample: carried worst-fit points + uniform fill."""
    size = min(size, m)
    carried = np.zeros(0, dtype=np.int64)
    if hard_points is not None and hard_points.size > 0 and reuse_fraction > 0:
        budget = max(1, int(reuse_fraction * size))
        carried = np.unique(hard_points.astype(np.int64))[:budget]
    remaining = size - carried.size
    if remaining > 0:
        pool = np.setdiff1d(np.arange(m, dtype=np.int64), carried, assume_unique=False)
        fill = rng.choice(pool, size=min(remaining, pool.size), replace=False)
        idx = np.concatenate([carried, fill])
    else:
        idx = carried[:size]
    rng.shuffle(idx)
    return idx


def hard_points_from_scores(
    points: np.ndarray, scores: np.ndarray, quantile: float = 0.10
) -> np.ndarray:
    """Bottom-quantile (worst TLB) points to carry into the next sample."""
    if points.size == 0:
        return points
    cutoff = np.quantile(scores, quantile)
    return points[scores <= cutoff]
