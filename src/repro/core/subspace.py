"""Incremental subspace tracking: fold appended rows into a fitted basis.

DROP's serving path treats a grown (append-only) dataset as a near-miss:
PR 3's prefix-fingerprint matching revalidates the cached map on the grown
data, but a failed revalidation falls back to a cold refit over the FULL
dataset — the most expensive operation in the service. Streaming-PCA theory
(lazy stochastic PCA, arXiv:1709.07175; stochastic-approximation PCA,
arXiv:1901.01798) says that is wasteful: the principal subspace of an
appended dataset can be tracked by folding in only the new rows.

This module implements that tracker as a mean-aware block incremental SVD
(the sequential Karhunen–Loeve / Ross et al. incremental-PCA merge):

* the state after fitting n rows is ``(V, S, mean, n)`` — the (d, w)
  orthonormal basis, its singular values, and the running mean;
* an appended suffix B of s rows updates the mean and merges via the
  augmented matrix ``[diag(S) Vᵀ; B - μ_B; sqrt(ns/(n+s)) (μ - μ_B)]``
  whose Gram matrix equals the grown dataset's centered scatter — one
  small SVD of (w + s + 1, d) instead of any pass over the n old rows;
* the merged basis is **TLB-gated**: the smallest prefix rank whose sampled
  TLB (same CI machinery as the fit path) clears the query's target is
  selected, and the carried state keeps ``TRACK_HEADROOM`` extra columns so
  the NEXT append can grow the rank if its rows open a new direction.

Cost: O((w + s) · d · min(w + s, d)) per append — O(suffix), independent of
the n rows already folded in — vs the cold refit's full Algorithm-2 run over
all n + s rows. Correctness is not assumed from the algebra alone: every
update revalidates against the query's TLB target on the grown data, and the
serving layer falls back to a cold refit when the gate fails, so the tracker
can only ever *save* work, never serve a stale map.

Everything here is float32 end-to-end (the repo's served-transform contract);
the merge asserts it, because the augmented-matrix path is an easy place to
silently promote to float64.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core.basis_search import _binary_search
from repro.core.bucketing import ShapeBucketCache
from repro.core.tlb import TLBEstimator
from repro.core.types import DropConfig, ReduceResult

# extra basis columns carried beyond the served rank: the merge can only
# grow the rank through directions present in its input, so headroom is what
# lets the NEXT append's TLB gate find a wider satisfying map without a refit
TRACK_HEADROOM = 8


@dataclass
class SubspaceTracker:
    """Updater state for one fitted map: enough to merge a suffix without
    touching the rows already folded in.

    ``v`` columns are orthonormal and singular-value ordered (nested, like a
    PCA basis), so prefix-TLB machinery applies to them unchanged. ``rows``
    is the count of rows folded in — the serving layer slices the suffix of
    a grown dataset as ``grown[tracker.rows:]``.
    """

    v: np.ndarray  # (d, w) float32 orthonormal, singular-value ordered
    s: np.ndarray  # (w,) float32 singular values of the centered data
    mean: np.ndarray  # (d,) float32 running mean of the folded rows
    rows: int

    @classmethod
    def from_fit(cls, x: np.ndarray, v: np.ndarray) -> "SubspaceTracker":
        """Bootstrap tracker state from a completed fit over ``x``: the
        singular values are estimated as the column norms of the centered
        data's projection onto the fitted basis — exact when ``v`` spans the
        true principal subspace, and close enough otherwise (the TLB gate,
        not the algebra, is what guards served quality).

        The running mean is computed EXACTLY over ``x`` rather than taken
        from the fit: DROP fits on progressive samples, so the fitted map's
        centering offset is a sample estimate — good enough to serve (TLB is
        mean-free), but the merge algebra folds means by row count and would
        compound a sampling error into every later update."""
        x = np.ascontiguousarray(np.asarray(x), dtype=np.float32)
        v = np.ascontiguousarray(np.asarray(v), dtype=np.float32)
        mean = x.mean(axis=0)
        s = np.linalg.norm((x - mean[None, :]) @ v, axis=0)
        return cls(v=v, s=s.astype(np.float32), mean=mean, rows=x.shape[0])

    @property
    def width(self) -> int:
        return int(self.v.shape[1])

    def rotation_from(self, v_served: np.ndarray) -> float:
        """Rotation-stability signal: sine of the largest principal angle
        between a served basis and this tracker's leading subspace of the
        same rank. 0.0 means the served map still spans the tracked
        directions exactly; 1.0 means some served direction left the tracked
        span entirely. The delta-serving layer gates append-vs-rollback on
        this — small rotations keep old transformed rows valid (TLB decides
        final quality), large ones void every downstream cache."""
        v_served = np.ascontiguousarray(np.asarray(v_served), dtype=np.float32)
        k = min(v_served.shape[1], self.width)
        vt = self.v[:, :k]
        v_served = v_served[:, :k]
        resid = v_served - vt @ (vt.T @ v_served)
        return float(min(1.0, np.linalg.norm(resid, ord=2)))

    def merge(self, suffix: np.ndarray, max_rank: int) -> "SubspaceTracker":
        """Fold ``suffix`` rows into the tracked subspace (pure: returns a
        new tracker, so cache entries shared across threads never mutate).

        Mean-shift + augmented block-incremental-SVD merge: the stacked
        matrix's Gram equals the grown centered scatter
        ``V S² Vᵀ + B_cᵀ B_c + (ns/(n+s)) δδᵀ`` with ``δ = μ - μ_B``, so its
        top right-singular vectors are the updated basis around the updated
        mean. Kept width is capped at ``max_rank``.
        """
        suffix = np.ascontiguousarray(np.asarray(suffix), dtype=np.float32)
        if suffix.ndim != 2 or suffix.shape[1] != self.v.shape[0]:
            raise ValueError(
                f"suffix shape {suffix.shape} does not extend a "
                f"{self.v.shape[0]}-dim tracker"
            )
        s_rows = suffix.shape[0]
        if s_rows == 0:
            return self
        n, total = self.rows, self.rows + s_rows
        mean_b = suffix.mean(axis=0)
        new_mean = (
            np.float32(n / total) * self.mean
            + np.float32(s_rows / total) * mean_b
        )
        coeff = np.float32(np.sqrt(n * s_rows / total))
        aug = np.concatenate(
            [
                self.s[:, None] * self.v.T,
                suffix - mean_b[None, :],
                coeff * (self.mean - mean_b)[None, :],
            ],
            axis=0,
        )
        _, s_new, vt = np.linalg.svd(aug, full_matrices=False)
        w = max(1, min(int(max_rank), vt.shape[0]))
        v_new = np.ascontiguousarray(vt[:w].T)
        # float32 served-transform contract: the augmented merge must not
        # silently promote (scalar coefficients above are cast explicitly)
        assert v_new.dtype == np.float32, f"merge promoted to {v_new.dtype}"
        assert new_mean.dtype == np.float32, (
            f"mean update promoted to {new_mean.dtype}"
        )
        return SubspaceTracker(
            v=v_new,
            s=np.ascontiguousarray(s_new[:w]),
            mean=new_mean,
            rows=total,
        )


def suffix_update(
    tracker: SubspaceTracker,
    grown: np.ndarray,
    cfg: DropConfig | None = None,
    *,
    bucket: ShapeBucketCache | None = None,
    headroom: int = TRACK_HEADROOM,
) -> tuple[SubspaceTracker, ReduceResult, int]:
    """Merge the suffix of ``grown`` (rows past ``tracker.rows``) into the
    tracked subspace and TLB-gate the smallest satisfying rank on the grown
    data. Returns ``(new_tracker, result, pairs_used)``.

    The gate reuses the fit path's CI-driven binary search over the merged
    (nested) basis, sampling pairs from the FULL grown dataset with the
    config-pinned validation seed — so a satisfied result carries exactly
    the same quality evidence as a served cache hit. ``result.satisfied``
    False means even the full tracked width cannot clear the target (the
    suffix opened more directions than the headroom covers): the caller
    should fall back to a cold refit.
    """
    cfg = cfg or DropConfig()
    t0 = time.perf_counter()
    grown = np.ascontiguousarray(np.asarray(grown), dtype=np.float32)
    m, d = grown.shape
    if m < tracker.rows:
        raise ValueError(
            f"grown dataset has {m} rows < tracker's {tracker.rows}"
        )
    cap_w = max(1, min(d, m, tracker.width + headroom))
    merged = tracker.merge(grown[tracker.rows :], cap_w)
    w = merged.width
    v = merged.v
    if bucket is not None:
        # shared rank-bucket padding: the gate compiles the same TLB-table
        # shapes as the fit and validation paths
        v = bucket.pad_basis(v, min(m, d))
    est = TLBEstimator(
        grown,
        jnp.asarray(v),
        np.random.default_rng(cfg.seed + 1),
        confidence=cfg.confidence,
        use_kernels=cfg.use_kernels,
        bucket=bucket,
    )
    k, tlb_mean, satisfied, pairs = _binary_search(
        est, cfg.target_tlb, w, cfg
    )
    k = max(int(k), 1)
    # Headroom exhaustion: a gate that only clears the target at the FULL
    # tracked width is serving the merge's least-converged trailing columns
    # with zero margin — the next append has no room to grow and quality
    # degrades silently append over append. Treat it as unsatisfied so the
    # caller falls back to a warm refit (and delta subscribers see a
    # rollback), unless the width already spans the whole space (min(m, d)),
    # where no refit could find more directions anyway.
    if satisfied and k >= w and w < min(m, d):
        satisfied = False
    result = ReduceResult(
        v=np.ascontiguousarray(merged.v[:, :k]),
        mean=merged.mean,
        k=k,
        tlb_estimate=float(tlb_mean),
        satisfied=bool(satisfied),
        runtime_s=time.perf_counter() - t0,
        iterations=[],
        method="pca",
    )
    assert result.v.dtype == np.float32  # served-transform contract
    # bound the carried state: the served rank plus headroom is all the next
    # append's gate can use, so wider columns are dead weight in the cache
    keep = min(w, k + headroom)
    trimmed = SubspaceTracker(
        v=np.ascontiguousarray(merged.v[:, :keep]),
        s=np.ascontiguousarray(merged.s[:keep]),
        mean=merged.mean,
        rows=merged.rows,
    )
    return trimmed, result, pairs
