"""Sampled TLB estimation with CLT confidence intervals (paper §3.4.2, Alg. 4).

TLB (Eq. 1) = mean over pairs of ||T(x_i) - T(x_j)|| / ||x_i - x_j||.

Exact TLB costs O(m^2 d); DROP instead estimates it from sampled pairs with a
Gaussian (CLT) confidence interval, doubling the pair count until the interval
clears the target (online-aggregation style).

TPU adaptation (DESIGN.md §2): because PCA bases are orthogonal and nested,
``||T_k x - T_k y||^2 = sum_{j<=k} (v_j · (x-y))^2`` — so ONE matmul of pair
differences against the full basis plus a prefix cumsum yields the TLB sample
at EVERY k simultaneously. The classic per-k evaluation (paper's binary search)
reads one column of this table; the TPU-native "prefix" search uses all of it.
Centering cancels in pair differences, so TLB is mean-free.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from scipy import stats

from repro.core.bucketing import ShapeBucketCache


def sample_pairs(m: int, p: int, rng: np.random.Generator) -> np.ndarray:
    """Draw p index pairs (i, j), i != j, uniformly (with replacement across
    pairs — standard for CLT-based online aggregation)."""
    i = rng.integers(0, m, size=p)
    j = rng.integers(0, m - 1, size=p)
    j = np.where(j >= i, j + 1, j)  # shift to skip the diagonal
    return np.stack([i, j], axis=1).astype(np.int32)


def nested_prefix_tlb(
    x: np.ndarray, expansion: np.ndarray, pairs: np.ndarray
) -> np.ndarray:
    """Sampled mean TLB at EVERY prefix length of a nested expansion.

    ``expansion`` is an (m, kmax) representation whose length-k prefix is the
    k-dim transform (FFT/DWT/PCA share this property), so one cumsum answers
    every k at once. This is the shared CI machinery behind every nested
    baseline's min-k search — float64 accumulation, clipped at 1 (the
    expansions are contractive up to padding/roundoff)."""
    xi, xj = x[pairs[:, 0]], x[pairs[:, 1]]
    dx2 = np.maximum(((xi - xj).astype(np.float64) ** 2).sum(-1), 1e-30)
    diff = (expansion[pairs[:, 0]] - expansion[pairs[:, 1]]).astype(np.float64)
    cum = np.cumsum(diff**2, axis=1)
    return np.sqrt(np.minimum(cum / dx2[:, None], 1.0)).mean(axis=0)


def nested_min_k(
    x: np.ndarray, expansion: np.ndarray, target: float, pairs: np.ndarray
) -> tuple[int, np.ndarray]:
    """Smallest prefix length achieving the TLB target (falls back to the
    full expansion width when nothing clears it). Returns (k, tlb-per-k)."""
    tlb_k = nested_prefix_tlb(x, expansion, pairs)
    ok = np.nonzero(tlb_k >= target)[0]
    k = int(ok[0]) + 1 if ok.size else expansion.shape[1]
    return k, tlb_k


def transform_tlb_sampled(
    x: np.ndarray, t: np.ndarray, pairs: np.ndarray, confidence: float = 0.95
) -> tuple[float, float, float]:
    """Sampled TLB CI of one fixed transform ``t`` of ``x`` (non-nested
    methods evaluate one k at a time through this)."""
    xi, xj = x[pairs[:, 0]], x[pairs[:, 1]]
    ti, tj = t[pairs[:, 0]], t[pairs[:, 1]]
    dx = np.sqrt(np.maximum(((xi - xj) ** 2).sum(-1), 1e-30))
    dt = np.sqrt(np.maximum(((ti - tj) ** 2).sum(-1), 0.0))
    return gaussian_ci(np.where(dx > 1e-15, dt / dx, 1.0), confidence)


def transform_min_k(
    x: np.ndarray,
    transform_fn,
    target: float,
    pairs: np.ndarray,
    kmax: int,
) -> int:
    """Binary search for the smallest k whose sampled mean TLB clears the
    target, for methods whose representations are not nested (PAA segments,
    JL redraws) but whose quality is monotone-ish in k."""
    lo, hi = 1, kmax
    while lo < hi:
        k = (lo + hi) // 2
        mean, _, _ = transform_tlb_sampled(x, transform_fn(x, k), pairs)
        if mean >= target:
            hi = k
        else:
            lo = k + 1
    return lo


@jax.jit
def prefix_tlb_table(xi: jax.Array, xj: jax.Array, v: jax.Array) -> jax.Array:
    """(p, d), (p, d), (d, kmax) -> (p, kmax) per-pair TLB at every prefix k."""
    diffs = xi - xj
    denom2 = jnp.sum(diffs * diffs, axis=-1, keepdims=True)  # (p, 1)
    z = jnp.matmul(diffs, v, precision=jax.lax.Precision.HIGHEST)  # (p, kmax)
    cum = jnp.cumsum(z * z, axis=-1)
    tlb = jnp.sqrt(jnp.clip(cum / jnp.maximum(denom2, 1e-30), 0.0, 1.0))
    # coincident pairs have zero distance in every basis: TLB contribution 1
    return jnp.where(denom2 > 1e-30, tlb, 1.0)


def _kernel_prefix_tlb(xi, xj, v):
    from repro.kernels.pairwise_tlb import ops as tlb_ops

    return tlb_ops.pairwise_tlb(xi, xj, v)


def gaussian_ci(vals: np.ndarray, confidence: float) -> tuple[float, float, float]:
    """CLT mean ± z * s/sqrt(n). Returns (mean, lo, hi)."""
    n = vals.shape[0]
    mean = float(vals.mean())
    z = float(stats.norm.ppf(0.5 + confidence / 2.0))
    half = z * float(vals.std(ddof=1)) / np.sqrt(n) if n > 1 else 1.0
    return mean, mean - half, mean + half


@dataclass
class TLBEstimate:
    mean: float
    lo: float
    hi: float
    pairs_used: int


class TLBEstimator:
    """Incrementally samples pairs from the FULL dataset and maintains the
    per-pair all-prefix TLB table for one candidate basis V.

    Pair draws double lazily; previously computed rows are reused (this is what
    lets DROP promote worst-fit pairs into the next iteration's sample)."""

    def __init__(
        self,
        x: np.ndarray,
        v: jax.Array,
        rng: np.random.Generator,
        confidence: float = 0.95,
        use_kernels: bool = False,
        bucket: ShapeBucketCache | None = None,
    ) -> None:
        self.x = x
        self.v = v
        self.rng = rng
        self.confidence = confidence
        self.bucket = bucket
        self.m = x.shape[0]
        self.num_pairs_total = self.m * (self.m - 1) // 2
        self._fn = _kernel_prefix_tlb if use_kernels else prefix_tlb_table
        self._pairs = np.zeros((0, 2), dtype=np.int32)
        self._table = np.zeros((0, int(v.shape[1])), dtype=np.float32)

    def _extend(self, p: int) -> None:
        if p <= self._pairs.shape[0]:
            return
        new = sample_pairs(self.m, p - self._pairs.shape[0], self.rng)
        xi = self.x[new[:, 0]]
        xj = self.x[new[:, 1]]
        if self.bucket is not None:
            # zero-pad the batch to its shape bucket: jit sees a bounded set of
            # pair-batch shapes across doublings/queries; padded rows (diff 0)
            # are sliced off below before they can touch the estimate
            padded = self.bucket.bucket_pairs(new.shape[0])
            if padded > new.shape[0]:
                pad = np.zeros((padded - new.shape[0], xi.shape[1]), xi.dtype)
                xi = np.concatenate([xi, pad], axis=0)
                xj = np.concatenate([xj, pad], axis=0)
        rows = np.asarray(self._fn(jnp.asarray(xi), jnp.asarray(xj), self.v))
        rows = rows[: new.shape[0]]
        self._pairs = np.concatenate([self._pairs, new], axis=0)
        self._table = np.concatenate([self._table, rows], axis=0)

    def table(self, p: int) -> np.ndarray:
        """(p, kmax) TLB table over the first p sampled pairs."""
        self._extend(p)
        return self._table[:p]

    def estimate_at_k(
        self, k: int, target: float, initial_pairs: int = 100, max_pairs: int = 6400
    ) -> TLBEstimate:
        """EVALUATE-TLB (Alg. 4 lines 11-18): double pairs until the CI clears
        the target (or the budget is exhausted). Uses only column k."""
        p = min(initial_pairs, max_pairs, self.num_pairs_total)
        while True:
            if k <= 0:
                return TLBEstimate(0.0, 0.0, 0.0, 0)
            vals = self.table(p)[:, k - 1]
            mean, lo, hi = gaussian_ci(vals, self.confidence)
            if lo > target or hi < target or p >= min(max_pairs, self.num_pairs_total):
                return TLBEstimate(mean, lo, hi, p)
            p = min(p * 2, max_pairs, self.num_pairs_total)

    def estimate_all_k(
        self, target: float, initial_pairs: int = 100, max_pairs: int = 6400
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
        """All-prefix estimation (TPU-native path): double pairs until the
        smallest-satisfying-k decision is CI-stable. Returns (mean_k, lo_k,
        hi_k, pairs_used), each of shape (kmax,)."""
        p = min(initial_pairs, max_pairs, self.num_pairs_total)
        z = float(stats.norm.ppf(0.5 + self.confidence / 2.0))
        while True:
            tab = self.table(p)
            mean = tab.mean(axis=0)
            half = z * tab.std(axis=0, ddof=1) / np.sqrt(p)
            lo, hi = mean - half, mean + half
            # decision stable when some k's lower bound clears the target, or
            # even the full basis' upper bound cannot reach it
            if (lo >= target).any() or hi[-1] < target or p >= min(
                max_pairs, self.num_pairs_total
            ):
                return mean, lo, hi, p
            p = min(p * 2, max_pairs, self.num_pairs_total)

    def point_scores(self, k: int) -> tuple[np.ndarray, np.ndarray]:
        """Per-point worst-fit scores from all evaluated pairs at dimension k:
        score(point) = min TLB over pairs touching it (lower = worse fit).
        Used for importance sampling / work reuse (§3.3.2)."""
        if self._pairs.shape[0] == 0 or k <= 0:
            return np.zeros(0, dtype=np.int32), np.zeros(0, dtype=np.float32)
        vals = self._table[:, k - 1]
        pts = self._pairs.ravel()
        scores = np.repeat(vals, 2)
        order = np.argsort(scores)  # ascending: worst first
        pts, scores = pts[order], scores[order]
        uniq, first = np.unique(pts, return_index=True)
        return uniq.astype(np.int32), scores[first].astype(np.float32)


def exact_tlb(x: np.ndarray, transform: np.ndarray, block: int = 512) -> float:
    """Exact O(m^2 d) TLB (Eq. 1) — test oracle only. ``transform`` is (d, k)."""
    x = np.asarray(x, dtype=np.float64)
    t = x @ np.asarray(transform, dtype=np.float64)
    m = x.shape[0]
    total, count = 0.0, 0
    for a in range(0, m, block):
        xa, ta = x[a : a + block], t[a : a + block]
        for b in range(a, m, block):
            xb, tb = x[b : b + block], t[b : b + block]
            dx = np.sqrt(np.maximum(
                ((xa[:, None, :] - xb[None, :, :]) ** 2).sum(-1), 1e-30))
            dt = np.sqrt(np.maximum(
                ((ta[:, None, :] - tb[None, :, :]) ** 2).sum(-1), 0.0))
            ratio = dt / dx
            if a == b:
                iu = np.triu_indices(xa.shape[0], k=1)
                total += ratio[iu].sum()
                count += iu[0].size
            else:
                total += ratio.sum()
                count += ratio.size
    return total / max(count, 1)
