"""Core dataclasses for the DROP optimizer (paper Table 1 notation)."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Literal, Sequence

import numpy as np

# Default progressive sampling schedule from §4.1 of the paper: ten steps,
# data processed no more than ~2.4x in total.
DEFAULT_SCHEDULE: tuple[float, ...] = (
    0.01, 0.02, 0.03, 0.04, 0.05, 0.10, 0.20, 0.30, 0.65, 1.00,
)


@dataclass(frozen=True)
class DropConfig:
    """Inputs of Problem 3.1 plus implementation knobs.

    Attributes:
        target_tlb: B — TLB preservation level (paper default 0.98).
        confidence: c — confidence for the sampled TLB estimate (default 0.95).
        schedule: progressive sampling schedule (fractions of m).
        reuse_fraction: q/100 — bottom-percentile of points carried into the
            next sample (importance sampling / work reuse; paper default 0.10).
        svd: "halko" (paper's randomized PCA) or "full" (exact SVD).
        halko_oversample: p in Algorithm 3 (default 5).
        halko_power_iters: q in Algorithm 3 (default 1).
        search: "binary" (paper Algorithm 4) or "prefix" (TPU-native all-prefix
            TLB search — one fused pass instead of O(log d) evaluations).
        initial_pairs: starting pair count for the TLB CI loop (paper: 100).
        max_pairs: cap on TLB evaluation pairs (paper observes <=300 typical).
        use_kernels: route hot matmuls through the Pallas kernel wrappers.
        min_iterations: run at least this many iterations before the progress
            estimator may terminate (needs 2 points for a slope).
        seed: determinism.
    """

    target_tlb: float = 0.98
    confidence: float = 0.95
    schedule: Sequence[float] = DEFAULT_SCHEDULE
    reuse_fraction: float = 0.10
    svd: Literal["halko", "full"] = "halko"
    halko_oversample: int = 5
    halko_power_iters: int = 1
    search: Literal["binary", "prefix"] = "binary"
    initial_pairs: int = 100
    # the paper observes <=300 pairs suffice; the cap only binds when the CI
    # straddles the target at the boundary k (where more pairs cannot change
    # the decision materially but cost O(pairs x d x k) each)
    max_pairs: int = 800
    use_kernels: bool = False
    min_iterations: int = 2
    seed: int = 0


@dataclass
class IterationRecord:
    """Per-iteration telemetry (i, m_i, k_i, r_i, obj_i)."""

    i: int
    sample_size: int
    k: int
    tlb_estimate: float
    runtime_s: float
    objective: float
    satisfied: bool
    pairs_used: int


@dataclass
class DropResult:
    """DROP output: T_k (here V: d x k, plus the train-mean for centering)."""

    v: np.ndarray  # (d, k) PCA projection matrix (columns = components)
    mean: np.ndarray  # (d,) training column means
    k: int
    tlb_estimate: float
    satisfied: bool
    runtime_s: float
    iterations: list[IterationRecord] = field(default_factory=list)

    def transform(self, y: np.ndarray) -> np.ndarray:
        """Apply the learned transformation (Algorithm 1 TRANSFORM)."""
        return (np.asarray(y) - self.mean) @ self.v

    @property
    def total_rows_processed(self) -> int:
        return sum(rec.sample_size for rec in self.iterations)


CostFn = Callable[[int], float]
