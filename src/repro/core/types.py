"""Core dataclasses for the DROP optimizer (paper Table 1 notation)."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Literal, Sequence

import numpy as np

# Default progressive sampling schedule from §4.1 of the paper: ten steps,
# data processed no more than ~2.4x in total.
DEFAULT_SCHEDULE: tuple[float, ...] = (
    0.01, 0.02, 0.03, 0.04, 0.05, 0.10, 0.20, 0.30, 0.65, 1.00,
)


@dataclass(frozen=True)
class DropConfig:
    """Inputs of Problem 3.1 plus implementation knobs.

    Attributes:
        target_tlb: B — TLB preservation level (paper default 0.98).
        confidence: c — confidence for the sampled TLB estimate (default 0.95).
        schedule: progressive sampling schedule (fractions of m).
        reuse_fraction: q/100 — bottom-percentile of points carried into the
            next sample (importance sampling / work reuse; paper default 0.10).
        svd: "halko" (paper's randomized PCA) or "full" (exact SVD).
        halko_oversample: p in Algorithm 3 (default 5).
        halko_power_iters: q in Algorithm 3 (default 1).
        search: "binary" (paper Algorithm 4) or "prefix" (TPU-native all-prefix
            TLB search — one fused pass instead of O(log d) evaluations).
        initial_pairs: starting pair count for the TLB CI loop (paper: 100).
        max_pairs: cap on TLB evaluation pairs (paper observes <=300 typical).
        use_kernels: route hot matmuls through the Pallas kernel wrappers.
        min_iterations: run at least this many iterations before the progress
            estimator may terminate (needs 2 points for a slope).
        seed: determinism.
    """

    target_tlb: float = 0.98
    confidence: float = 0.95
    schedule: Sequence[float] = DEFAULT_SCHEDULE
    reuse_fraction: float = 0.10
    svd: Literal["halko", "full"] = "halko"
    halko_oversample: int = 5
    halko_power_iters: int = 1
    search: Literal["binary", "prefix"] = "binary"
    initial_pairs: int = 100
    # the paper observes <=300 pairs suffice; the cap only binds when the CI
    # straddles the target at the boundary k (where more pairs cannot change
    # the decision materially but cost O(pairs x d x k) each)
    max_pairs: int = 800
    use_kernels: bool = False
    min_iterations: int = 2
    seed: int = 0


@dataclass
class IterationRecord:
    """Per-iteration telemetry (i, m_i, k_i, r_i, obj_i)."""

    i: int
    sample_size: int
    k: int
    tlb_estimate: float
    runtime_s: float
    objective: float
    satisfied: bool
    pairs_used: int


@dataclass
class ReduceResult:
    """Output of any ``Reducer`` — the paper's T_k as an explicit linear map.

    Every operator in the comparison (PCA, FFT, PAA, DWT, JL) is a linear
    transformation, so one representation serves them all: ``v`` is the
    (d, k) operator matrix and ``mean`` the centering offset (all-zero for
    the baselines, which do not center). This is what makes the serving
    stack method-agnostic: the TLB revalidation, the basis-reuse cache, and
    ``transform`` never need to know which method fitted the map.

    ``DropResult`` is the deprecated alias (the PCA-only era name).
    """

    v: np.ndarray  # (d, k) linear operator (PCA: basis columns)
    mean: np.ndarray  # (d,) centering offset (zeros for uncentered methods)
    k: int
    tlb_estimate: float
    satisfied: bool
    runtime_s: float
    iterations: list[IterationRecord] = field(default_factory=list)
    method: str = "pca"

    def transform(self, y: np.ndarray) -> np.ndarray:
        """Apply the learned transformation (Algorithm 1 TRANSFORM).

        Inputs are cast through float32 first: the map was fit in float32,
        and a float64 caller must see bit-identical outputs to a float32
        caller (served transforms are cached and compared across tenants).
        """
        y32 = np.asarray(y, dtype=np.float32)
        return (y32 - np.asarray(self.mean, dtype=np.float32)) @ np.asarray(
            self.v, dtype=np.float32
        )

    @property
    def total_rows_processed(self) -> int:
        return sum(rec.sample_size for rec in self.iterations)


DropResult = ReduceResult  # deprecated alias (pre-Reducer API)

CostFn = Callable[[int], float]
