"""Data substrate: synthetic UCR-like time series suite, windowing, LM token pipeline."""

from repro.data.timeseries import (  # noqa: F401
    ecg_like,
    random_walk,
    sinusoid_mixture,
    ucr_like_suite,
    white_noise,
    znormalize,
)
