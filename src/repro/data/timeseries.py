"""Synthetic structured time-series generators (UCR-archive stand-ins).

The UCR Time Series Classification Archive used by the paper is not
redistributable offline, so we synthesize datasets with the *structural*
properties the paper exploits: high regularity / low intrinsic dimensionality
(periodic ECG-like signals, sinusoid mixtures of controlled rank) plus
unstructured controls (random walks, white noise — the "Phoneme"-like worst
case). Shapes (m, d) are matched to the 18 largest UCR datasets.

All generators return float32 numpy arrays of shape (m, d) and a label vector
(m,) for downstream k-NN/classification experiments.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def znormalize(x: np.ndarray, axis: int = -1, eps: float = 1e-8) -> np.ndarray:
    """Per-series z-normalization (standard UCR preprocessing)."""
    mu = x.mean(axis=axis, keepdims=True)
    sd = x.std(axis=axis, keepdims=True)
    return ((x - mu) / (sd + eps)).astype(np.float32)


def sinusoid_mixture(
    m: int,
    d: int,
    rank: int = 8,
    n_classes: int = 4,
    noise: float = 0.05,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Linear combinations of `rank` fixed sinusoids -> data matrix of rank ~rank.

    This is the generator used in the paper's scalability experiment (§4.3):
    "sampling linear combinations of sinusoids with random amplitude and phase
    shifts such that the intrinsic dimensionality remains fixed".
    """
    rng = np.random.default_rng(seed)
    t = np.linspace(0.0, 1.0, d, dtype=np.float64)
    freqs = rng.uniform(1.0, 12.0, size=rank)
    phases = rng.uniform(0.0, 2 * np.pi, size=rank)
    basis = np.stack([np.sin(2 * np.pi * f * t + p) for f, p in zip(freqs, phases)])
    labels = rng.integers(0, n_classes, size=m)
    # class-conditioned amplitude means so k-NN retrieval is meaningful
    class_means = rng.normal(0.0, 1.0, size=(n_classes, rank))
    amps = class_means[labels] + 0.3 * rng.normal(size=(m, rank))
    x = amps @ basis + noise * rng.normal(size=(m, d))
    return znormalize(x), labels.astype(np.int32)


def ecg_like(
    m: int,
    d: int,
    n_classes: int = 5,
    noise: float = 0.05,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Quasi-periodic spike trains mimicking ECG heartbeats (highly structured).

    Each class has a characteristic beat morphology (QRS-like gaussian bumps);
    instances vary phase, rate, and baseline wander. Intrinsic dimensionality is
    low: a handful of morphology + phase factors explain most variance.
    """
    rng = np.random.default_rng(seed)
    t = np.linspace(0.0, 1.0, d, dtype=np.float64)
    labels = rng.integers(0, n_classes, size=m)
    # per-class morphology: widths/heights/offsets of 3 bumps (P, QRS, T waves)
    widths = rng.uniform(0.01, 0.05, size=(n_classes, 3))
    heights = np.abs(rng.normal(1.0, 0.5, size=(n_classes, 3))) * np.array([0.3, 1.5, 0.5])
    offsets = np.array([0.18, 0.30, 0.55]) + rng.normal(0, 0.02, size=(n_classes, 3))
    x = np.empty((m, d), dtype=np.float64)
    period = rng.uniform(0.28, 0.40, size=m)  # beats per unit time vary by instance
    phase = rng.uniform(0.0, 1.0, size=m)
    for i in range(m):
        c = labels[i]
        sig = 0.15 * np.sin(2 * np.pi * (t + phase[i]))  # baseline wander
        # repeat the beat template at quasi-periodic positions
        pos = np.arange(-1.0, 2.0, period[i]) + phase[i] * period[i]
        for p0 in pos:
            for b in range(3):
                center = p0 + offsets[c, b] * period[i]
                sig += heights[c, b] * np.exp(-0.5 * ((t - center) / widths[c, b]) ** 2)
        x[i] = sig
    x += noise * rng.normal(size=(m, d))
    return znormalize(x), labels.astype(np.int32)


def random_walk(
    m: int, d: int, n_classes: int = 2, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Gaussian random walks: decaying spectrum but far less structure."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, size=m)
    drift = (labels.astype(np.float64) - (n_classes - 1) / 2) * 0.02
    steps = rng.normal(size=(m, d)) + drift[:, None]
    return znormalize(np.cumsum(steps, axis=1)), labels.astype(np.int32)


def white_noise(
    m: int, d: int, n_classes: int = 2, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """No structure at all — the paper's 'sensor malfunction' / Phoneme-like
    worst case, where near-full sampling is required."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, size=m)
    return znormalize(rng.normal(size=(m, d))), labels.astype(np.int32)


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    kind: str  # generator name
    m: int
    d: int
    rank: int | None = None  # intrinsic rank for sinusoid datasets
    seed: int = 0


# Shape-matched stand-ins for the 18 largest UCR datasets used in the paper's
# evaluation (names keep the UCR flavor but data is synthetic; see DESIGN.md §2).
UCR_LIKE_SPECS: tuple[DatasetSpec, ...] = (
    DatasetSpec("SynStarLightCurves", "sinusoid", 9236, 1024, rank=4, seed=11),
    DatasetSpec("SynECG5000", "ecg", 5000, 140, seed=12),
    DatasetSpec("SynElectricDevices", "sinusoid", 16637, 96, rank=12, seed=13),
    DatasetSpec("SynFordA", "sinusoid", 4921, 500, rank=24, seed=14),
    DatasetSpec("SynFordB", "sinusoid", 4446, 500, rank=28, seed=15),
    DatasetSpec("SynMALLAT", "sinusoid", 2400, 1024, rank=8, seed=16),
    DatasetSpec("SynNonInvasiveECG", "ecg", 3765, 750, seed=17),
    DatasetSpec("SynPhoneme", "noise", 2110, 1024, seed=18),
    DatasetSpec("SynUWaveAll", "sinusoid", 4478, 945, rank=18, seed=19),
    DatasetSpec("SynWafer", "ecg", 7164, 152, seed=20),
    DatasetSpec("SynYoga", "sinusoid", 3300, 426, rank=10, seed=21),
    DatasetSpec("SynTwoPatterns", "sinusoid", 5000, 128, rank=6, seed=22),
    DatasetSpec("SynChlorine", "sinusoid", 4307, 166, rank=3, seed=23),
    DatasetSpec("SynCinC", "ecg", 1420, 1639, seed=24),
    DatasetSpec("SynHandOutlines", "sinusoid", 1370, 2709, rank=14, seed=25),
    DatasetSpec("SynInsectSound", "sinusoid", 2200, 600, rank=16, seed=26),
    DatasetSpec("SynRandomWalk", "walk", 4000, 512, seed=27),
    DatasetSpec("SynShapesAll", "sinusoid", 1200, 512, rank=9, seed=28),
)

_GENERATORS = {
    "sinusoid": lambda s: sinusoid_mixture(s.m, s.d, rank=s.rank or 8, seed=s.seed),
    "ecg": lambda s: ecg_like(s.m, s.d, seed=s.seed),
    "walk": lambda s: random_walk(s.m, s.d, seed=s.seed),
    "noise": lambda s: white_noise(s.m, s.d, seed=s.seed),
}


def make_dataset(spec: DatasetSpec) -> tuple[np.ndarray, np.ndarray]:
    return _GENERATORS[spec.kind](spec)


def ucr_like_suite(
    max_datasets: int | None = None, max_m: int | None = None
) -> dict[str, tuple[np.ndarray, np.ndarray]]:
    """Generate the full UCR-like suite as {name: (X, labels)}.

    ``max_m`` caps dataset rows (useful for fast CI runs); sampling the first
    rows is safe because generators draw instances i.i.d.
    """
    out: dict[str, tuple[np.ndarray, np.ndarray]] = {}
    for spec in UCR_LIKE_SPECS[: (max_datasets or len(UCR_LIKE_SPECS))]:
        x, y = make_dataset(spec)
        if max_m is not None and x.shape[0] > max_m:
            x, y = x[:max_m], y[:max_m]
        out[spec.name] = (x, y)
    return out


def mnist_like(
    m: int = 4096, side: int = 28, n_classes: int = 10, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """Structured image-like data (§4.5 stand-in for MNIST): smooth class
    prototypes mixed through a small factor space, flattened to (m, d).

    Like real MNIST (whose PCA spectrum concentrates ~90% of variance in a
    few dozen components), instances live near a low-dimensional manifold:
    each image is a class prototype plus a few smooth deformation modes, with
    mild pixel noise — so sampling-based PCA has real structure to find."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, size=m)
    yy, xx = np.mgrid[0:side, 0:side].astype(np.float64) / side
    protos, modes = [], []
    for c in range(n_classes):
        cx, cy = rng.uniform(0.3, 0.7, size=2)
        sx, sy = rng.uniform(0.08, 0.22, size=2)
        theta = rng.uniform(0, np.pi)
        u = (xx - cx) * np.cos(theta) + (yy - cy) * np.sin(theta)
        v = -(xx - cx) * np.sin(theta) + (yy - cy) * np.cos(theta)
        protos.append(np.exp(-0.5 * ((u / sx) ** 2 + (v / sy) ** 2)))
    # shared smooth deformation modes (translation/scale/shear gradients)
    base = protos[0]
    for gx, gy in ((1, 0), (0, 1), (1, 1), (2, 0), (0, 2)):
        modes.append(np.sin(np.pi * gx * xx) * np.sin(np.pi * gy * yy))
    protos = np.stack(protos).reshape(n_classes, -1)
    modes = np.stack(modes).reshape(len(modes), -1)
    coeff = 0.25 * rng.normal(size=(m, len(modes)))
    x = protos[labels] + coeff @ modes
    x += 0.005 * rng.normal(size=x.shape)
    return znormalize(x), labels.astype(np.int32)
