"""LM token pipeline: deterministic synthetic corpus, host-sharded batching.

Data here is a synthetic Zipf-distributed token stream with Markov structure
(so loss curves actually descend), generated deterministically from
(seed, step, host) — the same property a fleet-scale pipeline gets from
tfds/grain index files: any host can reconstruct its shard of any step
without coordination, which is what makes data loading restartable.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


class TokenPipeline:
    """Stateless per-step batch synthesis: batch(step) is a pure function."""

    def __init__(self, cfg: TokenPipelineConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_hosts
        rng = np.random.default_rng(cfg.seed)
        # fixed Markov transition structure over a small state space projected
        # onto the vocab: gives learnable bigram statistics
        self.n_states = 64
        self.trans = rng.dirichlet(np.ones(self.n_states) * 0.2, self.n_states)
        zipf = 1.0 / np.arange(1, cfg.vocab_size + 1) ** 1.1
        self.state_vocab = [
            rng.choice(cfg.vocab_size, p=zipf / zipf.sum(), size=256)
            for _ in range(self.n_states)
        ]

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id])
        )
        b, s = self.local_batch, cfg.seq_len
        states = rng.integers(0, self.n_states, size=b)
        toks = np.empty((b, s + 1), np.int32)
        for t in range(s + 1):
            # vectorized markov walk
            u = rng.random(b)
            cdfs = np.cumsum(self.trans[states], axis=1)
            states = (u[:, None] < cdfs).argmax(axis=1)
            pick = rng.integers(0, 256, size=b)
            toks[:, t] = np.array(
                [self.state_vocab[st][p] for st, p in zip(states, pick)]
            )
        return {
            "inputs": toks[:, :-1],
            "targets": toks[:, 1:],
            "mask": np.ones((b, s), np.float32),
        }
