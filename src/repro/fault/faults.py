"""Fault tolerance: failure injection, restart policy, straggler mitigation,
elastic re-mesh. Simulated faithfully on this container; each mechanism maps
1:1 onto its fleet-scale counterpart (noted inline).

At 1000+ nodes the dominant events are (a) node loss -> restart from the last
atomic checkpoint, (b) stragglers -> per-step deadline + skip/flag, (c)
topology change -> re-mesh and re-place mesh-agnostic checkpoints. The
Trainer (trainer.py) wires these together.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import numpy as np


class NodeFailure(RuntimeError):
    """Stand-in for a device/host loss (fleet: ICI error, preemption)."""


@dataclass
class FailureInjector:
    """Deterministic pseudo-random failures for restart-path testing."""

    failure_prob: float = 0.0
    seed: int = 0
    _rng: np.random.Generator = field(init=False)
    injected: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def maybe_fail(self, step: int) -> None:
        if self.failure_prob > 0 and self._rng.random() < self.failure_prob:
            self.injected += 1
            raise NodeFailure(f"injected node failure at step {step}")


@dataclass
class StragglerMonitor:
    """Per-step deadline tracking (fleet: collective timeouts + hot spares).

    A step exceeding ``deadline_factor`` x the rolling median is flagged; after
    ``tolerance`` consecutive flags the policy escalates (here: recorded and
    surfaced; fleet: evict + re-mesh)."""

    deadline_factor: float = 3.0
    tolerance: int = 3
    window: int = 32
    _times: list[float] = field(default_factory=list)
    flagged_steps: list[int] = field(default_factory=list)
    consecutive: int = 0

    def observe(self, step: int, seconds: float) -> bool:
        """Returns True when this step is a straggler."""
        hist = self._times[-self.window :]
        self._times.append(seconds)
        if len(self._times) > self.window:
            # a long-lived supervisor observes forever: only the rolling
            # window ever feeds the median, so older samples are dead weight
            del self._times[: len(self._times) - self.window]
        if len(hist) < 5:
            return False
        median = float(np.median(hist))
        if seconds > self.deadline_factor * median:
            self.flagged_steps.append(step)
            self.consecutive += 1
            return True
        self.consecutive = 0
        return False

    @property
    def should_escalate(self) -> bool:
        return self.consecutive >= self.tolerance


def remesh(tree, new_mesh, specs) -> object:
    """Elastic re-mesh: re-place a (host-resident or committed) pytree onto a
    different mesh. Checkpoints are mesh-agnostic (ckpt.py), so this is just
    device_put with shardings resolved against the new topology."""
    import jax
    from jax.sharding import NamedSharding

    shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(new_mesh, s), specs
    )
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(np.asarray(jax.device_get(x)), s),
        tree,
        shardings,
    )


@dataclass
class RestartPolicy:
    """Restart budget with capped exponential backoff.

    ``backoff_s`` is the base delay before the first restart; each further
    restart doubles it up to ``backoff_cap_s`` (0.0 disables sleeping, the
    test default). Both ``run`` (inline restart-on-NodeFailure) and the
    serving fleet's supervisor (which schedules restarts asynchronously via
    ``delay``) consume the same policy."""

    max_restarts: int = 5
    backoff_s: float = 0.0  # base delay; tests: none
    backoff_cap_s: float = 30.0

    def delay(self, restarts: int) -> float:
        """Backoff before restart number ``restarts`` (1-based):
        ``backoff_s * 2**(restarts-1)`` capped at ``backoff_cap_s``."""
        if self.backoff_s <= 0.0 or restarts <= 0:
            return 0.0
        return min(self.backoff_cap_s, self.backoff_s * 2.0 ** (restarts - 1))

    def run(self, fn: Callable[[], None]) -> int:
        """Run fn with restart-on-NodeFailure. Returns restart count."""
        restarts = 0
        while True:
            try:
                fn()
                return restarts
            except NodeFailure:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                d = self.delay(restarts)
                if d:
                    time.sleep(d)
