# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

from __future__ import annotations

import os

import jax


def kernel_backend_live() -> bool:
    """Whether a Pallas kernel would actually EXECUTE as a kernel here:
    native on TPU, interpreter under ``REPRO_PALLAS_INTERPRET=1``. The
    shared copy of the gating rule (``pairwise_reduce/ops.py`` dispatches
    through it; the older per-kernel ``ops.py`` files predate it) — callers
    with a fused-jnp fallback (``analytics.pairwise``) consult it before
    routing to a dispatcher, so ``use_kernels=True`` on a plain CPU backend
    falls back to the fused path instead of a materializing ref oracle.
    Deliberately import-light: no pallas imports at package level."""
    if jax.default_backend() == "tpu":
        return True
    return os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1"
