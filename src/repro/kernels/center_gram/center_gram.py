"""Fused mean-center + Gram (covariance) Pallas kernel.

G = (X - mean)ᵀ (X - mean) = XᵀX - m * mean meanᵀ

Used by the covariance-path PCA (d <= m regime: eigendecompose the d x d Gram
instead of SVD on the m x d matrix). Fusing the centering into the Gram
accumulation removes a full HBM round-trip of the centered copy of X — the
paper's Algorithm 1 materializes C_X; on TPU that write+read of m*d floats is
pure memory-roofline waste.

TPU mapping: grid (d/bi, d/bj, m/bm); the row axis is 'arbitrary' (sequential)
carrying the partial Gram tile and the two partial column-sum rows in VMEM
scratch; at the last row-step the tile is corrected by -m*mu_i muⱼᵀ and
flushed. X is read twice (once per column block side) but never written.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _center_gram_kernel(xi_ref, xj_ref, o_ref, g_ref, si_ref, sj_ref, *, nm: int, m: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        si_ref[...] = jnp.zeros_like(si_ref)
        sj_ref[...] = jnp.zeros_like(sj_ref)

    xi = xi_ref[...].astype(jnp.float32)  # (bm, bi)
    xj = xj_ref[...].astype(jnp.float32)  # (bm, bj)
    g_ref[...] += jnp.dot(xi.T, xj, preferred_element_type=jnp.float32)
    si_ref[...] += jnp.sum(xi, axis=0, keepdims=True)
    sj_ref[...] += jnp.sum(xj, axis=0, keepdims=True)

    @pl.when(pl.program_id(2) == nm - 1)
    def _flush():
        mu_i = si_ref[...] / m  # (1, bi)
        mu_j = sj_ref[...] / m  # (1, bj)
        o_ref[...] = (g_ref[...] - m * mu_i.T @ mu_j).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_d", "block_m", "interpret")
)
def center_gram_pallas(
    x: jax.Array,
    block_d: int = 256,
    block_m: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """(m, d) -> (d, d) centered Gram matrix, single streaming pass over X."""
    m, d = x.shape
    bd, bm = min(block_d, d), min(block_m, m)
    pd = (-d) % bd
    pm = (-m) % bm
    if pd or pm:
        # zero row padding adds nothing to sums; zero column padding yields
        # zero rows/cols in G which we slice away
        x = jnp.pad(x, ((0, pm), (0, pd)))
    mp, dp = x.shape
    nm = mp // bm

    out = pl.pallas_call(
        functools.partial(_center_gram_kernel, nm=nm, m=m),
        grid=(dp // bd, dp // bd, nm),
        in_specs=[
            pl.BlockSpec((bm, bd), lambda i, j, s: (s, i)),
            pl.BlockSpec((bm, bd), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bd, bd), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dp, dp), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bd, bd), jnp.float32),
            pltpu.VMEM((1, bd), jnp.float32),
            pltpu.VMEM((1, bd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, x)
    return out[:d, :d]
