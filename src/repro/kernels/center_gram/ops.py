"""Dispatch wrapper for the fused center+gram kernel."""

from __future__ import annotations

import os

import jax

from repro.kernels.center_gram.center_gram import center_gram_pallas
from repro.kernels.center_gram.ref import center_gram_ref


def center_gram(x: jax.Array, **kw) -> jax.Array:
    if jax.default_backend() == "tpu":
        return center_gram_pallas(x, **kw)
    if os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1":
        return center_gram_pallas(x, interpret=True, **kw)
    return center_gram_ref(x)
