"""Pure-jnp oracle for the fused center+gram kernel."""

import jax
import jax.numpy as jnp


def center_gram_ref(x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    c = xf - jnp.mean(xf, axis=0)
    return jnp.matmul(c.T, c, precision=jax.lax.Precision.HIGHEST)
