"""Pallas API compatibility across jax versions.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (~0.5);
the kernels are written against the new name and this shim keeps them
importable on the 0.4.x line baked into the container image.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
