"""Single-token flash-decode Pallas kernel — the per-shard hot loop of the
distributed decode attention (serve/decode.py runs this math per model shard;
on TPU this kernel replaces the jnp einsum path inside the shard_map).

For one new query against a length-T cache:
    scores(t) = q . k_t * scale   (masked by cache validity)
    out       = softmax(scores) @ V        via the online recurrence

TPU mapping: grid (B, T/bt). The T axis is 'arbitrary' (sequential): each step
streams one (bt, KV, hd) cache tile HBM->VMEM, updates the running
(max, denom, acc) scratch — O(1) VMEM regardless of T, reading the cache
exactly once (the op is purely HBM-bandwidth-bound, as the roofline analysis
shows for decode cells). Batch is 'parallel'.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _fd_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref, acc_ref,
               *, nt: int, scale: float):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)        # (KV, G, hd)
    k = k_ref[0].astype(jnp.float32)        # (bt, KV, hd)
    v = v_ref[0].astype(jnp.float32)        # (bt, KV, hd)
    ok = valid_ref[0]                        # (bt,)

    # scores: (KV, G, bt)
    s = jnp.einsum("kgh,tkh->kgt", q, k) * scale
    s = jnp.where(ok[None, None, :], s, NEG_INF)

    m_old = m_ref[...]                       # (KV, G)
    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])        # (KV, G, bt)
    corr = jnp.exp(m_old - m_new)
    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum(
        "kgt,tkh->kgh", p, v
    )

    @pl.when(pl.program_id(1) == nt - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_t", "interpret")
)
def flash_decode_pallas(
    q: jax.Array,        # (B, KV, G, hd)
    k_cache: jax.Array,  # (B, T, KV, hd)
    v_cache: jax.Array,  # (B, T, KV, hd)
    valid: jax.Array,    # (B, T) bool
    block_t: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Returns (B, KV, G, hd) attention output for the single new token."""
    b, kv, g, hd = q.shape
    t = k_cache.shape[1]
    bt = min(block_t, t)
    pt = (-t) % bt
    if pt:  # pad the cache tail; padded slots are masked invalid
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pt), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pt), (0, 0), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, pt)))
    nt = (t + pt) // bt
    scale = 1.0 / float(hd) ** 0.5

    return pl.pallas_call(
        functools.partial(_fd_kernel, nt=nt, scale=scale),
        grid=(b, nt),
        in_specs=[
            pl.BlockSpec((1, kv, g, hd), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((1, bt, kv, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, bt, kv, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, bt), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, kv, g, hd), lambda i, j: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((kv, g), jnp.float32),       # running max
            pltpu.VMEM((kv, g), jnp.float32),       # running denom
            pltpu.VMEM((kv, g, hd), jnp.float32),   # running numerator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k_cache, v_cache, valid)
