"""Dispatch wrapper for the flash-decode kernel."""

from __future__ import annotations

import os

import jax

from repro.kernels.flash_decode.flash_decode import flash_decode_pallas
from repro.kernels.flash_decode.ref import flash_decode_ref


def flash_decode(q, k_cache, v_cache, valid, **kw):
    if jax.default_backend() == "tpu":
        return flash_decode_pallas(q, k_cache, v_cache, valid, **kw)
    if os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1":
        return flash_decode_pallas(q, k_cache, v_cache, valid,
                                   interpret=True, **kw)
    return flash_decode_ref(q, k_cache, v_cache, valid)
