"""Pure-jnp oracle for the flash-decode kernel."""

import jax
import jax.numpy as jnp


def flash_decode_ref(q, k_cache, v_cache, valid):
    """q: (B, KV, G, hd); caches: (B, T, KV, hd); valid: (B, T)."""
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    s = jnp.einsum("bkgh,btkh->bkgt", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)
