"""Tiled MXU matmul Pallas kernel — the O(mdk) hot spot of SVD-Halko.

TPU mapping: grid (M/bm, N/bn, K/bk); A and B tiles stream HBM->VMEM per
BlockSpec; partial products accumulate in an f32 VMEM scratch tile so the MXU
(128x128 systolic array) sees hardware-aligned (bm, bk) x (bk, bn) contractions;
the K grid axis is 'arbitrary' (sequential) for the accumulation carry, M/N are
'parallel'. Default 256x256x512 tiles keep the working set
(bm*bk + bk*bn + bm*bn floats ~ 1.3 MB) well inside the ~16 MB/core VMEM while
amortizing HBM reads ~256x (arithmetic intensity >> the ~240 flop/byte ridge).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """C = A @ B with zero-padding to tile multiples (stripped on return)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {a.shape} @ {b.shape}"
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)

    def _pad(x, mult0, mult1):
        p0 = (-x.shape[0]) % mult0
        p1 = (-x.shape[1]) % mult1
        if p0 or p1:
            x = jnp.pad(x, ((0, p0), (0, p1)))
        return x

    ap = _pad(a, bm, bk)
    bp = _pad(b, bk, bn)
    mp, kp = ap.shape
    _, np_ = bp.shape
    nk = kp // bk

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]
