"""Jitted dispatch wrapper for the tiled matmul kernel.

On TPU backends the Pallas kernel runs natively; elsewhere (this CPU
container) we fall back to the jnp oracle unless ``REPRO_PALLAS_INTERPRET=1``
forces interpreter-mode execution (used by the kernel test-suite sweeps).
"""

from __future__ import annotations

import os

import jax

from repro.kernels.matmul.matmul import matmul_pallas
from repro.kernels.matmul.ref import matmul_ref


def _use_interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1"


def matmul(a: jax.Array, b: jax.Array, **block_kwargs) -> jax.Array:
    if jax.default_backend() == "tpu":
        return matmul_pallas(a, b, **block_kwargs)
    if _use_interpret():
        return matmul_pallas(a, b, interpret=True, **block_kwargs)
    return matmul_ref(a, b)
