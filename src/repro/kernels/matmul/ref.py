"""Pure-jnp oracle for the tiled matmul kernel."""

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.matmul(a, b, precision=jax.lax.Precision.HIGHEST)
