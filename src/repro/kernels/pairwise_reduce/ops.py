"""Dispatch wrappers for the pairwise-reduction kernels (TPU / interpret /
ref), following the ``kernels/matmul`` + ``kernels/pairwise_tlb`` convention:
native Pallas on TPU, interpreter mode under ``REPRO_PALLAS_INTERPRET=1``,
pure-jnp oracle everywhere else.

Note the production CPU path does NOT come through here:
``analytics.pairwise`` only routes to these wrappers when a kernel backend
is live (TPU or interpret mode), and otherwise runs its fused jnp scan —
the ref oracles below materialize the full distance matrix and exist for
the kernel test sweeps and direct callers only.
"""

from __future__ import annotations

import jax

from repro.kernels import kernel_backend_live
import jax.numpy as jnp

from repro.kernels.pairwise_reduce.pairwise_reduce import (
    pairwise_dbscan_pallas,
    pairwise_dbscan_split_pallas,
    pairwise_kde_pallas,
    pairwise_kde_split_pallas,
    pairwise_knn_pallas,
    pairwise_knn_split_pallas,
)
from repro.kernels.pairwise_reduce.ref import (
    pairwise_dbscan_ref,
    pairwise_dbscan_split_ref,
    pairwise_kde_ref,
    pairwise_kde_split_ref,
    pairwise_knn_ref,
    pairwise_knn_split_ref,
)


def pairwise_knn_reduce(xq: jax.Array, x: jax.Array, m: int, **kw):
    if jax.default_backend() == "tpu":
        return pairwise_knn_pallas(xq, x, m, **kw)
    if kernel_backend_live():  # non-TPU: true only under interpret mode
        return pairwise_knn_pallas(xq, x, m, interpret=True, **kw)
    return pairwise_knn_ref(xq, x, m)


def pairwise_dbscan_reduce(
    xq: jax.Array, x: jax.Array, m: int, eps2: float, **kw
):
    if jax.default_backend() == "tpu":
        return pairwise_dbscan_pallas(xq, x, m, float(eps2), **kw)
    if kernel_backend_live():
        return pairwise_dbscan_pallas(
            xq, x, m, float(eps2), interpret=True, **kw
        )
    return pairwise_dbscan_ref(xq, x, m, float(eps2))


def pairwise_kde_reduce(
    xq: jax.Array, x: jax.Array, m: int, inv_two_h2: float, **kw
):
    """Returns the compensated (sums, comps) pair; the ref oracle's
    one-shot sums carry zero compensation."""
    if jax.default_backend() == "tpu":
        return pairwise_kde_pallas(xq, x, m, float(inv_two_h2), **kw)
    if kernel_backend_live():
        return pairwise_kde_pallas(
            xq, x, m, float(inv_two_h2), interpret=True, **kw
        )
    sums = pairwise_kde_ref(xq, x, m, float(inv_two_h2))
    return sums, jnp.zeros_like(sums)


# ------------------------------------------------------------ split variants
# Per-shard partial reductions (leading shard axis in the grid), merged on
# the host by ``analytics.split.merge_*_partials``. ``x`` arrives
# shard-padded: (shards * shard_rows, d), shard_rows a multiple of the
# dataset tile.


def pairwise_knn_split_reduce(
    xq: jax.Array, x: jax.Array, m: int, shards: int, **kw
):
    if jax.default_backend() == "tpu":
        return pairwise_knn_split_pallas(xq, x, m, shards, **kw)
    if kernel_backend_live():
        return pairwise_knn_split_pallas(
            xq, x, m, shards, interpret=True, **kw
        )
    return pairwise_knn_split_ref(xq, x, m, shards)


def pairwise_dbscan_split_reduce(
    xq: jax.Array, x: jax.Array, m: int, eps2: float, shards: int, **kw
):
    if jax.default_backend() == "tpu":
        return pairwise_dbscan_split_pallas(
            xq, x, m, float(eps2), shards, **kw
        )
    if kernel_backend_live():
        return pairwise_dbscan_split_pallas(
            xq, x, m, float(eps2), shards, interpret=True, **kw
        )
    return pairwise_dbscan_split_ref(xq, x, m, float(eps2), shards)


def pairwise_kde_split_reduce(
    xq: jax.Array, x: jax.Array, m: int, inv_two_h2: float, shards: int, **kw
):
    if jax.default_backend() == "tpu":
        return pairwise_kde_split_pallas(
            xq, x, m, float(inv_two_h2), shards, **kw
        )
    if kernel_backend_live():
        return pairwise_kde_split_pallas(
            xq, x, m, float(inv_two_h2), shards, interpret=True, **kw
        )
    return pairwise_kde_split_ref(xq, x, m, float(inv_two_h2), shards)
