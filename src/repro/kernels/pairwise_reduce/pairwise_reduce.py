"""Fused pairwise-reduction Pallas kernels (the analytics-side hot path).

One kernel per downstream task, all the same shape: grid (m_q/bq, m/bk),
query-tile axis 'parallel', dataset-tile axis 'arbitrary' (sequential) so
the per-row online reduction carries across dataset tiles in VMEM scratch —
the (bq, d) x (d, bk) distance tile is MXU-shaped, lives only in VMEM, and
the m x m distance matrix never exists (flash-attention-style tiling,
mirroring ``kernels/pairwise_tlb``):

* ``pairwise_knn_pallas``    — running (min-d2, argmin), self excluded;
* ``pairwise_dbscan_pallas`` — eps-ball degree counts (carried) + packed
                               uint32 neighbor bitmasks (tile-local write);
* ``pairwise_kde_pallas``    — compensated (Neumaier) Gaussian exp-sum pair.

Each kernel also has a ``*_split_pallas`` variant with a LEADING 'parallel'
shard axis on the grid — the flash-decoding decomposition: per-shard
partials with global column indices, merged exactly on the host by
``analytics.split`` (see the split-scan contract in analytics/README.md).

The true row count ``m`` and the task scalar (eps^2 / 1/(2h^2)) are STATIC:
they bake the padding masks and threshold into the compiled kernel, keeping
the reduction bit-identical to the jnp engine's tile body at the cost of a
recompile per (m, scalar) — acceptable on the kernel path, which exists for
accelerator backends (CPU serving uses the fused jnp scan).

Like the sibling kernels this runs natively on TPU and under
``interpret=True`` everywhere else (the CPU test path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _tile_d2(xq_ref, x_ref, row0, col0, m, bq, bk):
    """(bq, bk) squared-distance tile with global row/col ids; padded
    dataset columns masked to +inf. ``row0``/``col0`` are the GLOBAL
    indices of the tile's first row/column (``i*bq``/``j*bk`` on the
    sequential grid; the split grid adds the shard offset to ``col0``)."""
    xqt = xq_ref[...].astype(jnp.float32)
    xt = x_ref[...].astype(jnp.float32)
    sq_q = jnp.sum(xqt * xqt, axis=1, keepdims=True)
    sq_t = jnp.sum(xt * xt, axis=1)
    d2 = sq_q + sq_t[None, :] - 2.0 * jnp.dot(
        xqt, xt.T, preferred_element_type=jnp.float32
    )
    rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = col0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    d2 = jnp.where(cols >= m, jnp.inf, d2)
    return d2, rows, cols


def _knn_body(xq_ref, x_ref, idx_ref, d2_ref, acc_d2, acc_idx, row0, col0, j, m, bq, bk):
    """Shared kNN tile fold: init at the first dataset tile, strict-``<``
    merge (keeps the earlier tile on ties — first-occurrence argmin,
    matching the jnp engine and the legacy global argmin exactly), write
    the carry out every step (the final tile's write is the answer)."""

    @pl.when(j == 0)
    def _init():
        acc_d2[...] = jnp.full_like(acc_d2, jnp.inf)
        acc_idx[...] = jnp.zeros_like(acc_idx)

    d2, rows, cols = _tile_d2(xq_ref, x_ref, row0, col0, m, bq, bk)
    d2 = jnp.where(rows == cols, jnp.inf, d2)  # self excluded
    t_d2 = jnp.min(d2, axis=1, keepdims=True)
    t_idx = (col0 + jnp.argmin(d2, axis=1)[:, None]).astype(jnp.int32)
    better = t_d2 < acc_d2[...]
    acc_d2[...] = jnp.where(better, t_d2, acc_d2[...])
    acc_idx[...] = jnp.where(better, t_idx, acc_idx[...])
    idx_ref[...] = acc_idx[...]
    d2_ref[...] = acc_d2[...]


def _knn_kernel(xq_ref, x_ref, idx_ref, d2_ref, acc_d2, acc_idx, *, m, bq, bk):
    i, j = pl.program_id(0), pl.program_id(1)
    _knn_body(
        xq_ref, x_ref, idx_ref, d2_ref, acc_d2, acc_idx,
        i * bq, j * bk, j, m, bq, bk,
    )


def _knn_split_kernel(
    xq_ref, x_ref, idx_ref, d2_ref, acc_d2, acc_idx, *, m, bq, bk, shard_rows
):
    """Grid-parallel split: leading shard axis, per-shard PARTIAL argmin
    with GLOBAL column indices (col0 folds in the shard offset); the host
    merges shards with ``analytics.split.merge_knn_partials``."""
    s, i, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    _knn_body(
        xq_ref, x_ref, idx_ref, d2_ref, acc_d2, acc_idx,
        i * bq, s * shard_rows + j * bk, j, m, bq, bk,
    )


def pack_bits_u32(mask: jax.Array) -> jax.Array:
    """(rows, cols) bool -> (rows, cols//32) uint32, little-endian bit order
    (bit j of word w flags column w*32 + j). THE bit-layout definition for
    this package: the kernel body and the ref oracle both pack through it,
    and the engine's jnp tile body (``analytics.pairwise._pack_bits``)
    mirrors it — cross-path agreement is pinned by the parity sweeps."""
    rows, cols = mask.shape
    u = mask.astype(jnp.uint32).reshape(rows, cols // 32, 32)
    weights = jnp.left_shift(jnp.uint32(1), jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(u * weights[None, None, :], axis=-1, dtype=jnp.uint32)


def _dbscan_body(xq_ref, x_ref, cnt_ref, packed_ref, acc_cnt, row0, col0, j, m, bq, bk, eps2):
    @pl.when(j == 0)
    def _init():
        acc_cnt[...] = jnp.zeros_like(acc_cnt)

    d2, _rows, _cols = _tile_d2(xq_ref, x_ref, row0, col0, m, bq, bk)
    mask = d2 <= eps2  # self included (d2=0); the host BFS drops it
    acc_cnt[...] += jnp.sum(mask, axis=1, keepdims=True, dtype=jnp.int32)
    cnt_ref[...] = acc_cnt[...]
    packed_ref[...] = pack_bits_u32(mask)


def _dbscan_kernel(xq_ref, x_ref, cnt_ref, packed_ref, acc_cnt, *, m, bq, bk, eps2):
    i, j = pl.program_id(0), pl.program_id(1)
    _dbscan_body(
        xq_ref, x_ref, cnt_ref, packed_ref, acc_cnt,
        i * bq, j * bk, j, m, bq, bk, eps2,
    )


def _dbscan_split_kernel(
    xq_ref, x_ref, cnt_ref, packed_ref, acc_cnt, *, m, bq, bk, eps2, shard_rows
):
    """Split variant: per-shard counts + tile-local packed segment writes;
    shard boundaries are whole bk-tiles, so the segment word layout IS the
    sequential one after shard-order concatenation."""
    s, i, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    _dbscan_body(
        xq_ref, x_ref, cnt_ref, packed_ref, acc_cnt,
        i * bq, s * shard_rows + j * bk, j, m, bq, bk, eps2,
    )


def _kde_body(xq_ref, x_ref, sum_ref, comp_ref, acc, comp, row0, col0, j, m, bq, bk, inv_two_h2):
    """Compensated (Neumaier) exp-sum fold — carries the rounding error of
    each tile add in a second f32 scratch, mirroring the jnp engine's carry
    (see ``analytics.pairwise._scan_core``); the caller folds sum + comp in
    float64 on the host."""

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        comp[...] = jnp.zeros_like(comp)

    d2, _rows, cols = _tile_d2(xq_ref, x_ref, row0, col0, m, bq, bk)
    e = jnp.exp(-jnp.maximum(d2, 0.0) * inv_two_h2)
    e = jnp.where(cols < m, e, 0.0)
    t = jnp.sum(e, axis=1, keepdims=True)
    a = acc[...]
    s_ = a + t
    comp[...] += jnp.where(
        jnp.abs(a) >= jnp.abs(t), (a - s_) + t, (t - s_) + a
    )
    acc[...] = s_
    sum_ref[...] = acc[...]
    comp_ref[...] = comp[...]


def _kde_kernel(xq_ref, x_ref, sum_ref, comp_ref, acc, comp, *, m, bq, bk, inv_two_h2):
    i, j = pl.program_id(0), pl.program_id(1)
    _kde_body(
        xq_ref, x_ref, sum_ref, comp_ref, acc, comp,
        i * bq, j * bk, j, m, bq, bk, inv_two_h2,
    )


def _kde_split_kernel(
    xq_ref, x_ref, sum_ref, comp_ref, acc, comp, *, m, bq, bk, inv_two_h2, shard_rows
):
    s, i, j = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    _kde_body(
        xq_ref, x_ref, sum_ref, comp_ref, acc, comp,
        i * bq, s * shard_rows + j * bk, j, m, bq, bk, inv_two_h2,
    )


def _pad_to(arr: jax.Array, rows: int) -> jax.Array:
    return jnp.pad(arr, ((0, rows - arr.shape[0]), (0, 0)))


def _grid_and_specs(xq, x, bq, bk):
    """Common ragged-shape padding + (grid, in_specs) for the three kernels."""
    mq, d = xq.shape
    pq = (-mq) % bq
    pk = (-x.shape[0]) % bk
    xq = _pad_to(xq, mq + pq)
    x = _pad_to(x, x.shape[0] + pk)
    grid = ((mq + pq) // bq, x.shape[0] // bk)
    in_specs = [
        pl.BlockSpec((bq, d), lambda i, j: (i, 0)),
        pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
    ]
    return xq, x, grid, in_specs


@functools.partial(
    jax.jit, static_argnames=("m", "block_q", "block_k", "interpret")
)
def pairwise_knn_pallas(
    xq: jax.Array,
    x: jax.Array,
    m: int,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """(mq, d), (mk, d) -> (nn index (mq,) int32, nn squared dist (mq,))."""
    mq = xq.shape[0]
    bq, bk = min(block_q, max(mq, 1)), block_k
    xq, x, grid, in_specs = _grid_and_specs(xq, x, bq, bk)
    idx, d2 = pl.pallas_call(
        functools.partial(_knn_kernel, m=m, bq=bq, bk=bk),
        grid=grid,
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((xq.shape[0], 1), jnp.int32),
            jax.ShapeDtypeStruct((xq.shape[0], 1), jnp.float32),
        ),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),  # running min d2
            pltpu.VMEM((bq, 1), jnp.int32),  # running argmin
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xq, x)
    return idx[:mq, 0], d2[:mq, 0]


@functools.partial(
    jax.jit,
    static_argnames=("m", "eps2", "block_q", "block_k", "interpret"),
)
def pairwise_dbscan_pallas(
    xq: jax.Array,
    x: jax.Array,
    m: int,
    eps2: float,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """-> (eps-ball counts (mq,) int32, packed bitmask (mq, mk_pad/32))."""
    mq = xq.shape[0]
    bq = min(block_q, max(mq, 1))
    bk = max(32, (block_k // 32) * 32)  # packed words divide the tile
    xq, x, grid, in_specs = _grid_and_specs(xq, x, bq, bk)
    w = x.shape[0] // 32
    cnt, packed = pl.pallas_call(
        functools.partial(
            _dbscan_kernel, m=m, bq=bq, bk=bk, eps2=float(eps2)
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, bk // 32), lambda i, j: (i, j)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((xq.shape[0], 1), jnp.int32),
            jax.ShapeDtypeStruct((xq.shape[0], w), jnp.uint32),
        ),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.int32),  # running degree count
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xq, x)
    return cnt[:mq, 0], packed[:mq]


@functools.partial(
    jax.jit,
    static_argnames=("m", "inv_two_h2", "block_q", "block_k", "interpret"),
)
def pairwise_kde_pallas(
    xq: jax.Array,
    x: jax.Array,
    m: int,
    inv_two_h2: float,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """-> compensated Gaussian exp-sum pair ((mq,) sums, (mq,) comps); the
    caller folds ``sums + comps`` in float64 and divides by m."""
    mq = xq.shape[0]
    bq, bk = min(block_q, max(mq, 1)), block_k
    xq, x, grid, in_specs = _grid_and_specs(xq, x, bq, bk)
    sums, comps = pl.pallas_call(
        functools.partial(
            _kde_kernel, m=m, bq=bq, bk=bk, inv_two_h2=float(inv_two_h2)
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, 1), lambda i, j: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((xq.shape[0], 1), jnp.float32),
            jax.ShapeDtypeStruct((xq.shape[0], 1), jnp.float32),
        ),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),  # running exp-sum
            pltpu.VMEM((bq, 1), jnp.float32),  # running compensation
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xq, x)
    return sums[:mq, 0], comps[:mq, 0]


# ------------------------------------------------------------ split variants
# Same kernels with a LEADING 'parallel' shard axis on the grid: every
# (shard, query-tile) pair carries its own online reduction over the shard's
# dataset tiles, producing per-shard PARTIALS in one pallas_call — the
# flash-decoding decomposition (cf. ``kernels/flash_decode``), merged
# exactly on the host by ``analytics.split``.


def _split_grid_and_specs(xq, x, shards, bq, bk):
    """Grid/specs for the split kernels. ``x`` arrives shard-padded from
    ``analytics.split._split_prepare``: (shards * shard_rows, d) with
    shard_rows a whole number of bk-tiles."""
    mq, d = xq.shape
    pq = (-mq) % bq
    xq = _pad_to(xq, mq + pq)
    nq = (mq + pq) // bq
    shard_rows = x.shape[0] // shards
    tps = shard_rows // bk  # tiles per shard
    grid = (shards, nq, tps)
    in_specs = [
        pl.BlockSpec((bq, d), lambda s, i, j: (i, 0)),
        pl.BlockSpec((bk, d), lambda s, i, j, tps=tps: (s * tps + j, 0)),
    ]
    return xq, grid, in_specs, nq, shard_rows


@functools.partial(
    jax.jit,
    static_argnames=("m", "shards", "block_q", "block_k", "interpret"),
)
def pairwise_knn_split_pallas(
    xq: jax.Array,
    x: jax.Array,
    m: int,
    shards: int,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """-> per-shard partials ((shards, mq) int32 idx, (shards, mq) d2)."""
    mq = xq.shape[0]
    bq, bk = min(block_q, max(mq, 1)), block_k
    xq, grid, in_specs, nq, shard_rows = _split_grid_and_specs(
        xq, x, shards, bq, bk
    )
    idx, d2 = pl.pallas_call(
        functools.partial(
            _knn_split_kernel, m=m, bq=bq, bk=bk, shard_rows=shard_rows
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((bq, 1), lambda s, i, j, nq=nq: (s * nq + i, 0)),
            pl.BlockSpec((bq, 1), lambda s, i, j, nq=nq: (s * nq + i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((shards * xq.shape[0], 1), jnp.int32),
            jax.ShapeDtypeStruct((shards * xq.shape[0], 1), jnp.float32),
        ),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xq, x)
    mq_pad = xq.shape[0]
    return (
        idx.reshape(shards, mq_pad)[:, :mq],
        d2.reshape(shards, mq_pad)[:, :mq],
    )


@functools.partial(
    jax.jit,
    static_argnames=("m", "eps2", "shards", "block_q", "block_k", "interpret"),
)
def pairwise_dbscan_split_pallas(
    xq: jax.Array,
    x: jax.Array,
    m: int,
    eps2: float,
    shards: int,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """-> ((shards, mq) int32 counts, (shards, mq, shard_words) uint32)."""
    mq = xq.shape[0]
    bq = min(block_q, max(mq, 1))
    bk = max(32, (block_k // 32) * 32)
    xq, grid, in_specs, nq, shard_rows = _split_grid_and_specs(
        xq, x, shards, bq, bk
    )
    w = shard_rows // 32
    cnt, packed = pl.pallas_call(
        functools.partial(
            _dbscan_split_kernel,
            m=m, bq=bq, bk=bk, eps2=float(eps2), shard_rows=shard_rows,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((bq, 1), lambda s, i, j, nq=nq: (s * nq + i, 0)),
            pl.BlockSpec(
                (bq, bk // 32), lambda s, i, j, nq=nq: (s * nq + i, j)
            ),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((shards * xq.shape[0], 1), jnp.int32),
            jax.ShapeDtypeStruct((shards * xq.shape[0], w), jnp.uint32),
        ),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xq, x)
    mq_pad = xq.shape[0]
    return (
        cnt.reshape(shards, mq_pad)[:, :mq],
        packed.reshape(shards, mq_pad, w)[:, :mq],
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "m", "inv_two_h2", "shards", "block_q", "block_k", "interpret"
    ),
)
def pairwise_kde_split_pallas(
    xq: jax.Array,
    x: jax.Array,
    m: int,
    inv_two_h2: float,
    shards: int,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """-> per-shard compensated pairs ((shards, mq) sums, (shards, mq) comps)."""
    mq = xq.shape[0]
    bq, bk = min(block_q, max(mq, 1)), block_k
    xq, grid, in_specs, nq, shard_rows = _split_grid_and_specs(
        xq, x, shards, bq, bk
    )
    sums, comps = pl.pallas_call(
        functools.partial(
            _kde_split_kernel,
            m=m, bq=bq, bk=bk,
            inv_two_h2=float(inv_two_h2), shard_rows=shard_rows,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((bq, 1), lambda s, i, j, nq=nq: (s * nq + i, 0)),
            pl.BlockSpec((bq, 1), lambda s, i, j, nq=nq: (s * nq + i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((shards * xq.shape[0], 1), jnp.float32),
            jax.ShapeDtypeStruct((shards * xq.shape[0], 1), jnp.float32),
        ),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xq, x)
    mq_pad = xq.shape[0]
    return (
        sums.reshape(shards, mq_pad)[:, :mq],
        comps.reshape(shards, mq_pad)[:, :mq],
    )
