"""Pure-jnp oracles for the pairwise-reduction kernels.

Deliberately UNFUSED: each oracle materializes the full (mq, mk) distance
matrix and reduces it in one shot — the simplest possible statement of the
semantics, used by the kernel test sweeps. Production CPU callers never come
here; ``analytics.pairwise`` falls back to its fused jnp scan instead.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _full_d2(xq: jax.Array, x: jax.Array, m: int) -> jax.Array:
    xq = xq.astype(jnp.float32)
    x = x.astype(jnp.float32)
    sq_q = jnp.sum(xq * xq, axis=1, keepdims=True)
    sq_x = jnp.sum(x * x, axis=1)
    d2 = sq_q + sq_x[None, :] - 2.0 * jnp.matmul(
        xq, x.T, precision=jax.lax.Precision.HIGHEST
    )
    cols = jnp.arange(x.shape[0])
    return jnp.where(cols[None, :] >= m, jnp.inf, d2)


def pairwise_knn_ref(xq: jax.Array, x: jax.Array, m: int):
    d2 = _full_d2(xq, x, m)
    rows = jnp.arange(xq.shape[0])
    cols = jnp.arange(x.shape[0])
    d2 = jnp.where(rows[:, None] == cols[None, :], jnp.inf, d2)
    idx = jnp.argmin(d2, axis=1).astype(jnp.int32)
    return idx, jnp.take_along_axis(d2, idx[:, None], axis=1)[:, 0]


def pairwise_dbscan_ref(xq: jax.Array, x: jax.Array, m: int, eps2: float):
    from repro.kernels.pairwise_reduce.pairwise_reduce import pack_bits_u32

    mask = _full_d2(xq, x, m) <= jnp.float32(eps2)
    counts = jnp.sum(mask, axis=1, dtype=jnp.int32)
    pad = (-x.shape[0]) % 32
    packed = pack_bits_u32(jnp.pad(mask, ((0, 0), (0, pad))))
    return counts, packed


def pairwise_kde_ref(xq: jax.Array, x: jax.Array, m: int, inv_two_h2: float):
    d2 = _full_d2(xq, x, m)
    e = jnp.where(
        jnp.isfinite(d2),
        jnp.exp(-jnp.maximum(d2, 0.0) * jnp.float32(inv_two_h2)),
        0.0,
    )
    return jnp.sum(e, axis=1)


# ------------------------------------------------------------ split oracles
# Per-shard PARTIALS sliced out of the one-shot full-matrix reduction —
# the simplest statement of the split kernels' contract (shard s covers
# dataset columns [s*shard_rows, (s+1)*shard_rows) with GLOBAL indices).


def _shard_cols(x: jax.Array, shards: int):
    shard_rows = x.shape[0] // shards
    return [(s * shard_rows, (s + 1) * shard_rows) for s in range(shards)]


def pairwise_knn_split_ref(xq: jax.Array, x: jax.Array, m: int, shards: int):
    d2 = _full_d2(xq, x, m)
    rows = jnp.arange(xq.shape[0])
    cols = jnp.arange(x.shape[0])
    d2 = jnp.where(rows[:, None] == cols[None, :], jnp.inf, d2)
    idx_p, d2_p = [], []
    for a, b in _shard_cols(x, shards):
        blk = d2[:, a:b]
        loc = jnp.argmin(blk, axis=1)
        idx_p.append((a + loc).astype(jnp.int32))
        d2_p.append(jnp.take_along_axis(blk, loc[:, None], axis=1)[:, 0])
    return jnp.stack(idx_p), jnp.stack(d2_p)


def pairwise_dbscan_split_ref(
    xq: jax.Array, x: jax.Array, m: int, eps2: float, shards: int
):
    from repro.kernels.pairwise_reduce.pairwise_reduce import pack_bits_u32

    mask = _full_d2(xq, x, m) <= jnp.float32(eps2)
    cnt_p, packed_p = [], []
    for a, b in _shard_cols(x, shards):
        blk = mask[:, a:b]
        cnt_p.append(jnp.sum(blk, axis=1, dtype=jnp.int32))
        pad = (-blk.shape[1]) % 32
        packed_p.append(pack_bits_u32(jnp.pad(blk, ((0, 0), (0, pad)))))
    return jnp.stack(cnt_p), jnp.stack(packed_p)


def pairwise_kde_split_ref(
    xq: jax.Array, x: jax.Array, m: int, inv_two_h2: float, shards: int
):
    d2 = _full_d2(xq, x, m)
    e = jnp.where(
        jnp.isfinite(d2),
        jnp.exp(-jnp.maximum(d2, 0.0) * jnp.float32(inv_two_h2)),
        0.0,
    )
    sums = jnp.stack(
        [jnp.sum(e[:, a:b], axis=1) for a, b in _shard_cols(x, shards)]
    )
    return sums, jnp.zeros_like(sums)  # one-shot sums carry no compensation
