"""Dispatch wrapper for the pairwise-TLB kernel (TPU native / interpret / ref)."""

from __future__ import annotations

import os

import jax

from repro.kernels.pairwise_tlb.pairwise_tlb import pairwise_tlb_pallas
from repro.kernels.pairwise_tlb.ref import pairwise_tlb_ref


def pairwise_tlb(xi: jax.Array, xj: jax.Array, v: jax.Array, **kw) -> jax.Array:
    if jax.default_backend() == "tpu":
        return pairwise_tlb_pallas(xi, xj, v, **kw)
    if os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1":
        return pairwise_tlb_pallas(xi, xj, v, interpret=True, **kw)
    return pairwise_tlb_ref(xi, xj, v)
