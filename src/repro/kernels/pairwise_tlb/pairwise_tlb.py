"""Fused all-prefix pairwise-TLB Pallas kernel (DROP's TLB evaluation).

For P sampled pairs and a (d, K) PCA basis V, computes the (P, K) table
    tlb[p, k] = ||(x_i - x_j) @ V[:, :k+1]|| / ||x_i - x_j||
in ONE pass: diff -> project (MXU) -> square -> prefix-cumsum -> normalize.
This is the TPU-native replacement for the paper's per-k TLB evaluations
(DESIGN.md §2): binary search over k collapses into reading this table.

TPU mapping: grid (P/bp, K/bk). The pair axis is 'parallel'; the K axis is
'arbitrary' (sequential) because the prefix sum carries across K tiles via an
f32 VMEM scratch column. d is kept unblocked: a (bp, d) diff tile at bp=128,
d<=4096 is ~2 MB — inside VMEM, and the (bp, d) x (d, bk) projection is
MXU-shaped. The per-pair squared-denominator is computed once at k-step 0 and
cached in scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _tlb_kernel(xi_ref, xj_ref, v_ref, o_ref, acc_ref, den_ref):
    diffs = (xi_ref[...] - xj_ref[...]).astype(jnp.float32)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        den_ref[...] = jnp.sum(diffs * diffs, axis=1, keepdims=True)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    z = jnp.dot(diffs, v_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)  # (bp, bk)
    zsq = z * z
    cum = jnp.cumsum(zsq, axis=1) + acc_ref[...]
    acc_ref[...] += jnp.sum(zsq, axis=1, keepdims=True)
    den = den_ref[...]
    tlb = jnp.sqrt(jnp.clip(cum / jnp.maximum(den, 1e-30), 0.0, 1.0))
    o_ref[...] = jnp.where(den > 1e-30, tlb, 1.0).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_p", "block_k", "interpret")
)
def pairwise_tlb_pallas(
    xi: jax.Array,
    xj: jax.Array,
    v: jax.Array,
    block_p: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """(P, d), (P, d), (d, K) -> (P, K) all-prefix TLB table."""
    p, d = xi.shape
    d2, k = v.shape
    assert xj.shape == (p, d) and d2 == d
    bp, bk = min(block_p, p), min(block_k, k)

    pp = (-p) % bp
    pk = (-k) % bk
    if pp:
        xi = jnp.pad(xi, ((0, pp), (0, 0)))
        xj = jnp.pad(xj, ((0, pp), (0, 0)))
    if pk:
        v = jnp.pad(v, ((0, 0), (0, pk)))

    out = pl.pallas_call(
        _tlb_kernel,
        grid=((p + pp) // bp, (k + pk) // bk),
        in_specs=[
            pl.BlockSpec((bp, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bp, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bk), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bp, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p + pp, k + pk), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bp, 1), jnp.float32),  # running sum of z^2 per pair
            pltpu.VMEM((bp, 1), jnp.float32),  # ||diff||^2 per pair
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xi, xj, v)
    return out[:p, :k]
