"""Pure-jnp oracle for the all-prefix pairwise-TLB kernel."""

import jax
import jax.numpy as jnp


def pairwise_tlb_ref(xi: jax.Array, xj: jax.Array, v: jax.Array) -> jax.Array:
    diffs = (xi - xj).astype(jnp.float32)
    denom2 = jnp.sum(diffs * diffs, axis=-1, keepdims=True)
    z = jnp.matmul(diffs, v.astype(jnp.float32),
                   precision=jax.lax.Precision.HIGHEST)
    cum = jnp.cumsum(z * z, axis=-1)
    tlb = jnp.sqrt(jnp.clip(cum / jnp.maximum(denom2, 1e-30), 0.0, 1.0))
    return jnp.where(denom2 > 1e-30, tlb, 1.0)
