"""DROP serving launcher CLI: batched multi-query DR with basis reuse.

    PYTHONPATH=src python -m repro.launch.drop_serve --queries 8

Generates a synthetic tenant workload (a pool of distinct datasets, with a
configurable fraction of repeat submissions — the paper-§5 regime), drains it
through ``DropService``, and reports queries/sec, cache behavior, and the
shared shape-bucket population. ``--compare-sequential`` also times cold
``drop()`` per query for a direct speedup figure.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import DropConfig, drop
from repro.core.cost import knn_cost
from repro.data import sinusoid_mixture
from repro.serve_drop import DropService


def build_workload(
    n_queries: int, n_datasets: int, rows: int, dim: int, seed: int
) -> list[np.ndarray]:
    """Round-robin over a dataset pool: n_datasets distinct matrices, repeats
    after the first pass (repeat fraction = 1 - n_datasets / n_queries)."""
    pool = [
        sinusoid_mixture(rows, dim, rank=5 + i, seed=seed + i)[0]
        for i in range(n_datasets)
    ]
    return [pool[i % n_datasets] for i in range(n_queries)]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--datasets", type=int, default=2,
                    help="distinct datasets in the pool (rest are repeats)")
    ap.add_argument("--rows", type=int, default=1500)
    ap.add_argument("--dim", type=int, default=96)
    ap.add_argument("--target", type=float, default=0.98)
    ap.add_argument("--max-inflight", type=int, default=4)
    ap.add_argument("--cache-entries", type=int, default=16)
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--compare-sequential", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    datasets = build_workload(
        args.queries, max(1, min(args.datasets, args.queries)),
        args.rows, args.dim, args.seed,
    )
    cfg = DropConfig(target_tlb=args.target, seed=args.seed)
    cost = knn_cost(args.rows)

    svc = DropService(
        max_inflight=args.max_inflight,
        cache_entries=args.cache_entries,
        enable_cache=not args.no_cache,
    )
    # warm the jit caches with one cold drop() per distinct dataset so the
    # reported throughput measures serving, not XLA compilation (plain drop()
    # shares the shape buckets but never touches the service cache)
    for x in datasets[: args.datasets]:
        drop(x, cfg, cost=cost)

    t0 = time.perf_counter()
    for x in datasets:
        svc.submit(x, cfg, cost)
    results = svc.run()
    dt = time.perf_counter() - t0

    qps = args.queries / dt
    hits = sum(r.cache_hit for r in results)
    print(f"served {args.queries} queries in {dt*1e3:.0f} ms  "
          f"({qps:.2f} queries/sec)")
    print(f"cache: {hits}/{args.queries} hits, "
          f"{svc.stats.warm_starts} warm starts, "
          f"{svc.stats.fit_calls} basis fits, "
          f"{len(svc.cache)} entries resident")
    print(f"buckets: {svc.bucket.summary()}")
    for r in results:
        tag = "HIT " if r.cache_hit else ("WARM" if r.warm_started else "COLD")
        print(f"  q{r.query_id:02d} [{tag}] k={r.result.k:3d} "
              f"tlb={r.result.tlb_estimate:.4f} wall={r.wall_s*1e3:7.1f} ms")

    if args.compare_sequential:
        t0 = time.perf_counter()
        for x in datasets:
            drop(x, cfg, cost=cost)
        t_seq = time.perf_counter() - t0
        print(f"sequential cold drop(): {t_seq*1e3:.0f} ms "
              f"({args.queries/t_seq:.2f} queries/sec) -> "
              f"service speedup {t_seq/dt:.2f}x")


if __name__ == "__main__":
    main()
