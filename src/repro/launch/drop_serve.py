"""DROP serving launcher CLI: batched multi-query DR with basis reuse.

    PYTHONPATH=src python -m repro.launch.drop_serve --queries 8
    PYTHONPATH=src python -m repro.launch.drop_serve --devices 2 --async
    PYTHONPATH=src python -m repro.launch.drop_serve --fleet 2
    PYTHONPATH=src python -m repro.launch.drop_serve --method pca,fft,paa

Generates a synthetic tenant workload (a pool of distinct datasets, with a
configurable fraction of repeat submissions — the paper-§5 regime), drains it
through ``DropService`` (or the sharded multi-device scheduler with
``--devices N``, the supervised process-worker fleet with ``--fleet N`` —
the CPU scale-out mode, one XLA client per worker, with fault-tolerant
restart and measured-cost placement — and the threaded ingest front-end
with ``--async``), and
reports queries/sec, cache behavior, per-device occupancy, and the shared
shape-bucket population. ``--method`` picks the Reducer per query (a comma
list cycles across the workload — FFT/PAA queries are scheduled and cached
exactly like DROP); ``--downstream`` prices the named analytics task as the
cost model, and ``--execute-downstream`` additionally RUNS it on each
query's reduced data before the query finishes (``--analytics-split N`` /
``--analytics-fanout`` select the exact-merge shard decomposition of that
scan — see ``analytics.split``). ``--compare-sequential`` also times cold
``reduce()`` per query
for a direct speedup figure. ``--grow-steps N`` switches to the append-only
demo: one tenant's dataset grows by ``--grow-frac`` rows per step and each
snapshot climbs the escalation ladder (prefix hit -> incremental suffix
update -> cold refit as last resort; tune with ``--suffix-budget`` /
``--no-suffix-update``). ``--subscribe`` is the pub/sub variant of the same
stream: instead of re-submitting grown snapshots, it opens ONE delta
subscription through the ingest front-end and applies the server-pushed
``append``/``rollback`` deltas client-side (``SubscriberState``), so each
append costs O(suffix) end-to-end — works against the in-process scheduler,
the sharded mesh, and the process fleet alike. ``--use-kernels`` opts served queries into the
Pallas kernel path end-to-end (fit matmuls + TLB validations; native on
TPU, interpreter under ``REPRO_PALLAS_INTERPRET=1``, fused-jnp fallback on
plain CPU — always safe to set).
"""

from __future__ import annotations

import argparse
import os
import sys
import time


def _requested_devices(argv: list[str]) -> int | None:
    """Pre-argparse peek at --devices (both '--devices N' and
    '--devices=N'); malformed values are left for argparse to report."""
    for i, arg in enumerate(argv):
        raw = None
        if arg == "--devices" and i + 1 < len(argv):
            raw = argv[i + 1]
        elif arg.startswith("--devices="):
            raw = arg.split("=", 1)[1]
        if raw is not None:
            try:
                return int(raw)
            except ValueError:
                return None
    return None


def _force_host_devices_from_argv() -> None:
    """--devices N needs the forced host platform BEFORE jax initializes
    (same trick as launch/dryrun.py); on real multi-device hardware
    XLA_FLAGS is already set and we leave it alone."""
    n = _requested_devices(sys.argv)
    if n is not None and n > 1:
        os.environ.setdefault(
            "XLA_FLAGS", f"--xla_force_host_platform_device_count={n}"
        )


_force_host_devices_from_argv()

import numpy as np  # noqa: E402

from repro.core import DropConfig, reduce  # noqa: E402
from repro.core.cost import downstream_cost  # noqa: E402
from repro.core.reducer import REDUCER_METHODS  # noqa: E402
from repro.data import sinusoid_mixture  # noqa: E402
from repro.serve_drop import (  # noqa: E402
    DropService,
    FleetSupervisor,
    IngestFrontend,
    RetryLater,
    ShardedDropService,
    SubscribeQuery,
    SubscriberState,
)


def build_workload(
    n_queries: int, n_datasets: int, rows: int, dim: int, seed: int
) -> list[np.ndarray]:
    """Round-robin over a dataset pool: n_datasets distinct matrices, repeats
    after the first pass (repeat fraction = 1 - n_datasets / n_queries)."""
    pool = [
        sinusoid_mixture(rows, dim, rank=5 + i, seed=seed + i)[0]
        for i in range(n_datasets)
    ]
    return [pool[i % n_datasets] for i in range(n_queries)]


def _serve_append_stream(svc, args, method, cfg, cost) -> None:
    """--grow-steps demo: one tenant's dataset grows by --grow-frac rows per
    step; each snapshot is submitted AFTER the previous one finished (prefix
    fingerprints are hashed at submit time against the live cache), so the
    stream exercises the escalation ladder: prefix hit -> suffix update ->
    cold refit as last resort. Non-PCA methods carry no updater state, so
    their ladder tops out at revalidate-or-refit."""
    append = max(1, int(args.rows * args.grow_frac))
    m_total = args.rows + args.grow_steps * append
    x_full = sinusoid_mixture(m_total, args.dim, rank=5, seed=args.seed)[0]
    reduce(x_full[: args.rows], method, cfg, cost)  # jit warm (convention)
    print(f"append stream [{method}]: m0={args.rows} +{append} rows x "
          f"{args.grow_steps} steps (suffix budget {args.suffix_budget})")
    t0 = time.perf_counter()
    for i in range(args.grow_steps + 1):
        snap = x_full[: args.rows + i * append]
        ts = time.perf_counter()
        svc.submit(snap, cfg, cost, method=method)
        r = svc.run()[0]
        tag = ("SUFX" if r.suffix_update else "HIT " if r.cache_hit
               else "WARM" if r.warm_started else "COLD")
        print(f"  step {i:02d} [{tag}] rows={snap.shape[0]:6d} "
              f"k={r.result.k:3d} tlb={r.result.tlb_estimate:.4f} "
              f"wall={(time.perf_counter() - ts) * 1e3:7.1f} ms")
    dt = time.perf_counter() - t0
    print(f"stream served in {dt*1e3:.0f} ms; cache: "
          f"{svc.stats.prefix_hits} prefix hits, "
          f"{svc.stats.suffix_updates} suffix updates "
          f"({svc.stats.suffix_update_failures} fell through), "
          f"{svc.stats.fit_calls} basis fits")


def _delta_line(delta: dict, client: SubscriberState) -> str:
    if delta["kind"] == "closed":
        return f"  seq {delta['seq']:02d} [CLOSED  ] error={delta.get('error')}"
    tag = ("APPEND  " if delta["kind"] == "append"
           else f"ROLLBACK/{delta.get('reason', '?')}")
    return (f"  seq {delta['seq']:02d} [{tag:8s}] "
            f"rows={client.rows.shape[0]:6d} k={client.basis.k:3d} "
            f"tlb={delta['tlb']:.4f} rot={delta['rotation']:.3f} "
            f"wall={delta['wall_s'] * 1e3:7.1f} ms")


def _serve_subscribe_stream(svc, args, method, cfg) -> None:
    """--subscribe demo: ONE delta subscription on a growing tenant. The
    server pushes the difference after each append — transformed suffix
    rows plus O(suffix) downstream patches while the tracker's rotation
    stays inside --rotation-tol (TLB-gated), a full restate when the basis
    moved — and the client folds every delta into ``SubscriberState``. The
    first delta is always the bootstrap rollback; unsubscribing delivers
    the terminal ``closed``."""
    append = max(1, int(args.rows * args.grow_frac))
    steps = args.grow_steps if args.grow_steps > 0 else 5
    m_total = args.rows + steps * append
    x_full = sinusoid_mixture(m_total, args.dim, rank=5, seed=args.seed)[0]
    print(f"pub/sub delta stream [{method}]: m0={args.rows} +{append} rows "
          f"x {steps} appends (rotation tol {args.rotation_tol})")
    client = SubscriberState()
    t0 = time.perf_counter()
    with IngestFrontend(svc, queue_capacity=args.queue_capacity) as fe:
        sid = fe.subscribe(SubscribeQuery(
            x=x_full[: args.rows], cfg=cfg, method=method,
            rotation_tol=args.rotation_tol,
        ))
        delta = fe.next_delta(sid, timeout=300.0)  # bootstrap rollback
        client.apply(delta)
        print(_delta_line(delta, client))
        for _ in range(steps):
            lo = client.rows.shape[0]
            fe.append(sid, x_full[lo: lo + append])
            delta = fe.next_delta(sid, timeout=300.0)
            client.apply(delta)
            print(_delta_line(delta, client))
        fe.unsubscribe(sid)
        delta = fe.next_delta(sid, timeout=300.0)
        client.apply(delta)
        print(_delta_line(delta, client))
    dt = time.perf_counter() - t0
    grown = x_full[: client.rows.shape[0]]
    err = float(np.max(np.abs(client.rows - client.basis.transform(grown))))
    print(f"stream served in {dt*1e3:.0f} ms; client folded "
          f"{client.appends} appends + {client.rollbacks} rollbacks "
          f"-> {client.rows.shape[0]} rows @ k={client.basis.k}")
    print(f"client-state parity vs basis.transform(grown): "
          f"max |diff| = {err:.3e}"
          + (" (bit-exact)" if err == 0.0 else ""))
    stats = getattr(svc, "stats", None)
    if stats is not None:
        print(f"server: {stats.subscriptions} subscriptions, "
              f"{stats.delta_serves} delta serves, "
              f"{stats.rollbacks} rollbacks, {stats.failures} failures")


def _submit_async(
    fe: IngestFrontend, datasets, methods, cfg, cost, downstream,
    execute_downstream: bool = False,
) -> list[int]:
    """Stream submissions through the bounded ingest queue, honoring
    reject-with-retry-after backpressure."""
    qids = []
    for x, m in zip(datasets, methods):
        while True:
            try:
                qids.append(
                    fe.submit(x, cfg, cost, method=m, downstream=downstream,
                              execute_downstream=execute_downstream)
                )
                break
            except RetryLater as e:
                time.sleep(e.retry_after_s)
    return qids


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--datasets", type=int, default=2,
                    help="distinct datasets in the pool (rest are repeats)")
    ap.add_argument("--rows", type=int, default=1500)
    ap.add_argument("--dim", type=int, default=96)
    ap.add_argument("--target", type=float, default=0.98)
    ap.add_argument("--method", type=str, default="pca",
                    help="reduction method per query; a comma list (e.g. "
                         "'pca,fft,paa') cycles across the workload")
    ap.add_argument("--downstream", type=str, default="knn",
                    choices=("knn", "dbscan", "kde"),
                    help="analytics task priced as the downstream cost model")
    ap.add_argument("--execute-downstream", action="store_true",
                    help="RUN the --downstream analytics on each query's "
                         "reduced data before it finishes (the served "
                         "end-to-end path; output lands on "
                         "ServeResult.downstream)")
    ap.add_argument("--analytics-split", type=int, default=None,
                    help="run executed analytics as N flash-decoding-style "
                         "dataset shards (exact merges — identical results; "
                         "see analytics.split)")
    ap.add_argument("--analytics-fanout", type=str, default=None,
                    choices=("xla", "mesh"),
                    help="shard execution: 'xla' batches shards in one "
                         "dispatch, 'mesh' shard_maps them across devices "
                         "(sharded scheduler defaults to mesh on >1 device)")
    ap.add_argument("--max-inflight", type=int, default=4)
    ap.add_argument("--cache-entries", type=int, default=16)
    ap.add_argument("--cache-ttl", type=int, default=None,
                    help="basis-cache TTL in scheduler ticks (default: none)")
    ap.add_argument("--suffix-budget", type=float, default=0.25,
                    help="append-only drift budget: a prefix-matched suffix "
                         "larger than this fraction of the fitted rows skips "
                         "revalidation and goes straight to the incremental "
                         "subspace update")
    ap.add_argument("--no-suffix-update", action="store_true",
                    help="disable incremental suffix updates (failed prefix "
                         "revalidations refit cold, the pre-tracking behavior)")
    ap.add_argument("--grow-steps", type=int, default=0,
                    help="append-stream demo: serve the base dataset, then "
                         "this many grown snapshots (each +grow-frac rows) "
                         "sequentially through the escalation ladder")
    ap.add_argument("--grow-frac", type=float, default=0.05,
                    help="per-append row growth for --grow-steps")
    ap.add_argument("--subscribe", action="store_true",
                    help="pub/sub demo: open ONE delta subscription on a "
                         "growing tenant and stream server-pushed append/"
                         "rollback deltas through the ingest front-end "
                         "(O(suffix) per append; reuses --grow-steps/"
                         "--grow-frac, default 5 appends)")
    ap.add_argument("--rotation-tol", type=float, default=0.25,
                    help="--subscribe append-vs-rollback gate on the "
                         "tracker's principal-angle rotation signal")
    ap.add_argument("--devices", type=int, default=1,
                    help="mesh devices for the sharded scheduler (>1 forces "
                         "the host-platform device count on CPU)")
    ap.add_argument("--fleet", type=int, default=0,
                    help="serve through N supervised worker PROCESSES (one "
                         "XLA client each — the CPU scale-out mode) instead "
                         "of the in-process scheduler; excludes --devices")
    ap.add_argument("--placement", type=str, default="cost",
                    choices=("cost", "rr"),
                    help="fleet placement: measured-cost (link alpha/beta + "
                         "queue depth / worker speed) or sticky round-robin")
    ap.add_argument("--async", dest="use_async", action="store_true",
                    help="stream queries through the threaded ingest "
                         "front-end instead of batch submit+run")
    ap.add_argument("--queue-capacity", type=int, default=64,
                    help="ingest backlog bound before reject-with-retry-after")
    ap.add_argument("--use-kernels", action="store_true",
                    help="route served queries' hot matmuls and TLB "
                         "validations through the Pallas kernel wrappers "
                         "(native on TPU; interpret-safe on CPU — set "
                         "REPRO_PALLAS_INTERPRET=1 to force interpreter "
                         "execution, otherwise CPU falls back to the fused "
                         "jnp paths)")
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--compare-sequential", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    datasets = build_workload(
        args.queries, max(1, min(args.datasets, args.queries)),
        args.rows, args.dim, args.seed,
    )
    methods = [m.strip() for m in args.method.split(",") if m.strip()]
    unknown = [m for m in methods if m not in REDUCER_METHODS]
    if unknown:
        ap.error(f"unknown --method {unknown}; know {REDUCER_METHODS}")
    methods = [methods[i % len(methods)] for i in range(args.queries)]
    cfg = DropConfig(
        target_tlb=args.target, seed=args.seed,
        use_kernels=args.use_kernels,
    )
    cost = downstream_cost(args.downstream, args.rows)

    if args.fleet > 0:
        if args.devices > 1:
            ap.error("--fleet (process workers) and --devices (in-process "
                     "mesh) are alternative scale-out modes; pick one")
        if args.grow_steps > 0 and not args.subscribe:
            ap.error("--grow-steps needs the in-process prefix cache; "
                     "drop --fleet (or add --subscribe: delta "
                     "subscriptions ARE fleet-capable)")
        # cost closures do not cross the process boundary: the workers
        # re-price the named downstream task themselves
        svc = FleetSupervisor(
            workers=args.fleet,
            enable_worker_cache=not args.no_cache,
            placement=args.placement,
        ).start()
        print(f"fleet of {args.fleet} worker processes "
              f"({args.placement} placement): {svc.devices}")
        cost = None
    elif args.devices > 1:
        svc = ShardedDropService(
            devices=args.devices,
            max_inflight=args.max_inflight,
            cache_entries=args.cache_entries,
            enable_cache=not args.no_cache,
            cache_ttl=args.cache_ttl,
            enable_suffix_update=not args.no_suffix_update,
            suffix_budget=args.suffix_budget,
            analytics_split=args.analytics_split,
            analytics_fanout=args.analytics_fanout,
        )
        print(f"sharded scheduler over {len(svc.devices)} devices: "
              f"{[str(d) for d in svc.devices]} "
              f"(analytics fanout: {svc.analytics_fanout})")
    else:
        svc = DropService(
            max_inflight=args.max_inflight,
            cache_entries=args.cache_entries,
            enable_cache=not args.no_cache,
            cache_ttl=args.cache_ttl,
            enable_suffix_update=not args.no_suffix_update,
            suffix_budget=args.suffix_budget,
            analytics_split=args.analytics_split,
            analytics_fanout=args.analytics_fanout or "xla",
        )
    if args.subscribe:
        if len(set(methods)) > 1:
            ap.error("--subscribe serves ONE growing tenant; give a "
                     "single --method")
        try:
            _serve_subscribe_stream(svc, args, methods[0], cfg)
        finally:
            if args.fleet:
                svc.shutdown()
        return

    if args.grow_steps > 0:
        if args.use_async:
            ap.error("--grow-steps is sequential by design (prefix matching "
                     "is submit-time); drop --async")
        if len(set(methods)) > 1:
            ap.error("--grow-steps serves ONE growing tenant; give a single "
                     "--method")
        _serve_append_stream(svc, args, methods[0], cfg, cost)
        return

    # warm the jit caches with one cold reduce() per distinct (dataset,
    # method) pair so the reported throughput measures serving, not XLA
    # compilation (plain reduce() shares the shape buckets but never touches
    # the service cache; the baseline single-shots compile nothing). Fleet
    # workers compile in their OWN processes, so warming here would be
    # wasted work — their first queries pay the compile instead.
    if not args.fleet:
        for i, x in enumerate(datasets[: args.datasets]):
            reduce(x, methods[i], cfg, cost)

    t0 = time.perf_counter()
    if args.use_async:
        with IngestFrontend(svc, queue_capacity=args.queue_capacity) as fe:
            qids = _submit_async(
                fe, datasets, methods, cfg, cost, args.downstream,
                args.execute_downstream,
            )
            results = sorted(
                (fe.result(q) for q in qids), key=lambda r: r.query_id
            )
    else:
        for x, m in zip(datasets, methods):
            svc.submit(x, cfg, cost, method=m, downstream=args.downstream,
                       execute_downstream=args.execute_downstream)
        results = svc.run()
    dt = time.perf_counter() - t0

    qps = args.queries / dt
    hits = sum(r.cache_hit for r in results)
    mode = "async ingest" if args.use_async else "batch"
    print(f"served {args.queries} queries in {dt*1e3:.0f} ms  "
          f"({qps:.2f} queries/sec, {mode})")
    if args.fleet:
        # worker-local caches/buckets live across the process boundary; the
        # supervisor surfaces its own fleet telemetry instead
        print(f"cache: {hits}/{args.queries} worker-cache hits, "
              f"{svc.stats.warm_starts} warm starts, "
              f"{svc.stats.rejected} backpressure rejections")
        print(f"fleet: {svc.stats.worker_deaths} deaths, "
              f"{svc.stats.worker_restarts} restarts, "
              f"{svc.stats.requeued_queries} requeues, "
              f"{svc.stats.rebalances} rebalances, "
              f"{svc.stats.straggler_flags} straggler flags")
        speeds = ", ".join(
            f"{w}={s:.2f}" for w, s in sorted(svc.worker_speeds().items())
        )
        links = ", ".join(
            f"{w}: a={p.alpha_s*1e6:.0f}us b={p.beta_s_per_byte*1e9:.2f}ns/B"
            for w, p in sorted(svc.link_profiles().items())
        )
        print(f"worker speeds: {speeds}")
        print(f"link profiles: {links}")
    else:
        print(f"cache: {hits}/{args.queries} hits, "
              f"{svc.stats.warm_starts} warm starts, "
              f"{svc.stats.suffix_updates} suffix updates, "
              f"{svc.stats.fit_calls} basis fits, "
              f"{len(svc.cache)} entries resident, "
              f"{svc.stats.rejected} backpressure rejections")
    if svc.stats.device_iterations:
        occ = ", ".join(
            f"{dev}={n}" for dev, n in sorted(svc.stats.device_iterations.items())
        )
        print(f"occupancy (iterations/device): {occ}; "
              f"steals={svc.stats.steals}")
    if not args.fleet:
        print(f"buckets: {svc.bucket.summary()}")
    if args.execute_downstream and not args.fleet:
        print(f"downstream [{args.downstream}]: {svc.stats.downstream_runs} "
              f"served executions "
              f"({svc.stats.downstream_failures} failed; "
              f"split={args.analytics_split or 1}, "
              f"fanout={svc.analytics_fanout})")
    for r in results:
        tag = ("SUFX" if r.suffix_update else "HIT " if r.cache_hit
               else "WARM" if r.warm_started else "COLD")
        where = f" @{r.worker}" if r.worker else ""
        ds = (
            f" ds={r.downstream_s*1e3:6.1f} ms"
            if getattr(r, "downstream", None) is not None
            else ""
        )
        print(f"  q{r.query_id:02d} [{tag}] {r.result.method:3s} "
              f"k={r.result.k:3d} tlb={r.result.tlb_estimate:.4f} "
              f"wall={r.wall_s*1e3:7.1f} ms{ds}{where}")
    if args.fleet:
        svc.shutdown()

    if args.compare_sequential:
        seq_cost = cost or downstream_cost(args.downstream, args.rows)
        t0 = time.perf_counter()
        for x, m in zip(datasets, methods):
            reduce(x, m, cfg, seq_cost)
        t_seq = time.perf_counter() - t0
        print(f"sequential cold reduce(): {t_seq*1e3:.0f} ms "
              f"({args.queries/t_seq:.2f} queries/sec) -> "
              f"service speedup {t_seq/dt:.2f}x")


if __name__ == "__main__":
    main()
