import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, print memory/cost analysis, and persist roofline JSONs.

The two lines above MUST precede any other import (jax locks the device count
on first init). Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_32b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --list

Outputs: artifacts/dryrun/<arch>__<shape>__<mesh>.json (resumable: existing
files are skipped unless --force).
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    cell_is_runnable,
    get_config,
)
from repro.launch.mesh import make_production_mesh
from repro.models.model import init_model
from repro.roofline.analysis import build_roofline, save_report, suggestion
from repro.serve.kvcache import cache_specs, cache_struct, plan_cache
from repro.sharding.specs import ShardCtx, param_specs
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; never allocate)
# ---------------------------------------------------------------------------

def params_struct(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_model(cfg, k), jax.random.PRNGKey(0))


def opt_struct(p_struct):
    return {
        "mu": jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), p_struct
        ),
        "nu": jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), p_struct
        ),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def batch_struct(cfg: ModelConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    out = {
        "inputs": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "targets": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
    }
    if cfg.family == "vlm":
        # stub vision frontend: precomputed patch/text embeddings
        out["inputs"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    if cfg.is_encoder_decoder:
        # stub audio frontend: precomputed frame embeddings
        out["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    return out


def batch_specs(cfg, shape, ctx: ShardCtx):
    dp = ctx.dp
    out = {
        "inputs": P(dp, None),
        "targets": P(dp, None),
        "mask": P(dp, None),
    }
    if cfg.family == "vlm":
        out["inputs"] = P(dp, None, None)
    if cfg.is_encoder_decoder:
        out["frames"] = P(dp, None, None)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig, ctx: ShardCtx):
    """(args_structs, in_specs, step_fn, donate) for one dry-run cell."""
    mesh = ctx.mesh
    p_struct = params_struct(cfg)
    p_specs = param_specs(p_struct)

    if shape.kind == "train":
        o_struct = opt_struct(p_struct)
        o_specs = param_specs(o_struct)
        b_struct = batch_struct(cfg, shape)
        b_specs = batch_specs(cfg, shape, ctx)
        opt_cfg = OptimizerConfig()
        step = make_train_step(cfg, opt_cfg, ctx, remat="full")
        return (
            (p_struct, o_struct, b_struct),
            (p_specs, o_specs, b_specs),
            step,
            (0, 1),
        )

    if shape.kind == "prefill":
        from repro.models.model import forward

        b_struct = batch_struct(cfg, shape)
        b_specs = batch_specs(cfg, shape, ctx)

        def step(params, batch):
            return forward(params, batch, cfg, ctx, remat="full")

        return ((p_struct, b_struct), (p_specs, b_specs), step, ())

    # decode
    from repro.serve.decode import decode_layout, serve_step

    plan = plan_cache(cfg, shape.global_batch, shape.seq_len)
    c_struct = cache_struct(cfg, plan)
    c_specs = cache_specs(cfg, plan, ctx)
    ba, _ = decode_layout(ctx, shape.global_batch)
    tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    lengths = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)

    def step(params, token, cache, lens):
        return serve_step(params, token, cache, lens, cfg, ctx)

    return (
        (p_struct, tok, c_struct, lengths),
        (p_specs, P(ba, None), c_specs, P(ba)),
        step,
        (2,),
    )


# ---------------------------------------------------------------------------
# run one cell
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             force: bool = False, verbose: bool = True) -> dict:
    mesh_name = "multi" if multi_pod else "single"
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "runnable": ok, "skip_reason": why, "status": "skipped",
    }
    if not ok:
        with open(out_path, "w") as f:
            json.dump(record, f, indent=2)
        return record

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = mesh.devices.size
        ctx = ShardCtx(mesh=mesh)
        args, specs, step, donate = input_specs(cfg, shape, ctx)
        shardings = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P),
        )
        with mesh:
            jitted = jax.jit(
                step, in_shardings=shardings, donate_argnums=donate
            )
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):  # jax<=0.4.x: one dict per device
            cost = cost[0] if cost else {}
        roof = build_roofline(cfg, shape, mesh_name, chips, compiled)
        record.update(
            status="ok",
            compile_s=time.time() - t0,
            memory_analysis=str(mem),
            cost_flops=float((cost or {}).get("flops", 0.0)),
            roofline=json.loads(json.dumps(roof.__dict__)),
            suggestion=suggestion(roof),
        )
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_name}] OK "
                  f"({record['compile_s']:.0f}s compile)")
            print("  memory_analysis:", mem)
            print(f"  terms: compute={roof.compute_s:.4f}s "
                  f"memory={roof.memory_s:.4f}s "
                  f"collective={roof.collective_s:.4f}s -> {roof.dominant}")
            print(f"  useful_ratio={roof.useful_ratio:.3f}  "
                  f"suggestion: {suggestion(roof)}")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        record.update(
            status="error",
            error=f"{type(e).__name__}: {e}",
            traceback=traceback.format_exc()[-4000:],
            compile_s=time.time() - t0,
        )
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_name}] FAILED: "
                  f"{record['error']}")
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2, default=str)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    args = ap.parse_args()

    if args.list:
        from repro.configs.base import all_cells

        for arch, shape, ok, why in all_cells():
            print(f"{arch:24s} {shape:12s} {'RUN' if ok else 'SKIP: ' + why}")
        return

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = (
        [(a, s) for a in ARCH_IDS for s in SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    statuses = []
    for arch, shape in cells:
        for mesh_name in meshes:
            rec = run_cell(arch, shape, mesh_name == "multi", args.out,
                           force=args.force)
            statuses.append((arch, shape, mesh_name, rec["status"]))
    n_ok = sum(1 for *_, s in statuses if s == "ok")
    n_skip = sum(1 for *_, s in statuses if s == "skipped")
    n_err = sum(1 for *_, s in statuses if s == "error")
    print(f"\n== dry-run summary: {n_ok} ok / {n_skip} skipped / {n_err} errors ==")
    for arch, shape, mesh_name, s in statuses:
        if s == "error":
            print(f"  FAILED: {arch} x {shape} x {mesh_name}")


if __name__ == "__main__":
    main()
