import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbing driver: lower one (arch x shape) cell with a named set
of optimization knobs and print the three roofline terms.

    PYTHONPATH=src python -m repro.launch.hillclimb --arch deepseek_67b \
        --shape train_4k --variant A1_constraints

Variants are hypothesis-driven changes logged in EXPERIMENTS.md §Perf.
"""

import argparse
import json
import time
from dataclasses import replace

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, get_config
from repro.launch.dryrun import (
    batch_specs,
    batch_struct,
    input_specs,
    opt_struct,
    params_struct,
)
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import build_roofline, suggestion
from repro.sharding.specs import ShardCtx, param_specs
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import make_train_step

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "artifacts", "hillclimb")


def _grad_compress_specs(cfg, shape, ctx, rank, remat):
    """DROP-compressed cross-pod gradient reduction (§Perf A7/A8).

    Rank-r bases for the big weight families (discovered by DROP on gradient
    matrices at runtime; here rank is the knob). The pod all-reduce of those
    grads shrinks to r/cols of the dense reduce; error-feedback residuals are
    carried per pod."""
    import numpy as np

    # the pod-manual shard_map trips an XLA SPMD assert on gathers: feed
    # stub embeddings (same bytes/FLOPs as post-lookup reality) and use the
    # one-hot label selection (ShardCtx.onehot_loss)
    ctx.onehot_loss = True
    p_struct = params_struct(cfg)
    o_struct = opt_struct(p_struct)
    b_struct = batch_struct(cfg, shape)
    b_struct["inputs"] = jax.ShapeDtypeStruct(
        (shape.global_batch, shape.seq_len, cfg.d_model), jnp.bfloat16
    )
    n_pods = ctx.mesh.devices.shape[0]

    # concrete bases (orthonormal columns) for the compressible matrices;
    # rank==0 means "same code path, dense (uncompressed) pod reduce"
    bases = {}
    rng = np.random.default_rng(0)
    if rank > 0:
        for path, leaf in jax.tree_util.tree_leaves_with_path(p_struct):
            names = tuple(
                p.key if hasattr(p, "key") else str(p) for p in path
            )
            if not any(n in names for n in
                       ("wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down")):
                continue
            cols = leaf.shape[-1]
            if cols < 4 * rank:
                continue
            q, _ = np.linalg.qr(rng.normal(size=(cols, rank)).astype(np.float32))
            from repro.train.grad_compress import _path_key

            bases[_path_key(path)] = jnp.asarray(q)

    step = make_train_step(
        cfg, OptimizerConfig(), ctx, remat=remat, compress_bases=bases
    )
    resid_struct = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((n_pods, *s.shape), jnp.float32),
        p_struct,
    )
    resid_specs = jax.tree_util.tree_map(
        lambda s: P("pod"), p_struct
    )
    b_specs = batch_specs(cfg, shape, ctx)
    b_specs["inputs"] = P(ctx.dp, None, None)  # stub embeddings are 3D
    specs = (
        param_specs(p_struct),
        param_specs(o_struct),
        b_specs,
        resid_specs,
    )
    args = (p_struct, o_struct, b_struct, resid_struct)
    return args, specs, step, (0, 1, 3)


def run_variant(
    arch: str,
    shape_name: str,
    variant: str,
    *,
    tuned: bool = False,
    microbatches: int = 1,
    remat: str = "full",
    mamba_split: bool = False,
    kv_rank: int | None = None,
    multi_pod: bool = False,
    kv_chunk: int | None = None,
    grad_compress_rank: int | None = None,
    serve_params: bool = False,
) -> dict:
    cfg = get_config(arch)
    if mamba_split:
        cfg = replace(cfg, mamba_split_proj=True)
    if kv_chunk is not None:
        cfg = replace(cfg, kv_chunk=kv_chunk)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = ShardCtx(mesh=mesh, tuned=tuned)

    t0 = time.time()
    if shape.kind == "train" and grad_compress_rank is not None:
        assert multi_pod, "grad compression targets the pod axis"
        args, specs, step, donate = _grad_compress_specs(
            cfg, shape, ctx, grad_compress_rank, remat
        )
    elif shape.kind == "train":
        p_struct = params_struct(cfg)
        o_struct = opt_struct(p_struct)
        b_struct = batch_struct(cfg, shape)
        specs = (
            param_specs(p_struct),
            param_specs(o_struct),
            batch_specs(cfg, shape, ctx),
        )
        step = make_train_step(
            cfg, OptimizerConfig(), ctx, remat=remat, microbatches=microbatches
        )
        args, donate = (p_struct, o_struct, b_struct), (0, 1)
    elif shape.kind == "decode" and kv_rank is not None:
        from repro.launch.kvcomp import compressed_decode_specs

        args, specs, step, donate = compressed_decode_specs(
            cfg, shape, ctx, kv_rank, serve_params=serve_params
        )
    else:
        args, specs, step, donate = input_specs(cfg, shape, ctx)
        if serve_params and shape.kind == "decode":
            specs = (param_specs(args[0], serve=True),) + tuple(specs[1:])

    shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    with mesh:
        compiled = jax.jit(
            step, in_shardings=shardings, donate_argnums=donate
        ).lower(*args).compile()
    roof = build_roofline(cfg, shape, "multi" if multi_pod else "single",
                          mesh.devices.size, compiled, note=variant)
    mem = compiled.memory_analysis()
    rec = {
        "arch": arch, "shape": shape_name, "variant": variant,
        "compile_s": round(time.time() - t0, 1),
        "compute_s": roof.compute_s, "memory_s": roof.memory_s,
        "collective_s": roof.collective_s, "dominant": roof.dominant,
        "useful_ratio": roof.useful_ratio,
        "temp_gb": mem.temp_size_in_bytes / 1e9,
        "arg_gb": mem.argument_size_in_bytes / 1e9,
        "collective_ops": {k: v / 1e9 for k, v in roof.collective_ops.items()},
        "suggestion": suggestion(roof),
    }
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, f"{arch}__{shape_name}__{variant}.json"),
              "w") as f:
        json.dump(rec, f, indent=2)
    print(
        f"[{variant}] compute={roof.compute_s:.4f}s memory={roof.memory_s:.4f}s "
        f"collective={roof.collective_s:.4f}s dom={roof.dominant} "
        f"useful={roof.useful_ratio:.3f} temp={rec['temp_gb']:.1f}GB "
        f"(compile {rec['compile_s']}s)"
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--tuned", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--mamba-split", action="store_true")
    ap.add_argument("--kv-rank", type=int, default=None)
    ap.add_argument("--kv-chunk", type=int, default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--grad-compress-rank", type=int, default=None)
    ap.add_argument("--serve-params", action="store_true")
    args = ap.parse_args()
    run_variant(
        args.arch, args.shape, args.variant,
        tuned=args.tuned, microbatches=args.microbatches, remat=args.remat,
        mamba_split=args.mamba_split, kv_rank=args.kv_rank,
        multi_pod=args.multi_pod, kv_chunk=args.kv_chunk,
        grad_compress_rank=args.grad_compress_rank,
        serve_params=args.serve_params,
    )


if __name__ == "__main__":
    main()
