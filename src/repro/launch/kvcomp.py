"""DROP KV-compressed decode for the dry-run (§Perf cell C).

The cache stores rank-r projections of K/V (bases discovered by DROP on
sampled key/value rows — serve/kv_compress.py); decode attention runs wholly
in r dims: scores = (q V_k)·c_k, out = (p·c_v) V_vᵀ. Cache memory and decode
HBM traffic scale by r/hd with exact algebra given the basis.
"""

from __future__ import annotations

import jax

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.dryrun import params_struct
from repro.models.layers import apply_mrope, apply_rope, rms_norm
from repro.serve.decode import _mlp_decode, _moe_decode, decode_layout
from repro.sharding.specs import ShardCtx, param_specs

NEG_INF = -1e30


def flash_decode_compressed(qc, ck, cv, basis_v, valid, ctx: ShardCtx, hd: int):
    """qc: (B,1,KV,G,r) query already in key-basis; ck/cv: (B,T,KV,r);
    returns (B,1,KV,G,hd) after expanding through basis_v."""
    batch_axes, seq_axes = decode_layout(ctx, qc.shape[0])
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    def local(ql, kl, vl, validl, bv):
        s = jnp.einsum("bqkgr,btkr->bkgqt", ql, kl,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(validl[:, None, None, None, :], s, NEG_INF)
        m = jnp.max(s, axis=-1)
        m_g = jax.lax.pmax(m, seq_axes) if seq_axes else m
        p = jnp.exp(s - m_g[..., None])
        l = jnp.sum(p, axis=-1)
        oc = jnp.einsum("bkgqt,btkr->bkgqr", p, vl,
                        preferred_element_type=jnp.float32)
        if seq_axes:
            l = jax.lax.psum(l, seq_axes)
            oc = jax.lax.psum(oc, seq_axes)
        o = jnp.einsum("bkgqr,hr->bkgqh", oc / jnp.maximum(l, 1e-30)[..., None],
                       bv.astype(jnp.float32))
        return o.transpose(0, 3, 1, 2, 4).astype(ql.dtype)

    if ctx.mesh is None:
        return local(qc, ck, cv, valid, basis_v)
    ba, sa = tuple(batch_axes), tuple(seq_axes)
    return shard_map(
        local,
        mesh=ctx.mesh,
        in_specs=(P(ba, None, None, None, None), P(ba, sa, None, None),
                  P(ba, sa, None, None), P(ba, sa), P(None, None)),
        out_specs=P(ba, None, None, None, None),
        check_vma=False,
    )(qc, ck, cv, valid, basis_v)


def serve_step_compressed(params, token, cache, lengths, bases, cfg, ctx):
    """Decode step with rank-r compressed attention caches (dense families)."""
    b = token.shape[0]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kv
    x = jnp.take(params["embed"], token[:, 0], axis=0)
    ck_all, cv_all = cache["attn"]["ck"], cache["attn"]["cv"]
    t = ck_all.shape[2]
    filled = jnp.minimum(lengths + 1, t)
    valid = jnp.arange(t)[None, :] < filled[:, None]
    slot = jnp.minimum(lengths, t - 1)
    bi = jnp.arange(b)

    def body(hcar, layer_in):
        layer, ck_l, cv_l, bk, bv = layer_in
        hn = rms_norm(hcar, layer["ln1"], cfg.norm_eps)
        q = (hn @ layer["attn"]["wq"]).reshape(b, 1, h, hd)
        k = (hn @ layer["attn"]["wk"]).reshape(b, 1, kv, hd)
        v = (hn @ layer["attn"]["wv"]).reshape(b, 1, kv, hd)
        if "q_norm" in layer["attn"]:
            q = rms_norm(q, layer["attn"]["q_norm"], cfg.norm_eps)
            k = rms_norm(k, layer["attn"]["k_norm"], cfg.norm_eps)
        pos_new = lengths[:, None]
        if cfg.mrope_sections:
            p3 = jnp.broadcast_to(pos_new, (3, b, 1))
            q = apply_mrope(q, p3, cfg.mrope_sections, cfg.rope_theta)
            k = apply_mrope(k, p3, cfg.mrope_sections, cfg.rope_theta)
        elif cfg.rope_theta > 0:
            q = apply_rope(q, pos_new, cfg.rope_theta)
            k = apply_rope(k, pos_new, cfg.rope_theta)
        # compress the new K/V rows into the DROP basis and cache them
        ck_new = jnp.einsum("bqkh,hr->bqkr", k, bk).astype(ck_l.dtype)
        cv_new = jnp.einsum("bqkh,hr->bqkr", v, bv).astype(cv_l.dtype)
        ck_l = ck_l.at[bi, slot].set(ck_new[:, 0])
        cv_l = cv_l.at[bi, slot].set(cv_new[:, 0])
        qc = jnp.einsum(
            "bqkgh,hr->bqkgr", q.reshape(b, 1, kv, g, hd), bk
        ).astype(ck_l.dtype)
        out = flash_decode_compressed(qc, ck_l, cv_l, bv, valid, ctx, hd)
        y = out.reshape(b, 1, h * hd)[:, 0] @ layer["attn"]["wo"]
        hcar = hcar + y.astype(hcar.dtype)
        if cfg.family == "moe":
            hcar = _moe_decode(hcar, layer, cfg, ctx)
        else:
            hcar = _mlp_decode(hcar, layer, cfg)
        return hcar, (ck_l, cv_l)

    x, (ck_new, cv_new) = jax.lax.scan(
        body, x,
        (params["layers"], ck_all, cv_all, bases["k"], bases["v"]),
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, {"attn": {"ck": ck_new, "cv": cv_new}}


def compressed_decode_specs(
    cfg: ModelConfig, shape: ShapeConfig, ctx: ShardCtx, rank: int,
    serve_params: bool = False,
):
    """(args, specs, step_fn, donate) for the compressed-decode dry-run."""
    dtype = jnp.dtype(cfg.dtype)
    b, t = shape.global_batch, shape.seq_len
    l, kvh = cfg.num_layers, cfg.num_kv_heads
    ba, sa = decode_layout(ctx, b)

    p_struct = params_struct(cfg)
    cache = {
        "attn": {
            "ck": jax.ShapeDtypeStruct((l, b, t, kvh, rank), dtype),
            "cv": jax.ShapeDtypeStruct((l, b, t, kvh, rank), dtype),
        }
    }
    cache_spec = {
        "attn": {
            "ck": P(None, ba, sa, None, None),
            "cv": P(None, ba, sa, None, None),
        }
    }
    bases = {
        "k": jax.ShapeDtypeStruct((l, cfg.head_dim, rank), jnp.float32),
        "v": jax.ShapeDtypeStruct((l, cfg.head_dim, rank), jnp.float32),
    }
    bases_spec = {"k": P(None, None, None), "v": P(None, None, None)}
    tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    lengths = jax.ShapeDtypeStruct((b,), jnp.int32)

    def step(params, token, cache, lens, bases_):
        return serve_step_compressed(params, token, cache, lens, bases_, cfg, ctx)

    args = (p_struct, tok, cache, lengths, bases)
    specs = (
        param_specs(p_struct, serve=serve_params),
        P(ba, None), cache_spec, P(ba), bases_spec,
    )
    return args, specs, step, (2,)
