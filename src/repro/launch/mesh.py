"""Production mesh construction (prescribed shapes).

single-pod:  (16, 16)    -> ("data", "model")        = 256 chips
multi-pod:   (2, 16, 16) -> ("pod", "data", "model") = 512 chips

A FUNCTION (not a module constant) so importing this module never touches
jax device state; only launch/dryrun.py forces the 512-device host platform.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) > n:  # e.g. 512 forced host devices, single-pod mesh
        return Mesh(np.asarray(devices[:n]).reshape(shape), axes)
    raise RuntimeError(
        f"need {n} devices for mesh {shape}, have {len(devices)} — run under "
        "launch/dryrun.py (which forces XLA_FLAGS device count) for dry-runs"
    )


def make_smoke_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh over however many host devices tests force (>=4)."""
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)
