"""Serving launcher CLI: batched greedy generation through the Engine.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama_1_1b \
        --batch 4 --prompt-len 16 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.models.model import init_model
from repro.serve.engine import Engine
from repro.sharding.specs import ShardCtx


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="tinyllama_1_1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_model(cfg, jax.random.PRNGKey(args.seed))
    ctx = ShardCtx(mesh=None)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(args.batch, args.prompt_len))

    eng = Engine(params, cfg, ctx, batch=args.batch,
                 context_len=args.prompt_len + args.max_new)
    t0 = time.perf_counter()
    res = eng.generate(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    n_tok = res.tokens.size + prompts.size
    print(f"{cfg.name}: generated {res.tokens.shape} in {dt*1e3:.0f} ms "
          f"({n_tok/dt:.0f} tok/s incl. prefill+compile)")
    print(res.tokens)


if __name__ == "__main__":
    main()
