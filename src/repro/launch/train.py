"""Training launcher CLI: reduced configs train for real on this host; full
configs lower/compile against the production meshes (use dryrun.py for the
no-allocation path).

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama_1_1b \
        --steps 100 --scale smoke [--drop-compress] [--failure-prob 0.02]
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.configs.base import ARCH_IDS, get_config, get_smoke_config
from repro.train.grad_compress import GradCompressConfig
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


class _CliTrainer(Trainer):
    seq_len = 256
    batch = 8

    def _seq_len(self) -> int:
        return self.seq_len

    def _batch(self) -> int:
        return self.batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="tinyllama_1_1b")
    ap.add_argument("--scale", choices=["smoke", "100m"], default="smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--failure-prob", type=float, default=0.0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "full", "dots"])
    ap.add_argument("--drop-compress", action="store_true")
    args = ap.parse_args()

    if args.scale == "smoke":
        cfg = get_smoke_config(args.arch)
    else:
        from repro.configs.scaled import scaled_100m

        cfg = scaled_100m(args.arch)
    print(f"training {cfg.name} ({cfg.param_count()/1e6:.1f}M params) "
          f"for {args.steps} steps")

    tcfg = TrainerConfig(
        total_steps=args.steps,
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        microbatches=args.microbatches,
        remat=args.remat,
        failure_prob=args.failure_prob,
        grad_compress=GradCompressConfig() if args.drop_compress else None,
    )
    trainer = _CliTrainer(
        cfg,
        OptimizerConfig(learning_rate=args.lr, warmup_steps=max(args.steps // 10, 1),
                        total_steps=args.steps),
        tcfg,
    )
    trainer.seq_len = args.seq_len
    trainer.batch = args.batch
    report = trainer.run()
    print(f"done: steps={report.steps_run} restarts={report.restarts} "
          f"loss {np.mean(report.losses[:5]):.4f} -> "
          f"{np.mean(report.losses[-5:]):.4f}")


if __name__ == "__main__":
    main()
