"""GQA attention with double-chunked online softmax (flash-style in JAX).

Design (DESIGN.md §5): for train/prefill the query SEQUENCE is sharded over
the "model" mesh axis (sp_q) — uniform across all assigned archs regardless of
head-count divisibility — while K/V (small for GQA) are gathered. The math
here is layout-agnostic; sharding is imposed by constraints in blocks.py.

Memory: scores are never materialized beyond one (q_chunk x kv_chunk) tile per
(batch, head): an outer scan over query chunks and an inner scan over KV
chunks carry online-softmax stats (m, l, acc), exactly the FlashAttention
recurrence. This is what keeps the 32k-prefill cells inside HBM.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


class AttnParams(NamedTuple):
    wq: jax.Array  # (d, H*hd)
    wk: jax.Array  # (d, KV*hd)
    wv: jax.Array  # (d, KV*hd)
    wo: jax.Array  # (H*hd, d)
    q_norm: jax.Array | None  # (hd,) qk_norm scales (qwen3)
    k_norm: jax.Array | None


def init_attention(key, d, num_heads, num_kv_heads, head_dim, dtype, qk_norm=False):
    from repro.models.layers import init_dense

    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": init_dense(k1, d, num_heads * head_dim, dtype),
        "wk": init_dense(k2, d, num_kv_heads * head_dim, dtype),
        "wv": init_dense(k3, d, num_kv_heads * head_dim, dtype),
        "wo": init_dense(k4, num_heads * head_dim, d, dtype),
        **(
            {
                "q_norm": jnp.ones((head_dim,), dtype=dtype),
                "k_norm": jnp.ones((head_dim,), dtype=dtype),
            }
            if qk_norm
            else {}
        ),
    }


def _mask(pos_q, pos_k, causal: bool, window: int | None):
    """(Cq, Ck) allowed-attention mask from absolute positions."""
    m = jnp.ones((pos_q.shape[0], pos_k.shape[0]), dtype=bool)
    if causal:
        m &= pos_q[:, None] >= pos_k[None, :]
    if window is not None:
        m &= pos_q[:, None] - pos_k[None, :] < window
    return m


def _attn_tile(q, kc, vc, mask, scale):
    """One online-softmax tile. q: (B, Cq, KV, G, hd); kc/vc: (B, Ck, KV, hd).
    Returns (s_max, p, pv) pieces for the recurrence."""
    s = jnp.einsum(
        "bqkgh,bskh->bkgqs", q, kc, preferred_element_type=jnp.float32
    ) * scale  # (B, KV, G, Cq, Ck)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    return s


def attention(
    x_q: jax.Array,  # (B, Sq, d) — possibly a seq shard
    x_kv: jax.Array,  # (B, Skv, d) — full sequence
    params: dict,
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    pos_q: jax.Array,  # (Sq,) absolute positions of the q rows
    pos_k: jax.Array,  # (Skv,)
    causal: bool = True,
    window: int | None = None,
    rope_theta: float = 10000.0,
    mrope_sections: tuple[int, ...] = (),
    qk_norm_eps: float = 1e-6,
    q_chunk: int = 512,
    kv_chunk: int = 512,
    kv_constrain=None,  # sharding hook: gathers K/V across the "model" axis
    return_kv: bool = False,
) -> jax.Array:
    """Full attention sublayer: qkv proj -> rope -> flash -> out proj."""
    from repro.models.layers import apply_mrope, apply_rope, rms_norm

    b, sq, d = x_q.shape
    skv = x_kv.shape[1]
    h, kv, hd = num_heads, num_kv_heads, head_dim
    g = h // kv

    q = (x_q @ params["wq"]).reshape(b, sq, h, hd)
    k = (x_kv @ params["wk"]).reshape(b, skv, kv, hd)
    v = (x_kv @ params["wv"]).reshape(b, skv, kv, hd)

    if "q_norm" in params:  # qwen3 qk_norm: per-head RMS norm before rope
        q = rms_norm(q, params["q_norm"], qk_norm_eps)
        k = rms_norm(k, params["k_norm"], qk_norm_eps)

    if mrope_sections:
        pq3 = jnp.broadcast_to(pos_q, (3,) + pos_q.shape)
        pk3 = jnp.broadcast_to(pos_k, (3,) + pos_k.shape)
        q = apply_mrope(q, pq3, mrope_sections, rope_theta)
        k = apply_mrope(k, pk3, mrope_sections, rope_theta)
    elif rope_theta > 0:
        q = apply_rope(q, pos_q, rope_theta)
        k = apply_rope(k, pos_k, rope_theta)

    if kv_constrain is not None:  # sp_q: K/V computed seq-sharded, gathered here
        k = kv_constrain(k)
        v = kv_constrain(v)

    out = flash_attention(
        q.reshape(b, sq, kv, g, hd),
        k,
        v,
        pos_q=pos_q,
        pos_k=pos_k,
        causal=causal,
        window=window,
        q_chunk=q_chunk,
        kv_chunk=kv_chunk,
    )  # (B, Sq, KV, G, hd)
    y = out.reshape(b, sq, h * hd) @ params["wo"]
    if return_kv:
        return y, (k, v)
    return y


def flash_attention(
    q: jax.Array,  # (B, Sq, KV, G, hd)
    k: jax.Array,  # (B, Skv, KV, hd)
    v: jax.Array,  # (B, Skv, KV, hd)
    *,
    pos_q: jax.Array,
    pos_k: jax.Array,
    causal: bool,
    window: int | None,
    q_chunk: int = 0,  # unused; queries stay parallel (sharded over "model")
    kv_chunk: int = 512,
) -> jax.Array:
    """Online-softmax attention, scanned over KV chunks only.

    Queries are NOT scanned: under sp_q sharding the q rows are split over the
    "model" mesh axis, so keeping them as one parallel dimension is what makes
    every device busy. Per-device transient is (B_local, H, Sq_local, Ck).
    Returns (B, Sq, KV, G, hd)."""
    b, sq, kv, g, hd = q.shape
    skv = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    ck = min(kv_chunk, skv)
    nk = skv // ck
    assert skv % ck == 0, (skv, ck)

    ks = k.reshape(b, nk, ck, kv, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nk, ck, kv, hd).transpose(1, 0, 2, 3, 4)
    pks = pos_k.reshape(nk, ck)

    def kv_step(carry, kv_in):
        m, l, acc = carry
        kc, vc, pk = kv_in
        s = _attn_tile(q, kc, vc, _mask(pos_q, pk, causal, window), scale)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        # (§Perf A6 tried re-quantizing p to bf16 for the PV contraction;
        # refuted — no transient win under CPU lowering, and it costs decode
        # parity accuracy. Keep f32 p; MXU handles the cast for free on TPU.)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p, vc, preferred_element_type=jnp.float32,
        )
        return (m_new, l, acc), None

    m0 = jnp.full((b, kv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kv, g, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (ks, vs, pks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)


def decode_attention(
    q: jax.Array,  # (B, 1, KV, G, hd) — the new token's query
    k_cache: jax.Array,  # (B, T, KV, hd)
    v_cache: jax.Array,  # (B, T, KV, hd)
    *,
    length_mask: jax.Array,  # (B, T) bool — valid cache slots
) -> jax.Array:
    """Single-step cache attention (unsharded reference; the distributed
    seq-sharded version lives in repro.serve.decode.flash_decode)."""
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    s = jnp.einsum(
        "bqkgh,btkh->bkgqt", q, k_cache, preferred_element_type=jnp.float32
    ) * scale
    s = jnp.where(length_mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkh->bqkgh", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
