"""Decoder blocks: dense / MoE / Mamba2 / cross-attention, sharding-aware.

Each block takes the sequence-parallel residual stream (B, S, d) sharded
(batch->dp, seq->"model"), applies Megatron-SP style gather/scatter around the
TP sublayers via ShardCtx constraints, and returns the residual in the same
layout. With ctx.mesh=None all constraints no-op (smoke tests).
"""

from __future__ import annotations

import functools

import jax

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.models.attention import attention, init_attention
from repro.models.mlp import gelu_mlp, init_gelu_mlp, init_swiglu, swiglu
from repro.models.moe import init_moe, moe_ffn
from repro.models.ssm import init_mamba2, mamba2_forward
from repro.models.layers import rms_norm
from repro.sharding.specs import ShardCtx


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_dense_block(key, cfg, dtype):
    ka, km = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attention(
            ka, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
            dtype, qk_norm=cfg.qk_norm,
        ),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": init_swiglu(km, cfg.d_model, cfg.d_ff, dtype),
    }


def init_moe_block(key, cfg, dtype):
    ka, km = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attention(
            ka, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
            dtype, qk_norm=cfg.qk_norm,
        ),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "moe": init_moe(km, cfg.d_model, cfg.moe_d_ff, cfg.num_experts, dtype),
    }


def init_mamba_block(key, cfg, dtype):
    return {
        "ln": jnp.ones((cfg.d_model,), dtype),
        "mamba": init_mamba2(key, cfg, dtype),
    }


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _attn_sublayer(x, params, cfg, ctx: ShardCtx, pos_q, pos_k, x_kv=None,
                   causal=True, return_kv=False):
    """Pre-norm attention with sp_q sharding. x is the seq-sharded residual."""
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    if ctx.tuned:
        h = ctx.residual(h)  # pin cotangent layout at the norm boundary
    h_kv = h if x_kv is None else x_kv
    out = attention(
        h,
        h_kv,
        params["attn"],
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim,
        pos_q=pos_q,
        pos_k=pos_k,
        causal=causal,
        window=cfg.sliding_window,
        rope_theta=cfg.rope_theta if causal else 0.0,  # no rope on cross-attn
        mrope_sections=cfg.mrope_sections,
        kv_chunk=cfg.kv_chunk,
        kv_constrain=ctx.kv_gathered if ctx.mesh is not None else None,
        return_kv=return_kv,
    )
    if return_kv:
        y, kv = out
        if ctx.tuned:
            y = ctx.residual(y)  # force reduce-scatter of the wo output
        return ctx.residual(x + y), kv
    if ctx.tuned:
        out = ctx.residual(out)
    return ctx.residual(x + out)


def _mlp_sublayer(x, params, cfg, ctx: ShardCtx, kind="swiglu"):
    h = rms_norm(x, params["ln2"], cfg.norm_eps)
    if ctx.tuned:
        h = ctx.residual(h)
    h = ctx.gathered(h)  # all-gather seq; TP (f-sharded) matmuls follow
    if kind == "swiglu":
        if ctx.tuned:
            # pin the TP intermediate so w_down's input cotangent stays
            # f-sharded (avoids a full (B,S,f) gather in backward)
            g = ctx.ffn_hidden(h @ params["mlp"]["w_gate"])
            u = ctx.ffn_hidden(h @ params["mlp"]["w_up"])
            y = (jax.nn.silu(g) * u) @ params["mlp"]["w_down"]
        else:
            y = swiglu(h, params["mlp"])
    else:
        y = gelu_mlp(h, params["mlp"])
    if ctx.tuned:
        y = ctx.residual(y)  # reduce-scatter the partial w_down output
    return ctx.residual(x + y)


def _moe_sublayer(x, params, cfg, ctx: ShardCtx):
    """MoE FFN: tokens local to each dp shard (shard_map), expert width TP."""
    h = rms_norm(x, params["ln2"], cfg.norm_eps)
    h = ctx.gathered(h)
    b, s, d = h.shape
    kwargs = dict(
        num_experts=cfg.num_experts,
        experts_per_token=cfg.experts_per_token,
        capacity_factor=cfg.capacity_factor,
        token_chunk=16384 if b * s > 16384 else None,
    )

    if ctx.mesh is None:
        y, aux = moe_ffn(h.reshape(b * s, d), params["moe"], **kwargs)
        return ctx.residual(x + y.reshape(b, s, d)), aux

    mesh = ctx.mesh
    dp = ctx.dp
    moe_specs = {
        "router": P(None, None),
        "w_gate": P(None, None, "model"),
        "w_up": P(None, None, "model"),
        "w_down": P(None, "model", None),
    }

    def local_fn(hl, p):
        bl, sl, _ = hl.shape
        y, aux = moe_ffn(hl.reshape(bl * sl, d), p, **kwargs)
        y = jax.lax.psum(y, "model")  # combine TP partial w_down outputs
        if dp:  # (psum/size, not pmean: XLA-CPU AllReducePromotion bug)
            n = 1
            for ax in dp:
                n *= mesh.devices.shape[list(mesh.axis_names).index(ax)]
            aux = jax.lax.psum(aux, dp) / n
        return y.reshape(bl, sl, d), aux

    y, aux = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(dp, None, None), moe_specs),
        out_specs=(P(dp, None, None), P()),
        check_vma=False,
    )(h, params["moe"])
    return ctx.residual(x + y), aux


def _mamba_sublayer(x, params, cfg, ctx: ShardCtx):
    h = rms_norm(x, params["ln"], cfg.norm_eps)
    if ctx.tuned:
        h = ctx.residual(h)
    h = ctx.gathered(h)  # SSD needs the full sequence; heads are TP-sharded
    y, _ = mamba2_forward(
        h, params["mamba"], cfg,
        constrain_heads=ctx.heads_sharded if (ctx.tuned and ctx.mesh) else None,
    )
    if ctx.tuned:
        y = ctx.residual(y)  # reduce-scatter the out_proj partials
    return ctx.residual(x + y)


# --------------------------------------------------------------------------
# block-level entry points (used by transformer.py scan bodies)
# --------------------------------------------------------------------------

def dense_block(x, params, cfg, ctx, pos):
    x = _attn_sublayer(x, params, cfg, ctx, pos, pos)
    x = _mlp_sublayer(x, params, cfg, ctx)
    return x


def moe_block(x, params, cfg, ctx, pos):
    x = _attn_sublayer(x, params, cfg, ctx, pos, pos)
    x, aux = _moe_sublayer(x, params, cfg, ctx)
    return x, aux


def mamba_block(x, params, cfg, ctx):
    return _mamba_sublayer(x, params, cfg, ctx)


def hybrid_attn_block(x, params, cfg, ctx, pos):
    """zamba2 shared transformer block: attention + dense MLP."""
    x = _attn_sublayer(x, params, cfg, ctx, pos, pos)
    x = _mlp_sublayer(x, params, cfg, ctx)
    return x
