"""Shared neural-net layers: norms, RoPE (incl. M-RoPE), embeddings.

Functional style: params are plain nested dicts of jax.Arrays; every layer is
an ``init_*`` returning params plus a pure ``apply`` function. Layer stacks
are created pre-stacked (leading layer dim) for scan-over-layers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dtype) * scale


def layer_norm(
    x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-6
) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(dtype) * scale + bias


def init_dense(key: jax.Array, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(
        dtype
    )


def init_embedding(key: jax.Array, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    """Inverse frequencies for rotary embedding (half of head_dim)."""
    return 1.0 / (
        theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim)
    )


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float = 10000.0
) -> jax.Array:
    """Standard RoPE. x: (..., S, H, hd), positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = jnp.asarray(rope_frequencies(hd, theta), dtype=jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    sections: tuple[int, ...],
    theta: float = 10000.0,
) -> jax.Array:
    """Qwen2-VL multimodal RoPE: head_dim/2 frequencies split into
    (temporal, height, width) sections, each rotated by its own position
    stream. ``positions``: (3, ..., S) — with the stubbed vision frontend all
    three streams carry the text position (the lowering-faithful degenerate
    case); real image patches would carry (t, h, w) grid coordinates.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    inv = jnp.asarray(rope_frequencies(hd, theta), dtype=jnp.float32)  # (hd/2,)
    # select the position stream per frequency slot by section
    sec_id = np.repeat(np.arange(len(sections)), sections)  # (hd/2,)
    pos = positions.astype(jnp.float32)  # (3, ..., S)
    pos_per_slot = jnp.take(pos, jnp.asarray(sec_id), axis=0)  # (hd/2, ..., S)
    pos_per_slot = jnp.moveaxis(pos_per_slot, 0, -1)  # (..., S, hd/2)
    ang = pos_per_slot * inv
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, d: int) -> np.ndarray:
    """Whisper-style fixed sinusoidal position embeddings (length, d)."""
    log_timescale = np.log(10000.0) / (d // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(d // 2))
    scaled = np.arange(length)[:, None] * inv[None, :]
    return np.concatenate([np.sin(scaled), np.cos(scaled)], axis=1).astype(np.float32)
