"""Feed-forward sublayers: SwiGLU (llama-family) and GELU (whisper)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense


def init_swiglu(key, d: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": init_dense(k1, d, d_ff, dtype),
        "w_up": init_dense(k2, d, d_ff, dtype),
        "w_down": init_dense(k3, d_ff, d, dtype),
    }


def swiglu(x: jax.Array, params: dict) -> jax.Array:
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    return h @ params["w_down"]


def init_gelu_mlp(key, d: int, d_ff: int, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w_up": init_dense(k1, d, d_ff, dtype),
        "w_down": init_dense(k2, d_ff, d, dtype),
    }


def gelu_mlp(x: jax.Array, params: dict) -> jax.Array:
    return jax.nn.gelu(x @ params["w_up"]) @ params["w_down"]
