"""Model registry: family dispatch for init / loss / forward."""

from __future__ import annotations

import jax

from repro.configs.base import ModelConfig
from repro.models import transformer, whisper
from repro.sharding.specs import ShardCtx


def init_model(cfg: ModelConfig, key: jax.Array) -> dict:
    if cfg.is_encoder_decoder:
        return whisper.init_whisper(cfg, key)
    return transformer.init_lm(cfg, key)


def loss_fn(params, batch, cfg: ModelConfig, ctx: ShardCtx, remat: str = "full"):
    if cfg.is_encoder_decoder:
        return whisper.whisper_loss(params, batch, cfg, ctx, remat=remat)
    return transformer.lm_loss(params, batch, cfg, ctx, remat=remat)


def forward(params, batch, cfg: ModelConfig, ctx: ShardCtx, remat: str = "none"):
    """Logits for a full sequence (prefill-style pass)."""
    if cfg.is_encoder_decoder:
        enc = whisper.encode(params, batch["frames"], cfg, ctx, remat=remat)
        return whisper.decode_train(params, batch["inputs"], enc, cfg, ctx, remat=remat)
    return transformer.forward(params, batch["inputs"], cfg, ctx, remat=remat)
