"""Mixture-of-Experts FFN: top-k routing with sort-based capacity dispatch.

Dispatch is the argsort formulation (static shapes, TPU-friendly): flatten
(token, k-choice) assignments, sort by expert, derive each assignment's
position within its expert group arithmetically, drop past-capacity
assignments, scatter into an (E, capacity, d) compute buffer, run the expert
FFNs as one vmapped einsum, and combine with the routing gates.

Parallelism (DESIGN.md §5): neither assigned MoE arch has expert counts
divisible by the 16-way "model" axis (8, 40), so experts are NOT
expert-sharded; instead expert FFN width f is TP-sharded over "model" and
tokens over ("pod","data") — the caller wraps ``moe_ffn`` in shard_map and
psums the partial w_down outputs (see blocks.py).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense


def init_moe(key, d: int, d_ff: int, num_experts: int, dtype):
    kr, k1, k2, k3 = jax.random.split(key, 4)
    e = num_experts
    return {
        "router": init_dense(kr, d, e, jnp.float32),  # router math in f32
        "w_gate": jax.vmap(lambda k: init_dense(k, d, d_ff, dtype))(
            jax.random.split(k1, e)
        ),
        "w_up": jax.vmap(lambda k: init_dense(k, d, d_ff, dtype))(
            jax.random.split(k2, e)
        ),
        "w_down": jax.vmap(lambda k: init_dense(k, d_ff, d, dtype))(
            jax.random.split(k3, e)
        ),
    }


def moe_ffn(
    x: jax.Array,  # (N, d) local tokens
    params: dict,
    *,
    num_experts: int,
    experts_per_token: int,
    capacity_factor: float = 1.25,
    token_chunk: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (out (N, d), load-balance aux loss). If the expert weights are
    f-slices (TP), ``out`` is a partial sum the caller must psum."""
    if token_chunk is not None and x.shape[0] > token_chunk:
        n = x.shape[0]
        assert n % token_chunk == 0, (n, token_chunk)
        xs = x.reshape(n // token_chunk, token_chunk, x.shape[1])
        outs, auxes = jax.lax.map(
            lambda xc: moe_ffn(
                xc,
                params,
                num_experts=num_experts,
                experts_per_token=experts_per_token,
                capacity_factor=capacity_factor,
            ),
            xs,
        )
        return outs.reshape(n, x.shape[1]), jnp.mean(auxes)

    n, d = x.shape
    e, k = num_experts, experts_per_token
    cap = max(1, math.ceil(k * n / e * capacity_factor))

    logits = x.astype(jnp.float32) @ params["router"]  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_logit, top_e = jax.lax.top_k(logits, k)  # (N, k)
    gates = jax.nn.softmax(top_logit, axis=-1)  # renormalize over chosen

    # load-balance auxiliary loss (Switch/GShard form)
    density = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=1), axis=0
    ) / k
    aux = e * jnp.sum(density * jnp.mean(probs, axis=0))

    # sort assignments by expert
    flat_e = top_e.reshape(-1)  # (N*k,)
    flat_t = jnp.repeat(jnp.arange(n), k)  # token of each assignment
    order = jnp.argsort(flat_e, stable=True)
    se, st = flat_e[order], flat_t[order]
    starts = jnp.searchsorted(se, jnp.arange(e))  # first slot of each expert
    pos = jnp.arange(n * k) - starts[se]  # position within expert group
    keep = pos < cap
    slot = jnp.where(keep, se * cap + pos, e * cap)  # overflow -> trash row

    buf = jnp.zeros((e * cap + 1, d), dtype=x.dtype).at[slot].set(x[st])
    ebuf = buf[:-1].reshape(e, cap, d)

    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", ebuf, params["w_gate"])
    ) * jnp.einsum("ecd,edf->ecf", ebuf, params["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h, params["w_down"])  # (E, cap, d)

    vals = y.reshape(e * cap, d)[jnp.minimum(slot, e * cap - 1)]
    vals = jnp.where(keep[:, None], vals, 0.0)
    w = gates.reshape(-1)[order][:, None].astype(x.dtype)
    out = jnp.zeros((n, d), dtype=x.dtype).at[st].add(vals * w)
    return out, aux
