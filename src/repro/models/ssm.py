"""Mamba2 SSD (state-space duality) block — chunked parallel form + decode.

Chunked SSD (Dao & Gu 2024, arXiv:2405.21060): split the sequence into chunks
of Q positions; within a chunk the recurrence is evaluated as a masked-decay
quadratic form (MXU matmuls); across chunks a short scan carries the (N x P)
state. This is the TPU-friendly formulation: O(S Q) FLOPs in matmul shape
instead of a length-S sequential scan.

Layout: x (B, S, H, P) with H = d_inner/headdim SSD heads (sharded over
"model": 80 and 64 both divide 16), B/C shared across heads (1 group).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense


def init_mamba2(key, cfg, dtype):
    """cfg: ModelConfig with ssm_* fields.

    §Perf iteration B1 (EXPERIMENTS.md): ``cfg.mamba_split_proj`` replaces the
    fused in_proj (whose [z|x|B|C|dt] channel layout splits at non-shard-
    aligned offsets, forcing a full gather of the 2di+2n+h projection) with
    per-stream projections whose output dims each shard cleanly: z/x TP on
    d_inner (head-aligned), B/C/dt replicated (tiny). Same math, same total
    parameter count."""
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    kin, kout, kconv, ka, kdt = jax.random.split(key, 5)
    conv_ch = di + 2 * n  # conv runs over [x, B, C]
    common = {
        "out_proj": init_dense(kout, di, d, dtype),
        "conv_w": (
            jax.random.normal(kconv, (cfg.conv_width, conv_ch), jnp.float32) * 0.1
        ).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.log(
            jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)
        ),  # A = -exp(a_log), per head
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.asarray(
            jnp.log(jnp.expm1(jnp.linspace(1e-3, 1e-1, h))), jnp.float32
        ),
        "norm_scale": jnp.ones((di,), dtype),  # gated RMSNorm pre-out_proj
    }
    if getattr(cfg, "mamba_split_proj", False):
        kz, kx, kb, kc, kd = jax.random.split(kin, 5)
        return {
            "z_proj": init_dense(kz, d, di, dtype),
            "x_proj": init_dense(kx, d, di, dtype),
            "b_proj": init_dense(kb, d, n, dtype),
            "c_proj": init_dense(kc, d, n, dtype),
            "dt_proj": init_dense(kd, d, h, dtype),
            **common,
        }
    return {
        # in_proj -> [z (di), x (di), B (n), C (n), dt (h)]
        "in_proj": init_dense(kin, d, 2 * di + 2 * n + h, dtype),
        **common,
    }


def _split_proj(proj, cfg):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z, xbc_dt = jnp.split(proj, [di], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [di + 2 * n], axis=-1)
    return z, xbc, dt  # (..., di), (..., di+2n), (..., h)


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d over (B, S, C) with kernel (W, C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(width):  # width is 4: unrolled taps
        out = out + pad[:, i : i + xbc.shape[1], :].astype(jnp.float32) * w[i].astype(
            jnp.float32
        )
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(xbc.dtype)


def _segsum_chunk(a: jax.Array) -> jax.Array:
    """a: (..., Q) log-decay increments -> (..., Q, Q) lower-triangular
    cumulative sums L[i,j] = sum_{t=j+1..i} a_t (NEG_INF above diagonal)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum_(j..i]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) — post-softplus step sizes
    a: jax.Array,  # (H,) negative decay rates (A)
    b_mat: jax.Array,  # (B, S, N)
    c_mat: jax.Array,  # (B, S, N)
    chunk: int,
    h0: jax.Array | None = None,  # (B, H, N, P) initial state
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final state (B,H,N,P))."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    xc = x.reshape(bsz, nc, q, h, p)
    dtc = dt.reshape(bsz, nc, q, h)
    bc = b_mat.reshape(bsz, nc, q, n)
    cc = c_mat.reshape(bsz, nc, q, n)

    da = dtc * a[None, None, None, :]  # (B, nc, Q, H) log-decay increments
    seg = jnp.cumsum(da, axis=2)  # (B, nc, Q, H) decay from chunk start

    # --- intra-chunk (quadratic within chunk, matmul-shaped) ---
    l_full = jnp.exp(_segsum_chunk(da.transpose(0, 1, 3, 2)))  # (B,nc,H,Q,Q)
    g = jnp.einsum("bcqn,bcsn->bcqs", cc, bc,
                   preferred_element_type=jnp.float32)  # (B,nc,Q,S')
    xw = xc * dtc[..., None]  # dt-weighted inputs
    y_intra = jnp.einsum(
        "bcqs,bchqs,bcshp->bcqhp", g, l_full, xw,
        preferred_element_type=jnp.float32,
    )

    # --- chunk summary states: decay-to-end weighted outer products ---
    decay_end = jnp.exp(seg[:, :, -1:, :] - seg)  # (B,nc,Q,H)
    states = jnp.einsum(
        "bcsn,bcshp,bcsh->bchnp", bc, xw, decay_end,
        preferred_element_type=jnp.float32,
    )  # (B,nc,H,N,P)

    # --- inter-chunk scan carrying the (N,P) state per head ---
    chunk_decay = jnp.exp(seg[:, :, -1, :])  # (B,nc,H)

    def step(carry, inp):
        st, dec = inp  # (B,H,N,P), (B,H)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit the state ENTERING this chunk

    init = (
        jnp.zeros((bsz, h, n, p), jnp.float32)
        if h0 is None
        else h0.astype(jnp.float32)
    )
    final, entering = jax.lax.scan(
        step,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    entering = entering.transpose(1, 0, 2, 3, 4)  # (B,nc,H,N,P)

    y_inter = jnp.einsum(
        "bcqn,bchnp,bcqh->bcqhp", cc, entering, jnp.exp(seg),
        preferred_element_type=jnp.float32,
    )
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y.astype(x.dtype), final


def _project_streams(x, params, cfg):
    """-> (z, x_pre, b_pre, c_pre, dt_raw) pre-conv streams, both layouts."""
    di, n = cfg.d_inner, cfg.ssm_state
    if "in_proj" in params:
        proj = x @ params["in_proj"]
        z, xbc_pre, dt_raw = _split_proj(proj, cfg)
        xp, bp, cp = jnp.split(xbc_pre, [di, di + n], axis=-1)
        return z, xp, bp, cp, dt_raw
    return (
        x @ params["z_proj"],
        x @ params["x_proj"],
        x @ params["b_proj"],
        x @ params["c_proj"],
        x @ params["dt_proj"],
    )


def mamba2_forward(
    x: jax.Array,  # (B, S, d)
    params: dict,
    cfg,
    state: dict | None = None,
    constrain_heads=None,
) -> tuple[jax.Array, dict]:
    """Full Mamba2 block (prefill/train path). Returns (out, new_state)."""
    from repro.models.layers import rms_norm

    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    bsz, s, _ = x.shape

    z, xp, bp, cp, dt_raw = _project_streams(x, params, cfg)
    if "in_proj" in params:
        xbc_pre = jnp.concatenate([xp, bp, cp], axis=-1)
        xbc = _causal_conv(xbc_pre, params["conv_w"], params["conv_b"])
        xin, b_mat, c_mat = jnp.split(xbc, [di, di + n], axis=-1)
    else:
        # per-stream conv keeps the x-stream heads-sharded end to end (B1)
        w, cb = params["conv_w"], params["conv_b"]
        xin = _causal_conv(xp, w[:, :di], cb[:di])
        b_mat = _causal_conv(bp, w[:, di : di + n], cb[di : di + n])
        c_mat = _causal_conv(cp, w[:, di + n :], cb[di + n :])
        xbc_pre = jnp.concatenate([xp, bp, cp], axis=-1)
    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"][None, None, :]
    )  # (B,S,H)
    a = -jnp.exp(params["a_log"])  # (H,)

    xh = xin.reshape(bsz, s, h, p)
    if constrain_heads is not None:
        xh = constrain_heads(xh)
    y, final = ssd_chunked(xh, dt, a, b_mat, c_mat, cfg.ssm_chunk)
    if constrain_heads is not None:
        y = constrain_heads(y)
    y = y + xh.astype(jnp.float32) * params["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, di).astype(x.dtype)

    # gated RMSNorm (mamba2 norm_before_gate=False): norm(y * silu(z))
    y = rms_norm(y * jax.nn.silu(z), params["norm_scale"], cfg.norm_eps)
    out = y @ params["out_proj"]

    # last (conv_width-1) pre-conv channels for decode continuation (left-pad
    # with zeros when the prefill was shorter than the conv receptive field)
    w1 = cfg.conv_width - 1
    conv_tail = jnp.pad(xbc_pre, ((0, 0), (max(w1 - s, 0), 0), (0, 0)))[:, -w1:, :]
    new_state = {"ssm": final, "conv": conv_tail}
    return out, new_state


def mamba2_decode_step(
    x: jax.Array,  # (B, 1, d)
    params: dict,
    cfg,
    state: dict,  # {"ssm": (B,H,N,P), "conv": (B, W-1, di+2n)}
) -> tuple[jax.Array, dict]:
    """Single-token recurrent update: O(1) state, no sequence dimension."""
    from repro.models.layers import rms_norm

    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    bsz = x.shape[0]

    z, xp, bp, cp, dt_raw = _project_streams(x[:, 0, :], params, cfg)
    xbc_new = jnp.concatenate([xp, bp, cp], axis=-1)

    # rolling causal conv over the last conv_width inputs
    conv_buf = jnp.concatenate([state["conv"], xbc_new[:, None, :]], axis=1)
    w = params["conv_w"].astype(jnp.float32)  # (W, C)
    xbc = jnp.einsum("bwc,wc->bc", conv_buf.astype(jnp.float32), w)
    xbc = jax.nn.silu(xbc + params["conv_b"].astype(jnp.float32)).astype(x.dtype)

    xin, b_mat, c_mat = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, :])
    a = -jnp.exp(params["a_log"])

    xh = xin.reshape(bsz, h, p).astype(jnp.float32)
    decay = jnp.exp(dt * a[None, :])  # (B,H)
    hs = state["ssm"] * decay[..., None, None] + jnp.einsum(
        "bn,bhp,bh->bhnp", b_mat.astype(jnp.float32), xh, dt
    )
    y = jnp.einsum("bn,bhnp->bhp", c_mat.astype(jnp.float32), hs)
    y = y + xh * params["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, di).astype(x.dtype)

    y = rms_norm(y * jax.nn.silu(z[:, None, :]), params["norm_scale"], cfg.norm_eps)
    out = y @ params["out_proj"]
    return out, {"ssm": hs, "conv": conv_buf[:, 1:, :]}
