"""Decoder-only LM assembly: init + forward for all LM-family architectures.

Layers are stacked (leading L dim) and executed with jax.lax.scan for compact
HLO (one layer body regardless of depth — essential for 95-layer configs on
512 simulated devices). Heterogeneous (hybrid) stacks scan contiguous Mamba
segments and unroll the few attention blocks. Activation rematerialization is
applied to the scan body per ``remat`` policy.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks as blk
from repro.models.layers import init_embedding, rms_norm
from repro.sharding.specs import ShardCtx

REMAT_POLICIES = {
    "none": None,
    "full": "nothing_saveable",
    "dots": "dots_with_no_batch_dims_saveable",
}


def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    policy = getattr(jax.checkpoint_policies, REMAT_POLICIES[remat])
    return jax.checkpoint(fn, policy=policy)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_lm(cfg: ModelConfig, key: jax.Array) -> dict[str, Any]:
    dtype = _dtype(cfg)
    k_emb, k_layers, k_head, k_shared = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "embed": init_embedding(k_emb, cfg.vocab_padded, cfg.d_model, dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        from repro.models.layers import init_dense

        params["lm_head"] = init_dense(k_head, cfg.d_model, cfg.vocab_padded, dtype)

    if cfg.family in ("dense", "vlm"):
        keys = jax.random.split(k_layers, cfg.num_layers)
        params["layers"] = jax.vmap(
            lambda k: blk.init_dense_block(k, cfg, dtype)
        )(keys)
    elif cfg.family == "moe":
        keys = jax.random.split(k_layers, cfg.num_layers)
        params["layers"] = jax.vmap(
            lambda k: blk.init_moe_block(k, cfg, dtype)
        )(keys)
    elif cfg.family == "ssm":
        keys = jax.random.split(k_layers, cfg.num_layers)
        params["layers"] = jax.vmap(
            lambda k: blk.init_mamba_block(k, cfg, dtype)
        )(keys)
    elif cfg.family == "hybrid":
        n_mamba = cfg.num_layers - len(cfg.attn_block_positions)
        keys = jax.random.split(k_layers, n_mamba)
        params["layers"] = jax.vmap(
            lambda k: blk.init_mamba_block(k, cfg, dtype)
        )(keys)
        # ONE shared attention block applied at every attn position (zamba2)
        params["shared_attn"] = blk.init_dense_block(k_shared, cfg, dtype)
    else:
        raise ValueError(f"init_lm does not handle family {cfg.family}")
    return params


def _hybrid_segments(cfg: ModelConfig) -> list[int]:
    """Lengths of the contiguous Mamba runs between/around the attention
    positions. zamba2 (38 blocks, attn at 9 & 28) -> [9, 18, 9]."""
    runs, prev_end = [], 0
    for pos in sorted(cfg.attn_block_positions):
        runs.append(pos - prev_end)
        prev_end = pos + 1
    runs.append(cfg.num_layers - prev_end)
    return runs


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def forward(
    params: dict,
    tokens: jax.Array,  # (B, S) int32 — or (B, S, d) precomputed embeddings
    cfg: ModelConfig,
    ctx: ShardCtx,
    remat: str = "full",
) -> jax.Array:
    """Token (or stub-frontend embedding) input -> logits (B, S, vocab_padded)."""
    if tokens.ndim == 2:
        tokens = ctx.tokens(tokens)
        x = jnp.take(params["embed"], tokens, axis=0)
    else:
        x = tokens.astype(_dtype(cfg))  # [vlm]/[audio] stub embeddings
    s = x.shape[1]
    pos = jnp.arange(s, dtype=jnp.int32)
    x = ctx.residual(x)

    if cfg.family in ("dense", "vlm"):

        def body(h, lp):
            return blk.dense_block(h, lp, cfg, ctx, pos), None

        x, _ = jax.lax.scan(_maybe_remat(body, remat), x, params["layers"])
    elif cfg.family == "moe":

        def body(h, lp):
            h, aux = blk.moe_block(h, lp, cfg, ctx, pos)
            return h, aux

        x, _aux = jax.lax.scan(_maybe_remat(body, remat), x, params["layers"])
    elif cfg.family == "ssm":

        def body(h, lp):
            return blk.mamba_block(h, lp, cfg, ctx), None

        x, _ = jax.lax.scan(_maybe_remat(body, remat), x, params["layers"])
    elif cfg.family == "hybrid":

        def body(h, lp):
            return blk.mamba_block(h, lp, cfg, ctx), None

        rematted = _maybe_remat(body, remat)

        def attn_apply(h, shared_params):
            return blk.hybrid_attn_block(h, shared_params, cfg, ctx, pos)

        if remat != "none":
            policy = getattr(jax.checkpoint_policies, REMAT_POLICIES[remat])
            attn_apply = jax.checkpoint(attn_apply, policy=policy)

        runs = _hybrid_segments(cfg)
        off = 0
        for i, ln in enumerate(runs):
            if ln > 0:
                seg = jax.tree_util.tree_map(
                    lambda a, o=off, n=ln: a[o : o + n], params["layers"]
                )
                x, _ = jax.lax.scan(rematted, x, seg)
                off += ln
            if i < len(runs) - 1:  # shared attention block between runs
                x = attn_apply(x, params["shared_attn"])
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    x = ctx.gathered(x)
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    )  # (d, Vp)
    logits = x @ head
    return ctx.logits(logits)


def lm_loss(
    params: dict,
    batch: dict,  # {"inputs": (B,S), "targets": (B,S), "mask": (B,S)}
    cfg: ModelConfig,
    ctx: ShardCtx,
    remat: str = "full",
) -> tuple[jax.Array, dict]:
    logits = forward(params, batch["inputs"], cfg, ctx, remat=remat)
    logits = logits.astype(jnp.float32)
    targets = batch["targets"]
    mask = batch["mask"].astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    if ctx.onehot_loss:
        onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
        label_logit = jnp.sum(logits * onehot, axis=-1)
    else:
        label_logit = jnp.take_along_axis(
            logits, targets[..., None].astype(jnp.int32), axis=-1
        )[..., 0]
    nll = (lse - label_logit) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    metrics = {"loss": loss, "ntokens": mask.sum()}
    return loss, metrics
