"""Whisper-tiny encoder-decoder backbone (arXiv:2212.04356).

The log-mel/conv audio frontend is a STUB per the assignment: the encoder
consumes precomputed frame embeddings (B, T_enc, d). Encoder blocks are
bidirectional self-attention; decoder blocks are causal self-attention +
cross-attention to the encoder output. Fixed sinusoidal positions (no RoPE),
pre-norm, GELU MLPs — faithful to the published architecture.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks as blk
from repro.models.attention import attention, init_attention
from repro.models.layers import (
    init_embedding,
    rms_norm,
    sinusoidal_positions,
)
from repro.models.mlp import gelu_mlp, init_gelu_mlp
from repro.models.transformer import REMAT_POLICIES, _maybe_remat
from repro.sharding.specs import ShardCtx


def _init_enc_block(key, cfg, dtype):
    ka, km = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attention(
            ka, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, dtype
        ),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": init_gelu_mlp(km, cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_block(key, cfg, dtype):
    ka, kc, km = jax.random.split(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attention(
            ka, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, dtype
        ),
        "ln_cross": jnp.ones((cfg.d_model,), dtype),
        "cross": init_attention(
            kc, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, dtype
        ),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": init_gelu_mlp(km, cfg.d_model, cfg.d_ff, dtype),
    }


def init_whisper(cfg: ModelConfig, key: jax.Array) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    k_emb, k_enc, k_dec = jax.random.split(key, 3)
    return {
        "embed": init_embedding(k_emb, cfg.vocab_padded, cfg.d_model, dtype),
        "enc_layers": jax.vmap(lambda k: _init_enc_block(k, cfg, dtype))(
            jax.random.split(k_enc, cfg.encoder_layers)
        ),
        "enc_norm": jnp.ones((cfg.d_model,), dtype),
        "dec_layers": jax.vmap(lambda k: _init_dec_block(k, cfg, dtype))(
            jax.random.split(k_dec, cfg.num_layers)
        ),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }


def _attn_sub(x, ln, attn_params, cfg, ctx, pos_q, pos_k, x_kv=None, causal=True):
    h = rms_norm(x, ln, cfg.norm_eps)
    out = attention(
        h,
        h if x_kv is None else x_kv,
        attn_params,
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim,
        pos_q=pos_q,
        pos_k=pos_k,
        causal=causal,
        window=None,
        rope_theta=0.0,  # whisper uses absolute sinusoidal positions
        kv_constrain=ctx.kv_gathered if ctx.mesh is not None else None,
    )
    return ctx.residual(x + out)


def encode(params, frames: jax.Array, cfg: ModelConfig, ctx: ShardCtx,
           remat: str = "full") -> jax.Array:
    """frames: (B, T_enc, d) stub-frontend embeddings -> encoder states."""
    t = frames.shape[1]
    pos_emb = jnp.asarray(sinusoidal_positions(t, cfg.d_model), frames.dtype)
    x = ctx.residual(frames + pos_emb[None])
    pos = jnp.arange(t, dtype=jnp.int32)

    def body(h, lp):
        h = _attn_sub(h, lp["ln1"], lp["attn"], cfg, ctx, pos, pos, causal=False)
        hh = rms_norm(h, lp["ln2"], cfg.norm_eps)
        hh = ctx.gathered(hh)
        return ctx.residual(h + gelu_mlp(hh, lp["mlp"])), None

    x, _ = jax.lax.scan(_maybe_remat(body, remat), x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def decode_train(
    params,
    tokens: jax.Array,  # (B, S) int32
    enc_out: jax.Array,  # (B, T_enc, d)
    cfg: ModelConfig,
    ctx: ShardCtx,
    remat: str = "full",
) -> jax.Array:
    """Teacher-forced decoder pass -> logits (B, S, vocab_padded)."""
    b, s = tokens.shape
    tokens = ctx.tokens(tokens)
    x = jnp.take(params["embed"], tokens, axis=0)
    pos_emb = jnp.asarray(sinusoidal_positions(s, cfg.d_model), x.dtype)
    x = ctx.residual(x + pos_emb[None])
    pos = jnp.arange(s, dtype=jnp.int32)
    pos_enc = jnp.arange(enc_out.shape[1], dtype=jnp.int32)
    enc_out = ctx.gathered(enc_out)

    def body(h, lp):
        h = _attn_sub(h, lp["ln1"], lp["attn"], cfg, ctx, pos, pos, causal=True)
        h = _attn_sub(
            h, lp["ln_cross"], lp["cross"], cfg, ctx, pos, pos_enc,
            x_kv=enc_out, causal=False,
        )
        hh = rms_norm(h, lp["ln2"], cfg.norm_eps)
        hh = ctx.gathered(hh)
        return ctx.residual(h + gelu_mlp(hh, lp["mlp"])), None

    x, _ = jax.lax.scan(_maybe_remat(body, remat), x, params["dec_layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    x = ctx.gathered(x)
    logits = x @ params["embed"].T  # tied head
    return ctx.logits(logits)


def whisper_loss(params, batch, cfg, ctx, remat: str = "full"):
    """batch: {"frames": (B,T,d), "inputs": (B,S), "targets", "mask"}."""
    enc = encode(params, batch["frames"], cfg, ctx, remat=remat)
    logits = decode_train(params, batch["inputs"], enc, cfg, ctx, remat=remat)
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    label = jnp.take_along_axis(
        logits, batch["targets"][..., None].astype(jnp.int32), axis=-1
    )[..., 0]
    mask = batch["mask"].astype(jnp.float32)
    loss = ((lse - label) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss, {"loss": loss, "ntokens": mask.sum()}
