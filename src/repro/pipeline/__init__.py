"""End-to-end DR pipeline optimization: race any ``Reducer`` against the
downstream analytics it feeds, objective R + C_m(k) (paper §3.1 / §4.4)."""

from repro.pipeline.optimizer import (  # noqa: F401
    DOWNSTREAMS,
    AnalyticsOptions,
    MethodOutcome,
    OptimizerReport,
    WorkloadOptimizer,
    run_downstream,
)
