"""End-to-end workload optimizer — the paper's §4.4 comparison as an API.

DROP's headline claim is not "fast PCA" but that a DR *optimizer* should
weigh reduction cost against downstream analytics cost end-to-end: FFT/PAA
fit faster, but their larger k makes every later distance computation more
expensive, and over an O(m^2 k) workload DROP's smaller basis wins by up to
16x. ``WorkloadOptimizer`` makes that trade a first-class decision instead
of a benchmark script:

    report = WorkloadOptimizer().optimize(x, downstream="knn")
    report.chosen            # e.g. "pca"
    report.best.result       # the winning ReduceResult
    report.outcomes          # per-method ReduceResults + priced objectives

For each candidate method the optimizer runs its ``Reducer`` (DROP's own
Eq.-2 stopping for PCA; one-shot searches for the baselines), prices the
downstream task via ``core.cost.downstream_cost`` (C_m(k), calibrated
seconds), and picks the method minimizing the paper's objective
``R + C_m(k)`` among those that satisfied the TLB target. ``execute=``
optionally runs the actual analytics from ``analytics/`` on the reduced
data, so the report also carries *measured* end-to-end wall clock
(``benchmarks/bench_e2e_workload.py`` uses this to reproduce §4.4).

Running every candidate's DR is the optimizer, not a shortcut: reduction
cost is the small term of the objective (that is the thesis), so the
decision-relevant unknowns are the per-method k's, which only the fits
reveal. Candidates are walked cheapest-DR-first (``plan``) so partial
reports — e.g. under a caller-imposed deadline — cover the cheap methods.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from repro.core.cost import CostModel, downstream_cost
from repro.core.reducer import REDUCER_METHODS, make_reducer
from repro.core.types import DropConfig, ReduceResult

# analytics runners keyed by the same names core.cost.downstream_cost prices.
# Contract (changed with the split fan-out): each entry is called as
# fn(xt, opts) with an ``AnalyticsOptions`` — registrants must accept the
# options object even if they ignore it
DOWNSTREAMS: dict[str, Callable[[np.ndarray, "AnalyticsOptions"], object]] = {}


@dataclass(frozen=True)
class AnalyticsOptions:
    """Execution knobs threaded from the optimizer/serving layers into the
    analytics runners (``analytics.split`` fan-out semantics):

    ``use_kernels`` — Pallas kernel path where a kernel backend is live;
    ``split``       — run the dataset axis as N flash-decoding-style shards
                      (None = the sequential fused scan);
    ``fanout``      — "xla" batches shards in one dispatch on one device,
                      "mesh" shard_maps them across ``devices``;
    ``devices``     — mesh fan-out targets (None = all visible devices)."""

    use_kernels: bool = False
    split: int | None = None
    fanout: str = "xla"
    devices: tuple | None = None


def _register_downstreams() -> None:
    from repro.analytics import dbscan, gaussian_kde, nearest_neighbors

    def _kw(o: AnalyticsOptions) -> dict:
        return dict(
            use_kernels=o.use_kernels, split=o.split,
            fanout=o.fanout, devices=o.devices,
        )

    DOWNSTREAMS.update(
        knn=lambda xt, o: nearest_neighbors(xt, **_kw(o)),
        dbscan=lambda xt, o: dbscan(xt, **_kw(o)),
        kde=lambda xt, o: gaussian_kde(xt, **_kw(o)),
    )


_register_downstreams()


def run_downstream(
    name: str,
    xt: np.ndarray,
    *,
    use_kernels: bool = False,
    split: int | None = None,
    fanout: str = "xla",
    devices=None,
):
    """Execute the named analytics task on reduced data ``xt``. All three
    tasks run on the fused pairwise engine; ``use_kernels`` opts into its
    Pallas kernel path where a kernel backend is live (TPU/interpret), and
    ``split``/``fanout``/``devices`` select the shard decomposition
    (``analytics.split`` — exact merges, same results)."""
    try:
        fn = DOWNSTREAMS[name]
    except KeyError:
        raise KeyError(
            f"unknown downstream {name!r}; know {tuple(DOWNSTREAMS)}"
        ) from None
    opts = AnalyticsOptions(
        use_kernels=use_kernels,
        split=split,
        fanout=fanout,
        devices=None if devices is None else tuple(devices),
    )
    return fn(np.ascontiguousarray(xt, dtype=np.float32), opts)


# DR-cost ordering for the plan: O(md) PAA, O(md) Haar, O(md log d) FFT,
# O(mdk) JL draws per probe, then DROP's sampled loop (cheap in rows touched
# but the only multi-step method)
_PLAN_ORDER = ("paa", "dwt", "fft", "jl", "pca")


@dataclass
class MethodOutcome:
    """One candidate's end-to-end accounting."""

    method: str
    result: ReduceResult
    reduce_s: float  # measured DR wall clock (R)
    downstream_est_s: float  # priced C_m(k)
    objective: float  # R + C_m(k), the paper's Problem 3.1 objective
    downstream_s: float | None = None  # measured, when executed
    end_to_end_s: float | None = None  # reduce_s + measured downstream


@dataclass
class OptimizerReport:
    downstream: str
    target_tlb: float
    chosen: str
    outcomes: dict[str, MethodOutcome] = field(default_factory=dict)

    @property
    def best(self) -> MethodOutcome:
        return self.outcomes[self.chosen]

    def summary(self) -> str:
        lines = [
            f"downstream={self.downstream} target_tlb={self.target_tlb} "
            f"chosen={self.chosen}"
        ]
        for m, o in sorted(self.outcomes.items(), key=lambda kv: kv[1].objective):
            measured = (
                f" e2e={o.end_to_end_s*1e3:8.1f}ms"
                if o.end_to_end_s is not None
                else ""
            )
            lines.append(
                f"  {m:4s} k={o.result.k:4d} tlb={o.result.tlb_estimate:.4f} "
                f"satisfied={str(o.result.satisfied):5s} "
                f"R={o.reduce_s*1e3:8.1f}ms C_m(k)={o.downstream_est_s*1e3:8.1f}ms "
                f"objective={o.objective*1e3:8.1f}ms{measured}"
            )
        return "\n".join(lines)


class WorkloadOptimizer:
    """Plan and race ``Reducer``s against the end-to-end objective.

    ``methods`` — candidate operators (default: the paper's §4.4 trio plus
    DWT; pass ``REDUCER_METHODS`` for all five).
    ``cfg`` — shared ``DropConfig`` (TLB target, confidence, seeds;
    ``cfg.use_kernels`` also routes the EXECUTED analytics through the
    fused engine's Pallas kernel path, end-to-end with the DR fits).
    ``cost_coeff`` — override the calibrated seconds/(m^2 k) coefficient of
    the downstream cost model (see ``core.cost.calibrate_quadratic``).
    ``legacy_cost`` — price with the paper's pure O(m^2 k) model instead of
    the default model with the measured k-independent O(m^2) memory term
    (the term is method-independent, so the CHOICE is identical either
    way — only the absolute priced objectives differ).
    ``analytics_split`` / ``analytics_fanout`` / ``analytics_devices`` —
    shard decomposition for the EXECUTED analytics (``analytics.split``:
    split=N dataset shards, fanout="mesh" fans them across devices); the
    merges are exact, so the report's measured downstream numbers describe
    the same computation.
    """

    def __init__(
        self,
        methods: Sequence[str] = ("pca", "fft", "paa", "dwt"),
        cfg: DropConfig | None = None,
        cost_coeff: float | None = None,
        legacy_cost: bool = False,
        analytics_split: int | None = None,
        analytics_fanout: str = "xla",
        analytics_devices=None,
    ) -> None:
        unknown = [m for m in methods if m not in REDUCER_METHODS]
        if unknown:
            raise KeyError(f"unknown methods {unknown}; know {REDUCER_METHODS}")
        self.methods = tuple(methods)
        self.cfg = cfg or DropConfig()
        self.cost_coeff = cost_coeff
        self.legacy_cost = legacy_cost
        self.analytics_split = analytics_split
        self.analytics_fanout = analytics_fanout
        self.analytics_devices = analytics_devices

    def plan(self, x: np.ndarray, downstream: str = "knn") -> list[str]:
        """Candidate evaluation order: cheapest DR first, DROP last (a
        partial report covers the cheap methods). Also validates the
        downstream name."""
        self._cost_model(downstream, x.shape[0])  # raises on unknown name
        return [m for m in _PLAN_ORDER if m in self.methods]

    def _cost_model(self, downstream: str, m: int) -> CostModel:
        if self.cost_coeff is not None:
            return downstream_cost(
                downstream, m, coeff=self.cost_coeff,
                legacy_cost=self.legacy_cost,
            )
        return downstream_cost(downstream, m, legacy_cost=self.legacy_cost)

    def optimize(
        self,
        x: np.ndarray,
        downstream: str = "knn",
        *,
        execute: str = "none",  # "none" | "chosen" | "all"
    ) -> OptimizerReport:
        """Race the candidates end-to-end and pick the objective minimizer.

        Methods that fail the TLB target cannot win (a cheap-but-lossy
        transform is not a valid answer to Problem 3.1); if every method
        fails, the best-TLB result is chosen so callers always get a map.
        """
        if execute not in ("none", "chosen", "all"):
            raise ValueError(f"execute={execute!r}")
        x = np.ascontiguousarray(x, dtype=np.float32)
        cost = self._cost_model(downstream, x.shape[0])
        report = OptimizerReport(
            downstream=downstream, target_tlb=self.cfg.target_tlb, chosen=""
        )
        for method in self.plan(x, downstream):
            t0 = time.perf_counter()
            runner = make_reducer(method, x, self.cfg, cost)
            while runner.step():
                pass
            res = runner.result()
            reduce_s = time.perf_counter() - t0
            est = cost(res.k)
            outcome = MethodOutcome(
                method=method,
                result=res,
                reduce_s=reduce_s,
                downstream_est_s=est,
                objective=reduce_s + est,
            )
            report.outcomes[method] = outcome

        satisfied = [
            m for m, o in report.outcomes.items() if o.result.satisfied
        ]
        if satisfied:
            report.chosen = min(
                satisfied, key=lambda m: report.outcomes[m].objective
            )
        else:  # nothing hit the target: closest TLB wins (documented)
            report.chosen = max(
                report.outcomes,
                key=lambda m: report.outcomes[m].result.tlb_estimate,
            )
        if execute != "none":
            targets = (
                report.outcomes.values()
                if execute == "all"
                else [report.best]
            )
            for o in targets:
                xt = o.result.transform(x)
                t0 = time.perf_counter()
                run_downstream(
                    downstream,
                    xt,
                    use_kernels=self.cfg.use_kernels,
                    split=self.analytics_split,
                    fanout=self.analytics_fanout,
                    devices=self.analytics_devices,
                )
                o.downstream_s = time.perf_counter() - t0
                o.end_to_end_s = o.reduce_s + o.downstream_s
        return report
