"""Three-term roofline from a compiled dry-run artifact.

    compute    = HLO_dot_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device     / HBM_BW
    collective = collective_bytes_per_dev / ICI_BW

FLOPs/bytes come from the trip-count-aware HLO parser (hlo_parse.py) because
``cost_analysis()`` counts scan bodies once (verified; see tests). Shapes in
post-SPMD HLO are per-device, so all terms are per-device per step. We also
record raw cost_analysis numbers for cross-checking.

MODEL_FLOPS (the "useful work" yardstick): 6·N_active·tokens for training,
2·N_active·tokens for prefill, 2·N_active·batch for one decode step — the
standard convention (attention FLOPs excluded), so the useful-compute ratio
both exposes remat/recompute waste and (for long contexts) attention's share.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass

from repro.configs.base import ModelConfig, ShapeConfig
from repro.roofline import hw
from repro.roofline.hlo_parse import analyze


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    step_kind: str
    # per-device, per-step
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    # the three terms (seconds)
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    # usefulness
    model_flops_total: float
    hlo_flops_total: float
    useful_ratio: float
    # diagnostics
    collective_ops: dict
    cost_analysis_flops: float
    cost_analysis_bytes: float
    memory_stats: dict
    note: str = ""

    def terms(self) -> dict[str, float]:
        return {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * tokens
    # decode: one token per sequence per step
    return 2.0 * n * shape.global_batch


def build_roofline(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh_name: str,
    chips: int,
    compiled,
    note: str = "",
) -> Roofline:
    totals = analyze(compiled.as_text())
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):  # jax<=0.4.x: one dict per device
        ca = ca[0] if ca else {}
    mem = compiled.memory_analysis()
    mem_stats = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
    }

    compute_s = totals.dot_flops / hw.PEAK_FLOPS_BF16
    memory_s = totals.bytes_materialized / hw.HBM_BW
    collective_s = totals.collective_bytes / hw.ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    hlo_total = totals.dot_flops * chips
    return Roofline(
        arch=cfg.name,
        shape=shape.name,
        mesh=mesh_name,
        chips=chips,
        step_kind=shape.kind,
        flops_per_device=totals.dot_flops,
        bytes_per_device=totals.bytes_materialized,
        collective_bytes_per_device=totals.collective_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops_total=mf,
        hlo_flops_total=hlo_total,
        useful_ratio=mf / hlo_total if hlo_total else 0.0,
        collective_ops=totals.collective_ops,
        cost_analysis_flops=float(ca.get("flops", 0.0)),
        cost_analysis_bytes=float(ca.get("bytes accessed", 0.0)),
        memory_stats=mem_stats,
        note=note,
    )


def suggestion(r: Roofline) -> str:
    """One sentence on what would move the dominant term down."""
    if r.dominant == "compute":
        if r.useful_ratio < 0.4:
            return (
                "compute-bound with low useful ratio: cut recompute (remat "
                "policy) and causal-masked waste in attention tiles"
            )
        return "compute-bound near useful peak: only algorithmic FLOP cuts help"
    if r.dominant == "memory":
        return (
            "memory-bound: shrink materialized bytes (fuse/bf16 intermediates, "
            "smaller attention tiles, compressed KV cache)"
        )
    return (
        "collective-bound: reshard to cut gather volume (smaller KV gather, "
        "DROP-compressed pod all-reduce, overlap collectives with compute)"
    )


def save_report(path: str, r: Roofline) -> None:
    with open(path, "w") as f:
        json.dump(asdict(r), f, indent=2)
