"""Trip-count-aware HLO accounting.

``compiled.cost_analysis()`` visits every instruction ONCE — a scan-over-95-
layers model reports one layer's FLOPs (verified empirically; see
EXPERIMENTS.md §Dry-run methodology). This parser rebuilds totals from the
post-SPMD optimized HLO text:

* computations are parsed with their instructions (opcode, result shape);
* ``while`` trip counts are recovered from the loop-condition's compare-
  against-constant (how jax.lax.scan lowers);
* an execution-count map is propagated through the call graph
  (while bodies x trip count, fusions/calls x call sites);
* dot/convolution FLOPs are recomputed from operand shapes and contracting
  dims; collective bytes from per-device result sizes.

Shapes in post-SPMD HLO are PER-DEVICE, so all totals are per-device per
step — exactly what the roofline terms need.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{$")
_TRIP_RE = re.compile(r'known_trip_count"?[:=]\s*\{"?n"?[:=]\s*"?(\d+)"?')
_CALLEE_BRACE_RE = re.compile(r"(\w+)=\{([^}]*)\}")
_CALLEE_SINGLE_RE = re.compile(r"(body|condition|to_apply|calls)=%?([\w.\-]+)")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of all array shapes mentioned in a result type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype in _DTYPE_BYTES:
            out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


@dataclass
class Instr:
    name: str
    opcode: str
    result_type: str
    line: str


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)


COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        m = _COMP_START_RE.match(line.strip())
        if m and line.strip().endswith("{"):
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if im:
            cur.instrs.append(
                Instr(im.group(1), im.group(3), im.group(2), line.strip())
            )
    return comps


_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _dot_flops(instr: Instr, symbols: dict[str, list[int]]) -> int:
    """2 * prod(result dims) * prod(lhs contracting dims).

    Scheduled HLO omits operand types inside the call parens, so the lhs
    shape is resolved through the module-wide symbol table (name -> dims)."""
    mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.line)
    res = _shape_dims(instr.result_type)
    if not res:
        return 0
    out_elems = int(np.prod(res[0][1])) if res[0][1] else 1
    contracted = 1
    # operand list: text between the opcode's '(' and the matching ')'
    args = instr.line.split(f"{instr.opcode}(", 1)[-1]
    names = _OPERAND_RE.findall(args)
    if mm and names:
        # inline-typed operand (unscheduled HLO) takes precedence
        typed = re.match(r"\s*(\w+)\[([\d,]*)\]", args)
        if typed and typed.group(1) in _DTYPE_BYTES:
            lhs_dims = [int(d) for d in typed.group(2).split(",") if d]
        else:
            lhs_dims = symbols.get(names[0], [])
        for ci in mm.group(1).split(","):
            if ci and int(ci) < len(lhs_dims):
                contracted *= lhs_dims[int(ci)]
    return 2 * out_elems * contracted


def build_symbols(
    comps: dict[str, Computation],
) -> tuple[dict[str, list[int]], dict[str, int]]:
    """name -> (result dims, dtype byte width) for every instruction (names
    are unique module-wide in post-optimization HLO; collisions keep the last
    writer, which is fine for operand lookups)."""
    table: dict[str, list[int]] = {}
    widths: dict[str, int] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            dims = _shape_dims(ins.result_type)
            if dims:
                table[ins.name] = dims[0][1]
                widths[ins.name] = _DTYPE_BYTES.get(dims[0][0], 4)
    return table, widths


def _trip_count(instr: Instr, comps: dict[str, Computation]) -> int:
    """Trip count of a while op: the compiler's known_trip_count when present
    (jax scans always carry it), else the condition's compare constant."""
    m = _TRIP_RE.search(instr.line)
    if m:
        return int(m.group(1))
    cond = next((c for k, c in _callees(instr) if k == "condition"), None)
    best = 1
    if cond in comps:
        for ins in comps[cond].instrs:
            if ins.opcode == "constant":
                cm = re.search(r"constant\((\d+)\)", ins.line)
                if cm:
                    best = max(best, int(cm.group(1)))
    return best


_CALLEE_KEYS = ("body", "condition", "to_apply", "calls", "branch_computations")


def _callees(instr: Instr) -> list[tuple[str, str]]:
    """(kind, computation_name) referenced by an instruction."""
    out = []
    for m in _CALLEE_BRACE_RE.finditer(instr.line):
        key, inner = m.group(1), m.group(2)
        if key not in _CALLEE_KEYS:
            continue
        for name in inner.split(","):
            name = name.strip().lstrip("%")
            if name:
                out.append((key, name))
    for m in _CALLEE_SINGLE_RE.finditer(instr.line):
        out.append((m.group(1), m.group(2)))
    return out


def execution_counts(comps: dict[str, Computation]) -> dict[str, int]:
    """Executions per computation, propagating while trip counts."""
    counts = {name: 0 for name in comps}
    entry = None
    for name in comps:
        # ENTRY computation: jax names it e.g. main.NNN; detect by not being
        # referenced anywhere
        entry = name
    referenced = set()
    for comp in comps.values():
        for ins in comp.instrs:
            for _, callee in _callees(ins):
                referenced.add(callee)
    roots = [n for n in comps if n not in referenced]

    def visit(name: str, mult: int, depth=0):
        if name not in comps or depth > 50:
            return
        counts[name] += mult
        for ins in comps[name].instrs:
            callees = _callees(ins)
            if ins.opcode == "while":
                body = next((c for k, c in callees if k == "body"), None)
                cond = next((c for k, c in callees if k == "condition"), None)
                trips = _trip_count(ins, comps)
                if body:
                    visit(body, mult * trips, depth + 1)
                if cond:
                    visit(cond, mult * (trips + 1), depth + 1)
            else:
                for _, callee in callees:
                    visit(callee, mult, depth + 1)

    for r in roots:
        visit(r, 1)
    return counts


@dataclass
class HloTotals:
    dot_flops: float
    bytes_materialized: float  # writes + reads (HBM traffic proxy)
    collective_bytes: float
    collective_ops: dict
    per_comp_trips: dict
    top_bytes: list  # largest (bytes*execs, opcode, name) contributors


def _instr_bytes(
    ins: Instr,
    symbols: dict[str, list[int]],
    dtype_bytes_of: dict[str, int],
    slicing: bool = False,
) -> float:
    """HBM traffic of one top-level instruction: result write + operand reads.

    * In-place updates (dynamic-update-slice / scatter, possibly fused) alias
      their big buffer operand: the untouched region is neither rewritten nor
      reread, so the largest operand is subtracted from both sides.
    * ``slicing`` fusions (internal dynamic-slice) read at most a result-sized
      window of each oversized operand (e.g. one layer of a stacked cache)."""
    write = _shape_bytes(ins.result_type)
    args = ins.line.split(f"{ins.opcode}(", 1)[-1]
    op_sizes = []
    for name in _OPERAND_RE.findall(args):
        dims = symbols.get(name)
        if dims is None:
            continue
        elems = int(np.prod(dims)) if dims else 1
        op_sizes.append(elems * dtype_bytes_of.get(name, 4))
    if slicing and write > 0:
        op_sizes = [min(s, 2 * write) for s in op_sizes]
    reads = sum(op_sizes)
    if "dynamic-update-slice" in ins.line or ins.opcode == "scatter" or (
        "scatter" in ins.name
    ):
        big = max(op_sizes, default=0)
        write = max(write - big, 0)
        reads = max(reads - big, 0)
    return float(write + reads)


# ops that never materialize a new buffer (aliasing / metadata only).
# "while"/"conditional" results alias their body buffers (bodies are counted).
_NO_MATERIALIZE = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id",
    "while", "conditional", "copy-start", "copy-done",
}


def _fused_computations(comps: dict[str, Computation]) -> set[str]:
    """Computations reached via calls/to_apply (fusion bodies, reducers):
    their instructions do not materialize buffers — only the caller's output
    does. body/condition/branch computations DO run at top level."""
    fused = set()
    for comp in comps.values():
        for ins in comp.instrs:
            for kind, callee in _callees(ins):
                if kind in ("calls", "to_apply"):
                    fused.add(callee)
    return fused


def analyze(text: str) -> HloTotals:
    comps = parse_hlo(text)
    counts = execution_counts(comps)
    fused = _fused_computations(comps)
    symbols, widths = build_symbols(comps)
    flops = 0.0
    coll_bytes = 0.0
    mat_bytes = 0.0
    coll_ops: dict[str, float] = {}
    top: list[tuple[float, str, str]] = []
    # opcode sets per computation, for detecting slicing fusions
    opset = {
        n: {i.opcode for i in c.instrs} for n, c in comps.items()
    }
    for name, comp in comps.items():
        mult = max(counts.get(name, 0), 0)
        if mult == 0:
            continue
        materializes = name not in fused
        for ins in comp.instrs:
            # FLOPs counted everywhere (dots live inside fusions too)
            if ins.opcode in ("dot", "convolution"):
                flops += mult * _dot_flops(ins, symbols)
            if not materializes or ins.opcode in _NO_MATERIALIZE:
                continue
            slicing = ins.opcode in ("dynamic-slice", "slice", "gather") or (
                ins.opcode == "fusion"
                and any(
                    op in ("dynamic-slice", "slice", "gather")
                    for _, callee in _callees(ins)
                    for op in opset.get(callee, ())
                )
            )
            b = _instr_bytes(ins, symbols, widths, slicing=slicing)
            mat_bytes += mult * b
            if b * mult > 0:
                top.append((b * mult, ins.opcode, f"{name}/{ins.name}"))
            if ins.opcode in COLLECTIVES or any(
                ins.opcode.startswith(c) for c in COLLECTIVES
            ):
                # collective wire bytes: the (per-device) payload, counted once
                w = _shape_bytes(ins.result_type)
                coll_bytes += mult * w
                coll_ops[ins.opcode] = coll_ops.get(ins.opcode, 0) + mult * w
    top.sort(reverse=True)
    return HloTotals(
        dot_flops=flops,
        bytes_materialized=mat_bytes,
        collective_bytes=coll_bytes,
        collective_ops=coll_ops,
        per_comp_trips={n: c for n, c in counts.items() if c > 1},
        top_bytes=top[:12],
    )
