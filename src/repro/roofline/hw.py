"""Target-hardware constants: TPU v5e (per chip), per the assignment."""

PEAK_FLOPS_BF16 = 197e12  # FLOP/s per chip (bf16 MXU)
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 45e9  # bytes/s per link (assignment: ~50 GB/s; we use 45 sustained)
VMEM_BYTES = 16 * 2**20  # ~16 MiB per core working set

CHIPS_SINGLE_POD = 256
CHIPS_MULTI_POD = 512
