"""Single-token decode: distributed flash-decode + per-family serve_step.

flash_decode is the sequence-parallel decode attention (DESIGN.md §5): the KV
cache's time axis is sharded over "model"; every shard computes attention of
the (replicated, single-token) query against its local cache slice and the
partial softmax stats (running max + denominator) are combined with
pmax/psum — the distributed form of the FlashAttention recurrence. This is
what makes 32k-cache x128-batch and 500k-cache decode fit and balance.
"""

from __future__ import annotations

import functools

import jax

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.models.attention import decode_attention
from repro.models.layers import apply_mrope, apply_rope, rms_norm
from repro.models.mlp import gelu_mlp, swiglu
from repro.models.moe import moe_ffn
from repro.models.ssm import mamba2_decode_step
from repro.sharding.specs import ShardCtx

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# distributed flash-decode
# ---------------------------------------------------------------------------

def decode_layout(ctx: ShardCtx, batch: int) -> tuple[tuple, tuple]:
    """(batch_axes, seq_axes) for decode-cache sharding.

    Batch shards over dp when divisible; the cache sequence axis shards over
    "model" plus any dp axes the batch could not use — so long_500k (batch=1)
    spreads its 500k-slot cache over ALL chips."""
    if ctx.mesh is None:
        return (), ()
    sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    batch_axes, seq_axes = [], []
    rem = batch
    for ax in ctx.dp:
        if rem % sizes[ax] == 0 and rem >= sizes[ax]:
            batch_axes.append(ax)
            rem //= sizes[ax]
        else:
            seq_axes.append(ax)
    seq_axes.append("model")
    return tuple(batch_axes), tuple(seq_axes)


def flash_decode(
    q: jax.Array,  # (B, 1, KV, G, hd)
    k_cache: jax.Array,  # (B, T, KV, hd) — T sharded over seq_axes under mesh
    v_cache: jax.Array,
    valid: jax.Array,  # (B, T) bool
    ctx: ShardCtx,
) -> jax.Array:
    if ctx.mesh is None or "model" not in ctx.mesh.axis_names:
        return decode_attention(q, k_cache, v_cache, length_mask=valid)

    batch_axes, seq_axes = decode_layout(ctx, q.shape[0])
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))

    def local(ql, kl, vl, validl):
        # ql: (b, 1, KV, G, hd) replicated over seq_axes; kl: (b, T/shards, KV, hd)
        if jax.default_backend() == "tpu":
            # per-shard hot loop as a Pallas kernel (one HBM pass over the
            # cache slice); stats still combined across shards below
            from repro.kernels.flash_decode.ops import flash_decode as fd_kernel

            # kernel returns normalized output; recover (m, l, o) by also
            # computing local stats — cheaper: use the jnp stats path on TPU
            # only for the cross-shard terms. For simplicity the kernel path
            # is used when there is a single seq shard:
            if not seq_axes:
                out = fd_kernel(ql[:, 0], kl, vl, validl)
                return out[:, None].astype(ql.dtype)
        s = jnp.einsum(
            "bqkgh,btkh->bkgqt", ql, kl, preferred_element_type=jnp.float32
        ) * scale
        s = jnp.where(validl[:, None, None, None, :], s, NEG_INF)
        m = jnp.max(s, axis=-1)  # local max (b, KV, G, 1)
        m_g = jax.lax.pmax(m, seq_axes)
        p = jnp.exp(s - m_g[..., None])
        l = jax.lax.psum(jnp.sum(p, axis=-1), seq_axes)
        o = jnp.einsum("bkgqt,btkh->bkgqh", p, vl,
                       preferred_element_type=jnp.float32)
        o = jax.lax.psum(o, seq_axes)
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4).astype(ql.dtype)  # (b,1,KV,G,hd)

    ba = tuple(batch_axes)
    sa = tuple(seq_axes)
    return shard_map(
        local,
        mesh=ctx.mesh,
        in_specs=(
            P(ba, None, None, None, None),
            P(ba, sa, None, None),
            P(ba, sa, None, None),
            P(ba, sa),
        ),
        out_specs=P(ba, None, None, None, None),
        check_vma=False,
    )(q, k_cache, v_cache, valid)


# ---------------------------------------------------------------------------
# per-layer decode sublayers
# ---------------------------------------------------------------------------

def _attn_decode(
    x, layer, cfg: ModelConfig, ctx: ShardCtx, k_c, v_c, pos_c, lengths,
    *, ring: bool, use_rope: bool = True,
):
    """One attention layer's decode. Returns (out, k_c, v_c, pos_c)."""
    b = x.shape[0]
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kv
    t = k_c.shape[1]

    hn = rms_norm(x, layer["ln1"], cfg.norm_eps)
    q = (hn @ layer["attn"]["wq"]).reshape(b, 1, h, hd)
    k = (hn @ layer["attn"]["wk"]).reshape(b, 1, kv, hd)
    v = (hn @ layer["attn"]["wv"]).reshape(b, 1, kv, hd)
    if "q_norm" in layer["attn"]:
        q = rms_norm(q, layer["attn"]["q_norm"], cfg.norm_eps)
        k = rms_norm(k, layer["attn"]["k_norm"], cfg.norm_eps)
    if use_rope:
        pos_new = lengths[:, None]  # (B, 1) absolute position of the new token
        if cfg.mrope_sections:
            p3 = jnp.broadcast_to(pos_new, (3, b, 1))
            q = apply_mrope(q, p3, cfg.mrope_sections, cfg.rope_theta)
            k = apply_mrope(k, p3, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = apply_rope(q, pos_new, cfg.rope_theta)
            k = apply_rope(k, pos_new, cfg.rope_theta)

    # write the new K/V into the cache (ring buffers wrap at T=window)
    slot = lengths % t if ring else jnp.minimum(lengths, t - 1)
    bi = jnp.arange(b)
    k_c = k_c.at[bi, slot].set(k[:, 0])
    v_c = v_c.at[bi, slot].set(v[:, 0])
    pos_c = pos_c.at[bi, slot].set(lengths)

    # valid slots: written and (for SWA) within the window
    filled = jnp.minimum(lengths + 1, t)
    valid = jnp.arange(t)[None, :] < filled[:, None]
    if ring and cfg.sliding_window is not None:
        valid &= pos_c > (lengths[:, None] - cfg.sliding_window)

    out = flash_decode(q.reshape(b, 1, kv, g, hd), k_c, v_c, valid, ctx)
    y = out.reshape(b, 1, h * hd)[:, 0] @ layer["attn"]["wo"]
    return x + y, k_c, v_c, pos_c


def _mlp_decode(x, layer, cfg, kind="swiglu"):
    hn = rms_norm(x, layer["ln2"], cfg.norm_eps)
    y = swiglu(hn, layer["mlp"]) if kind == "swiglu" else gelu_mlp(hn, layer["mlp"])
    return x + y


def _moe_decode(x, layer, cfg, ctx):
    hn = rms_norm(x, layer["ln2"], cfg.norm_eps)
    y, _ = moe_ffn(
        hn,
        layer["moe"],
        num_experts=cfg.num_experts,
        experts_per_token=cfg.experts_per_token,
        capacity_factor=max(cfg.capacity_factor, 2.0),  # tiny N: avoid drops
    )
    return x + y


# ---------------------------------------------------------------------------
# serve_step per family
# ---------------------------------------------------------------------------

def serve_step(
    params: dict,
    token: jax.Array,  # (B, 1) int32
    cache: dict,
    lengths: jax.Array,  # (B,) filled context lengths
    cfg: ModelConfig,
    ctx: ShardCtx,
) -> tuple[jax.Array, dict]:
    """One decode step: next-token logits + updated cache."""
    b = token.shape[0]
    x = jnp.take(params["embed"], token[:, 0], axis=0)  # (B, d)

    if cfg.family in ("dense", "vlm", "moe"):
        x, cache = _decode_attn_stack(params, x, cache, lengths, cfg, ctx)
    elif cfg.family == "ssm":
        x, cache = _decode_ssm_stack(params, x, cache, lengths, cfg, ctx)
    elif cfg.family == "hybrid":
        x, cache = _decode_hybrid(params, x, cache, lengths, cfg, ctx)
    elif cfg.is_encoder_decoder:
        x, cache = _decode_encdec(params, x, cache, lengths, cfg, ctx)
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head  # (B, Vp)
    return logits, cache


def _decode_attn_stack(params, x, cache, lengths, cfg, ctx):
    ring = cfg.sliding_window is not None
    ac = cache["attn"]
    pos_c = ac["pos"]

    def body(carry, layer_in):
        h, pos_c = carry
        layer, k_l, v_l = layer_in
        h, k_l, v_l, pos_c = _attn_decode(
            h, layer, cfg, ctx, k_l, v_l, pos_c, lengths, ring=ring
        )
        if cfg.family == "moe":
            h = _moe_decode(h, layer, cfg, ctx)
        else:
            h = _mlp_decode(h, layer, cfg)
        return (h, pos_c), (k_l, v_l)

    (x, pos_c), (k_new, v_new) = jax.lax.scan(
        body, (x, pos_c), (params["layers"], ac["k"], ac["v"])
    )
    return x, {"attn": {"k": k_new, "v": v_new, "pos": pos_c}}


def _decode_ssm_stack(params, x, cache, lengths, cfg, ctx):
    sc = cache["ssm"]

    def body(h, layer_in):
        layer, ssm_l, conv_l = layer_in
        hn = rms_norm(h, layer["ln"], cfg.norm_eps)
        y, new_state = mamba2_decode_step(
            hn[:, None, :], layer["mamba"], cfg, {"ssm": ssm_l, "conv": conv_l}
        )
        return h + y[:, 0], (new_state["ssm"], new_state["conv"])

    x, (ssm_new, conv_new) = jax.lax.scan(
        body, x, (params["layers"], sc["ssm"], sc["conv"])
    )
    return x, {"ssm": {"ssm": ssm_new, "conv": conv_new}}


def _decode_hybrid(params, x, cache, lengths, cfg, ctx):
    from repro.models.transformer import _hybrid_segments

    sc, ac = cache["ssm"], cache["attn"]
    pos_c = ac["pos"]
    runs = _hybrid_segments(cfg)

    def mamba_body(h, layer_in):
        layer, ssm_l, conv_l = layer_in
        hn = rms_norm(h, layer["ln"], cfg.norm_eps)
        y, ns = mamba2_decode_step(
            hn[:, None, :], layer["mamba"], cfg, {"ssm": ssm_l, "conv": conv_l}
        )
        return h + y[:, 0], (ns["ssm"], ns["conv"])

    ssm_out, conv_out, k_out, v_out = [], [], [], []
    off = 0
    for i, ln in enumerate(runs):
        if ln > 0:
            seg = jax.tree_util.tree_map(
                lambda a, o=off, n=ln: a[o : o + n], params["layers"]
            )
            x, (s_n, c_n) = jax.lax.scan(
                mamba_body, x, (seg, sc["ssm"][off : off + ln], sc["conv"][off : off + ln])
            )
            ssm_out.append(s_n)
            conv_out.append(c_n)
            off += ln
        if i < len(runs) - 1:
            shared = params["shared_attn"]
            x, k_n, v_n, pos_c = _attn_decode(
                x, shared, cfg, ctx, ac["k"][i], ac["v"][i], pos_c, lengths,
                ring=False,
            )
            x = _mlp_decode(x, shared, cfg)
            k_out.append(k_n)
            v_out.append(v_n)

    return x, {
        "ssm": {
            "ssm": jnp.concatenate(ssm_out, axis=0),
            "conv": jnp.concatenate(conv_out, axis=0),
        },
        "attn": {
            "k": jnp.stack(k_out, axis=0),
            "v": jnp.stack(v_out, axis=0),
            "pos": pos_c,
        },
    }


def _decode_encdec(params, x, cache, lengths, cfg, ctx):
    """Whisper decoder step: causal self-attn cache + fixed cross K/V."""
    from repro.models.layers import sinusoidal_positions

    ac, cc = cache["attn"], cache["cross"]
    pos_c = ac["pos"]
    b = x.shape[0]
    pos_table = jnp.asarray(
        sinusoidal_positions(ac["k"].shape[2], cfg.d_model), x.dtype
    )
    x = x + pos_table[jnp.minimum(lengths, pos_table.shape[0] - 1)]

    t_enc = cc["k"].shape[2]  # padded to a shardable multiple; mask the tail
    cross_valid = jnp.broadcast_to(
        jnp.arange(t_enc)[None, :] < cfg.encoder_ctx, (b, t_enc)
    )
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    g = cfg.num_heads // kv

    def body(carry, layer_in):
        h, pos_c = carry
        layer, k_l, v_l, ck_l, cv_l = layer_in
        h, k_l, v_l, pos_c = _attn_decode(
            h, layer, cfg, ctx, k_l, v_l, pos_c, lengths, ring=False,
            use_rope=False,
        )
        # cross attention against the precomputed encoder K/V
        hn = rms_norm(h, layer["ln_cross"], cfg.norm_eps)
        qc = (hn @ layer["cross"]["wq"]).reshape(b, 1, kv, g, hd)
        out = flash_decode(qc, ck_l, cv_l, cross_valid, ctx)
        h = h + out.reshape(b, cfg.num_heads * hd) @ layer["cross"]["wo"]
        h = _mlp_decode(h, layer, cfg, kind="gelu")
        return (h, pos_c), (k_l, v_l)

    (x, pos_c), (k_new, v_new) = jax.lax.scan(
        body, (x, pos_c),
        (params["dec_layers"], ac["k"], ac["v"], cc["k"], cc["v"]),
    )
    return x, {
        "attn": {"k": k_new, "v": v_new, "pos": pos_c},
        "cross": cc,
    }
