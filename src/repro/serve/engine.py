"""Batched serving loop: continuous decode over a request batch.

Small but real: greedy sampling, per-request lengths, EOS termination, and
token-by-token prefill through the same serve_step (exactness over speed on
this CPU container; on TPU the prefill cells lower the full forward pass).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.serve.decode import serve_step
from repro.serve.kvcache import plan_cache, zeros_cache
from repro.sharding.specs import ShardCtx


@dataclass
class ServeResult:
    tokens: np.ndarray  # (B, max_new) generated ids
    steps: int
    finished: np.ndarray


class Engine:
    def __init__(
        self,
        params: dict,
        cfg: ModelConfig,
        ctx: ShardCtx,
        batch: int,
        context_len: int,
        eos_id: int = 2,
    ):
        self.params = params
        self.cfg = cfg
        self.ctx = ctx
        self.plan = plan_cache(cfg, batch, context_len)
        self.cache = zeros_cache(cfg, self.plan)
        self.lengths = jnp.zeros((batch,), jnp.int32)
        self.eos_id = eos_id
        self._step = jax.jit(
            lambda p, t, c, l: serve_step(p, t, c, l, cfg, ctx)
        )

    def ingest(self, prompts: np.ndarray) -> jax.Array:
        """Token-by-token prefill of (B, S_prompt). Returns last logits."""
        logits = None
        for s in range(prompts.shape[1]):
            tok = jnp.asarray(prompts[:, s : s + 1], jnp.int32)
            logits, self.cache = self._step(
                self.params, tok, self.cache, self.lengths
            )
            self.lengths = self.lengths + 1
        return logits

    def generate(self, prompts: np.ndarray, max_new: int = 16) -> ServeResult:
        b = prompts.shape[0]
        logits = self.ingest(prompts)
        out = np.zeros((b, max_new), np.int32)
        finished = np.zeros((b,), bool)
        for i in range(max_new):
            nxt = jnp.argmax(logits[:, : self.cfg.vocab_size], axis=-1).astype(
                jnp.int32
            )
            out[:, i] = np.asarray(nxt)
            finished |= np.asarray(nxt) == self.eos_id
            if finished.all():
                out = out[:, : i + 1]
                break
            logits, self.cache = self._step(
                self.params, nxt[:, None], self.cache, self.lengths
            )
            self.lengths = self.lengths + 1
        return ServeResult(tokens=out, steps=int(self.lengths[0]), finished=finished)
