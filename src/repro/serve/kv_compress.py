"""DROP-based KV-cache compression (beyond-paper integration).

Keys/values are highly structured across a long context (attention sinks,
local repetition) — exactly the regime where the paper shows tiny samples
recover a TLB-preserving PCA basis. We run DROP over sampled key rows
(B*T*KV, hd) from a prefill, obtain a rank-r basis V_k (hd, r) per layer, and
store the cache in the compressed space:

    c_k = k @ V_k          scores q.k_hat = (q V_k) . c_k      (exact algebra)
    c_v = v @ V_v          out = (p @ c_v) V_v^T

so decode attention runs entirely in r dims: cache memory AND decode
memory-bandwidth shrink by r/hd. TLB preservation on key rows bounds the
distortion of ||k_i - k_j||, which controls score perturbation for normalized
queries — the paper's distance-preservation contract, reused verbatim.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class KVCompressConfig:
    # keys need a HIGH preservation target: softmax amplifies score
    # perturbation, so sub-rank bases degrade sharply below the data's true
    # rank (measured: rel-err 0.46 @0.95 vs 0.014 @0.98 on rank-6 keys)
    target_tlb: float = 0.98
    max_rank: int | None = None  # default: head_dim (no-op bound)
    sample_rows: int = 4096


def discover_kv_basis(
    rows: np.ndarray, cfg: KVCompressConfig, seed: int = 0
) -> np.ndarray:
    """DROP over sampled K (or V) rows -> (hd, r) basis."""
    from repro.core import DropConfig, drop
    from repro.core.cost import zero_cost

    if rows.shape[0] > cfg.sample_rows:
        idx = np.random.default_rng(seed).choice(
            rows.shape[0], cfg.sample_rows, replace=False
        )
        rows = rows[idx]
    res = drop(
        rows.astype(np.float32),
        DropConfig(
            target_tlb=cfg.target_tlb, search="prefix", seed=seed,
            schedule=(0.1, 0.25, 0.5, 1.0), max_pairs=1600,
        ),
        cost=zero_cost(),
    )
    r = res.k if cfg.max_rank is None else min(res.k, cfg.max_rank)
    return np.asarray(res.v[:, :r], dtype=np.float32)


def compress_cache_layer(k, v, basis_k, basis_v):
    """(B,T,KV,hd) -> (B,T,KV,r) compressed cache entries. Centering is
    intentionally omitted: pair differences (what TLB preserves) are mean-free
    and attention logits tolerate a shared offset absorbed by softmax."""
    ck = jnp.einsum("btkh,hr->btkr", k, basis_k)
    cv = jnp.einsum("btkh,hr->btkr", v, basis_v)
    return ck, cv


def decode_attention_compressed(
    q: jax.Array,  # (B, 1, KV, G, hd)
    ck: jax.Array,  # (B, T, KV, r)
    cv: jax.Array,  # (B, T, KV, r)
    basis_k: jax.Array,  # (hd, r)
    basis_v: jax.Array,  # (hd, r)
    valid: jax.Array,  # (B, T)
) -> jax.Array:
    """Attention computed wholly in the compressed space."""
    hd = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    qc = jnp.einsum("bqkgh,hr->bqkgr", q.astype(jnp.float32), basis_k)
    s = jnp.einsum("bqkgr,btkr->bkgqt", qc, ck.astype(jnp.float32)) * scale
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    oc = jnp.einsum("bkgqt,btkr->bkgqr", p, cv.astype(jnp.float32))
    o = jnp.einsum("bkgqr,hr->bkgqh", oc, basis_v)
    return o.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # (B,1,KV,G,hd)


def compression_report(hd: int, ranks: list[int]) -> dict:
    r = float(np.mean(ranks)) if ranks else hd
    return {
        "head_dim": hd,
        "mean_rank": r,
        "cache_bytes_ratio": r / hd,
        "decode_hbm_ratio": r / hd,
    }
