"""KV/state cache structures for decoding, per architecture family.

Layouts (DESIGN.md §5):
* attention cache: (L, B, T, KV, hd) x2, sharded (batch->dp, T->"model") —
  sequence-sharded so 32k/500k caches split across the TP axis; attention over
  the shards is the distributed flash-decode in decode.py.
* SWA (mixtral): ring buffer of size window — the reason long_500k is feasible
  for a quadratic-attention arch.
* SSM state: (L, B, H, N, P) + conv tail (L, B, W-1, C) — O(1) in context.
* whisper: decoder self cache + precomputed cross K/V over encoder frames.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class CachePlan:
    """Static description of a model's decode cache (also used to build
    ShapeDtypeStructs for the dry-run without allocating)."""

    kind: str  # "attn" | "ssm" | "hybrid" | "encdec"
    attn_len: int  # T dimension of the attention cache (window for SWA)
    batch: int


def plan_cache(cfg: ModelConfig, batch: int, context_len: int) -> CachePlan:
    attn_len = context_len
    if cfg.sliding_window is not None:
        attn_len = min(cfg.sliding_window, context_len)
    if cfg.family == "ssm":
        kind = "ssm"
    elif cfg.family == "hybrid":
        kind = "hybrid"
    elif cfg.is_encoder_decoder:
        kind = "encdec"
    else:
        kind = "attn"
    return CachePlan(kind=kind, attn_len=attn_len, batch=batch)


def _attn_cache_struct(cfg, n_layers, batch, t, dtype):
    shape = (n_layers, batch, t, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jax.ShapeDtypeStruct(shape, dtype),
        "v": jax.ShapeDtypeStruct(shape, dtype),
        # absolute positions held in each slot (ring buffers wrap): (L? no —
        # positions are shared across layers) (B, T) int32
        "pos": jax.ShapeDtypeStruct((batch, t), jnp.int32),
    }


def _ssm_cache_struct(cfg, n_layers, batch, dtype):
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "ssm": jax.ShapeDtypeStruct(
            (n_layers, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
            jnp.float32,
        ),
        "conv": jax.ShapeDtypeStruct(
            (n_layers, batch, cfg.conv_width - 1, conv_ch), dtype
        ),
    }


def cache_struct(cfg: ModelConfig, plan: CachePlan) -> dict:
    """ShapeDtypeStruct pytree of the cache (allocate with zeros_like_struct)."""
    dtype = jnp.dtype(cfg.dtype)
    b, t = plan.batch, plan.attn_len
    if plan.kind == "attn":
        return {"attn": _attn_cache_struct(cfg, cfg.num_layers, b, t, dtype)}
    if plan.kind == "ssm":
        return {"ssm": _ssm_cache_struct(cfg, cfg.num_layers, b, dtype)}
    if plan.kind == "hybrid":
        n_attn = len(cfg.attn_block_positions)
        n_mamba = cfg.num_layers - n_attn
        return {
            "ssm": _ssm_cache_struct(cfg, n_mamba, b, dtype),
            "attn": _attn_cache_struct(cfg, n_attn, b, t, dtype),
        }
    if plan.kind == "encdec":
        # cross cache length padded to a shardable multiple (512); the decode
        # path masks slots >= encoder_ctx
        t_enc = ((cfg.encoder_ctx + 511) // 512) * 512
        return {
            "attn": _attn_cache_struct(cfg, cfg.num_layers, b, t, dtype),
            "cross": _attn_cache_struct(cfg, cfg.num_layers, b, t_enc, dtype),
        }
    raise ValueError(plan.kind)


def zeros_cache(cfg: ModelConfig, plan: CachePlan) -> dict:
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_struct(cfg, plan)
    )


def cache_specs(cfg: ModelConfig, plan: CachePlan, ctx) -> dict:
    """PartitionSpec tree matching cache_struct (DESIGN.md §5 decode layout:
    batch over dp where divisible, cache seq over "model" + leftover dp)."""
    from jax.sharding import PartitionSpec as P

    from repro.serve.decode import decode_layout

    ba, sa = decode_layout(ctx, plan.batch)

    def attn_spec():
        return {
            "k": P(None, ba, sa, None, None),
            "v": P(None, ba, sa, None, None),
            "pos": P(ba, sa),
        }

    out: dict = {}
    struct = cache_struct(cfg, plan)
    if "attn" in struct:
        out["attn"] = attn_spec()
    if "cross" in struct:
        out["cross"] = attn_spec()
    if "ssm" in struct:
        out["ssm"] = {
            "ssm": P(None, ba, "model", None, None),  # SSD heads TP-sharded
            "conv": P(None, ba, None, None),
        }
    return out


def cache_bytes(cfg: ModelConfig, plan: CachePlan) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(cache_struct(cfg, plan)):
        total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return total
