"""Multi-tenant DR serving: batched ``ReduceQuery``s over any ``Reducer``
method (pca/fft/paa/dwt/jl), shared shape buckets, a method-agnostic reuse
cache that amortizes fitting across repeat workloads (paper §5) including
append-only prefix matching, a sharded multi-device scheduler, a supervised
process-worker fleet (the CPU scale-out mode: fault-tolerant restart +
measured-cost placement), and an async ingest front-end.

See README.md in this package for the scheduler state machine, the cache
hierarchy, and the migration table from the PCA-only era names."""

from repro.serve_drop.cache import (  # noqa: F401
    BasisCacheEntry,
    BasisReuseCache,
    dataset_fingerprint,
)
from repro.serve_drop.delta import (  # noqa: F401
    APPEND,
    CLOSED,
    ROLLBACK,
    SubscribeQuery,
    SubscriberState,
    SubscriptionClosed,
)
from repro.serve_drop.fleet import (  # noqa: F401
    FleetSupervisor,
    LinkProfile,
)
from repro.serve_drop.ingest import (  # noqa: F401
    IngestFrontend,
    RetryLater,
)
from repro.serve_drop.service import (  # noqa: F401
    DropQuery,
    DropService,
    ReduceQuery,
    ServeResult,
    ServiceStats,
)
from repro.serve_drop.sharded import ShardedDropService  # noqa: F401
