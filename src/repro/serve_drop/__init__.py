"""Multi-tenant DROP serving: batched queries, shared shape buckets, and a
basis-reuse cache that amortizes fitting across repeat workloads (paper §5)."""

from repro.serve_drop.cache import (  # noqa: F401
    BasisCacheEntry,
    BasisReuseCache,
    dataset_fingerprint,
)
from repro.serve_drop.service import (  # noqa: F401
    DropQuery,
    DropService,
    ServeResult,
    ServiceStats,
)
