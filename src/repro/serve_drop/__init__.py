"""Multi-tenant DROP serving: batched queries, shared shape buckets, a
basis-reuse cache that amortizes fitting across repeat workloads (paper §5),
a sharded multi-device scheduler, and an async ingest front-end.

See README.md in this package for the scheduler state machine and the
cache hierarchy."""

from repro.serve_drop.cache import (  # noqa: F401
    BasisCacheEntry,
    BasisReuseCache,
    dataset_fingerprint,
)
from repro.serve_drop.ingest import (  # noqa: F401
    IngestFrontend,
    RetryLater,
)
from repro.serve_drop.service import (  # noqa: F401
    DropQuery,
    DropService,
    ServeResult,
    ServiceStats,
)
from repro.serve_drop.sharded import ShardedDropService  # noqa: F401
