"""LRU basis-reuse cache for repeat DROP workloads (paper §5).

§5 of the paper shows that when workloads repeat — the common case for a
DR service fronting dashboards or periodic batch analytics — reusing the
fitted basis converts DROP's cost into a single cheap TLB validation. The
related lazy-PCA line of work (arXiv:1709.07175) makes the same argument:
amortize the expensive factorization across queries and recompute lazily
only when the validation fails.

Entries are keyed by (dataset fingerprint, quantized TLB target):

* **exact hit** — same data, same (or looser) target: the cached (V, mean, k)
  is revalidated against the live data with a sampled TLB estimate and, if it
  still clears the target, served without any fitting.
* **warm hit** — same data but no reusable entry: a cold run still starts
  with ``prev_k`` seeded from the smallest cached satisfying k fitted at a
  target >= the request's, shrinking the first Halko fit. Entries fitted at
  looser targets are ignored here — their smaller k is not a valid upper
  bound for a tighter search.

The fingerprint is a content hash over the array's shape/dtype and a strided
row subsample — O(sqrt) of the data, collision-safe in practice for the
service's trust domain, and cheap enough to run per query.

Staleness under drift: the exact-hit revalidation samples pairs with a
seed pinned by the query config, so identical resubmissions validate on
identical pairs forever — drift concentrated in never-sampled pairs is
invisible to it. ``ttl_ticks`` bounds that blind spot: an entry older than
the TTL (age measured in scheduler ticks, advanced by the service once per
ADMITTED query, so a TTL counts serving decisions — independent of
drain-thread count and of idle polling) is no longer served from
``get_exact`` even when the fingerprint matches, forcing a full refit whose
result re-populates the entry with a fresh basis AND a fresh age. Expired
entries still seed warm starts — a stale warm rank bound is
self-correcting in ``DropRunner``.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

# targets within one TLB "mil" share a cache slot: serving a 0.9801-target
# query from a 0.98-fitted basis is exactly the §5 reuse story
TARGET_QUANTUM = 1e-3


def dataset_fingerprint(x: np.ndarray, max_rows: int = 64) -> str:
    """Content hash of shape, dtype, and a strided row subsample."""
    x = np.ascontiguousarray(x)
    h = hashlib.sha1()
    h.update(repr((x.shape, str(x.dtype))).encode())
    stride = max(1, x.shape[0] // max_rows)
    h.update(x[::stride].tobytes())
    if x.shape[0] > 1:
        h.update(x[-1].tobytes())  # strided view can miss the tail
    return h.hexdigest()


def quantize_target(target: float) -> int:
    return int(round(target / TARGET_QUANTUM))


@dataclass
class BasisCacheEntry:
    """A fitted basis worth reusing: the paper's T_k plus its provenance."""

    v: np.ndarray  # (d, k)
    mean: np.ndarray  # (d,)
    k: int
    target_tlb: float
    tlb_estimate: float
    satisfied: bool
    born_tick: int = 0  # stamped by put(); age = cache clock - born_tick


class BasisReuseCache:
    """Bounded LRU over fitted bases, with exact and warm-start lookups.

    ``ttl_ticks`` (None = never expire) caps how long an entry may serve
    exact hits: past the TTL the entry is invisible to ``get_exact`` — the
    query refits cold and ``put`` re-inserts it with a fresh age."""

    def __init__(self, capacity: int = 16, ttl_ticks: int | None = None) -> None:
        self.capacity = max(int(capacity), 1)
        self.ttl_ticks = ttl_ticks
        self._entries: OrderedDict[tuple[str, int], BasisCacheEntry] = OrderedDict()
        self.evictions = 0
        self.expired_hits = 0
        self._now = 0

    def __len__(self) -> int:
        return len(self._entries)

    def keys(self) -> list[tuple[str, int]]:
        return list(self._entries.keys())

    def tick(self) -> int:
        """Advance the scheduler clock (one admitted query = one tick)."""
        self._now += 1
        return self._now

    def _expired(self, entry: BasisCacheEntry) -> bool:
        return (
            self.ttl_ticks is not None
            and self._now - entry.born_tick > self.ttl_ticks
        )

    def get_exact(self, fp: str, target: float) -> BasisCacheEntry | None:
        """A satisfying entry for this dataset fitted at a target >= ours
        (checked loosest-first is unnecessary: any such basis, revalidated,
        serves the request). Refreshes LRU recency. Entries past the TTL are
        skipped (counted in ``expired_hits``): the caller falls through to a
        cold refit, which re-inserts a fresh entry."""
        candidates = []
        for key, entry in self._entries.items():
            if not (
                key[0] == fp
                and key[1] >= quantize_target(target)
                and entry.satisfied
            ):
                continue
            if self._expired(entry):
                self.expired_hits += 1
            else:
                candidates.append(key)
        if not candidates:
            return None
        # prefer the smallest satisfying basis among eligible targets
        key = min(candidates, key=lambda c: self._entries[c].k)
        self._entries.move_to_end(key)
        return self._entries[key]

    def get_warm_k(self, fp: str, target: float) -> int | None:
        """Rank bound for a cold run on known data: the smallest cached
        satisfying k whose fit target was >= the request's (a basis fitted at
        a looser target cannot bound a tighter search). Expired entries still
        qualify — a stale bound is a hint the runner drops after one failed
        iteration, so it cannot poison the refit."""
        ks = [
            e.k
            for (efp, tq), e in self._entries.items()
            if efp == fp and e.satisfied and tq >= quantize_target(target)
        ]
        return min(ks) if ks else None

    def put(self, fp: str, entry: BasisCacheEntry) -> None:
        key = (fp, quantize_target(entry.target_tlb))
        entry.born_tick = self._now  # (re)insertion restarts the TTL clock
        if key in self._entries:
            self._entries.move_to_end(key)
        self._entries[key] = entry
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1
