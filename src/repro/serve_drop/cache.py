"""LRU reuse cache for fitted reduction operators (paper §5).

§5 of the paper shows that when workloads repeat — the common case for a
DR service fronting dashboards or periodic batch analytics — reusing the
fitted map converts DROP's cost into a single cheap TLB validation. The
related lazy-PCA line of work (arXiv:1709.07175) makes the same argument:
amortize the expensive factorization across queries and recompute lazily
only when the validation fails. Since every ``Reducer`` (PCA, FFT, PAA,
DWT, JL) produces the same artifact — a (d, k) linear map — the cache is
method-agnostic: FFT/PAA results are as cacheable as PCA bases.

Entries are keyed by (dataset fingerprint, method, quantized TLB target):

* **exact hit** — same data, same method, same (or looser) target: the
  cached (V, mean, k) is revalidated with a sampled TLB estimate on the
  live data and, if it still clears the target, served without any fitting.
* **prefix hit** — append-only streams: a dataset grown by appended rows
  misses on its full fingerprint, but if a cached entry's row count marks a
  prefix whose fingerprint matches, the cached map is revalidated on the
  FULL grown data (suffix included) instead of refitting cold. A pass
  serves the entry and re-registers it under the grown fingerprint. PCA
  entries additionally carry ``tracker`` — ``core.subspace`` updater state
  (basis + singular values + running mean) — so a FAILED prefix
  revalidation, or a suffix past the service's drift budget, escalates to
  an O(suffix) incremental subspace update instead of a cold refit (the
  refit is the last resort, not the default; see ``DropService``).
* **warm hit** — same data/method but no reusable entry: a cold PCA run
  still starts with ``prev_k`` seeded from the smallest cached satisfying k
  fitted at a target >= the request's. Entries fitted at looser targets are
  ignored here — their smaller k is not a valid upper bound for a tighter
  search.

The fingerprint is a content hash over the array's shape/dtype and a strided
row subsample — O(sqrt) of the data, collision-safe in practice for the
service's trust domain, and cheap enough to run per query.

Staleness under drift: the exact-hit revalidation samples pairs with a
seed pinned by the query config, so identical resubmissions validate on
identical pairs forever — drift concentrated in never-sampled pairs is
invisible to it. ``ttl_ticks`` bounds that blind spot: an entry older than
the TTL (age measured in scheduler ticks, advanced by the service once per
ADMITTED query, so a TTL counts serving decisions — independent of
drain-thread count and of idle polling) is no longer served from
``get_exact`` even when the fingerprint matches, forcing a full refit whose
result re-populates the entry with a fresh basis AND a fresh age. Expired
entries still seed warm starts — a stale warm rank bound is
self-correcting in ``PcaDropReducer``.

TTL auto-tuning (``auto_ttl=True``): revalidation verdicts reported via
``note_validation`` steer the effective TTL between 1 and the configured
``ttl_ticks`` — a failed revalidation (observed drift) halves it, a
sustained run of validated hits doubles it back. Under drift the blind-spot
window shrinks toward "refit every time"; on a stable workload it recovers
the configured reuse budget. The service surfaces the live value as
``ServiceStats.effective_ttl``.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

# targets within one TLB "mil" share a cache slot: serving a 0.9801-target
# query from a 0.98-fitted basis is exactly the §5 reuse story
TARGET_QUANTUM = 1e-3

# validated-hit streak that earns one TTL doubling under auto_ttl
AUTO_TTL_GROW_STREAK = 4


def dataset_fingerprint(x: np.ndarray, max_rows: int = 64) -> str:
    """Content hash of shape, dtype, and a strided row subsample."""
    x = np.ascontiguousarray(x)
    h = hashlib.sha1()
    h.update(repr((x.shape, str(x.dtype))).encode())
    stride = max(1, x.shape[0] // max_rows)
    h.update(x[::stride].tobytes())
    if x.shape[0] > 1:
        h.update(x[-1].tobytes())  # strided view can miss the tail
    return h.hexdigest()


def quantize_target(target: float) -> int:
    return int(round(target / TARGET_QUANTUM))


@dataclass
class BasisCacheEntry:
    """A fitted map worth reusing: the paper's T_k plus its provenance."""

    v: np.ndarray  # (d, k)
    mean: np.ndarray  # (d,)
    k: int
    target_tlb: float
    tlb_estimate: float
    satisfied: bool
    method: str = "pca"
    rows: int = 0  # fitted dataset's row count (prefix matching key)
    born_tick: int = 0  # stamped by put(); age = cache clock - born_tick
    # core.subspace.SubspaceTracker updater state (None for methods without
    # an incremental path): what lets the serving layer fold an appended
    # suffix into this map instead of refitting cold. tracker.rows must
    # equal ``rows`` — the suffix of a grown dataset is sliced from it.
    tracker: object | None = None


class BasisReuseCache:
    """Bounded LRU over fitted maps, with exact/prefix/warm-start lookups.

    ``ttl_ticks`` (None = never expire) caps how long an entry may serve
    exact hits: past the TTL the entry is invisible to ``get_exact`` — the
    query refits cold and ``put`` re-inserts it with a fresh age. With
    ``auto_ttl`` the live bound floats between 1 and ``ttl_ticks`` on
    revalidation verdicts (see module docstring)."""

    def __init__(
        self,
        capacity: int = 16,
        ttl_ticks: int | None = None,
        auto_ttl: bool = False,
    ) -> None:
        self.capacity = max(int(capacity), 1)
        self.base_ttl = ttl_ticks
        self.ttl_ticks = ttl_ticks
        self.auto_ttl = auto_ttl and ttl_ticks is not None
        self._entries: OrderedDict[
            tuple[str, str, int], BasisCacheEntry
        ] = OrderedDict()
        self.evictions = 0
        self.expired_hits = 0
        self.validation_failures = 0
        self._streak = 0  # consecutive validated hits (auto-TTL growth)
        self._now = 0

    def __len__(self) -> int:
        return len(self._entries)

    def keys(self) -> list[tuple[str, str, int]]:
        return list(self._entries.keys())

    def tick(self) -> int:
        """Advance the scheduler clock (one admitted query = one tick)."""
        self._now += 1
        return self._now

    def note_validation(self, passed: bool) -> None:
        """Feed a revalidation verdict to the TTL auto-tuner: failures are
        observed drift (shrink the blind-spot window), sustained validated
        hits earn the configured budget back."""
        if not passed:
            self.validation_failures += 1
        if not self.auto_ttl:
            return
        if passed:
            self._streak += 1
            if self._streak >= AUTO_TTL_GROW_STREAK:
                self._streak = 0
                self.ttl_ticks = min(self.base_ttl, max(self.ttl_ticks, 1) * 2)
        else:
            self._streak = 0
            self.ttl_ticks = max(1, self.ttl_ticks // 2)

    def _expired(self, entry: BasisCacheEntry) -> bool:
        return (
            self.ttl_ticks is not None
            and self._now - entry.born_tick > self.ttl_ticks
        )

    def _eligible(
        self, key: tuple[str, str, int], fp: str, method: str, qt: int
    ) -> bool:
        return (
            key[0] == fp
            and key[1] == method
            and key[2] >= qt
            and self._entries[key].satisfied
        )

    def get_exact(
        self, fp: str, target: float, method: str = "pca"
    ) -> BasisCacheEntry | None:
        """A satisfying entry for this dataset/method fitted at a target >=
        ours (checked loosest-first is unnecessary: any such map,
        revalidated, serves the request). Refreshes LRU recency. Entries
        past the TTL are skipped; a lookup that MISSES because its only
        eligible entries expired counts once in ``expired_hits`` (a live
        entry serving the hit does not charge the stat for stale
        bystanders): the caller falls through to a cold refit, which
        re-inserts a fresh entry."""
        qt = quantize_target(target)
        candidates = []
        expired = 0
        for key, entry in self._entries.items():
            if not self._eligible(key, fp, method, qt):
                continue
            if self._expired(entry):
                expired += 1
            else:
                candidates.append(key)
        if not candidates:
            if expired:
                self.expired_hits += 1  # expiry CAUSED this miss
            return None
        # prefer the smallest satisfying map among eligible targets
        key = min(candidates, key=lambda c: self._entries[c].k)
        self._entries.move_to_end(key)
        return self._entries[key]

    def prefix_row_counts(
        self, m: int, d: int, target: float, method: str = "pca"
    ) -> list[int]:
        """Candidate strict-prefix lengths for an (m, d) dataset: the row
        counts of live satisfying entries of this method/target. Metadata
        scan only — the caller hashes the prefixes OUTSIDE the scheduler
        lock (see ``DropService.try_submit``) and matches via
        ``find_prefix``. Longest first: they validated the most rows."""
        qt = quantize_target(target)
        return sorted(
            {
                e.rows
                for key, e in self._entries.items()
                if key[1] == method
                and key[2] >= qt
                and e.satisfied
                and 0 < e.rows < m
                and e.v.shape[0] == d
                and not self._expired(e)
            },
            reverse=True,
        )

    def find_prefix(
        self, prefix_fps: dict[int, str], target: float, method: str = "pca"
    ) -> BasisCacheEntry | None:
        """Append-only stream reuse: an entry fitted on a strict PREFIX of
        the query's dataset (matched against the submit-time-hashed
        ``prefix_fps``: rows -> fingerprint of x[:rows]) whose map can be
        revalidated on the grown data instead of refitting cold."""
        for rows in sorted(prefix_fps, reverse=True):
            entry = self.get_exact(prefix_fps[rows], target, method)
            if entry is not None and entry.rows == rows:
                return entry
        return None

    def get_warm_k(
        self, fp: str, target: float, method: str = "pca"
    ) -> int | None:
        """Rank bound for a cold run on known data: the smallest cached
        satisfying k whose fit target was >= the request's (a basis fitted at
        a looser target cannot bound a tighter search). Expired entries still
        qualify — a stale bound is a hint the runner drops after one failed
        iteration, so it cannot poison the refit."""
        qt = quantize_target(target)
        ks = [
            e.k
            for (efp, meth, tq), e in self._entries.items()
            if efp == fp and meth == method and e.satisfied and tq >= qt
        ]
        return min(ks) if ks else None

    def put(self, fp: str, entry: BasisCacheEntry) -> None:
        key = (fp, entry.method, quantize_target(entry.target_tlb))
        entry.born_tick = self._now  # (re)insertion restarts the TTL clock
        if key in self._entries:
            self._entries.move_to_end(key)
        self._entries[key] = entry
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1
