"""Delta-serving protocol types: pub/sub subscriptions over append streams.

The streaming gap this closes: PR 4 made the *basis* incremental
(``core.subspace``), but a client of the request/response surface still
re-submits the grown dataset after every append — re-transforming all m
rows and re-running kNN/DBSCAN/KDE from scratch even when the served map
did not move. The delta protocol makes the server push the DIFFERENCE
instead, borrowing the append-only contract FlashToken uses for KV caches
(an append either extends the cache or returns ``(rollback, to_append)``
telling the consumer to rewind first):

* ``{"kind": "append"}`` — the tracker absorbed the suffix with the served
  rank/rotation stable (TLB-gated): carries the transformed suffix rows
  plus O(suffix) downstream patches. Subscriber state extends in place.
* ``{"kind": "rollback"}`` — the basis rotated (drift, headroom
  exhaustion, or a warm refit): carries the new basis and a FULL restate
  of transformed rows and downstream outputs. Subscriber state rebuilds.
* ``{"kind": "closed"}`` — terminal: unsubscribe, frontend drain, or an
  error (carried in ``error``). Nothing follows it.

Ordering: deltas for one subscription are sequence-numbered and delivered
in order, at most once (poll pops them). The first delta is always a
rollback (``reason="subscribe"``) carrying the bootstrap state — a client
needs no side channel to start. Every delta's compute is O(suffix) on the
append path; rollbacks pay the cold cost exactly when a snapshot client
would have had to anyway.

``SubscriberState`` is the reference client: feed it every delta and its
fields stay equal to a cold recompute over the grown dataset (the parity
suite pins this bit-for-bit for transforms/kNN/labels and to compensated-
sum tolerance for KDE densities).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import numpy as np

from repro.core.types import DropConfig, ReduceResult

__all__ = [
    "APPEND",
    "ROLLBACK",
    "CLOSED",
    "SubscribeQuery",
    "SubscriberState",
    "SubscriptionClosed",
]

APPEND = "append"
ROLLBACK = "rollback"
CLOSED = "closed"


class SubscriptionClosed(Exception):
    """Raised by blocking delta waits once a subscription is terminal."""


@dataclass
class SubscribeQuery:
    """One subscription request: serve ``x`` and keep serving deltas as it
    grows. ``rotation_tol`` is the append-vs-rollback gate on the tracker's
    rotation signal (``SubspaceTracker.rotation_from``): sines below it keep
    old transformed rows valid enough for the TLB revalidation to have the
    final word; above it the basis moved and subscribers must rebuild."""

    x: np.ndarray
    cfg: DropConfig = field(default_factory=DropConfig)
    method: str = "pca"
    # downstream analytics maintained per-subscription (analytics.incremental)
    eps: float = 0.5
    min_samples: int = 5
    bandwidth: float = 1.0
    rotation_tol: float = 0.25


@dataclass(eq=False)  # identity semantics, like the service's work items
class _Subscription:
    """Server-side subscription record (owned by ``DropService``; every
    field mutation happens under the scheduler lock except the compute that
    produces it)."""

    sub_id: int
    query: SubscribeQuery
    x: np.ndarray  # grown dataset: rows folded into served state so far
    state: str = "pending"  # pending (bootstrapping) | live | closed
    seq: int = 0  # next delta sequence number
    result: ReduceResult | None = None  # currently served map
    tracker: object = None  # SubspaceTracker (None for non-pca methods)
    analytics: object = None  # IncrementalAnalytics
    deltas: deque = field(default_factory=deque)  # emitted, not yet polled
    pending_suffixes: deque = field(default_factory=deque)  # not yet served
    inflight: bool = False  # a _DeltaServe item for this sub is scheduled
    close_requested: bool = False  # unsubscribe arrived mid-flight
    error: str | None = None
    boot_qid: int | None = None  # bootstrap ReduceQuery id (pending state)


class SubscriberState:
    """Reference delta consumer: applies the protocol and exposes the same
    outputs a cold ``optimize + transform + analytics`` pass would give.

    Raises on protocol violations (out-of-order seq, append before
    bootstrap) so tests and demos catch server bugs instead of absorbing
    them."""

    def __init__(self) -> None:
        self.basis: ReduceResult | None = None
        self.rows: np.ndarray | None = None  # transformed rows (m, k)
        self.knn_idx: np.ndarray | None = None
        self.knn_d2: np.ndarray | None = None
        self.labels: np.ndarray | None = None
        self.densities: np.ndarray | None = None
        self.closed = False
        self.error: str | None = None
        self.appends = 0
        self.rollbacks = 0
        self._next_seq = 0

    def apply(self, delta: dict) -> None:
        if self.closed:
            raise SubscriptionClosed("delta after closed")
        seq = int(delta["seq"])
        if seq != self._next_seq:
            raise ValueError(
                f"out-of-order delta: expected seq {self._next_seq}, got {seq}"
            )
        self._next_seq = seq + 1
        kind = delta["kind"]
        if kind == CLOSED:
            self.closed = True
            self.error = delta.get("error")
            return
        if kind == ROLLBACK:
            self.rollbacks += 1
            self.basis = delta["basis"]
            self.rows = np.asarray(delta["rows"])
            knn = delta["knn"]
            self.knn_idx = np.asarray(knn["idx"])
            self.knn_d2 = np.asarray(knn["d2"])
            self.labels = np.asarray(delta["labels"])
            self.densities = np.asarray(delta["densities"])
            return
        if kind != APPEND:
            raise ValueError(f"unknown delta kind {kind!r}")
        if self.rows is None:
            raise ValueError("append delta before bootstrap rollback")
        self.appends += 1
        base = int(delta["base_rows"])
        if base != self.rows.shape[0]:
            raise ValueError(
                f"append base {base} != held rows {self.rows.shape[0]}"
            )
        self.rows = np.concatenate([self.rows, np.asarray(delta["rows"])])
        knn = delta["knn"]
        changed = np.asarray(knn["changed"], dtype=np.int64)
        self.knn_idx = np.concatenate(
            [self.knn_idx, np.asarray(knn["append_idx"])]
        )
        self.knn_d2 = np.concatenate(
            [self.knn_d2, np.asarray(knn["append_d2"])]
        )
        self.knn_idx[changed] = np.asarray(knn["idx"])
        self.knn_d2[changed] = np.asarray(knn["d2"])
        self.labels = np.asarray(delta["labels"])
        self.densities = np.asarray(delta["densities"])
