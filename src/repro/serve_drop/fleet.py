"""Process-worker serving fleet with fault-tolerant supervision.

The sharded in-process scheduler cannot scale on CPU hosts: XLA:CPU
serializes execution across forced host devices inside one client (one
execution pool per client — see the bench notes in
``benchmarks/bench_drop_serve.py``). Real scale-out on a multi-core host
therefore means one *process* (one XLA client) per device slot. This
module promotes the worker-process pattern that used to live privately in
that bench into a first-class deployment mode:

* **FleetSupervisor** — spawns one core-pinned worker process per slot,
  routes ``ReduceQuery``s to workers over pipes, and streams
  ``ServeResult``s back. It duck-types the ``DropService`` surface
  (``submit``/``try_submit``/``backlog``/``take_result``/``poll``/``run``/
  ``stats``/``on_result``), so the existing ``IngestFrontend`` async
  front-end works unchanged: sync, threaded, and process modes share one
  API.
* **protocol** — length-prefixed pickle frames over the worker's
  stdin/stdout pipes (the worker re-points its ``stdout`` at stderr first,
  so stray prints can never corrupt framing). Messages: ``ready``,
  heartbeats, queries, results, echo pings (link profiling), compute
  probes, stop. This replaces the old line-oriented READY/GO handshake.
* **fault tolerance** — worker death is detected three ways: pipe EOF
  (fastest — a ``kill -9`` lands here), exitcode polling, and heartbeat
  timeout (a hung-but-alive worker is killed and treated as dead). A dead
  worker's in-flight queries are re-dispatched to live workers (bounded by
  ``max_query_retries``, then finished with ``ServeResult.error``) — a
  client blocked in ``result()`` is NEVER hung. Restarts go through
  ``fault.RestartPolicy`` (capped exponential backoff; a worker past the
  budget is retired and its slot removed). ``fault.FailureInjector`` can
  be wired into workers (``failure_prob``) so chaos tests exercise the
  whole ladder deterministically, and a per-worker
  ``fault.StragglerMonitor`` watches serve times.
* **measured placement** — beyond round-robin: at startup the supervisor
  profiles each link's transfer cost by echoing payloads of increasing
  size and fitting the classic alpha/beta model (``rtt/2 ~ alpha +
  beta * bytes``, the same latency/bandwidth decomposition colossal-ai's
  ``AlphaBetaProfiler`` fits for device links), plus each worker's compute
  speed with a fixed probe. Placement then minimizes *measured* cost:
  ``link(bytes) + (queue_depth + 1) * est_seconds / speed``, where
  ``est_seconds`` is a per-tenant EWMA and ``speed`` keeps being
  re-estimated from observed serve times. Tenants are sticky to their
  home worker (its basis cache is warm for them) and move only when
  another worker is decisively cheaper (``rebalance_margin``), surfaced
  as ``ServiceStats.rebalances``.
* **link re-profiling** — the alpha/beta fit is NOT startup-only: a link
  profile ages out after ``reprofile_interval_s`` seconds or
  ``reprofile_after_serves`` serves, whereupon the supervision loop
  re-runs a cheap echo probe on the idle worker (a background thread off
  the serving hot path) and REPLACES the fit. Compute speed needs no
  probe — the serve-time EWMA keeps it fresh — but transfer cost is only
  observable by echoing, so a link that degrades after startup (shared
  NIC, cgroup throttling, pipe contention) would otherwise keep its
  stale, optimistic profile and placement would keep routing tenants
  into the slow link. Re-profiles are surfaced as
  ``ServiceStats.reprofiles``.

Costs across the boundary: ``CostModel`` closures do not pickle, so fleet
queries carry the ``downstream`` task name (workers re-price it) or one of
the named cost families (``zero``/``knn``/``linear``, rebuilt from the
dataset's row count); arbitrary callables are rejected at submit.

The module top imports stdlib only: the worker bootstrap must pin CPU
affinity BEFORE numpy/jax initialize their thread pools, so every heavy
import here is deferred into the function that needs it.
"""

from __future__ import annotations

import os
import pickle
import queue
import struct
import subprocess
import sys
import threading
import time
from collections import deque
from dataclasses import dataclass, field

_LEN = struct.Struct("<Q")
_INJECTED_EXIT = 43  # worker exit code for an injected NodeFailure "crash"
_STOP_WRITER = object()  # sentinel that retires a writer thread

# worker bootstrap for `python -c`: pin affinity from --cores before ANY
# heavy import (numpy/XLA size their pools from the mask they see first)
_WORKER_BOOT = (
    "import os, sys\n"
    "argv = sys.argv[1:]\n"
    "if '--cores' in argv:\n"
    "    cores = argv[argv.index('--cores') + 1]\n"
    "    if cores and hasattr(os, 'sched_setaffinity'):\n"
    "        os.sched_setaffinity(0, {int(c) for c in cores.split(',')})\n"
    "from repro.serve_drop.fleet import _worker_main\n"
    "_worker_main(argv)\n"
)


# ------------------------------------------------------------------ framing


def _send_frame(f, obj) -> None:
    payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    f.write(_LEN.pack(len(payload)))
    f.write(payload)
    f.flush()


def _read_exact(f, n: int) -> bytes | None:
    buf = b""
    while len(buf) < n:
        chunk = f.read(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return buf


def _recv_frame(f):
    """One framed message, or None on EOF (peer gone)."""
    head = _read_exact(f, _LEN.size)
    if head is None:
        return None
    payload = _read_exact(f, _LEN.unpack(head)[0])
    if payload is None:
        return None
    return pickle.loads(payload)


def _cost_spec(cost):
    """Serializable form of a downstream cost model (see module docstring)."""
    if cost is None:
        return None
    name = getattr(cost, "name", None)
    if name in ("zero", "knn", "linear"):
        return name
    try:
        pickle.dumps(cost)
        return ("pickled", cost)
    except Exception:
        raise ValueError(
            "fleet queries cannot carry arbitrary cost callables across the "
            "process boundary; pass downstream='knn'/'dbscan'/'kde' or a "
            "named CostModel (zero/knn/linear) instead"
        ) from None


def _cost_from_spec(spec, rows: int):
    if spec is None:
        return None
    if isinstance(spec, tuple):
        return spec[1]
    from repro.core.cost import knn_cost, linear_cost, zero_cost

    return {"zero": zero_cost, "knn": lambda: knn_cost(rows),
            "linear": lambda: linear_cost(rows)}[spec]()


# ------------------------------------------------------------- worker side


def _compute_probe(reps: int = 3) -> float:
    """Fixed CPU-bound probe (seconds): relative worker speed under its
    core pinning. numpy-only so it never touches the XLA jit cache."""
    import numpy as np

    rng = np.random.default_rng(0)
    a = rng.standard_normal((256, 64)).astype(np.float32)
    t0 = time.perf_counter()
    for _ in range(reps):
        np.linalg.svd(a, full_matrices=False)
    return time.perf_counter() - t0


def _serve_one(svc, msg):
    """Run one query through the worker's service; returns its ServeResult
    (query ids are remapped to the supervisor's)."""
    x = msg["x"]
    cost = _cost_from_spec(msg["cost"], x.shape[0])
    qid = svc.submit(
        x, msg["cfg"], cost, method=msg["method"],
        downstream=msg["downstream"],
        execute_downstream=msg.get("xds", False),
    )
    out = None
    for r in svc.run():
        if r.query_id == qid:
            out = r
    out.query_id = msg["qid"]
    return out


def _worker_main(argv: list[str]) -> None:
    """Fleet worker entry: serve framed queries over stdin/stdout."""
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fleet-worker", type=int, required=True)
    ap.add_argument("--incarnation", type=int, default=0)
    ap.add_argument("--cores", type=str, default="")
    ap.add_argument("--heartbeat-s", type=float, default=0.5)
    ap.add_argument("--failure-prob", type=float, default=0.0)
    ap.add_argument("--failure-seed", type=int, default=0)
    ap.add_argument("--slowdown-s", type=float, default=0.0)
    # test knob: delay echo replies only after the first N pings, so a link
    # can "degrade" after the startup profile completes (see
    # FleetSupervisor.worker_link_delays)
    ap.add_argument("--pong-delay-s", type=float, default=0.0)
    ap.add_argument("--pong-delay-after", type=int, default=0)
    ap.add_argument("--no-cache", action="store_true")
    args = ap.parse_args(argv)

    # the `-c` bootstrap pins affinity pre-import; re-apply for direct runs
    if args.cores and hasattr(os, "sched_setaffinity"):
        os.sched_setaffinity(0, {int(c) for c in args.cores.split(",")})

    # claim the real stdout for frames, then point fd 1 (and sys.stdout) at
    # stderr: a stray print anywhere below lands in the log, not the protocol
    out = os.fdopen(os.dup(1), "wb")
    os.dup2(2, 1)
    sys.stdout = sys.stderr
    inp = os.fdopen(os.dup(0), "rb")
    wlock = threading.Lock()

    def send(msg) -> None:
        with wlock:
            _send_frame(out, msg)

    # heavy imports AFTER affinity: numpy/XLA size their pools off the mask
    import numpy as np

    from repro.core.types import ReduceResult
    from repro.fault.faults import FailureInjector, NodeFailure
    from repro.serve_drop.delta import SubscribeQuery
    from repro.serve_drop.service import DropService, ServeResult

    svc = DropService(enable_cache=not args.no_cache)
    injector = (
        FailureInjector(args.failure_prob, seed=args.failure_seed)
        if args.failure_prob > 0
        else None
    )

    stop_hb = threading.Event()

    def heartbeat() -> None:
        while not stop_hb.wait(args.heartbeat_s):
            try:
                send({"t": "hb"})
            except OSError:
                return

    threading.Thread(target=heartbeat, daemon=True).start()
    send({"t": "ready", "pid": os.getpid(), "incarnation": args.incarnation})

    served = 0
    pings = 0
    # delta subscriptions homed on this worker: supervisor sid -> local sid.
    # Deltas are flushed after every message that can produce them and
    # forwarded as framed "delta" messages; the local service's sequence
    # numbers pass through unchanged (one worker owns a subscription for
    # its whole life — a worker death closes it at the supervisor).
    subs: dict[int, int] = {}

    def flush_subs() -> None:
        for sid, lid in list(subs.items()):
            for dlt in svc.poll_deltas(lid):
                send({"t": "delta", "sid": sid, "delta": dlt})

    def sub_error(sid: int, exc: BaseException) -> None:
        # seq=None: the supervisor stamps the next sequence number itself
        send({"t": "delta", "sid": sid, "delta": {
            "kind": "closed", "seq": None,
            "error": f"{type(exc).__name__}: {exc}",
        }})

    while True:
        msg = _recv_frame(inp)
        if msg is None or msg["t"] == "stop":
            break
        t = msg["t"]
        if t == "ping":  # link profiling: echo the payload back
            pings += 1
            if args.pong_delay_s > 0 and pings > args.pong_delay_after:
                time.sleep(args.pong_delay_s)  # simulated link degradation
            send({"t": "pong", "n": msg["n"], "blob": msg["blob"]})
        elif t == "prof":
            send({"t": "prof", "n": msg["n"], "seconds": _compute_probe()})
        elif t == "q":
            served += 1
            if injector is not None:
                try:
                    injector.maybe_fail(served)
                except NodeFailure:
                    os._exit(_INJECTED_EXIT)  # simulate a hard crash
            if args.slowdown_s > 0:
                time.sleep(args.slowdown_s)
            t0 = time.perf_counter()
            try:
                res = _serve_one(svc, msg)
            except Exception as exc:  # the query, not the worker, fails
                d = int(msg["x"].shape[1])
                res = ServeResult(
                    query_id=msg["qid"],
                    result=ReduceResult(
                        v=np.zeros((d, 0), np.float32),
                        mean=np.zeros(d, np.float32),
                        k=0, tlb_estimate=0.0, satisfied=False,
                        runtime_s=0.0, iterations=[], method=msg["method"],
                    ),
                    error=f"{type(exc).__name__}: {exc}",
                )
            send({"t": "res", "qid": msg["qid"], "res": res,
                  "serve_s": time.perf_counter() - t0})
            flush_subs()  # a query drain may also land pending delta work
        elif t == "sub":
            try:
                lid = svc.subscribe(SubscribeQuery(
                    x=msg["x"], cfg=msg["cfg"], method=msg["method"],
                    eps=msg["eps"], min_samples=msg["min_samples"],
                    bandwidth=msg["bandwidth"],
                    rotation_tol=msg["rotation_tol"],
                ))
                subs[msg["sid"]] = lid
                while svc.poll():
                    pass
            except Exception as exc:
                sub_error(msg["sid"], exc)
            flush_subs()
        elif t == "app":
            try:
                svc.append(subs[msg["sid"]], msg["x"])
                while svc.poll():
                    pass
            except Exception as exc:
                sub_error(msg["sid"], exc)
            flush_subs()
        elif t == "unsub":
            lid = subs.get(msg["sid"])
            if lid is not None:
                try:
                    svc.unsubscribe(lid)
                    while svc.poll():
                        pass
                except Exception:
                    pass  # supervisor already fabricated the closed delta
            flush_subs()
    stop_hb.set()
    os._exit(0)


# --------------------------------------------------------- supervisor side


@dataclass
class LinkProfile:
    """Fitted alpha/beta transfer-cost model for one supervisor->worker
    link: one-way seconds ~ alpha + beta * payload_bytes."""

    alpha_s: float = 1e-4
    beta_s_per_byte: float = 1e-9

    def seconds(self, nbytes: int) -> float:
        return self.alpha_s + self.beta_s_per_byte * float(nbytes)


@dataclass(eq=False)
class _FleetSub:
    """Supervisor-side record of one delta subscription (homed on one
    worker for life; a worker death closes it with an error delta)."""

    sid: int
    worker: int  # index of the home worker
    fp: str
    state: str = "pending"  # pending | live | closed
    next_seq: int = 0  # stamps supervisor-fabricated closed deltas
    deltas: deque = field(default_factory=deque)
    error: str | None = None


@dataclass(eq=False)
class _FleetQuery:
    qid: int
    x: object  # np.ndarray (float32, contiguous)
    cfg: object
    cost: object  # _cost_spec form
    method: str
    downstream: str | None
    fp: str
    t0: float  # submit time (ServeResult.wall_s baseline)
    nbytes: int
    execute_downstream: bool = False
    retries: int = 0
    dispatch_t: float = 0.0


class _Worker:
    """Supervisor-side handle for one worker slot (survives restarts)."""

    def __init__(self, index: int, cores: list[int] | None) -> None:
        self.index = index
        self.label = f"worker-{index}"
        self.cores = cores
        self.proc: subprocess.Popen | None = None
        self.state = "new"  # new|starting|ready|dead|restarting|lost
        self.incarnation = 0
        self.restarts = 0
        self.restart_due = 0.0
        self.last_seen = 0.0
        self.ready_evt = threading.Event()
        self.outbox: queue.Queue = queue.Queue()
        self.assigned: dict[int, _FleetQuery] = {}
        self.link = LinkProfile()
        self.probe_s: float | None = None
        self.speed = 1.0  # relative throughput (1.0 = fleet reference)
        self.served = 0
        self.straggler = None  # fault.StragglerMonitor, set by supervisor
        self.rpc: dict[int, tuple[threading.Event, dict]] = {}
        # link-profile freshness (reprofile age-out; see _maybe_reprofile)
        self.profiled_at = 0.0  # perf_counter of the last alpha/beta fit
        self.served_at_profile = 0  # w.served when that fit was taken
        self.reprofiling = False  # a background echo probe is in flight


class FleetSupervisor:
    """Process-per-slot serving fleet behind the ``DropService`` surface.

    ``workers`` processes are spawned (core-pinned on Linux), profiled, and
    supervised: crash -> requeue + restart, hang -> kill + restart, chaos
    injection via ``failure_prob``. Use it exactly like a service::

        with FleetSupervisor(workers=2) as fleet:
            qid = fleet.submit(x, cfg, downstream="knn")
            res = fleet.run()[0]            # or fleet.result(qid)

    or behind the async front-end: ``IngestFrontend(FleetSupervisor(...))``.
    """

    def __init__(
        self,
        workers: int = 2,
        *,
        restart_policy=None,
        heartbeat_s: float = 0.5,
        heartbeat_timeout_s: float | None = None,
        enable_worker_cache: bool = True,
        placement: str = "cost",  # "cost" (measured) or "rr" (sticky RR)
        rebalance_margin: float = 0.7,
        default_query_s: float = 0.05,
        max_query_retries: int = 2,
        profile: bool = True,
        reprofile_interval_s: float = 60.0,
        reprofile_after_serves: int = 256,
        pin_cores: bool = True,
        failure_prob: float = 0.0,
        failure_seed: int = 0,
        worker_slowdowns: list[float] | None = None,
        worker_link_delays: list[float] | None = None,
        link_delay_after_pings: int = 9,
        startup_timeout_s: float = 180.0,
    ) -> None:
        from repro.fault.faults import RestartPolicy, StragglerMonitor
        from repro.serve_drop.service import ServiceStats

        if placement not in ("cost", "rr"):
            raise ValueError(f"unknown placement {placement!r}")
        n = max(int(workers), 1)
        self.restart_policy = restart_policy or RestartPolicy(
            max_restarts=3, backoff_s=0.05, backoff_cap_s=5.0
        )
        self.heartbeat_s = heartbeat_s
        self.heartbeat_timeout_s = heartbeat_timeout_s or max(
            10.0, 20.0 * heartbeat_s
        )
        self.enable_worker_cache = enable_worker_cache
        self.placement = placement
        self.rebalance_margin = float(rebalance_margin)
        self.default_query_s = float(default_query_s)
        self.max_query_retries = int(max_query_retries)
        self.profile = profile
        # link-profile age-out: whichever trips first re-triggers the echo
        # probe (<=0 disables that trigger; profile=False disables both)
        self.reprofile_interval_s = float(reprofile_interval_s)
        self.reprofile_after_serves = int(reprofile_after_serves)
        self.failure_prob = float(failure_prob)
        self.failure_seed = int(failure_seed)
        self.worker_slowdowns = worker_slowdowns or []
        # test knobs: per-worker echo delay that kicks in only after the
        # first ``link_delay_after_pings`` pings — the default 9 equals the
        # startup probe's ping count (1 throwaway + 4 sizes x 2 reps), so
        # the link "degrades" right after its startup profile is taken
        self.worker_link_delays = worker_link_delays or []
        self.link_delay_after_pings = int(link_delay_after_pings)
        self.startup_timeout_s = startup_timeout_s
        self.stats = ServiceStats()
        self.on_result = None  # ingest hook, fired with no lock held
        self.on_delta = None  # delta hook, fired with no lock held
        self._subs: dict[int, _FleetSub] = {}
        self._next_sub_id = 0

        cores = self._core_partition(n) if pin_cores else [None] * n
        self._workers = [_Worker(i, cores[i]) for i in range(n)]
        for w in self._workers:
            w.straggler = StragglerMonitor()
        self._lock = threading.RLock()
        self._pending: deque[_FleetQuery] = deque()
        self._results: dict[int, object] = {}
        self._tenant_home: dict[str, int] = {}
        self._tenant_ref_s: dict[str, float] = {}
        self._next_id = 0
        self._next_nonce = 0
        self._rr = 0
        self._started = False
        self._stopping = False
        self._monitor: threading.Thread | None = None

    # ------------------------------------------------------------ lifecycle

    @property
    def devices(self) -> list[str]:
        """Worker labels (IngestFrontend sizes its drain pool off this)."""
        return [w.label for w in self._workers if w.state != "lost"]

    @staticmethod
    def _core_partition(n: int) -> list[list[int] | None]:
        """Strided core sets per worker: each worker's XLA client otherwise
        spawns an nproc-wide pool and N workers x nproc threads thrash. A
        single worker keeps the full mask (it IS the machine's share)."""
        if n == 1 or not hasattr(os, "sched_getaffinity"):
            return [None] * n
        cores = sorted(os.sched_getaffinity(0))
        return [cores[i::n] or cores for i in range(n)]

    def start(self) -> "FleetSupervisor":
        if self._started:
            return self
        self._started = True
        for w in self._workers:
            self._spawn(w)
        deadline = time.perf_counter() + self.startup_timeout_s
        for w in self._workers:
            while not w.ready_evt.wait(0.1):
                if w.proc is not None and w.proc.poll() is not None:
                    self.shutdown()
                    raise RuntimeError(
                        f"{w.label} exited during startup "
                        f"(exit {w.proc.returncode})"
                    )
                if time.perf_counter() > deadline:
                    self.shutdown()
                    raise RuntimeError(
                        f"{w.label} did not come up (see stderr)"
                    )
        if self.profile:
            for w in self._workers:
                try:
                    self._profile_worker(w)
                except (RuntimeError, TimeoutError):
                    pass  # died mid-profile: supervision restarts it; the
                    # default link/speed estimates hold until observed
            self._normalize_speeds()
        self._monitor = threading.Thread(
            target=self._monitor_loop, name="fleet-monitor", daemon=True
        )
        self._monitor.start()
        return self

    def _spawn(self, w: _Worker) -> None:
        """Launch one worker process and its reader/writer threads. The
        bootstrap pins cores before any heavy import."""
        argv = [
            "--fleet-worker", str(w.index),
            "--incarnation", str(w.incarnation),
            "--heartbeat-s", str(self.heartbeat_s),
        ]
        if w.cores:
            argv += ["--cores", ",".join(map(str, w.cores))]
        if not self.enable_worker_cache:
            argv += ["--no-cache"]
        if self.failure_prob > 0:
            argv += [
                "--failure-prob", str(self.failure_prob),
                "--failure-seed",
                str(self.failure_seed + 1000 * w.index + 17 * w.incarnation),
            ]
        if w.index < len(self.worker_slowdowns):
            argv += ["--slowdown-s", str(self.worker_slowdowns[w.index])]
        if w.index < len(self.worker_link_delays):
            argv += [
                "--pong-delay-s", str(self.worker_link_delays[w.index]),
                "--pong-delay-after", str(self.link_delay_after_pings),
            ]
        env = dict(os.environ)
        import repro

        src = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        w.proc = subprocess.Popen(
            [sys.executable, "-c", _WORKER_BOOT] + argv,
            env=env, stdin=subprocess.PIPE, stdout=subprocess.PIPE,
        )
        w.state = "starting"
        w.last_seen = time.perf_counter()
        w.ready_evt = threading.Event()
        w.outbox = queue.Queue()
        threading.Thread(
            target=self._write_loop, args=(w, w.proc),
            name=f"fleet-w{w.index}-tx", daemon=True,
        ).start()
        threading.Thread(
            target=self._read_loop, args=(w, w.proc),
            name=f"fleet-w{w.index}-rx", daemon=True,
        ).start()

    def shutdown(self, timeout_s: float = 10.0) -> None:
        """Stop workers and supervision. Pending/in-flight queries are NOT
        waited for — call ``run()`` (or drain via IngestFrontend) first."""
        self._stopping = True
        for w in self._workers:
            if w.proc is not None and w.proc.poll() is None:
                w.outbox.put({"t": "stop"})
            w.outbox.put(_STOP_WRITER)
        deadline = time.perf_counter() + timeout_s
        for w in self._workers:
            if w.proc is None:
                continue
            try:
                w.proc.wait(timeout=max(0.1, deadline - time.perf_counter()))
            except subprocess.TimeoutExpired:
                w.proc.kill()
                w.proc.wait()
        if self._monitor is not None:
            self._monitor.join(timeout=2.0)

    def __enter__(self) -> "FleetSupervisor":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # ------------------------------------------------------------ profiling

    def _rpc(self, w: _Worker, msg: dict, timeout_s: float = 30.0) -> dict:
        with self._lock:
            n = self._next_nonce
            self._next_nonce += 1
            evt, slot = threading.Event(), {}
            w.rpc[n] = (evt, slot)
        w.outbox.put({**msg, "n": n})
        if not evt.wait(timeout_s):
            with self._lock:
                w.rpc.pop(n, None)
            raise TimeoutError(f"{w.label}: no reply to {msg['t']}")
        reply = slot["msg"]
        if reply.get("t") == "dead":  # resolved by _handle_death
            raise RuntimeError(f"{w.label} died mid-{msg['t']}")
        return reply

    def _fit_link(self, w: _Worker, sizes: list[int], reps: int) -> None:
        """Fit and REPLACE the link's alpha/beta model from echo
        round-trips over growing payloads, stamping the profile fresh."""
        import numpy as np

        self._rpc(w, {"t": "ping", "blob": b""})  # throwaway: first-recv cost
        rtts = []
        for s in sizes:
            blob = b"\0" * s
            best = float("inf")
            for _ in range(reps):
                t0 = time.perf_counter()
                self._rpc(w, {"t": "ping", "blob": blob})
                best = min(best, time.perf_counter() - t0)
            rtts.append(best)
        beta, alpha = np.polyfit(np.asarray(sizes, float), np.asarray(rtts), 1)
        # one-way cost; clamp: tiny-noise fits can go (meaninglessly) negative
        with self._lock:
            w.link = LinkProfile(
                alpha_s=max(float(alpha) / 2.0, 1e-6),
                beta_s_per_byte=max(float(beta) / 2.0, 1e-12),
            )
            w.profiled_at = time.perf_counter()
            w.served_at_profile = w.served

    def _profile_worker(self, w: _Worker) -> None:
        """Startup profiling: the link's alpha/beta transfer model plus the
        worker's compute speed with a fixed probe (colossal-ai
        AlphaBetaProfiler-style, over pipes)."""
        self._fit_link(w, [1 << 10, 1 << 15, 1 << 18, 1 << 20], reps=2)
        w.probe_s = float(self._rpc(w, {"t": "prof"})["seconds"])

    def _maybe_reprofile(self, now: float) -> None:
        """Age out stale link profiles (supervision tick). A ready, IDLE
        worker whose fit is older than ``reprofile_interval_s`` or has
        ``reprofile_after_serves`` serves behind it gets a cheap echo probe
        on a background thread — queries never wait behind pings, and an
        idle worker's pipe carries nothing else, so the fit is clean.
        Compute speed is NOT re-probed: the serve-time EWMA tracks it."""
        if not self.profile:
            return
        for w in self._workers:
            with self._lock:
                stale_t = (
                    self.reprofile_interval_s > 0
                    and now - w.profiled_at > self.reprofile_interval_s
                )
                stale_n = (
                    self.reprofile_after_serves > 0
                    and w.served - w.served_at_profile
                    >= self.reprofile_after_serves
                )
                due = (
                    w.state == "ready"
                    and not w.reprofiling
                    and w.profiled_at > 0.0  # startup profile completed
                    and not w.assigned  # idle: stay off the hot path
                    and (stale_t or stale_n)
                )
                if due:
                    w.reprofiling = True
            if due:
                threading.Thread(
                    target=self._reprofile, args=(w,),
                    name=f"fleet-w{w.index}-reprofile", daemon=True,
                ).start()

    def _reprofile(self, w: _Worker) -> None:
        """One background link re-profile (cheaper than startup: one rep,
        no megabyte payload). A worker death mid-probe is absorbed — the
        supervision ladder owns restarts, and the old profile stands until
        a probe completes."""
        try:
            self._fit_link(w, [1 << 10, 1 << 15, 1 << 18], reps=1)
            with self._lock:
                self.stats.reprofiles += 1
        except (RuntimeError, TimeoutError):
            pass
        finally:
            with self._lock:
                w.reprofiling = False

    def _normalize_speeds(self) -> None:
        probed = [w.probe_s for w in self._workers if w.probe_s]
        if not probed:
            return
        ref = min(probed)
        for w in self._workers:
            if w.probe_s:
                w.speed = ref / w.probe_s

    # ------------------------------------------------------------- pipe I/O

    def _write_loop(self, w: _Worker, proc: subprocess.Popen) -> None:
        outbox = w.outbox  # bound to THIS incarnation (respawn swaps it)
        while True:
            item = outbox.get()
            if item is _STOP_WRITER:
                return
            try:
                _send_frame(proc.stdin, item)
            except (OSError, ValueError):
                return  # death is handled by the reader's EOF

    def _read_loop(self, w: _Worker, proc: subprocess.Popen) -> None:
        while True:
            try:
                msg = _recv_frame(proc.stdout)
            except Exception:
                msg = None
            if msg is None:
                break
            w.last_seen = time.perf_counter()
            t = msg.get("t")
            if t == "ready":
                with self._lock:
                    if proc is w.proc:
                        w.state = "ready"
                w.ready_evt.set()
            elif t == "res":
                self._commit_result(w, proc, msg)
            elif t == "delta":
                self._commit_delta(w, proc, msg)
            elif t in ("pong", "prof"):
                with self._lock:
                    pending = w.rpc.pop(msg.get("n"), None)
                if pending is not None:
                    pending[1]["msg"] = msg
                    pending[0].set()
            # "hb" needs nothing beyond the last_seen update above
        if not self._stopping:
            self._handle_death(w, proc, "pipe EOF")

    # ------------------------------------------------------------- results

    def _commit_result(self, w: _Worker, proc, msg: dict) -> None:
        qid = msg["qid"]
        with self._lock:
            fq = w.assigned.pop(qid, None) if proc is w.proc else None
            if fq is None or qid in self._results:
                return  # stale duplicate (query was requeued after a death)
            res = msg["res"]
            res.worker = w.label
            res.retries = fq.retries
            res.wall_s = time.perf_counter() - fq.t0
            self._results[qid] = res
            if res.error:
                self.stats.failures += 1
            if res.cache_hit:
                self.stats.cache_hits += 1
            if res.prefix_hit:
                self.stats.prefix_hits += 1
            if res.warm_started:
                self.stats.warm_starts += 1
            if res.suffix_update:
                self.stats.suffix_updates += 1
            iters = len(res.result.iterations)
            self.stats.iterations += iters
            self.stats.device_iterations[w.label] = (
                self.stats.device_iterations.get(w.label, 0) + max(1, iters)
            )
            self._observe_speed(w, fq, float(msg.get("serve_s", 0.0)))
        self._notify(qid)

    def _observe_speed(self, w: _Worker, fq: _FleetQuery, serve_s: float) -> None:
        """Online throughput tracking: serve times, normalized by the
        worker's current speed, maintain a per-tenant reference estimate;
        deviations from it re-estimate the worker's speed. Caller holds
        the lock."""
        if serve_s <= 0:
            return
        w.served += 1
        if w.straggler is not None and w.straggler.observe(w.served, serve_s):
            self.stats.straggler_flags += 1
        ref = self._tenant_ref_s.get(fq.fp)
        if ref is not None:
            obs = max(min(ref / serve_s, 20.0), 0.05)
            w.speed = 0.7 * w.speed + 0.3 * obs
        norm = serve_s * w.speed
        self._tenant_ref_s[fq.fp] = (
            norm if ref is None else 0.5 * ref + 0.5 * norm
        )

    def _notify(self, qid: int) -> None:
        cb = self.on_result
        if cb is not None:
            cb(qid)

    # ------------------------------------------------------------ placement

    def _live(self) -> list[_Worker]:
        return [w for w in self._workers if w.state == "ready"]

    def _cost(self, w: _Worker, fq: _FleetQuery) -> float:
        est = self._tenant_ref_s.get(fq.fp, self.default_query_s)
        return w.link.seconds(fq.nbytes) + (len(w.assigned) + 1) * est / max(
            w.speed, 1e-3
        )

    def _place(self, fq: _FleetQuery) -> _Worker | None:
        """Pick a worker for ``fq`` (None when none is live — the query
        waits in ``_pending`` for a restart). Caller holds the lock."""
        live = self._live()
        if not live:
            return None
        home_i = self._tenant_home.get(fq.fp)
        home = (
            self._workers[home_i]
            if home_i is not None and self._workers[home_i].state == "ready"
            else None
        )
        if self.placement == "rr":
            if home is None:
                home = live[self._rr % len(live)]
                self._rr += 1
                self._tenant_home[fq.fp] = home.index
            return home
        best = min(live, key=lambda w: (self._cost(w, fq), w.index))
        if home is None:
            self._tenant_home[fq.fp] = best.index
            return best
        if best is not home and self._cost(best, fq) < (
            self.rebalance_margin * self._cost(home, fq)
        ):
            # decisively cheaper elsewhere: move the tenant (it forfeits
            # the old home's warm cache, which the margin prices in)
            self.stats.rebalances += 1
            self._tenant_home[fq.fp] = best.index
            return best
        return home

    def _dispatch(self, fq: _FleetQuery, w: _Worker) -> None:
        """Hand a query to a worker (caller holds the lock). The payload is
        framed by the worker's writer thread, so a full pipe never blocks
        the scheduler."""
        fq.dispatch_t = time.perf_counter()
        w.assigned[fq.qid] = fq
        w.outbox.put({
            "t": "q", "qid": fq.qid, "x": fq.x, "cfg": fq.cfg,
            "cost": fq.cost, "method": fq.method, "downstream": fq.downstream,
            "xds": fq.execute_downstream,
        })

    # -------------------------------------------------------------- intake

    def submit(
        self, x, cfg=None, cost=None, *, method: str = "pca",
        downstream: str | None = None, execute_downstream: bool = False,
    ) -> int:
        qid = self.try_submit(
            x, cfg, cost, method=method, downstream=downstream,
            execute_downstream=execute_downstream,
        )
        assert qid is not None  # unbounded submit never rejects
        return qid

    def try_submit(
        self, x, cfg=None, cost=None, *, method: str = "pca",
        downstream: str | None = None, execute_downstream: bool = False,
        max_backlog: int | None = None,
    ) -> int | None:
        """Enqueue unless the fleet backlog is at ``max_backlog`` (ingest
        backpressure). The conversion/hash work runs on the submitter's
        thread, like ``DropService.try_submit``."""
        import numpy as np

        from repro.core.types import DropConfig
        from repro.serve_drop.cache import dataset_fingerprint

        if not self._started:
            self.start()
        if execute_downstream and downstream is None:
            raise ValueError("execute_downstream requires a downstream task")
        x = np.ascontiguousarray(np.asarray(x), dtype=np.float32)
        cfg = cfg or DropConfig()
        spec = _cost_spec(cost)
        fp = dataset_fingerprint(x)
        with self._lock:
            if max_backlog is not None and self._backlog_locked() >= max_backlog:
                self.stats.rejected += 1
                return None
            qid = self._next_id
            self._next_id += 1
            self.stats.queries += 1
            fq = _FleetQuery(
                qid=qid, x=x, cfg=cfg, cost=spec, method=method,
                downstream=downstream, fp=fp, t0=time.perf_counter(),
                nbytes=int(x.nbytes), execute_downstream=execute_downstream,
            )
            w = self._place(fq)
            if w is None:
                self._pending.append(fq)
            else:
                self._dispatch(fq, w)
        return qid

    def _backlog_locked(self) -> int:
        return len(self._pending) + sum(
            len(w.assigned) for w in self._workers
        )

    def backlog(self) -> int:
        with self._lock:
            return self._backlog_locked()

    def take_result(self, qid: int):
        with self._lock:
            return self._results.pop(qid, None)

    def result(self, qid: int, timeout: float | None = None):
        """Block until query ``qid`` finishes (fault handling guarantees it
        does while any worker survives); raises TimeoutError otherwise."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        while True:
            res = self.take_result(qid)
            if res is not None:
                return res
            if deadline is not None and time.perf_counter() > deadline:
                raise TimeoutError(f"query {qid} still pending")
            time.sleep(0.002)

    # ------------------------------------------------------------- pub/sub

    def subscribe(self, query) -> int:
        """Open a delta subscription (``delta.SubscribeQuery``), homed on
        one worker for its whole life: the worker runs the full delta
        subsystem locally (tracker, incremental analytics) and streams
        framed ``delta`` messages back; the supervisor only routes. A home
        worker's death closes its subscriptions with an error delta — a
        delta consumer is never left hanging, same contract as queries."""
        import numpy as np

        from repro.serve_drop.cache import dataset_fingerprint
        from repro.serve_drop.delta import SubscribeQuery

        if not self._started:
            self.start()
        if not isinstance(query, SubscribeQuery):
            raise TypeError("fleet.subscribe takes a SubscribeQuery")
        x = np.ascontiguousarray(np.asarray(query.x), dtype=np.float32)
        fp = dataset_fingerprint(x)
        with self._lock:
            live = self._live()
            if not live:
                raise RuntimeError("no live workers to home the subscription")
            home_i = self._tenant_home.get(fp)
            if home_i is not None and self._workers[home_i].state == "ready":
                w = self._workers[home_i]  # warm cache: same data, same home
            else:
                w = min(live, key=lambda c: (len(c.assigned), c.index))
                self._tenant_home[fp] = w.index
            sid = self._next_sub_id
            self._next_sub_id += 1
            self._subs[sid] = _FleetSub(sid=sid, worker=w.index, fp=fp)
            self.stats.subscriptions += 1
            w.outbox.put({
                "t": "sub", "sid": sid, "x": x, "cfg": query.cfg,
                "method": query.method, "eps": query.eps,
                "min_samples": query.min_samples,
                "bandwidth": query.bandwidth,
                "rotation_tol": query.rotation_tol,
            })
        return sid

    def append(self, sub_id: int, suffix) -> None:
        import numpy as np

        from repro.serve_drop.delta import SubscriptionClosed

        suffix = np.ascontiguousarray(np.asarray(suffix), dtype=np.float32)
        with self._lock:
            sub = self._subs.get(sub_id)
            if sub is None or sub.state == "closed":
                raise SubscriptionClosed(f"subscription {sub_id} is closed")
            self._workers[sub.worker].outbox.put(
                {"t": "app", "sid": sub_id, "x": suffix}
            )

    def poll_deltas(self, sub_id: int, max_n: int | None = None) -> list:
        with self._lock:
            sub = self._subs.get(sub_id)
            if sub is None:
                raise KeyError(f"unknown subscription {sub_id}")
            out: list = []
            while sub.deltas and (max_n is None or len(out) < max_n):
                out.append(sub.deltas.popleft())
            return out

    def unsubscribe(self, sub_id: int, *, force: bool = False) -> None:
        """Ask the home worker to close the subscription (its final
        ``closed`` delta flows back framed). ``force=True`` additionally
        fabricates the terminal delta NOW — late worker emissions for a
        closed sub are dropped — so drain paths terminate deterministically
        even when the home worker is wedged."""
        notify = False
        with self._lock:
            sub = self._subs.get(sub_id)
            if sub is None or sub.state == "closed":
                return
            w = self._workers[sub.worker]
            if w.state == "ready" and not force:
                w.outbox.put({"t": "unsub", "sid": sub_id})
            else:
                if w.state == "ready":
                    w.outbox.put({"t": "unsub", "sid": sub_id})
                self._close_sub(sub, None)
                notify = True
        if notify:
            self._notify_delta(sub_id)

    def live_subscriptions(self) -> list[int]:
        with self._lock:
            return [
                sid for sid, sub in self._subs.items()
                if sub.state != "closed"
            ]

    def _close_sub(self, sub: _FleetSub, error: str | None) -> None:
        """Fabricate the terminal delta (caller holds the lock)."""
        sub.deltas.append(
            {"kind": "closed", "seq": sub.next_seq, "error": error}
        )
        sub.next_seq += 1
        sub.state = "closed"
        sub.error = error

    def _notify_delta(self, sub_id: int) -> None:
        cb = self.on_delta
        if cb is not None:
            cb(sub_id)

    def _commit_delta(self, w: _Worker, proc, msg: dict) -> None:
        sid = msg["sid"]
        dlt = msg["delta"]
        with self._lock:
            sub = self._subs.get(sid)
            if sub is None or sub.state == "closed" or proc is not w.proc:
                return  # late emission for a closed/stale sub: drop it
            if dlt.get("seq") is None:  # worker-side failure path
                dlt["seq"] = sub.next_seq
            sub.next_seq = int(dlt["seq"]) + 1
            sub.deltas.append(dlt)
            kind = dlt.get("kind")
            if kind == "closed":
                sub.state = "closed"
                sub.error = dlt.get("error")
            else:
                if sub.state == "pending":
                    sub.state = "live"
                if kind == "append":
                    self.stats.delta_serves += 1
                elif kind == "rollback" and dlt.get("reason") != "subscribe":
                    self.stats.rollbacks += 1
        self._notify_delta(sid)

    # ---------------------------------------------------------- supervision

    def _monitor_loop(self) -> None:
        while not self._stopping:
            self._supervise_once()
            time.sleep(0.02)

    def _supervise_once(self) -> None:
        """One supervision tick: exitcode/heartbeat death checks, due
        restarts, and pending-query placement."""
        now = time.perf_counter()
        for w in self._workers:
            state, proc = w.state, w.proc
            if proc is None:
                continue
            if state in ("starting", "ready"):
                if proc.poll() is not None:
                    self._handle_death(w, proc, f"exit {proc.returncode}")
                elif (
                    state == "ready"
                    and now - w.last_seen > self.heartbeat_timeout_s
                ):
                    # alive but mute: kill so the pipe EOFs deterministically
                    proc.kill()
                    self._handle_death(w, proc, "heartbeat timeout")
            elif state == "restarting" and now >= w.restart_due:
                with self._lock:
                    if w.state != "restarting":
                        continue
                    w.incarnation += 1
                    self.stats.worker_restarts += 1
                    self._spawn(w)
        self._maybe_reprofile(now)
        self._flush_pending()

    def _flush_pending(self) -> None:
        with self._lock:
            while self._pending:
                fq = self._pending[0]
                w = self._place(fq)
                if w is None:
                    return
                self._pending.popleft()
                self._dispatch(fq, w)

    def _handle_death(self, w: _Worker, proc, why: str) -> None:
        """A worker died (or was killed as hung): requeue or fail its
        in-flight queries so no client ever hangs, then schedule the
        restart under the RestartPolicy. Subscriptions homed on the dead
        worker carry state a restart cannot recover (tracker + incremental
        analytics live in the dead process), so they close with an error
        delta — the subscriber re-subscribes and bootstraps fresh."""
        failed: list[int] = []
        dead_subs: list[int] = []
        with self._lock:
            if proc is not w.proc or w.state in ("dead", "restarting", "lost"):
                return
            w.state = "dead"
            self.stats.worker_deaths += 1
            w.outbox.put(_STOP_WRITER)
            for n, (evt, slot) in list(w.rpc.items()):
                slot["msg"] = {"t": "dead"}
                evt.set()
                w.rpc.pop(n, None)
            orphans = list(w.assigned.values())
            w.assigned.clear()
            exitcode = proc.poll()
            for sub in self._subs.values():
                if sub.worker == w.index and sub.state != "closed":
                    self._close_sub(
                        sub, f"{w.label} died ({why}, exit={exitcode})"
                    )
                    self.stats.failures += 1
                    dead_subs.append(sub.sid)
            for fq in orphans:
                if fq.qid in self._results:
                    continue
                fq.retries += 1
                self._tenant_home.pop(fq.fp, None)  # home is gone
                if fq.retries > self.max_query_retries:
                    failed.append(fq.qid)
                    self._fail_query(
                        fq,
                        f"{w.label} died ({why}, exit={exitcode}); "
                        f"{fq.retries - 1} retries exhausted",
                    )
                else:
                    self.stats.requeued_queries += 1
                    tgt = self._place(fq)
                    if tgt is None:
                        self._pending.append(fq)
                    else:
                        self._dispatch(fq, tgt)
            if w.restarts >= self.restart_policy.max_restarts:
                w.state = "lost"
                self.stats.workers_lost += 1
                if not any(
                    x.state in ("starting", "ready", "restarting", "dead")
                    for x in self._workers
                ):
                    # nobody left to restart: fail the stranded backlog
                    while self._pending:
                        fq = self._pending.popleft()
                        failed.append(fq.qid)
                        self._fail_query(fq, "no workers left in the fleet")
            else:
                w.restarts += 1
                w.state = "restarting"
                w.restart_due = time.perf_counter() + self.restart_policy.delay(
                    w.restarts
                )
        for qid in failed:
            self._notify(qid)
        for sid in dead_subs:
            self._notify_delta(sid)

    def _fail_query(self, fq: _FleetQuery, error: str) -> None:
        """Finish a query with ServeResult.error (caller holds the lock)."""
        import numpy as np

        from repro.core.types import ReduceResult
        from repro.serve_drop.service import ServeResult

        d = int(fq.x.shape[1])
        self.stats.failures += 1
        self._results[fq.qid] = ServeResult(
            query_id=fq.qid,
            result=ReduceResult(
                v=np.zeros((d, 0), np.float32), mean=np.zeros(d, np.float32),
                k=0, tlb_estimate=0.0, satisfied=False, runtime_s=0.0,
                iterations=[], method=fq.method,
            ),
            wall_s=time.perf_counter() - fq.t0,
            error=error,
            retries=fq.retries,
        )

    # ------------------------------------------------------------ draining

    def _poll_once(self) -> tuple[bool, bool]:
        """Scheduler-primitive shim for ``IngestFrontend``: results arrive
        on reader threads, so a tick only supervises; (False, more)."""
        self._supervise_once()
        return False, self.backlog() > 0

    def poll(self) -> bool:
        """One supervision tick; True while queries are pending. Sleeps a
        moment so bare ``while poll(): pass`` loops don't busy-spin."""
        _, more = self._poll_once()
        if more:
            time.sleep(0.002)
        return more

    def run(self, timeout: float | None = None) -> list:
        """Drain everything submitted so far; results ordered by query id
        (the ``DropService.run`` contract)."""
        if not self._started:
            self.start()
        deadline = None if timeout is None else time.perf_counter() + timeout
        while self.backlog():
            if deadline is not None and time.perf_counter() > deadline:
                raise TimeoutError(f"{self.backlog()} queries still pending")
            time.sleep(0.005)
        with self._lock:
            out = [self._results[qid] for qid in sorted(self._results)]
            self._results = {}
        return out

    # ------------------------------------------------------------ telemetry

    def occupancy(self) -> dict[str, int]:
        with self._lock:
            return {
                w.label: self.stats.device_iterations.get(w.label, 0)
                for w in self._workers
            }

    def link_profiles(self) -> dict[str, LinkProfile]:
        with self._lock:
            return {w.label: w.link for w in self._workers}

    def worker_speeds(self) -> dict[str, float]:
        with self._lock:
            return {w.label: w.speed for w in self._workers}


if __name__ == "__main__":  # direct worker entry (debugging aid)
    _worker_main(sys.argv[1:])
