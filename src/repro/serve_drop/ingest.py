"""Async ingest front-end: accept queries while the scheduler drains.

``DropService.run()`` is batch-shaped — submit everything, then drain. A
serving deployment instead sees an open stream of tenant queries, so this
module adds the thread/condition front-end the ROADMAP asks for:

* **drain threads** — ``start()`` spawns one drain thread per mesh device
  (``service.drain_width``: 1 for the single-host service, device count for
  the sharded one); each repeatedly executes the service's lock-protected
  scheduler primitive, sleeping on a condition while idle.
* **backpressure** — the service backlog (queued + in-flight) is bounded by
  ``queue_capacity``; a submit over the bound raises ``RetryLater`` carrying
  a ``retry_after_s`` hint estimated from recent query service times
  (reject-with-retry-after, never block-and-deadlock).
* **completion** — ``result(qid)`` blocks (with optional timeout) until the
  scheduler finishes that query; the service's ``on_result`` hook wakes
  waiters, so there is no polling of the results dict.

The frontend owns no scheduler state of its own: every admission, cache,
and placement decision stays in the service, so the sync and async paths
cannot diverge.
"""

from __future__ import annotations

import threading
import time
from collections import deque

import numpy as np

from repro.core.types import CostFn, DropConfig
from repro.serve_drop.delta import SubscribeQuery, SubscriptionClosed
from repro.serve_drop.service import DropService, ServeResult


class RetryLater(RuntimeError):
    """Backpressure rejection: the ingest queue is full. ``retry_after_s``
    estimates when capacity should free up."""

    def __init__(self, retry_after_s: float, backlog: int) -> None:
        super().__init__(
            f"ingest queue full ({backlog} queries pending); "
            f"retry after {retry_after_s:.3f}s"
        )
        self.retry_after_s = retry_after_s
        self.backlog = backlog


class IngestFrontend:
    """Thread-safe streaming front-end over a ``DropService``.

    Usage::

        with IngestFrontend(ShardedDropService(devices=4)) as fe:
            qid = fe.submit(x, cfg)          # may raise RetryLater
            res = fe.result(qid, timeout=30)
    """

    def __init__(
        self,
        service: DropService,
        *,
        queue_capacity: int = 64,
    ) -> None:
        self.service = service
        self.queue_capacity = max(int(queue_capacity), 1)
        self._wake = threading.Condition()  # drain threads sleep here
        self._done = threading.Condition()  # result() waiters sleep here
        self._delta = threading.Condition()  # next_delta() waiters sleep here
        self._stop = threading.Event()  # drain threads exit on this
        self._closing = threading.Event()  # submits reject on this first
        self._threads: list[threading.Thread] = []
        self._recent_walls: deque[float] = deque(maxlen=32)
        service.on_result = self._on_result
        if hasattr(service, "on_delta"):
            service.on_delta = self._on_delta

    # ------------------------------------------------------------ lifecycle

    @property
    def drain_width(self) -> int:
        """One drain thread per device; the base service has one device."""
        return len(getattr(self.service, "devices", [None]))

    def start(self) -> "IngestFrontend":
        if self._threads:
            return self
        self._stop.clear()
        self._closing.clear()
        self._threads = [
            threading.Thread(
                target=self._drain, name=f"drop-ingest-{i}", daemon=True
            )
            for i in range(self.drain_width)
        ]
        for t in self._threads:
            t.start()
        return self

    def close(
        self, drain: bool = True, progress_deadline_s: float = 30.0
    ) -> None:
        """Stop the drain threads; ``drain=True`` finishes accepted work
        first. New submits are rejected as soon as close() begins, and any
        straggler that raced past the closing check is drained synchronously
        at the end — an accepted query is never left without a scheduler.

        The backlog wait is bounded by a PROGRESS deadline, not a total
        one: as long as the backlog keeps shrinking we keep waiting, but a
        backlog that has not moved for ``progress_deadline_s`` (a wedged
        scheduler — e.g. every drain tick raising) is abandoned so close()
        always returns. Queries stranded that way stay unresolved in the
        service; ``stats.drain_failures`` records the ticks that raised.

        Live subscriptions terminate deterministically: close() requests an
        orderly unsubscribe up front (in-flight deltas still deliver, then
        the final ``closed``), and any subscription still live once the
        drain ends — including the wedged-scheduler path — is force-closed,
        so every subscriber sees a terminal delta and no ``next_delta``
        waiter is left stranded."""
        self._closing.set()  # reject new submits before waiting on backlog
        live_subs = getattr(self.service, "live_subscriptions", None)
        unsubscribe = getattr(self.service, "unsubscribe", None)
        if live_subs is not None and unsubscribe is not None:
            for sid in live_subs():
                # orderly: queued suffixes drop, in-flight work lands first
                unsubscribe(sid)
        if drain and self._threads:
            last = self.service.backlog()
            t_last = time.perf_counter()
            while True:
                backlog = self.service.backlog()
                if not backlog:
                    break
                if backlog < last:
                    last, t_last = backlog, time.perf_counter()
                elif time.perf_counter() - t_last > progress_deadline_s:
                    break  # no progress: a drain would wait forever
                time.sleep(0.002)
        self._stop.set()
        with self._wake:
            self._wake.notify_all()
        for t in self._threads:
            t.join()
        self._threads = []
        if drain:
            while self.service.backlog():  # straggler sweep (see docstring)
                try:
                    if not self.service.poll():
                        break
                except Exception:  # same containment as the drain loop
                    with self.service._lock:
                        self.service.stats.drain_failures += 1
                    break
        if live_subs is not None and unsubscribe is not None:
            for sid in live_subs():
                # still live after the drain (wedged scheduler, or drain
                # was False): force the terminal delta NOW — a stranded
                # in-flight emission is dropped by the closed state
                unsubscribe(sid, force=True)
        with self._delta:  # belt and braces: no waiter sleeps past close
            self._delta.notify_all()

    def __enter__(self) -> "IngestFrontend":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close(drain=exc == (None, None, None))

    # -------------------------------------------------------------- intake

    def submit(
        self,
        x: np.ndarray,
        cfg: DropConfig | None = None,
        cost: CostFn | None = None,
        *,
        method: str = "pca",
        downstream: str | None = None,
        execute_downstream: bool = False,
    ) -> int:
        """Enqueue a query from any thread (any Reducer ``method``; the
        single-shot baselines are one-step runners to the scheduler).
        Raises ``RetryLater`` when the bounded queue is full (backpressure)
        or the frontend is closed. The capacity check is atomic with the
        enqueue (``try_submit``), so concurrent submitters can never
        jointly overshoot the bound."""
        if self._closing.is_set() or self._stop.is_set():
            backlog = self.service.backlog()
            raise RetryLater(self._retry_after(backlog), backlog)
        qid = self.service.try_submit(
            x, cfg, cost, method=method, downstream=downstream,
            execute_downstream=execute_downstream,
            max_backlog=self.queue_capacity,
        )
        if qid is None:
            backlog = self.service.backlog()
            raise RetryLater(self._retry_after(backlog), backlog)
        with self._wake:
            self._wake.notify_all()
        return qid

    def _retry_after(self, backlog: int) -> float:
        """Expected time for one slot to free: backlog / observed service
        rate, floored so clients never busy-spin."""
        if self._recent_walls:
            per_query = sum(self._recent_walls) / len(self._recent_walls)
        else:
            per_query = 0.05
        width = max(self.drain_width, 1)
        return max(0.005, per_query * max(backlog, 1) / width / 4)

    # -------------------------------------------------------------- pub/sub

    def subscribe(
        self,
        x: np.ndarray | SubscribeQuery,
        cfg: DropConfig | None = None,
        *,
        method: str = "pca",
        eps: float = 0.5,
        min_samples: int = 5,
        bandwidth: float = 1.0,
        rotation_tol: float = 0.25,
    ) -> int:
        """Open a delta subscription (``x`` may be a dataset or a prebuilt
        ``SubscribeQuery``). The first delta — a ``rollback`` with reason
        ``"subscribe"`` carrying the full bootstrap state — arrives via
        ``next_delta``/``poll_deltas`` once the scheduler serves the
        reduction. Raises ``RetryLater`` when the frontend is closing."""
        if self._closing.is_set() or self._stop.is_set():
            backlog = self.service.backlog()
            raise RetryLater(self._retry_after(backlog), backlog)
        if isinstance(x, SubscribeQuery):
            query = x
        else:
            query = SubscribeQuery(
                x=x, cfg=cfg or DropConfig(), method=method, eps=eps,
                min_samples=min_samples, bandwidth=bandwidth,
                rotation_tol=rotation_tol,
            )
        sid = self.service.subscribe(query)
        with self._wake:
            self._wake.notify_all()
        return sid

    def append(self, sub_id: int, suffix: np.ndarray) -> None:
        """Queue appended rows on a subscription from any thread; the
        resulting delta arrives asynchronously. Raises
        ``SubscriptionClosed`` once the subscription is terminal."""
        self.service.append(sub_id, suffix)
        with self._wake:
            self._wake.notify_all()

    def poll_deltas(self, sub_id: int, max_n: int | None = None) -> list:
        """Non-blocking: pop whatever deltas have been emitted (in order,
        at most once)."""
        return self.service.poll_deltas(sub_id, max_n=max_n)

    def next_delta(self, sub_id: int, timeout: float | None = None) -> dict:
        """Block until the subscription's next delta; the final ``closed``
        delta is delivered like any other, after which this raises
        ``SubscriptionClosed``. Raises TimeoutError on expiry."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._delta:
            while True:
                got = self.service.poll_deltas(sub_id, max_n=1)
                if got:
                    return got[0]
                if sub_id not in self.service.live_subscriptions():
                    raise SubscriptionClosed(
                        f"subscription {sub_id} is closed"
                    )
                remaining = (
                    None if deadline is None
                    else deadline - time.perf_counter()
                )
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(f"subscription {sub_id}: no delta")
                # like result(): _on_delta serializes behind _delta, so no
                # wakeup can be lost between the poll and the wait
                self._delta.wait(
                    timeout=0.05 if remaining is None else min(remaining, 0.05)
                )

    def unsubscribe(self, sub_id: int) -> None:
        self.service.unsubscribe(sub_id)

    def _on_delta(self, sub_id: int) -> None:
        with self._delta:
            self._delta.notify_all()

    # ------------------------------------------------------------- results

    def result(self, qid: int, timeout: float | None = None) -> ServeResult:
        """Block until query ``qid`` finishes; raises TimeoutError."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._done:
            while True:
                res = self.service.take_result(qid)
                if res is not None:
                    self._recent_walls.append(res.wall_s)
                    return res
                remaining = (
                    None if deadline is None else deadline - time.perf_counter()
                )
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(f"query {qid} still pending")
                # remaining=None waits until _on_result notifies — the hook
                # is serialized behind _done, so no wakeup can be lost
                self._done.wait(timeout=remaining)

    def _on_result(self, qid: int) -> None:
        with self._done:
            self._done.notify_all()

    # --------------------------------------------------------------- drain

    def _drain(self) -> None:
        while not self._stop.is_set():
            try:
                stepped, more = self.service._poll_once()
            except Exception:
                # An exception escaping the scheduler tick (the service
                # contains runner/validation/commit errors itself, so this
                # is an admission- or infrastructure-level failure) used to
                # kill this daemon thread silently — after which
                # close(drain=True) waited forever on a backlog nothing
                # would drain. Count it, yield, and keep the thread alive;
                # close()'s progress deadline bounds the truly wedged case.
                with self.service._lock:
                    self.service.stats.drain_failures += 1
                time.sleep(0.001)
                continue
            if stepped:
                continue
            if more:
                # placeable work exists but every runner is mid-step on
                # another drain thread — yield rather than spin
                time.sleep(0.0005)
                continue
            with self._wake:
                if not self._stop.is_set() and not self.service.backlog():
                    self._wake.wait(timeout=0.05)
