"""DropService: batched multi-query dimensionality reduction with reuse.

The service accepts many DR queries — each a ``ReduceQuery``: dataset +
method (any ``Reducer``: pca/fft/paa/dwt/jl) + target TLB + downstream cost
(a callable, or a named analytics task priced via ``core.cost``) — and
drives them through the shared device:

* **admission** — each query is fingerprinted and checked against the
  ``BasisReuseCache`` (keyed fingerprint × method × target). An exact hit
  is revalidated with a sampled TLB estimate on the live data (no fitting
  at all); an append-only stream whose PREFIX fingerprint matches a cached
  entry revalidates that entry on the grown data; a warm hit seeds the
  §3.4.3 rank bound of a cold PCA run; a miss runs cold.
* **suffix escalation** — a prefix-matched PCA entry that FAILS
  revalidation, or whose suffix exceeds ``suffix_budget`` (as a fraction
  of the fitted rows), is repaired by a ``_SuffixUpdate`` work item: an
  O(suffix) incremental subspace merge (``core.subspace``) TLB-gated on
  the grown data. Only when even the updated map cannot clear the target
  does the query fall to a cold refit — the service's most expensive
  operation becomes the last resort on append-only streams, not the
  default drift response.
* **scheduling** — cold runs are ``Reducer`` state machines built by
  ``make_reducer`` (DROP's multi-step Algorithm-2 loop for PCA; one-step
  runners for the deterministic baselines); the scheduler round-robins
  single steps across up to ``max_inflight`` runners, so a query that
  terminates after two cheap iterations frees its slot immediately instead
  of queueing behind a heavy tenant.
* **shape sharing** — all runners and validators quantize through one
  ``ShapeBucketCache``, so tenants with compatible shapes reuse each
  other's XLA executables (the jit cache is keyed by shape).

Per-query numerics are identical to the sequential ``reduce()``/``drop()``
APIs with the same config: every runner owns its RNG streams, and
interleaving never reorders any single query's draws.

Thread-safety: ``submit``, ``poll``, and ``take_result`` may be called from
different threads — one scheduler lock guards the queue, flight, cache, and
stats, while every unit of device compute (a runner iteration OR a cache-hit
revalidation) runs outside the lock, so ingest threads are never blocked
behind device compute. The ``on_result`` hook fires with no scheduler lock
held (waiters may re-enter ``take_result`` freely). A runner iteration that
raises is contained: the query finishes with ``ServeResult.error`` set and
the scheduler keeps draining the rest. ``ShardedDropService`` builds on
this by running one drain thread per mesh device, and ``serve_drop.ingest``
layers the bounded-queue async front-end on top.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core.bucketing import DEFAULT_BUCKETS, ShapeBucketCache
from repro.core.reducer import Reducer, make_reducer, method_cacheable
from repro.core.subspace import (
    TRACK_HEADROOM,
    SubspaceTracker,
    suffix_update as subspace_suffix_update,
)
from repro.core.tlb import TLBEstimator
from repro.core.types import CostFn, DropConfig, ReduceResult
from repro.serve_drop.cache import (
    BasisCacheEntry,
    BasisReuseCache,
    dataset_fingerprint,
)
from repro.serve_drop.delta import (
    APPEND,
    CLOSED,
    ROLLBACK,
    SubscribeQuery,
    SubscriptionClosed,
    _Subscription,
)


@dataclass
class ReduceQuery:
    """One tenant request: reduce ``x`` to the smallest TLB-preserving map
    with ``method``, priced against ``cost`` (or the named ``downstream``
    analytics task). ``DropQuery`` is the deprecated PCA-era alias."""

    query_id: int
    x: np.ndarray
    cfg: DropConfig
    cost: CostFn | None = None
    method: str = "pca"
    downstream: str | None = None  # provenance; cost resolved at submit()
    # run the named downstream analytics on the reduced data and attach the
    # output to ServeResult.downstream (the served end-to-end path); the
    # analytics execute as a scheduled work item like any device compute
    execute_downstream: bool = False
    fingerprint: str = ""  # computed once at submit()
    # rows -> fingerprint of x[:rows] for cached candidate prefix lengths,
    # hashed on the submitter's thread (append-only stream matching); best
    # effort — entries cached after submit() are not probed
    prefix_fps: dict = field(default_factory=dict)
    t0: float | None = None  # pinned at first dequeue (includes deferral time)


DropQuery = ReduceQuery  # deprecated alias (pre-Reducer-protocol name)


@dataclass
class ServeResult:
    query_id: int
    result: ReduceResult
    cache_hit: bool = False  # served straight from the basis cache
    prefix_hit: bool = False  # cache hit via append-only prefix fingerprint
    warm_started: bool = False  # cold run, but rank bound seeded from cache
    suffix_update: bool = False  # served by an incremental subspace update
    wall_s: float = 0.0
    error: str | None = None  # set when the query's runner raised mid-flight
    downstream: object = None  # executed analytics output (execute_downstream)
    downstream_s: float = 0.0  # analytics compute seconds (within wall_s)
    worker: str | None = None  # fleet mode: label of the worker that served it
    retries: int = 0  # fleet mode: re-dispatches after a worker death


@dataclass
class ServiceStats:
    queries: int = 0
    cache_hits: int = 0
    prefix_hits: int = 0  # subset of cache_hits served via prefix matching
    cache_misses: int = 0
    warm_starts: int = 0
    fit_calls: int = 0
    iterations: int = 0
    validation_pairs: int = 0
    suffix_updates: int = 0  # queries served by an incremental merge
    suffix_update_failures: int = 0  # updates that fell through (or raised)
    downstream_runs: int = 0  # served analytics executions (execute_downstream)
    downstream_failures: int = 0  # analytics executions that raised
    # delta-serving (serve_drop.delta): pub/sub subscription counters
    subscriptions: int = 0  # subscribe() calls accepted
    delta_serves: int = 0  # append deltas served (O(suffix) path)
    rollbacks: int = 0  # rollback deltas forced by appends (drift/headroom/refit)
    failures: int = 0  # queries finished with ServeResult.error set
    rejected: int = 0  # ingest backpressure rejections (reject-with-retry-after)
    steals: int = 0  # runners migrated to an idle device between rounds
    drain_failures: int = 0  # exceptions caught at the ingest drain loop
    # fleet mode (serve_drop.fleet): process-worker supervision counters
    worker_deaths: int = 0  # workers that died or were declared hung
    worker_restarts: int = 0  # restarts performed under the RestartPolicy
    workers_lost: int = 0  # workers past the restart budget (slot retired)
    requeued_queries: int = 0  # in-flight queries re-dispatched after a death
    rebalances: int = 0  # tenants moved to a measured-cheaper worker
    straggler_flags: int = 0  # worker serve times flagged by StragglerMonitor
    reprofiles: int = 0  # periodic link re-profiles (stale-profile age-out)
    effective_ttl: int | None = None  # live auto-tuned cache TTL (ticks)
    # per-device occupancy: device label -> iterations stepped there; the
    # single-host service books everything under "default"
    device_iterations: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return dict(self.__dict__)


@dataclass(eq=False)  # identity semantics: scheduler queues remove by object
class _InFlight:
    query: ReduceQuery
    runner: Reducer
    fingerprint: str
    warm_started: bool
    t0: float  # queue-pinned at first dequeue (includes deferral time)
    device: object = None  # mesh device the runner is placed on (sharded)


@dataclass(eq=False)
class _Validation:
    """A pending cache-hit revalidation: device compute, so it is scheduled
    like a runner iteration (outside the lock) instead of inside admission.
    Its fingerprint stays visible to the dedup check while it runs."""

    query: ReduceQuery
    entry: BasisCacheEntry
    fingerprint: str
    t0: float
    device: object = None  # mesh device to validate on (sharded)
    prefix: bool = False  # entry matched via prefix fingerprint (append)


@dataclass(eq=False)
class _SuffixUpdate:
    """A pending incremental subspace update for an append-only stream:
    merge the suffix into the cached updater state and TLB-gate the result
    on the grown data. Device compute, scheduled exactly like a
    ``_Validation`` (off-lock, fingerprint visible to dedup); a failed gate
    falls through to the cold-refit path, a raising update finishes the
    query with ``ServeResult.error`` instead of wedging the drain."""

    query: ReduceQuery
    entry: BasisCacheEntry
    fingerprint: str
    t0: float
    device: object = None  # mesh device to update on (sharded)


@dataclass(eq=False)
class _DeltaServe:
    """A pending delta computation for one subscription: either the
    bootstrap (the subscription's reduction finished — ``base`` holds it —
    and the initial transformed rows + downstream state must build) or an
    append (fold queued suffixes into the served state and emit an append
    or rollback delta). Device compute, scheduled through the validation
    deque like every other off-lock work item; at most ONE is in flight per
    subscription, so the delta chain is serialized and sequence numbers
    never race. A raising compute closes the subscription with an error
    delta — never wedging the drain."""

    sub: _Subscription
    kind: str  # "bootstrap" | "append"
    t0: float
    suffixes: list = field(default_factory=list)  # append: queued suffix rows
    base: ServeResult | None = None  # bootstrap: the finished reduction
    device: object = None  # mesh device to compute on (sharded)

    @property
    def fingerprint(self) -> str:
        # never dedup-matches a query (real fingerprints are sha1 hex), so
        # admission's `_fingerprint_inflight` short-circuits before touching
        # the `.query` attribute this item does not have
        return ""


@dataclass(eq=False)
class _Downstream:
    """A pending served-analytics execution: the query's reduction already
    finished (``base`` holds its committed ``ServeResult``) and the named
    downstream task now runs on the reduced data. Device compute, scheduled
    exactly like a ``_Validation`` (off-lock, counted in flight); a raising
    analytics run finishes the query with ``ServeResult.error`` set while
    KEEPING the reduction result — the map is still good."""

    query: ReduceQuery
    base: ServeResult
    t0: float
    device: object = None  # mesh device to run the analytics on (sharded)

    @property
    def fingerprint(self) -> str:  # dedup visibility, like the other items
        return self.query.fingerprint


class DropService:
    """Multi-tenant DROP scheduler with an LRU basis-reuse cache."""

    def __init__(
        self,
        *,
        max_inflight: int = 4,
        cache_entries: int = 16,
        bucket: ShapeBucketCache | None = None,
        enable_cache: bool = True,
        cache_ttl: int | None = None,
        cache_ttl_auto: bool = False,
        enable_suffix_update: bool = True,
        suffix_budget: float = 0.25,
        analytics_split: int | None = None,
        analytics_fanout: str = "xla",
        analytics_devices=None,
    ) -> None:
        self.max_inflight = max(int(max_inflight), 1)
        # served-analytics execution knobs (``analytics.split`` semantics):
        # split=N runs the downstream pairwise scan as N dataset shards,
        # fanout="mesh" fans them across analytics_devices — exact merges,
        # so the served output is independent of the decomposition
        self.analytics_split = analytics_split
        self.analytics_fanout = analytics_fanout
        self.analytics_devices = (
            None if analytics_devices is None else tuple(analytics_devices)
        )
        # append-only escalation knobs: a prefix-matched suffix larger than
        # suffix_budget * fitted rows skips revalidation (a map fitted that
        # many rows ago mostly buys a failed validation) and goes straight
        # to the incremental update; 0.0 means always update, and
        # enable_suffix_update=False restores the PR 3 revalidate-or-refit
        # behavior (no tracker state is kept either)
        self.enable_suffix_update = enable_suffix_update
        self.suffix_budget = float(suffix_budget)
        # share the process-wide buckets by default: plain drop() calls (e.g.
        # the CLI's jit warmup) and the service then compile the same shapes
        self.bucket = bucket or DEFAULT_BUCKETS
        self.cache = BasisReuseCache(
            capacity=cache_entries, ttl_ticks=cache_ttl, auto_ttl=cache_ttl_auto
        )
        self.enable_cache = enable_cache
        self.stats = ServiceStats(effective_ttl=self.cache.ttl_ticks)
        self._queue: deque[ReduceQuery] = deque()
        self._inflight: deque[_InFlight] = deque()
        self._validations: deque[_Validation] = deque()
        self._results: dict[int, ServeResult] = {}
        # query ids whose results became visible but have not been notified
        # yet (drained by the next _poll_once tick, under the lock)
        self._done_now: list[int] = []
        self._next_id = 0
        # one scheduler lock guards queue/flight/cache/results/stats; device
        # compute (steps AND revalidations) runs outside it so submit()
        # never waits behind the device
        self._lock = threading.RLock()
        # work currently executing outside the lock: counts toward
        # max_inflight and keeps its fingerprint visible to admission dedup
        self._stepping_now: list = []
        # ingest hook: called with each finished query id, with NO scheduler
        # lock held (a waiter may re-enter take_result from the callback)
        self.on_result: Callable[[int], None] | None = None
        # delta-serving state: subscriptions by id, plus the map from a
        # bootstrap ReduceQuery's id to its subscription (consumed by
        # _notify, which turns the finished reduction into the first delta)
        self._subs: dict[int, _Subscription] = {}
        self._sub_boot: dict[int, _Subscription] = {}
        self._next_sub_id = 0
        # delta hook: called with each subscription id that gained deltas,
        # with NO scheduler lock held (mirror of on_result)
        self.on_delta: Callable[[int], None] | None = None

    # ------------------------------------------------------------- intake

    def submit(
        self,
        x: np.ndarray,
        cfg: DropConfig | None = None,
        cost: CostFn | None = None,
        *,
        method: str = "pca",
        downstream: str | None = None,
        execute_downstream: bool = False,
    ) -> int:
        """Enqueue a query; returns its id (results keyed by it).

        ``method`` selects the Reducer (pca/fft/paa/dwt/jl); ``downstream``
        names an analytics task (knn/dbscan/kde) to price as the cost model
        when ``cost`` is not given explicitly. ``execute_downstream=True``
        additionally RUNS that task on the reduced data before the query
        finishes, attaching the output as ``ServeResult.downstream`` (the
        service's analytics knobs select the shard decomposition).

        Thread-safe: the fingerprint is hashed outside the scheduler lock, so
        concurrent submitters only serialize on the queue append."""
        qid = self.try_submit(
            x, cfg, cost, method=method, downstream=downstream,
            execute_downstream=execute_downstream,
        )
        assert qid is not None  # unbounded submit never rejects
        return qid

    def try_submit(
        self,
        x: np.ndarray,
        cfg: DropConfig | None = None,
        cost: CostFn | None = None,
        *,
        method: str = "pca",
        downstream: str | None = None,
        execute_downstream: bool = False,
        max_backlog: int | None = None,
    ) -> int | None:
        """Enqueue unless the backlog is at ``max_backlog``; returns the
        query id or None on rejection. The bound check and the append are
        one critical section, so concurrent submitters cannot jointly
        overshoot the bound (ingest backpressure relies on this).

        The O(m*d) float32/contiguity conversion AND all fingerprint hashing
        (full + candidate prefixes) happen HERE, on the submitter's thread
        outside the scheduler lock — the runner and the validation path then
        take zero-copy views, so admission under the lock never copies or
        hashes a tenant's dataset."""
        x = np.ascontiguousarray(np.asarray(x), dtype=np.float32)
        cfg = cfg or DropConfig()
        if execute_downstream and downstream is None:
            raise ValueError("execute_downstream requires a downstream task")
        fp = dataset_fingerprint(x)
        if cost is None and downstream is not None:
            from repro.core.cost import downstream_cost

            cost = downstream_cost(downstream, x.shape[0])
        prefix_fps: dict[int, str] = {}
        if self.enable_cache and method_cacheable(method):
            with self._lock:  # metadata scan only (no hashing under lock)
                counts = self.cache.prefix_row_counts(
                    x.shape[0], x.shape[1], cfg.target_tlb, method
                )
            prefix_fps = {r: dataset_fingerprint(x[:r]) for r in counts}
        with self._lock:
            if (
                max_backlog is not None
                and len(self._queue) + self._inflight_count() >= max_backlog
            ):
                self.stats.rejected += 1
                return None
            qid = self._next_id
            self._next_id += 1
            self._queue.append(
                ReduceQuery(query_id=qid, x=x, cfg=cfg, cost=cost,
                            method=method, downstream=downstream,
                            execute_downstream=execute_downstream,
                            fingerprint=fp, prefix_fps=prefix_fps)
            )
            self.stats.queries += 1
        return qid

    def backlog(self) -> int:
        """Queued + in-flight + mid-step queries (ingest backpressure gauge)."""
        with self._lock:
            return len(self._queue) + self._inflight_count()

    def take_result(self, qid: int) -> ServeResult | None:
        """Pop one finished result by query id (None while still pending)."""
        with self._lock:
            return self._results.pop(qid, None)

    # ------------------------------------------------------ delta serving

    def subscribe(self, query: SubscribeQuery) -> int:
        """Open a subscription: serve ``query.x`` once (a normal reduction
        through the scheduler) and then push deltas as appends arrive. The
        first delta is always a ``rollback`` with ``reason="subscribe"``
        carrying the bootstrap state. Returns the subscription id."""
        x = np.ascontiguousarray(np.asarray(query.x), dtype=np.float32)
        if x.ndim != 2:
            raise ValueError(f"expected (m, d) dataset, got shape {x.shape}")
        query.x = x
        with self._lock:
            sid = self._next_sub_id
            self._next_sub_id += 1
            sub = _Subscription(sub_id=sid, query=query, x=x)
            self._subs[sid] = sub
            self.stats.subscriptions += 1
            # submit + boot-map registration are one critical section (the
            # lock is reentrant), so a concurrent drain thread cannot finish
            # the bootstrap query before _notify knows it belongs to a sub
            qid = self.try_submit(x, query.cfg, None, method=query.method)
            sub.boot_qid = qid
            self._sub_boot[qid] = sub
        return sid

    def append(self, sub_id: int, suffix: np.ndarray) -> None:
        """Queue appended rows for a subscription. The scheduler folds them
        in as one delta (consecutive appends between ticks batch); the
        subscriber sees either an O(suffix) ``append`` delta or a
        ``rollback`` when the basis had to move."""
        suffix = np.ascontiguousarray(np.asarray(suffix), dtype=np.float32)
        with self._lock:
            sub = self._subs.get(sub_id)
            if sub is None or sub.state == "closed":
                raise SubscriptionClosed(f"subscription {sub_id} is closed")
            if suffix.ndim != 2 or suffix.shape[1] != sub.x.shape[1]:
                raise ValueError(
                    f"suffix shape {suffix.shape} does not extend a "
                    f"{sub.x.shape[1]}-dim subscription"
                )
            if suffix.shape[0] == 0:
                return
            sub.pending_suffixes.append(suffix)
            self._maybe_schedule_delta(sub)

    def poll_deltas(self, sub_id: int, max_n: int | None = None) -> list:
        """Pop emitted deltas for a subscription, in sequence order, at most
        once. Unknown ids raise KeyError (a closed-and-drained subscription
        stays known until the process ends — ids are never reused)."""
        with self._lock:
            sub = self._subs.get(sub_id)
            if sub is None:
                raise KeyError(f"unknown subscription {sub_id}")
            out: list = []
            while sub.deltas and (max_n is None or len(out) < max_n):
                out.append(sub.deltas.popleft())
            return out

    def unsubscribe(self, sub_id: int, *, force: bool = False) -> None:
        """Close a subscription: drops queued suffixes and emits a final
        ``closed`` delta. With work in flight the close is deferred until
        the flight lands (its delta still delivers, then the close) unless
        ``force=True``, which closes immediately and discards the in-flight
        emission — the drain path uses force so no subscription can hold
        ``close()`` hostage."""
        with self._lock:
            sub = self._subs.get(sub_id)
            if sub is None or sub.state == "closed":
                return
            sub.pending_suffixes.clear()
            if (sub.inflight or sub.state == "pending") and not force:
                sub.close_requested = True
                notify = None
            else:
                notify = self._emit(sub, {"kind": CLOSED, "error": None})
        self._fire_deltas([] if notify is None else [notify])

    def live_subscriptions(self) -> list[int]:
        with self._lock:
            return [
                sid for sid, sub in self._subs.items()
                if sub.state != "closed"
            ]

    def _maybe_schedule_delta(self, sub: _Subscription) -> None:
        """Schedule ONE append work item when the subscription is live, has
        queued suffixes, and nothing for it is in flight (the chain is
        strictly serial per subscription). Caller holds the lock."""
        if (
            sub.state != "live"
            or sub.inflight
            or sub.close_requested
            or not sub.pending_suffixes
        ):
            return
        item = _DeltaServe(
            sub=sub, kind="append", t0=time.perf_counter(),
            suffixes=list(sub.pending_suffixes),
        )
        sub.pending_suffixes.clear()
        sub.inflight = True
        self._place_validation(item)  # sharded: pick a device
        self._validations.append(item)

    def _emit(self, sub: _Subscription, delta: dict) -> int | None:
        """Sequence-stamp and queue one delta; returns the sub id to notify
        (None when the subscription already closed — emissions from a
        stranded in-flight item are dropped, preserving at-most-once with
        ``closed`` terminal). Caller holds the lock."""
        if sub.state == "closed":
            return None
        delta["seq"] = sub.seq
        sub.seq += 1
        sub.deltas.append(delta)
        if delta["kind"] == CLOSED:
            sub.state = "closed"
            sub.error = delta.get("error")
            sub.pending_suffixes.clear()
        return sub.sub_id

    def _fire_deltas(self, sub_ids: list[int]) -> None:
        """Fire the delta hook with no scheduler lock held (same lock-order
        contract as ``_notify``)."""
        if self.on_delta is not None:
            for sid in sub_ids:
                self.on_delta(sid)

    # ------------------------------------------------------ cache serving

    def _validation_bucket(self, val: _Validation) -> ShapeBucketCache:
        """Bucket cache for a validation's shapes (the sharded subclass
        returns the device class's cache, matching the fits on that class)."""
        return self.bucket

    def _validate(self, val: _Validation) -> tuple[bool, ReduceResult | None]:
        """Revalidate a cached basis on the live data: sampled TLB, no
        fit_basis call anywhere — this is the §5 reuse win. Device compute:
        runs OUTSIDE the scheduler lock, like a runner iteration."""
        q, entry = val.query, val.entry
        bucket = self._validation_bucket(val)
        tv = time.perf_counter()  # validation compute (excludes queue wait)
        # shared rank-bucket padding: hit shapes coincide with fit shapes
        v = bucket.pad_basis(entry.v, min(q.x.shape))
        est = TLBEstimator(
            np.ascontiguousarray(q.x, dtype=np.float32),
            jnp.asarray(v),
            np.random.default_rng(q.cfg.seed + 1),
            confidence=q.cfg.confidence,
            use_kernels=q.cfg.use_kernels,
            bucket=bucket,
        )
        e = est.estimate_at_k(
            entry.k,
            q.cfg.target_tlb,
            initial_pairs=q.cfg.initial_pairs,
            max_pairs=q.cfg.max_pairs,
        )
        with self._lock:
            self.stats.validation_pairs += e.pairs_used
        if e.mean < q.cfg.target_tlb:
            return False, None  # stale (near-repeat drifted): fall to cold
        # runtime_s stays compute-only (matching the cold path's semantics);
        # ServeResult.wall_s carries queue wait + deferral
        return True, ReduceResult(
            v=entry.v,
            mean=entry.mean,
            k=entry.k,
            tlb_estimate=e.mean,
            satisfied=True,
            runtime_s=time.perf_counter() - tv,
            iterations=[],
            method=entry.method,
        )

    # -------------------------------------------------------- scheduling

    def _admit(self) -> None:
        """Move queued queries into flight (cold runners) or into the
        validation queue (cache hits, revalidated outside the lock).

        A query whose dataset is already being fitted or validated in flight
        (same method) is deferred: when the running tenant finishes, its map
        lands in the cache and the deferred repeat is served by validation
        instead of a duplicate cold fit (the §5 reuse case under
        concurrency). Each admitted query advances the cache TTL clock by
        one tick, so a TTL counts serving decisions — independent of
        drain-thread count and of idle polling."""
        deferred: deque[ReduceQuery] = deque()
        while self._queue and self._inflight_count() < self.max_inflight:
            q = self._queue.popleft()
            if q.t0 is None:
                q.t0 = time.perf_counter()
            t0, fp = q.t0, q.fingerprint
            use_cache = self.enable_cache and method_cacheable(q.method)
            if use_cache and self._fingerprint_inflight(fp, q.method):
                deferred.append(q)
                continue
            self.cache.tick()
            if use_cache:
                entry = self.cache.get_exact(fp, q.cfg.target_tlb, q.method)
                prefix = False
                if entry is None:
                    # append-only stream: a cached map fitted on a prefix of
                    # this dataset (hashed at submit time) is revalidated on
                    # the grown data instead of refitting cold
                    entry = self.cache.find_prefix(
                        q.prefix_fps, q.cfg.target_tlb, q.method
                    )
                    prefix = entry is not None
                if entry is not None:
                    val = self._route_hit(q, entry, fp, t0, prefix)
                    self._place_validation(val)  # sharded: pick a device
                    self._validations.append(val)
                    continue
            self._launch_cold(q, fp, t0)
        self._queue.extendleft(reversed(deferred))  # keep submission order

    def _route_hit(self, q, entry, fp, t0, prefix):
        """Turn a cache hit into its work item. Normally a revalidation —
        but a prefix match whose suffix exceeds the drift budget skips it
        and goes straight to the incremental subspace update (revalidating
        a map that predates that much new data mostly buys a failed
        validation before the same update runs anyway)."""
        if prefix and self._suffix_updatable(q, entry):
            if q.x.shape[0] - entry.rows > self.suffix_budget * entry.rows:
                return _SuffixUpdate(q, entry, fp, t0)
        return _Validation(q, entry, fp, t0, prefix=prefix)

    def _suffix_updatable(self, q: ReduceQuery, entry: BasisCacheEntry) -> bool:
        """Whether ``entry`` carries updater state that can absorb this
        query's suffix (tracker rows must mark exactly the entry's prefix)."""
        return (
            self.enable_suffix_update
            and entry.tracker is not None
            and entry.tracker.rows == entry.rows
            and entry.method == q.method
        )

    def _place_validation(self, val) -> None:
        """Assign a device to a pending validation or suffix update (no-op
        on one device; the sharded subclass load-balances it like a runner)."""

    def _launch_cold(
        self,
        q: ReduceQuery,
        fp: str,
        t0: float,
        fallback_warm_k: int | None = None,
    ) -> None:
        """Warm-start bookkeeping + runner launch. ``fallback_warm_k``
        carries the rank of a prefix-matched entry that failed revalidation
        (the full-fingerprint lookup cannot see it). Caller holds the lock."""
        use_cache = self.enable_cache and method_cacheable(q.method)
        warm_k = (
            self.cache.get_warm_k(fp, q.cfg.target_tlb, q.method)
            if use_cache
            else None
        )
        if warm_k is None:
            warm_k = fallback_warm_k
        # misses count failed lookups, so only when the cache could have
        # served this query; a warm start is a warm start, not also a miss
        if warm_k is not None:
            self.stats.warm_starts += 1
        elif use_cache:
            self.stats.cache_misses += 1
        self._launch(q, fp, warm_k, t0)

    def _inflight_count(self) -> int:
        return (
            len(self._inflight)
            + len(self._validations)
            + len(self._stepping_now)
        )

    def _fingerprint_inflight(self, fp: str, method: str) -> bool:
        return any(
            fl.fingerprint == fp and fl.query.method == method
            for fl in self._iter_inflight()
        )

    def _iter_inflight(self):
        """All live work: placed runners (the sharded subclass adds
        per-device queues), queued validations, and anything mid-compute
        outside the lock."""
        yield from self._inflight
        yield from self._validations
        yield from self._stepping_now

    def _launch(
        self, q: ReduceQuery, fp: str, warm_k: int | None, t0: float
    ) -> None:
        """Build the method's Reducer and place it in flight. The sharded
        subclass overrides this to pick a mesh device and its per-class
        bucket."""
        runner = make_reducer(
            q.method, q.x, q.cfg, q.cost, warm_prev_k=warm_k,
            bucket=self.bucket,
        )
        self._inflight.append(
            _InFlight(q, runner, fp, warm_started=warm_k is not None, t0=t0)
        )

    def _commit(self, sr: ServeResult, q: ReduceQuery, t0: float) -> None:
        """Retire a query's reduction result: either finish it outright, or
        — when the query asked for executed analytics and the reduction
        produced a usable map — hold the result and schedule a
        ``_Downstream`` work item (off-lock device compute, load-balanced
        by the sharded subclass like any validation). Caller holds the
        lock; finished ids queue on ``_done_now`` for the tick to notify."""
        if (
            q.execute_downstream
            and q.downstream is not None
            and sr.error is None
        ):
            ds = _Downstream(q, sr, t0)
            self._place_validation(ds)  # sharded: pick a device
            self._validations.append(ds)
            return
        self._results[q.query_id] = sr
        self._done_now.append(q.query_id)

    def _finish(self, fl: _InFlight) -> None:
        res = fl.runner.result()
        self.stats.fit_calls += fl.runner.fit_calls
        self.stats.iterations += len(res.iterations)
        self._commit(
            ServeResult(
                query_id=fl.query.query_id,
                result=res,
                warm_started=fl.warm_started,
                wall_s=time.perf_counter() - fl.t0,
            ),
            fl.query,
            fl.t0,
        )
        if res.satisfied and self.enable_cache and fl.runner.cacheable:
            tracker = None
            if self.enable_suffix_update and getattr(
                fl.runner, "supports_update", False
            ):
                # memoized by the off-lock priming in _step; the guard
                # matches it — a failing bootstrap costs the entry its
                # incremental path, never the drain
                try:
                    tracker = fl.runner.tracker()
                except Exception:
                    tracker = None
            self.cache.put(
                fl.fingerprint,
                BasisCacheEntry(
                    v=res.v,
                    mean=res.mean,
                    k=res.k,
                    target_tlb=fl.query.cfg.target_tlb,
                    tlb_estimate=res.tlb_estimate,
                    satisfied=True,
                    method=fl.query.method,
                    rows=fl.query.x.shape[0],
                    tracker=tracker,
                ),
            )

    def _fail(self, fl: _InFlight, exc: BaseException) -> None:
        """A runner iteration raised: finish the query with the best basis
        found so far (or an empty one) and keep the scheduler alive. Caller
        holds the lock."""
        try:
            res = fl.runner.result()  # valid once one iteration completed
        except Exception:
            d = fl.query.x.shape[1]
            res = ReduceResult(
                v=np.zeros((d, 0), np.float32), mean=np.zeros(d, np.float32),
                k=0, tlb_estimate=0.0, satisfied=False, runtime_s=0.0,
                iterations=list(fl.runner.records), method=fl.query.method,
            )
        self.stats.failures += 1
        self.stats.fit_calls += fl.runner.fit_calls
        self.stats.iterations += len(res.iterations)  # steps it did complete
        self._results[fl.query.query_id] = ServeResult(
            query_id=fl.query.query_id,
            result=res,
            warm_started=fl.warm_started,
            wall_s=time.perf_counter() - fl.t0,
            error=f"{type(exc).__name__}: {exc}",
        )

    # ------------------------------------------------- scheduling primitives

    def _pop_runner(self) -> _InFlight | None:
        """Next runner to step (round-robin). Caller holds the lock."""
        return self._inflight.popleft() if self._inflight else None

    def _pop_work(self):
        """Next unit of device compute: pending revalidations and suffix
        updates first (they are short and serve a waiting tenant), else a
        runner iteration. Caller holds the lock."""
        if self._validations:
            return self._validations.popleft()
        return self._pop_runner()

    def _requeue_runner(self, fl: _InFlight) -> None:
        """Rotate a still-live runner back into flight. Caller holds the lock."""
        self._inflight.append(fl)

    def _discard_runner(self, fl: _InFlight) -> None:
        """Drop a runner from flight wherever it is queued (abandon path).
        Caller holds the lock."""
        try:
            self._inflight.remove(fl)
        except ValueError:
            pass

    def _step(self, fl: _InFlight) -> bool:
        """Run one iteration of ``fl`` outside the lock; returns liveness."""
        alive = fl.runner.step()
        if (
            not alive
            and self.enable_cache
            and self.enable_suffix_update
            and getattr(fl.runner, "supports_update", False)
        ):
            # prime the updater state here, off-lock: _finish (under the
            # scheduler lock) then attaches the memoized tracker for free.
            # Only satisfied results are cached, so an unsatisfiable query
            # must not pay the O(m·d·k) bootstrap for a tracker nobody keeps
            try:
                if fl.runner.result().satisfied:
                    fl.runner.tracker()
            except Exception:
                pass  # no best basis (all steps raised): nothing to track
        label = "default" if fl.device is None else str(fl.device)
        with self._lock:
            self.stats.device_iterations[label] = (
                self.stats.device_iterations.get(label, 0) + 1
            )
        return alive

    def _work_remains(self) -> bool:
        return bool(self._queue or self._inflight_count())

    def _notify(self, qids: list[int]) -> None:
        """Fire the ingest hook with no scheduler lock held (lock order is
        always hook-side-lock -> scheduler-lock, never the reverse).

        A finished query that bootstraps a subscription is consumed here
        instead of notified: its result becomes a scheduled ``_DeltaServe``
        (the subscribe rollback), so subscribers never see the internal
        query id."""
        for qid in qids:
            with self._lock:
                sub = self._sub_boot.pop(qid, None)
            if sub is not None:
                self._bootstrap_subscription(sub, qid)
                continue
            if self.on_result is not None:
                self.on_result(qid)

    def _bootstrap_subscription(self, sub: _Subscription, qid: int) -> None:
        """The subscription's reduction finished: schedule the bootstrap
        delta compute, or close the subscription if the reduction errored
        (or an unsubscribe won the race)."""
        sr = self.take_result(qid)
        notify: list[int] = []
        with self._lock:
            if sub.state == "closed":
                pass  # force-unsubscribed while bootstrapping: nothing to do
            elif sr is None or sr.error is not None:
                error = "bootstrap result missing" if sr is None else sr.error
                self.stats.failures += 1
                notify_id = self._emit(sub, {"kind": CLOSED, "error": error})
                if notify_id is not None:
                    notify.append(notify_id)
            else:
                item = _DeltaServe(
                    sub=sub, kind="bootstrap", t0=time.perf_counter(), base=sr
                )
                sub.inflight = True
                self._place_validation(item)  # sharded: pick a device
                self._validations.append(item)
        self._fire_deltas(notify)

    def _run_validation(self, val: _Validation, done: list[int]) -> None:
        """Execute one revalidation outside the lock and commit the verdict:
        a pass serves the cached map (a prefix match is additionally
        re-registered under the grown dataset's fingerprint — with the
        suffix folded into its updater state — so the stream's next append
        matches again), a failed PREFIX validation escalates to an
        incremental suffix update when the entry carries updater state, and
        only otherwise falls through to a cold launch (with warm-start
        bookkeeping; a failed prefix entry still seeds the warm rank
        bound). Verdicts feed the cache's TTL auto-tuner."""
        errored = False
        try:
            passed, result = self._validate(val)
        except Exception:
            # a broken entry must not serve — but an infrastructure error is
            # NOT a drift observation, so it stays out of the TTL tuner
            passed, result, errored = False, None, True
        q = val.query
        new_tracker = None
        if passed and val.prefix and self._suffix_updatable(q, val.entry):
            # fold the validated suffix into the updater state (pure merge:
            # the shared entry never mutates), still outside the lock, so
            # the stream's NEXT append keeps its incremental path
            try:
                new_tracker = val.entry.tracker.merge(
                    q.x[val.entry.rows :], val.entry.tracker.width
                )
            except Exception:
                new_tracker = None  # re-register without updater state
        with self._lock:
            self._stepping_now.remove(val)
            if not errored:
                self.cache.note_validation(passed)
            self.stats.effective_ttl = self.cache.ttl_ticks
            if passed:
                self.stats.cache_hits += 1
                if val.prefix:
                    self.stats.prefix_hits += 1
                    self.cache.put(
                        val.fingerprint,
                        BasisCacheEntry(
                            v=val.entry.v,
                            mean=val.entry.mean,
                            k=val.entry.k,
                            target_tlb=q.cfg.target_tlb,
                            tlb_estimate=result.tlb_estimate,
                            satisfied=True,
                            method=val.entry.method,
                            rows=q.x.shape[0],
                            tracker=new_tracker,
                        ),
                    )
                self._commit(
                    ServeResult(
                        query_id=q.query_id,
                        result=result,
                        cache_hit=True,
                        prefix_hit=val.prefix,
                        wall_s=time.perf_counter() - val.t0,
                    ),
                    q,
                    val.t0,
                )
            elif (
                not errored
                and val.prefix
                and self._suffix_updatable(q, val.entry)
            ):
                # drift observed on an append-only stream: repair the map
                # from the suffix before giving up on reuse entirely. An
                # ERRORED validation is different — a broken entry would
                # break the merge the same way, so it keeps the guaranteed
                # cold-refit fallback
                upd = _SuffixUpdate(q, val.entry, val.fingerprint, val.t0)
                self._place_validation(upd)
                self._validations.append(upd)
            else:
                self._launch_cold(
                    q, val.fingerprint, val.t0,
                    fallback_warm_k=(
                        val.entry.k
                        if val.prefix and val.entry.satisfied
                        else None
                    ),
                )

    def _apply_suffix_update(self, upd: _SuffixUpdate):
        """Device compute for one suffix update (outside the lock): merge
        the appended rows into the cached updater state and TLB-gate the
        smallest satisfying rank on the grown data. The sharded subclass
        wraps this in the work item's device scope."""
        return subspace_suffix_update(
            upd.entry.tracker,
            upd.query.x,
            upd.query.cfg,
            bucket=self._validation_bucket(upd),
        )

    def _run_suffix_update(self, upd: _SuffixUpdate, done: list[int]) -> None:
        """Execute one incremental subspace update outside the lock and
        commit: a TLB-satisfying merge serves the query and re-registers
        the cache entry (updated map + updater state) under the grown
        fingerprint; a failed gate falls through to the cold-refit last
        resort; an update that RAISES finishes the query with
        ``ServeResult.error`` set — never wedging the drain."""
        q = upd.query
        error, tracker, result, pairs = None, None, None, 0
        try:
            tracker, result, pairs = self._apply_suffix_update(upd)
        except Exception as exc:
            error = f"{type(exc).__name__}: {exc}"
        with self._lock:
            self._stepping_now.remove(upd)
            self.stats.validation_pairs += pairs
            if error is not None:
                self.stats.failures += 1
                self.stats.suffix_update_failures += 1
                d = q.x.shape[1]
                res = ReduceResult(
                    v=np.zeros((d, 0), np.float32),
                    mean=np.zeros(d, np.float32),
                    k=0, tlb_estimate=0.0, satisfied=False, runtime_s=0.0,
                    iterations=[], method=q.method,
                )
                self._results[q.query_id] = ServeResult(
                    query_id=q.query_id,
                    result=res,
                    wall_s=time.perf_counter() - upd.t0,
                    error=error,
                )
                done.append(q.query_id)
            elif result.satisfied:
                self.stats.suffix_updates += 1
                self.cache.put(
                    upd.fingerprint,
                    BasisCacheEntry(
                        v=result.v,
                        mean=result.mean,
                        k=result.k,
                        target_tlb=q.cfg.target_tlb,
                        tlb_estimate=result.tlb_estimate,
                        satisfied=True,
                        method=q.method,
                        rows=q.x.shape[0],
                        tracker=tracker,
                    ),
                )
                self._commit(
                    ServeResult(
                        query_id=q.query_id,
                        result=result,
                        suffix_update=True,
                        wall_s=time.perf_counter() - upd.t0,
                    ),
                    q,
                    upd.t0,
                )
            else:
                # the suffix outgrew the tracked headroom: cold refit is the
                # last resort, warm-started from the entry's known-good rank
                self.stats.suffix_update_failures += 1
                self._launch_cold(
                    q, upd.fingerprint, upd.t0,
                    fallback_warm_k=(
                        upd.entry.k if upd.entry.satisfied else None
                    ),
                )

    def _apply_downstream(self, ds: _Downstream):
        """Device compute for one served-analytics run (outside the lock):
        project the dataset through the finished map and execute the named
        task via the optimizer's registry — same code path, same analytics
        knobs (``split``/``fanout``/``devices``) as ``WorkloadOptimizer``.
        The sharded subclass wraps this in the work item's device scope (or
        lets the mesh fan-out claim the whole mesh)."""
        from repro.pipeline.optimizer import run_downstream

        xt = ds.base.result.transform(ds.query.x)
        return run_downstream(
            ds.query.downstream,
            xt,
            use_kernels=ds.query.cfg.use_kernels,
            split=self.analytics_split,
            fanout=self.analytics_fanout,
            devices=self.analytics_devices,
        )

    def _run_downstream(self, ds: _Downstream, done: list[int]) -> None:
        """Execute one served analytics task outside the lock and commit:
        the output lands on the ALREADY-FINISHED reduction result
        (``ServeResult.downstream``); a raising run sets
        ``ServeResult.error`` but keeps the map — the reduction itself
        succeeded, only the analytics leg failed."""
        t_ds = time.perf_counter()
        out, error = None, None
        try:
            out = self._apply_downstream(ds)
        except Exception as exc:
            error = f"downstream: {type(exc).__name__}: {exc}"
        downstream_s = time.perf_counter() - t_ds
        q = ds.query
        with self._lock:
            self._stepping_now.remove(ds)
            sr = ds.base
            sr.downstream = out
            sr.downstream_s = downstream_s
            sr.wall_s = time.perf_counter() - ds.t0
            if error is None:
                self.stats.downstream_runs += 1
            else:
                sr.error = error
                self.stats.downstream_failures += 1
                self.stats.failures += 1
            self._results[q.query_id] = sr
            done.append(q.query_id)

    def _revalidate_basis(
        self,
        grown: np.ndarray,
        served: ReduceResult,
        cfg: DropConfig,
        bucket: ShapeBucketCache,
    ) -> tuple[bool, int, float]:
        """Sampled TLB of the SERVED map on the grown data — the same gate
        ``_validate`` applies to cache hits, reused as the delta protocol's
        quality check. Returns (passed, pairs_used, tlb_mean)."""
        v = bucket.pad_basis(served.v, min(grown.shape))
        est = TLBEstimator(
            grown,
            jnp.asarray(v),
            np.random.default_rng(cfg.seed + 1),
            confidence=cfg.confidence,
            use_kernels=cfg.use_kernels,
            bucket=bucket,
        )
        e = est.estimate_at_k(
            served.k,
            cfg.target_tlb,
            initial_pairs=cfg.initial_pairs,
            max_pairs=cfg.max_pairs,
        )
        return e.mean >= cfg.target_tlb, e.pairs_used, float(e.mean)

    def _cold_refit_for(
        self,
        sub: _Subscription,
        grown: np.ndarray,
        served: ReduceResult,
        bucket: ShapeBucketCache,
    ) -> tuple[ReduceResult, object]:
        """Run a warm-started cold refit to completion for a subscription
        whose suffix outgrew every incremental path (the same last resort
        the request/response ladder ends in). Returns (result, tracker)."""
        sq = sub.query
        runner = make_reducer(
            sq.method, grown, sq.cfg, None,
            warm_prev_k=served.k if served.satisfied else None,
            bucket=bucket,
        )
        while runner.step():
            pass
        res = runner.result()
        tracker = None
        if self.enable_suffix_update and getattr(
            runner, "supports_update", False
        ):
            try:
                tracker = runner.tracker()
            except Exception:
                tracker = None
        with self._lock:
            self.stats.fit_calls += runner.fit_calls
            self.stats.iterations += len(res.iterations)
        return res, tracker

    def _apply_delta(self, item: _DeltaServe) -> tuple[dict, dict]:
        """Device compute for one delta (outside the lock): produce the
        delta dict plus the subscription-state updates the commit section
        applies under the lock. The sharded subclass wraps this in the work
        item's device scope."""
        if item.kind == "bootstrap":
            return self._delta_bootstrap(item)
        return self._delta_append(item)

    def _delta_bootstrap(self, item: _DeltaServe) -> tuple[dict, dict]:
        """Build the subscribe rollback: transform the dataset through the
        freshly served map, cold-build the incremental analytics state, and
        bootstrap the subspace tracker the append gate will merge into."""
        from repro.analytics.incremental import IncrementalAnalytics

        sub = item.sub
        sq = sub.query
        res = item.base.result
        xt = res.transform(sub.x)
        analytics = IncrementalAnalytics(
            xt,
            eps=sq.eps,
            min_samples=sq.min_samples,
            bandwidth=sq.bandwidth,
            bucket=self._validation_bucket(item),
        )
        snap = analytics.snapshot()
        tracker = None
        if (
            sq.method == "pca"
            and self.enable_suffix_update
            and res.v.shape[1] > 0
        ):
            try:
                tracker = SubspaceTracker.from_fit(sub.x, res.v)
            except Exception:
                tracker = None  # costs the sub its incremental basis path
        delta = {
            "kind": ROLLBACK,
            "reason": "subscribe",
            "basis": res,
            "rows": xt,
            "knn": {"idx": snap.knn_idx, "d2": snap.knn_d2},
            "labels": snap.labels,
            "densities": snap.densities,
            "tlb": res.tlb_estimate,
            "rotation": 0.0,
            "wall_s": time.perf_counter() - item.t0,
        }
        updates = {
            "x": sub.x,
            "result": res,
            "tracker": tracker,
            "analytics": analytics,
            "rollback": True,
            "pairs": 0,
            "cache_put": None,
        }
        return delta, updates

    def _delta_append(self, item: _DeltaServe) -> tuple[dict, dict]:
        """Fold queued suffixes into the served state through the delta
        escalation ladder:

        1. merge the suffix into the subspace tracker (pure, O(suffix)) and
           read the rotation signal against the SERVED basis;
        2. rotation stable -> TLB-revalidate the served map on the grown
           data (the PR 3 gate); a pass emits an ``append`` delta — suffix
           transform + incremental analytics, all O(s*m);
        3. gate failed -> O(suffix) TLB-gated suffix update
           (``core.subspace``), a satisfying merge emits a ``rollback``
           (reason drift/headroom: the basis moved, downstream rebuilt);
        4. even that unsatisfied -> warm cold refit, ``rollback`` with
           reason "refit" — the same last resort as the query ladder.

        The subscription's fields are read without the lock: only the delta
        chain mutates them and at most one item per sub is in flight."""
        sub = item.sub
        sq = sub.query
        served = sub.result
        suffix = (
            item.suffixes[0]
            if len(item.suffixes) == 1
            else np.concatenate(item.suffixes)
        )
        grown = np.concatenate([sub.x, suffix])
        bucket = self._validation_bucket(item)
        m_old = sub.x.shape[0]
        pairs = 0
        rot = 0.0
        merged = None
        stable = False
        tlb_mean = served.tlb_estimate
        if sub.tracker is not None:
            cap = max(
                1,
                min(
                    grown.shape[1], grown.shape[0],
                    sub.tracker.width + TRACK_HEADROOM,
                ),
            )
            merged = sub.tracker.merge(suffix, cap)
            rot = merged.rotation_from(served.v)
        if merged is None or rot <= sq.rotation_tol:
            stable, pairs, tlb_mean = self._revalidate_basis(
                grown, served, sq.cfg, bucket
            )
        cacheable = self.enable_cache and method_cacheable(sq.method)
        if stable:
            # O(suffix) append: old transformed rows stay valid (row-wise
            # transform => bit-identical to transforming the grown dataset),
            # downstream state folds the suffix in incrementally
            xt_suf = served.transform(suffix)
            patch = sub.analytics.append(xt_suf)
            snap = sub.analytics.snapshot()
            tracker_new = sub.tracker
            if merged is not None:
                keep = min(merged.width, served.k + TRACK_HEADROOM)
                tracker_new = SubspaceTracker(
                    v=np.ascontiguousarray(merged.v[:, :keep]),
                    s=np.ascontiguousarray(merged.s[:keep]),
                    mean=merged.mean,
                    rows=merged.rows,
                )
            delta = {
                "kind": APPEND,
                "base_rows": m_old,
                "rows": xt_suf,
                "knn": {
                    "changed": patch["changed"],
                    "idx": patch["idx"],
                    "d2": patch["d2"],
                    "append_idx": patch["append_idx"],
                    "append_d2": patch["append_d2"],
                },
                "labels": snap.labels,
                "densities": snap.densities,
                "tlb": tlb_mean,
                "rotation": rot,
                "wall_s": time.perf_counter() - item.t0,
            }
            cache_put = None
            if cacheable:
                # re-register the (still valid) map under the grown
                # fingerprint, updater state folded in: plain queries on
                # the same stream keep hitting the cache
                cache_put = (
                    dataset_fingerprint(grown),
                    BasisCacheEntry(
                        v=served.v, mean=served.mean, k=served.k,
                        target_tlb=sq.cfg.target_tlb,
                        tlb_estimate=tlb_mean, satisfied=True,
                        method=sq.method, rows=grown.shape[0],
                        tracker=tracker_new,
                    ),
                )
            updates = {
                "x": grown,
                "result": served,
                "tracker": tracker_new,
                "analytics": sub.analytics,
                "rollback": False,
                "pairs": pairs,
                "cache_put": cache_put,
            }
            return delta, updates
        # basis must move: escalate exactly like the query ladder
        res_new, tracker_new = None, None
        reason = "drift" if merged is not None and rot > sq.rotation_tol \
            else "headroom"
        if merged is not None:
            try:
                tracker_new, res2, p2 = subspace_suffix_update(
                    sub.tracker, grown, sq.cfg, bucket=bucket
                )
                pairs += p2
                if res2.satisfied:
                    res_new = res2
            except Exception:
                res_new, tracker_new = None, None
        if res_new is None:
            reason = "refit"
            res_new, tracker_new = self._cold_refit_for(
                sub, grown, served, bucket
            )
        xt = res_new.transform(grown)
        sub.analytics.rebuild(xt)
        snap = sub.analytics.snapshot()
        delta = {
            "kind": ROLLBACK,
            "reason": reason,
            "basis": res_new,
            "rows": xt,
            "knn": {"idx": snap.knn_idx, "d2": snap.knn_d2},
            "labels": snap.labels,
            "densities": snap.densities,
            "tlb": res_new.tlb_estimate,
            "rotation": rot,
            "wall_s": time.perf_counter() - item.t0,
        }
        cache_put = None
        if cacheable and res_new.satisfied:
            cache_put = (
                dataset_fingerprint(grown),
                BasisCacheEntry(
                    v=res_new.v, mean=res_new.mean, k=res_new.k,
                    target_tlb=sq.cfg.target_tlb,
                    tlb_estimate=res_new.tlb_estimate, satisfied=True,
                    method=sq.method, rows=grown.shape[0],
                    tracker=tracker_new,
                ),
            )
        updates = {
            "x": grown,
            "result": res_new,
            "tracker": tracker_new,
            "analytics": sub.analytics,
            "rollback": True,
            "pairs": pairs,
            "cache_put": cache_put,
        }
        return delta, updates

    def _run_delta(self, item: _DeltaServe, done: list[int]) -> None:
        """Execute one delta compute outside the lock and commit: apply the
        subscription-state updates, emit the delta (dropped if an
        unsubscribe closed the sub mid-flight), chain the next queued
        append, and honor a deferred close. A raising compute emits a final
        ``closed`` delta with the error — subscriptions fail loudly, never
        silently stall."""
        sub = item.sub
        delta, updates, error = None, None, None
        try:
            delta, updates = self._apply_delta(item)
        except Exception as exc:
            error = f"{type(exc).__name__}: {exc}"
        notify: list[int] = []
        with self._lock:
            self._stepping_now.remove(item)
            sub.inflight = False
            if error is not None:
                self.stats.failures += 1
                nid = self._emit(sub, {"kind": CLOSED, "error": error})
                if nid is not None:
                    notify.append(nid)
            elif sub.state != "closed":
                self.stats.validation_pairs += updates["pairs"]
                sub.x = updates["x"]
                sub.result = updates["result"]
                sub.tracker = updates["tracker"]
                sub.analytics = updates["analytics"]
                if sub.state == "pending":
                    sub.state = "live"
                if item.kind != "bootstrap":
                    if updates["rollback"]:
                        self.stats.rollbacks += 1
                    else:
                        self.stats.delta_serves += 1
                if updates["cache_put"] is not None:
                    self.cache.put(*updates["cache_put"])
                nid = self._emit(sub, delta)
                if nid is not None:
                    notify.append(nid)
                if sub.close_requested:
                    nid = self._emit(sub, {"kind": CLOSED, "error": None})
                    if nid is not None:
                        notify.append(nid)
                else:
                    self._maybe_schedule_delta(sub)
        self._fire_deltas(notify)

    def _poll_once(self) -> tuple[bool, bool]:
        """One scheduler tick. Returns (stepped, work_remains)."""
        with self._lock:
            self._admit()
            work = self._pop_work()
            if work is not None:
                self._stepping_now.append(work)
            more = self._work_remains()
        if work is None:
            return False, more
        done: list[int] = []
        try:
            if isinstance(work, _DeltaServe):
                self._run_delta(work, done)
            elif isinstance(work, _Downstream):
                self._run_downstream(work, done)
            elif isinstance(work, _SuffixUpdate):
                self._run_suffix_update(work, done)
            elif isinstance(work, _Validation):
                self._run_validation(work, done)
            else:
                try:
                    alive = self._step(work)  # device compute, off the lock
                except Exception as exc:
                    with self._lock:
                        self._stepping_now.remove(work)
                        self._fail(work, exc)
                    done.append(work.query.query_id)
                    alive = None
                if alive is not None:
                    with self._lock:
                        self._stepping_now.remove(work)
                        if alive:
                            self._requeue_runner(work)  # rotate: fair share
                        else:
                            self._finish(work)
        except Exception as exc:
            # containment of last resort: the per-path handlers above catch
            # COMPUTE errors, but a commit section (cache put, tracker merge
            # bookkeeping, stats) raising would otherwise escape into the
            # drain thread with the work item half-retired — the query then
            # never finishes and close(drain=True) waits on it forever.
            # Retire the item everywhere it could still be referenced and
            # finish its query with ServeResult.error.
            self._abandon(work, exc, done)
        with self._lock:
            # results committed via _commit (this tick's, or a concurrent
            # tick's not-yet-drained ones) become notifications here
            done.extend(self._done_now)
            self._done_now.clear()
            more = self._work_remains()
        self._notify(done)
        if done:
            # _notify may have SCHEDULED work (a finished bootstrap query
            # becomes a _DeltaServe item there) after `more` was computed —
            # re-check so run()/drain loops don't exit with it pending
            with self._lock:
                more = more or self._work_remains()
        return True, more

    def _abandon(self, work, exc: BaseException, done: list[int]) -> None:
        """Finish ``work``'s query with an error after a scheduler-side
        exception left it in an unknown state (see ``_poll_once``). The
        query is failed only if nothing else already produced its result."""
        if isinstance(work, _DeltaServe):
            # no query to fail: close the subscription with the error so a
            # blocked delta waiter wakes instead of waiting forever
            sub = work.sub
            notify: list[int] = []
            with self._lock:
                if work in self._stepping_now:
                    self._stepping_now.remove(work)
                self.stats.failures += 1
                sub.inflight = False
                nid = self._emit(
                    sub,
                    {
                        "kind": CLOSED,
                        "error": f"scheduler: {type(exc).__name__}: {exc}",
                    },
                )
                if nid is not None:
                    notify.append(nid)
            self._fire_deltas(notify)
            return
        q = work.query
        with self._lock:
            if work in self._stepping_now:
                self._stepping_now.remove(work)
            if isinstance(work, _InFlight):
                # a requeued runner that then raised in commit: pull it back
                # out so no thread steps a half-retired item
                self._discard_runner(work)
            if q.query_id in self._results:
                return  # the result was committed before the raise: keep it
            self.stats.failures += 1
            d = q.x.shape[1]
            self._results[q.query_id] = ServeResult(
                query_id=q.query_id,
                result=ReduceResult(
                    v=np.zeros((d, 0), np.float32),
                    mean=np.zeros(d, np.float32),
                    k=0, tlb_estimate=0.0, satisfied=False, runtime_s=0.0,
                    iterations=[], method=q.method,
                ),
                wall_s=time.perf_counter() - getattr(work, "t0", time.perf_counter()),
                error=f"scheduler: {type(exc).__name__}: {exc}",
            )
            done.append(q.query_id)

    def poll(self) -> bool:
        """One scheduler tick: admit, then run one unit of work — a pending
        cache revalidation or one iteration of the oldest in-flight runner
        (round-robin). Returns True while work remains. Thread-safe:
        concurrent pollers execute disjoint work items."""
        return self._poll_once()[1]

    def run(self) -> list[ServeResult]:
        """Drain all submitted queries; results ordered by query id."""
        while self.poll():
            pass
        return self._collect_results()

    def _collect_results(self) -> list[ServeResult]:
        with self._lock:
            out = [self._results[qid] for qid in sorted(self._results)]
            self._results = {}
        return out
