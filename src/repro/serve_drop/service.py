"""DropService: batched multi-query DROP with basis reuse.

The service accepts many DR queries (dataset + target TLB + downstream cost
function) and drives them through the shared device:

* **admission** — each query is fingerprinted and checked against the
  ``BasisReuseCache``. An exact hit is revalidated with a sampled TLB
  estimate on the live data (no fitting at all); a warm hit seeds the
  §3.4.3 rank bound of a cold run; a miss runs cold.
* **scheduling** — cold runs are ``DropRunner`` state machines; the
  scheduler round-robins single iterations across up to ``max_inflight``
  runners, so a query that terminates after two cheap iterations frees its
  slot immediately instead of queueing behind a heavy tenant.
* **shape sharing** — all runners and validators quantize through one
  ``ShapeBucketCache``, so tenants with compatible shapes reuse each
  other's XLA executables (the jit cache is keyed by shape).

Per-query numerics are identical to sequential ``drop()`` with the same
config: every runner owns its RNG streams, and interleaving never reorders
any single query's draws.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core.bucketing import DEFAULT_BUCKETS, ShapeBucketCache
from repro.core.drop import DropRunner
from repro.core.tlb import TLBEstimator
from repro.core.types import CostFn, DropConfig, DropResult
from repro.serve_drop.cache import (
    BasisCacheEntry,
    BasisReuseCache,
    dataset_fingerprint,
)


@dataclass
class DropQuery:
    """One tenant request: reduce ``x`` to the smallest TLB-preserving basis."""

    query_id: int
    x: np.ndarray
    cfg: DropConfig
    cost: CostFn | None = None
    fingerprint: str = ""  # computed once at submit()
    t0: float | None = None  # pinned at first dequeue (includes deferral time)


@dataclass
class ServeResult:
    query_id: int
    result: DropResult
    cache_hit: bool = False  # served straight from the basis cache
    warm_started: bool = False  # cold run, but rank bound seeded from cache
    wall_s: float = 0.0


@dataclass
class ServiceStats:
    queries: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    warm_starts: int = 0
    fit_calls: int = 0
    iterations: int = 0
    validation_pairs: int = 0

    def as_dict(self) -> dict:
        return dict(self.__dict__)


@dataclass
class _InFlight:
    query: DropQuery
    runner: DropRunner
    fingerprint: str
    warm_started: bool
    t0: float  # queue-pinned at first dequeue (includes deferral time)


class DropService:
    """Multi-tenant DROP scheduler with an LRU basis-reuse cache."""

    def __init__(
        self,
        *,
        max_inflight: int = 4,
        cache_entries: int = 16,
        bucket: ShapeBucketCache | None = None,
        enable_cache: bool = True,
    ) -> None:
        self.max_inflight = max(int(max_inflight), 1)
        # share the process-wide buckets by default: plain drop() calls (e.g.
        # the CLI's jit warmup) and the service then compile the same shapes
        self.bucket = bucket or DEFAULT_BUCKETS
        self.cache = BasisReuseCache(capacity=cache_entries)
        self.enable_cache = enable_cache
        self.stats = ServiceStats()
        self._queue: deque[DropQuery] = deque()
        self._inflight: deque[_InFlight] = deque()
        self._results: dict[int, ServeResult] = {}
        self._next_id = 0

    # ------------------------------------------------------------- intake

    def submit(
        self,
        x: np.ndarray,
        cfg: DropConfig | None = None,
        cost: CostFn | None = None,
    ) -> int:
        """Enqueue a query; returns its id (results keyed by it)."""
        qid = self._next_id
        self._next_id += 1
        x = np.asarray(x)
        self._queue.append(
            DropQuery(query_id=qid, x=x, cfg=cfg or DropConfig(), cost=cost,
                      fingerprint=dataset_fingerprint(x))
        )
        self.stats.queries += 1
        return qid

    # ------------------------------------------------------ cache serving

    def _try_cache(self, q: DropQuery, fp: str, t0: float) -> bool:
        """Serve ``q`` from the basis cache if a revalidated entry covers it."""
        entry = self.cache.get_exact(fp, q.cfg.target_tlb)
        if entry is None:
            return False
        tv = time.perf_counter()  # validation compute (excludes queue wait)
        # revalidate on the live data: sampled TLB of the cached basis. No
        # fit_basis call anywhere on this path — this is the §5 reuse win.
        # Zero-pad the basis to its rank bucket so the jitted TLB table keeps
        # the bucketed shapes of the fit path (zero columns never change the
        # entries the validation reads); min(m, d) mirrors the fit path's
        # hard cap so late-iteration fit shapes and hit shapes coincide.
        v = entry.v
        pad_w = self.bucket.bucket_rank(entry.k, min(q.x.shape))
        if pad_w > v.shape[1]:
            v = np.concatenate(
                [v, np.zeros((v.shape[0], pad_w - v.shape[1]), v.dtype)], axis=1
            )
        est = TLBEstimator(
            np.ascontiguousarray(q.x, dtype=np.float32),
            jnp.asarray(v),
            np.random.default_rng(q.cfg.seed + 1),
            confidence=q.cfg.confidence,
            use_kernels=q.cfg.use_kernels,
            bucket=self.bucket,
        )
        e = est.estimate_at_k(
            entry.k,
            q.cfg.target_tlb,
            initial_pairs=q.cfg.initial_pairs,
            max_pairs=q.cfg.max_pairs,
        )
        self.stats.validation_pairs += e.pairs_used
        if e.mean < q.cfg.target_tlb:
            return False  # stale (near-repeat data drifted): fall through to cold
        # runtime_s stays compute-only (matching the cold path's semantics);
        # ServeResult.wall_s carries queue wait + deferral
        result = DropResult(
            v=entry.v,
            mean=entry.mean,
            k=entry.k,
            tlb_estimate=e.mean,
            satisfied=True,
            runtime_s=time.perf_counter() - tv,
            iterations=[],
        )
        self._results[q.query_id] = ServeResult(
            query_id=q.query_id,
            result=result,
            cache_hit=True,
            wall_s=time.perf_counter() - t0,
        )
        self.stats.cache_hits += 1
        return True

    # -------------------------------------------------------- scheduling

    def _admit(self) -> None:
        """Move queued queries into flight (or serve them from cache).

        A query whose dataset is already being fitted in flight is deferred:
        when the running tenant finishes, its basis lands in the cache and
        the deferred repeat is served by validation instead of a duplicate
        cold fit (the §5 reuse case under concurrency)."""
        deferred: deque[DropQuery] = deque()
        while self._queue and len(self._inflight) < self.max_inflight:
            q = self._queue.popleft()
            if q.t0 is None:
                q.t0 = time.perf_counter()
            t0, fp = q.t0, q.fingerprint
            if self.enable_cache and any(
                fl.fingerprint == fp for fl in self._inflight
            ):
                deferred.append(q)
                continue
            if self.enable_cache and self._try_cache(q, fp, t0):
                continue
            warm_k = (
                self.cache.get_warm_k(fp, q.cfg.target_tlb)
                if self.enable_cache
                else None
            )
            # misses count failed lookups, so only when the cache is live;
            # a warm start is counted as a warm start, not also a miss
            if warm_k is not None:
                self.stats.warm_starts += 1
            elif self.enable_cache:
                self.stats.cache_misses += 1
            runner = DropRunner(
                q.x, q.cfg, q.cost, warm_prev_k=warm_k, bucket=self.bucket
            )
            self._inflight.append(
                _InFlight(q, runner, fp, warm_started=warm_k is not None, t0=t0)
            )
        self._queue.extendleft(reversed(deferred))  # keep submission order

    def _finish(self, fl: _InFlight) -> None:
        res = fl.runner.result()
        self.stats.fit_calls += fl.runner.fit_calls
        self.stats.iterations += len(res.iterations)
        self._results[fl.query.query_id] = ServeResult(
            query_id=fl.query.query_id,
            result=res,
            warm_started=fl.warm_started,
            wall_s=time.perf_counter() - fl.t0,
        )
        if res.satisfied and self.enable_cache:
            self.cache.put(
                fl.fingerprint,
                BasisCacheEntry(
                    v=res.v,
                    mean=res.mean,
                    k=res.k,
                    target_tlb=fl.query.cfg.target_tlb,
                    tlb_estimate=res.tlb_estimate,
                    satisfied=True,
                ),
            )

    def poll(self) -> bool:
        """One scheduler tick: admit, then run one iteration of the oldest
        in-flight runner (round-robin). Returns True while work remains."""
        self._admit()
        if not self._inflight:
            return bool(self._queue)
        fl = self._inflight.popleft()
        if fl.runner.step():
            self._inflight.append(fl)  # rotate: fair share of device time
        else:
            self._finish(fl)
        return bool(self._inflight or self._queue)

    def run(self) -> list[ServeResult]:
        """Drain all submitted queries; results ordered by query id."""
        while self.poll():
            pass
        out = [self._results[qid] for qid in sorted(self._results)]
        self._results = {}
        return out
