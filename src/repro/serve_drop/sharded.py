"""Sharded multi-device DROP scheduler.

Extends the single-host ``DropService`` by *placing* each in-flight
``Reducer`` on a mesh device (``jax.device_put`` of the runner's PRNG key
plus a ``jax.default_device`` scope around its steps, for the PCA loop;
the single-shot baseline reducers are host-numpy and placement is pure
bookkeeping), so independent tenants' iterations execute on independent
devices:

* **placement** — admission assigns each cold runner to the least-loaded
  device slot; the runner's jitted stages (Halko fit, pairwise TLB) then
  dispatch to that device only.
* **per-class bucket caches** — one ``ShapeBucketCache`` per device
  *class* (platform): tenants on the same class quantize through one
  policy, so same-device tenants reuse XLA executables (the jit cache is
  keyed by shape x device) while a heterogeneous mesh (cpu + tpu) keeps
  separate telemetry per class.
* **work stealing** — between ``poll()`` rounds, an idle device steals the
  youngest queued runner from the heaviest same-class slot (migration is a
  single ``place()`` call because inter-step runner state is host numpy).
* **threaded drain** — ``run()`` on a multi-device mesh spawns one drain
  thread per device; each thread executes the same lock-protected
  ``_poll_once`` primitive, so steps of different tenants overlap across
  devices while scheduling stays serialized. Python-side overhead shares
  the GIL, but XLA compilation and execution release it — on a cold
  multi-tenant workload (the expensive case) compile+compute parallelize
  across the mesh.

Numerics: per-query results are bit-identical to the single-device
``DropService`` (and to sequential ``drop()``) — every runner owns its RNG
streams, placement never reorders a query's draws, and same-class devices
execute identical programs.

With one visible device the scheduler degenerates exactly to the base
class (single slot, no threads), so CPU test environments run unchanged.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field

import jax

from repro.core.bucketing import ShapeBucketCache
from repro.core.reducer import make_reducer
from repro.serve_drop.service import DropService, ServeResult, _InFlight
from repro.sharding.specs import serve_devices


@dataclass
class _DeviceSlot:
    """One mesh device's run queue."""

    device: jax.Device
    runners: deque = field(default_factory=deque)

    @property
    def label(self) -> str:
        return str(self.device)


class ShardedDropService(DropService):
    """Multi-device DROP scheduler: per-device run queues + work stealing.

    ``devices`` may be an int (first n visible devices), an explicit device
    list, or None (every visible device). All other knobs match
    ``DropService``.
    """

    def __init__(
        self,
        *,
        devices: int | list | None = None,
        max_inflight: int = 4,
        cache_entries: int = 16,
        enable_cache: bool = True,
        cache_ttl: int | None = None,
        enable_suffix_update: bool = True,
        suffix_budget: float = 0.25,
        analytics_split: int | None = None,
        analytics_fanout: str | None = None,
    ) -> None:
        if isinstance(devices, int) or devices is None:
            devices = serve_devices(devices)
        devices = list(devices)
        # served analytics default to the mesh fan-out when a real mesh
        # exists (every device computes one dataset-shard partial of the
        # pairwise scan; exact merges — see analytics.split), and to the
        # single-device split otherwise
        if analytics_fanout is None:
            analytics_fanout = "mesh" if len(devices) > 1 else "xla"
        # one bucket cache per device class: same-class tenants share one
        # quantization policy (=> shared executables per device), while a
        # mixed mesh keeps per-class bucket telemetry honest
        self.class_buckets: dict[str, ShapeBucketCache] = {}
        for d in devices:
            self.class_buckets.setdefault(d.platform, ShapeBucketCache())
        first_class = devices[0].platform
        super().__init__(
            max_inflight=max_inflight,
            cache_entries=cache_entries,
            bucket=self.class_buckets[first_class],
            enable_cache=enable_cache,
            cache_ttl=cache_ttl,
            enable_suffix_update=enable_suffix_update,
            suffix_budget=suffix_budget,
            analytics_split=analytics_split,
            analytics_fanout=analytics_fanout,
            analytics_devices=(
                tuple(devices) if analytics_fanout == "mesh" else None
            ),
        )
        self.devices = devices
        self._slots = [_DeviceSlot(d) for d in devices]
        self._rr = 0  # round-robin cursor over slots for _pop_runner

    # -------------------------------------------------------- placement

    def _stepping_by_device(self) -> dict[str, int]:
        """Work owned by each device that is not in its run queue: items
        mid-compute outside the lock AND queued validations (both carry a
        device). Caller holds the lock."""
        counts: dict[str, int] = {}
        for fl in list(self._stepping_now) + list(self._validations):
            dev = getattr(fl, "device", None)
            if dev is not None:
                counts[str(dev)] = counts.get(str(dev), 0) + 1
        return counts

    def _load(self, slot: _DeviceSlot, stepping: dict[str, int]) -> int:
        """A device's live tenants: queued runners + its mid-step work +
        its queued validations. Placement and stealing share this
        accounting, so admissions never pile onto a device that merely
        LOOKS empty because its work is all mid-step, and a burst of
        cache-hit validations spreads across the mesh instead of landing
        on one 'idle' device."""
        return len(slot.runners) + stepping.get(slot.label, 0)

    def _least_loaded(self) -> _DeviceSlot:
        stepping = self._stepping_by_device()
        return min(self._slots, key=lambda s: self._load(s, stepping))

    def _launch(self, q, fp, warm_k, t0) -> None:
        """Admit a cold runner onto the least-loaded device slot."""
        slot = self._least_loaded()
        bucket = self.class_buckets[slot.device.platform]
        runner = make_reducer(
            q.method, q.x, q.cfg, q.cost, warm_prev_k=warm_k, bucket=bucket
        )
        runner.place(slot.device)
        fl = _InFlight(
            q, runner, fp, warm_started=warm_k is not None, t0=t0,
            device=slot.device,
        )
        slot.runners.append(fl)

    def _place_validation(self, val) -> None:
        """Validations are device compute too: load-balance them so a
        repeat-heavy workload does not turn device 0 into the hit-serving
        hotspot."""
        val.device = self._least_loaded().device

    def _validation_bucket(self, val):
        device = val.device or self.devices[0]
        return self.class_buckets[device.platform]

    def _validate(self, val):
        with jax.default_device(val.device or self.devices[0]):
            return super()._validate(val)

    def _apply_suffix_update(self, upd):
        # the merge itself is host numpy, but the TLB gate's jitted table
        # must land on the work item's device like any validation
        with jax.default_device(upd.device or self.devices[0]):
            return super()._apply_suffix_update(upd)

    def _apply_delta(self, item):
        # delta computes (transform + rectangular pairwise scans + TLB
        # gates) are device compute: pin them to the item's assigned device
        # so subscription traffic load-balances like validations do
        with jax.default_device(item.device or self.devices[0]):
            return super()._apply_delta(item)

    def _apply_downstream(self, ds):
        # mesh fan-out claims the whole mesh by construction (shard_map
        # places one dataset-shard partial per device), so the work item's
        # device assignment is bookkeeping only; a single-device analytics
        # run is pinned like any validation
        if self.analytics_fanout == "mesh":
            return super()._apply_downstream(ds)
        with jax.default_device(ds.device or self.devices[0]):
            return super()._apply_downstream(ds)

    def _slot_of(self, device) -> _DeviceSlot:
        return next(s for s in self._slots if s.device == device)

    # ------------------------------------------------------- scheduling

    def _inflight_count(self) -> int:
        return (
            sum(len(s.runners) for s in self._slots)
            + len(self._validations)
            + len(self._stepping_now)
        )

    def _iter_inflight(self):
        for s in self._slots:
            yield from s.runners
        yield from self._validations
        yield from self._stepping_now

    def _rebalance(self) -> None:
        """Work stealing: an idle device takes the youngest queued runner
        from the heaviest same-class device. "Idle" counts runners mid-step
        on other drain threads, so a device busy with its only tenant never
        triggers a migration ping-pong; a donor keeps at least one runner.
        The youngest runner has the most iterations left, so the migration
        cost (re-compiling its shapes on the new device, if unseen there)
        amortizes best. Caller holds the lock."""
        stepping = self._stepping_by_device()

        def load(s: _DeviceSlot) -> int:
            return self._load(s, stepping)

        for idle in self._slots:
            if load(idle) > 0:
                continue
            donors = [
                s
                for s in self._slots
                if s is not idle
                and len(s.runners) >= 1
                and load(s) >= 2
                and s.device.platform == idle.device.platform
            ]
            if not donors:
                continue
            donor = max(donors, key=load)
            fl = donor.runners.pop()  # youngest: admitted/rotated last
            fl.runner.place(idle.device)
            fl.device = idle.device
            idle.runners.append(fl)
            self.stats.steals += 1

    def _pop_runner(self) -> _InFlight | None:
        """Round-robin across device slots so concurrent pollers pick
        runners on distinct devices. Caller holds the lock."""
        self._rebalance()
        for off in range(len(self._slots)):
            slot = self._slots[(self._rr + off) % len(self._slots)]
            if slot.runners:
                self._rr = (self._rr + off + 1) % len(self._slots)
                return slot.runners.popleft()
        return None

    def _requeue_runner(self, fl: _InFlight) -> None:
        self._slot_of(fl.device).runners.append(fl)

    def _discard_runner(self, fl: _InFlight) -> None:
        for s in self._slots:
            try:
                s.runners.remove(fl)
                return
            except ValueError:
                continue

    def _step(self, fl: _InFlight) -> bool:
        # default_device routes the step's uncommitted arrays (TLB pair
        # batches, basis upload) to the runner's device; the committed PRNG
        # key already pins the Halko fit there. Occupancy bookkeeping lives
        # in the base _step (labelled by fl.device).
        with jax.default_device(fl.device):
            return super()._step(fl)

    # ----------------------------------------------------------- drain

    def run(self) -> list[ServeResult]:
        """Drain all submitted queries. On a multi-device mesh, one drain
        thread per device executes the shared scheduler primitive; results
        are ordered by query id either way."""
        if len(self.devices) == 1:
            return super().run()
        threads = [
            threading.Thread(target=self._drain, name=f"drop-drain-{i}")
            for i in range(len(self.devices))
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        return self._collect_results()

    def _drain(self) -> None:
        while True:
            stepped, more = self._poll_once()
            if not more:
                return
            if not stepped:
                # every placeable runner is mid-step on another thread:
                # yield briefly instead of spinning on the lock
                time.sleep(0.0005)

    def occupancy(self) -> dict[str, int]:
        """Iterations executed per device (scheduler balance telemetry)."""
        with self._lock:
            return {
                s.label: self.stats.device_iterations.get(s.label, 0)
                for s in self._slots
            }
