"""Sharding rules: logical activation/param layouts -> PartitionSpecs.

Strategy (DESIGN.md §5), uniform across all 10 archs on the prescribed meshes
(16,16)=("data","model") and (2,16,16)=("pod","data","model"):

* batch            -> ("pod","data")   [pure DP across pods]
* residual seq     -> "model"          [Megatron-style sequence parallelism]
* attention        -> query-seq sharded over "model" (sp_q), K/V gathered
* d_ff / vocab / SSD heads / expert-ffn width -> "model" (TP)
* params & optimizer state -> FSDP over "data", TP over "model", replicated
  over "pod" (keeps the slow inter-pod axis out of the all-gather path)
* decode KV cache  -> (batch -> "data", cache seq -> "model") + flash-decode

``ShardCtx`` carries the mesh; with mesh=None every constraint is a no-op so
the same model code runs in single-device smoke tests.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def serve_devices(n: int | None = None) -> list[jax.Device]:
    """Devices for the serve-layer tenant mesh (a 1-D "tenant" axis: each
    in-flight DROP runner is pinned to one device, so placement — not SPMD —
    is the unit of parallelism).

    ``n=None`` takes every visible device; otherwise the first ``n``,
    clamped to availability. Mirrors ``ShardCtx(mesh=None)`` fallback
    semantics: with one visible device the result is ``[default device]``
    and the sharded scheduler degenerates to the single-host path, so CPU
    tests run unchanged."""
    devices = jax.devices()
    if n is None:
        return list(devices)
    return list(devices)[: max(1, min(int(n), len(devices)))]


@dataclass
class ShardCtx:
    mesh: Mesh | None
    # axes already bound manually by an enclosing shard_map (e.g. "pod" in the
    # compressed-gradient path) — they must not appear in inner specs
    manual_axes: tuple[str, ...] = ()
    # §Perf iteration A1 (EXPERIMENTS.md): pin shardings on sublayer outputs
    # and TP intermediates so backward cotangents reduce-scatter instead of
    # all-reducing full activations. Default ON (validated win); settable to
    # False to reproduce the paper-faithful baseline measurements.
    tuned: bool = True
    # §Perf A8: select label logits with a one-hot contraction instead of
    # take_along_axis — gathers over vocab-sharded logits hit an XLA SPMD
    # partitioner assert inside partial-manual (pod) shard_maps.
    onehot_loss: bool = False

    @property
    def dp(self):  # the data-parallel axis bundle
        if self.mesh is None:
            return ()
        return tuple(a for a in dp_axes(self.mesh) if a not in self.manual_axes)

    def constrain(self, x: jax.Array, spec: P) -> jax.Array:
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    # --- logical activation layouts -------------------------------------
    def residual(self, x):  # (B, S, d): seq-parallel residual stream
        return self.constrain(x, P(self.dp, "model", None))

    def gathered(self, x):  # (B, S, d): sequence gathered (MLP/MoE/SSM entry)
        return self.constrain(x, P(self.dp, None, None))

    def ffn_hidden(self, x):  # (B, S, f): TP intermediate
        return self.constrain(x, P(self.dp, None, "model"))

    def kv_gathered(self, x):  # (B, Skv, KV, hd): replicated K/V for sp_q attn
        return self.constrain(x, P(self.dp, None, None, None))

    def heads_sharded(self, x):  # (B, S, H, P): SSD/attn heads on "model"
        return self.constrain(x, P(self.dp, None, "model", None))

    def logits(self, x):  # (B, S, V): vocab-TP logits
        return self.constrain(x, P(self.dp, None, "model"))

    def tokens(self, x):  # (B, S) int
        return self.constrain(x, P(self.dp, None))

    def kv_cache(self, x):  # (B, T, KV, hd): decode cache, seq on "model"
        return self.constrain(x, P(self.dp, "model", None, None))


# ---------------------------------------------------------------------------
# Parameter specs — resolved by leaf path name patterns
# ---------------------------------------------------------------------------

_PARAM_RULES: tuple[tuple[tuple[str, ...], P], ...] = (
    # embeddings / head: vocab-TP + FSDP
    (("embed",), P("model", "data")),
    (("lm_head",), P("data", "model")),
    # attention projections
    (("wq",), P("data", "model")),
    (("wk",), P("data", "model")),
    (("wv",), P("data", "model")),
    (("wo",), P("model", "data")),
    # dense MLP
    (("w_gate",), P("data", "model")),
    (("w_up",), P("data", "model")),
    (("w_down",), P("model", "data")),
    # MoE (leading expert dim; matched before the dense rules in _spec_for)
    (("moe", "w_gate"), P(None, "data", "model")),
    (("moe", "w_up"), P(None, "data", "model")),
    (("moe", "w_down"), P(None, "model", "data")),
    (("moe", "router"), P("data", None)),
    # mamba2
    (("in_proj",), P("data", "model")),
    (("out_proj",), P("model", "data")),
    (("conv_w",), P(None, "model")),
    (("conv_b",), P("model",)),
    (("norm_scale",), P("model",)),
)


def _spec_for(path: tuple[str, ...], ndim: int) -> P:
    """Match the most specific rule whose name parts all appear in the path
    (in order); pad with leading Nones for stacked-layer dims."""
    best: tuple[int, P] | None = None
    for names, spec in _PARAM_RULES:
        idx = 0
        for part in path:
            if idx < len(names) and names[idx] == part:
                idx += 1
        if idx == len(names):
            if best is None or len(names) > best[0]:
                best = (len(names), spec)
    if best is None:
        return P()  # replicate (norm scales, biases, scalars)
    spec = best[1]
    pad = ndim - len(spec)
    if pad < 0:  # rank-reduced leaf (e.g. smoke shapes) — replicate
        return P()
    return P(*([None] * pad), *spec)


def param_specs(params: Any, serve: bool = False) -> Any:
    """Pytree of PartitionSpecs matching a params pytree.

    ``serve=True`` (§Perf C3): drop the FSDP ("data") factor and keep TP only
    — decoding re-reads weights every token, so FSDP's per-token all-gathers
    dominate decode collectives; TP-only weights trade HBM for zero gathers."""

    def walk(path, leaf):
        names = tuple(
            p.key if hasattr(p, "key") else str(p)
            for p in path
        )
        ndim = getattr(leaf, "ndim", 0)
        spec = _spec_for(names, ndim)
        if serve:
            spec = P(*[None if ax == "data" else ax for ax in spec])
        return spec

    return jax.tree_util.tree_map_with_path(walk, params)


def param_shardings(params: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(params)
    )
