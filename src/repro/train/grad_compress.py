"""DROP-based low-rank gradient compression (beyond-paper integration).

The paper's insight — highly structured matrices admit aggressive sampling-
based PCA with a distance-preservation target — applies to gradient matrices
in large-scale training: per-layer gradients are famously low-rank (PowerSGD,
GaLore). Here DROP *discovers* the rank from a TLB-style preservation target
instead of fixing it a priori:

* every ``refresh_every`` steps, the host runs DROP on the (reshaped) gradient
  matrix of each compressible parameter -> basis V_i (c x r_i);
* between refreshes, the cross-POD all-reduce runs in the r-dim basis:
  psum(G V) V^T, cutting inter-pod bytes by r/c;
* PowerSGD-style error feedback accumulates the per-pod compression residual
  into the next step's gradient so the optimizer sees an unbiased long-run
  signal.

This targets the collective roofline term of multi-pod training (the "pod"
axis is the slow DCN/ICI link) — see EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class GradCompressConfig:
    target_tlb: float = 0.90  # distance preservation on gradient rows
    max_rank: int = 64
    min_cols: int = 256  # only compress matrices with >= this many columns
    refresh_every: int = 50


def compressible(path_names: tuple[str, ...], leaf) -> bool:
    """2D+ weight matrices only (never norms/scalars/embeddings)."""
    if getattr(leaf, "ndim", 0) < 2:
        return False
    if "embed" in path_names:  # embedding grads are sparse-ish; keep exact
        return False
    return True


def _as_matrix(g: jax.Array) -> jax.Array:
    """Collapse leading dims: (..., c) -> (r, c)."""
    return g.reshape(-1, g.shape[-1])


def discover_basis(
    grad_matrix: np.ndarray, cfg: GradCompressConfig, seed: int = 0
) -> np.ndarray | None:
    """Run DROP on gradient rows to find a TLB-preserving basis (host side).

    Returns V (c, r) with r <= max_rank, or None when DROP finds no useful
    compression (r too close to c)."""
    from repro.core import DropConfig, drop
    from repro.core.cost import zero_cost

    m, c = grad_matrix.shape
    if c < cfg.min_cols or m < 32:
        return None
    res = drop(
        grad_matrix.astype(np.float32),
        DropConfig(
            target_tlb=cfg.target_tlb,
            search="prefix",
            seed=seed,
            schedule=(0.05, 0.1, 0.25, 0.5),
            max_pairs=1600,
        ),
        cost=zero_cost(),
    )
    if not res.satisfied:
        return None  # gradients not low-rank enough at this TLB target
    r = min(res.k, cfg.max_rank)
    if r >= c // 2:  # not worth the two extra matmuls
        return None
    return np.asarray(res.v[:, :r], dtype=np.float32)


def compress_tree(grads: Any, bases: dict[str, jax.Array]) -> Any:
    """Project gradients into their DROP bases (identity where no basis)."""

    def fn(path, g):
        name = _path_key(path)
        v = bases.get(name)
        if v is None:
            return g
        gm = _as_matrix(g).astype(jnp.float32)
        return (gm @ v).astype(jnp.float32)  # (r_rows, r)

    return jax.tree_util.tree_map_with_path(fn, grads)


def expand_tree(compressed: Any, grads_like: Any, bases: dict[str, jax.Array]) -> Any:
    def fn(path, c, like):
        name = _path_key(path)
        v = bases.get(name)
        if v is None:
            return c
        return (c @ v.T).reshape(like.shape).astype(like.dtype)

    return jax.tree_util.tree_map_with_path(
        lambda p, c, l: fn(p, c, l), compressed, grads_like
    )


def compression_residual(grads: Any, bases: dict[str, jax.Array]) -> Any:
    """e = G - (G V) V^T, for error feedback."""

    def fn(path, g):
        name = _path_key(path)
        v = bases.get(name)
        if v is None:
            return jnp.zeros_like(g)
        gm = _as_matrix(g).astype(jnp.float32)
        approx = (gm @ v) @ v.T
        return (gm - approx).reshape(g.shape).astype(g.dtype)

    return jax.tree_util.tree_map_with_path(fn, grads)


def compressed_bytes_ratio(grads: Any, bases: dict[str, jax.Array]) -> float:
    """Fraction of all-reduce bytes remaining after compression."""
    total, kept = 0, 0
    for path, g in jax.tree_util.tree_leaves_with_path(grads):
        n = int(np.prod(g.shape))
        total += n
        v = bases.get(_path_key(path))
        if v is None:
            kept += n
        else:
            rows = n // g.shape[-1]
            kept += rows * v.shape[1]
    return kept / max(total, 1)


def _path_key(path) -> str:
    return "/".join(
        str(p.key) if hasattr(p, "key") else str(p) for p in path
    )


def refresh_bases(
    grads: Any, cfg: GradCompressConfig, seed: int = 0
) -> dict[str, jax.Array]:
    """Host-side DROP pass over every compressible gradient matrix."""
    bases: dict[str, jax.Array] = {}
    for i, (path, g) in enumerate(jax.tree_util.tree_leaves_with_path(grads)):
        names = tuple(str(p.key) if hasattr(p, "key") else str(p) for p in path)
        if not compressible(names, g):
            continue
        v = discover_basis(np.asarray(_as_matrix(g)), cfg, seed=seed + i)
        if v is not None:
            bases[_path_key(path)] = jnp.asarray(v)
    return bases
