"""AdamW + gradient clipping + LR schedules, implemented from scratch
(no optax in this environment — and the substrate must be self-contained).

Optimizer state mirrors the param pytree (same shapes => same shardings), so
FSDP sharding of params automatically shards the moments.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # "cosine" | "linear" | "constant"


def lr_at(step: jax.Array, cfg: OptimizerConfig) -> jax.Array:
    """Warmup + decay schedule (fp32 scalar)."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    if cfg.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = jnp.float32(1.0)
    return cfg.learning_rate * warm * decay


def init_optimizer(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(tree)
    ]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads
    ), norm


def adamw_update(
    params: Any, grads: Any, state: dict, cfg: OptimizerConfig
) -> tuple[Any, dict, dict]:
    """One AdamW step. Moments are fp32 regardless of param dtype."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    count = state["count"] + 1
    lr = lr_at(count, cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * g32 * g32
        mu_hat = mu / bc1
        nu_hat = nu / bc2
        step = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "mu": treedef.unflatten([o[1] for o in out]),
        "nu": treedef.unflatten([o[2] for o in out]),
        "count": count,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
