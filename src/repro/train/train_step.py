"""Distributed train step: loss -> grads -> AdamW, with microbatch gradient
accumulation, remat, and optional DROP gradient compression across pods.

The step is a pure function jitted with explicit in/out shardings by the
launcher (launch/train.py, launch/dryrun.py). Parallelism falls out of the
sharding specs: XLA inserts FSDP all-gathers around layer use, reduce-scatters
for grads over "data", all-reduce over "pod" — the latter optionally replaced
by the compressed shard_map psum below.
"""

from __future__ import annotations

import functools
from typing import Any

import jax

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig
from repro.models.model import loss_fn
from repro.sharding.specs import ShardCtx
from repro.train.optimizer import OptimizerConfig, adamw_update


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: OptimizerConfig,
    ctx: ShardCtx,
    remat: str = "full",
    microbatches: int = 1,
    compress_bases: dict | None = None,
):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def grads_of(params, batch):
        def loss_only(p, b):
            return loss_fn(p, b, cfg, ctx, remat=remat)

        if microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(loss_only, has_aux=True)(
                params, batch
            )
            return loss, metrics, grads

        # gradient accumulation: scan over microbatch splits, fp32 accumulator
        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        micro = jax.tree_util.tree_map(split, batch)
        zero = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

        def body(acc, mb):
            (loss, metrics), g = jax.value_and_grad(loss_only, has_aux=True)(
                params, mb
            )
            acc = jax.tree_util.tree_map(
                lambda a, x: a + x.astype(jnp.float32), acc, g
            )
            return acc, loss

        grads, losses = jax.lax.scan(body, zero, micro)
        grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
        loss = jnp.mean(losses)
        return loss, {"loss": loss}, grads

    def train_step(params, opt_state, batch):
        loss, metrics, grads = grads_of(params, batch)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg
        )
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return params, opt_state, metrics

    if compress_bases is None or ctx.mesh is None or "pod" not in (
        ctx.mesh.axis_names
    ):
        return train_step

    # ------------------------------------------------------------------
    # DROP-compressed cross-pod gradient reduction.
    #
    # The whole grad computation runs inside a shard_map that binds ONLY the
    # "pod" axis manually ("data"/"model" stay auto-sharded), so gradients
    # reaching this code are per-pod partial means. The pod all-reduce then
    # happens in the DROP-discovered low-rank basis: pmean(G V) V^T, cutting
    # inter-pod bytes to r/c of the dense reduce. Residuals (error feedback)
    # are returned per-pod for the trainer to fold into the next step.
    # NOTE: not supported for MoE families (nested shard_map in the MoE block
    # would re-bind "pod"); launchers enforce this.
    # ------------------------------------------------------------------
    from repro.train import grad_compress as gc

    mesh = ctx.mesh
    n_pods = mesh.devices.shape[list(mesh.axis_names).index("pod")]
    # XLA-CPU platform bug (verified by bisection; EXPERIMENTS.md §Perf A8):
    # with_sharding_constraint on auto axes INSIDE a partial-manual shard_map
    # aborts the SPMD partitioner. Inner model constraints are therefore
    # disabled here (mesh=None ctx); data/model sharding still propagates from
    # the jit-level in_shardings. On TPU builds the constraints can stay on.
    inner_ctx = ShardCtx(mesh=None)
    inner_ctx.onehot_loss = ctx.onehot_loss

    def per_pod(params_, batch_, residual_):
        residual_ = jax.tree_util.tree_map(lambda e: e[0], residual_)

        def loss_only(p, b):
            return loss_fn(p, b, cfg, inner_ctx, remat=remat)

        (loss, metrics), grads = jax.value_and_grad(loss_only, has_aux=True)(
            params_, batch_
        )
        # fold in last step's compression residual (error feedback)
        grads = jax.tree_util.tree_map(
            lambda g, e: g + e.astype(g.dtype), grads, residual_
        )

        def pod_mean(x):  # NB: lax.pmean trips an XLA-CPU AllReducePromotion
            return jax.lax.psum(x, "pod") / n_pods  # bug; psum+div is safe

        def reduce_leaf(path, g):
            v = compress_bases.get(gc._path_key(path))
            if v is None:
                return pod_mean(g), jnp.zeros_like(g)
            gm = g.reshape(-1, g.shape[-1]).astype(jnp.float32)
            low = gm @ v
            approx_local = (low @ v.T).reshape(g.shape).astype(g.dtype)
            reduced = (pod_mean(low) @ v.T).reshape(g.shape)
            return reduced.astype(g.dtype), g - approx_local

        paths = jax.tree_util.tree_leaves_with_path(grads)
        treedef = jax.tree_util.tree_structure(grads)
        pairs = [reduce_leaf(p, g) for p, g in paths]
        grads_red = jax.tree_util.tree_unflatten(treedef, [a for a, _ in pairs])
        new_resid = jax.tree_util.tree_unflatten(
            treedef, [b[None] for _, b in pairs]
        )
        loss = jax.lax.psum(loss, "pod") / n_pods
        metrics = jax.tree_util.tree_map(
            lambda m: jax.lax.psum(m, "pod") / n_pods, metrics
        )
        return loss, metrics, grads_red, new_resid

    def train_step_compressed(params, opt_state, batch, residual):
        loss, metrics, grads, residual = shard_map(
            per_pod,
            mesh=mesh,
            in_specs=(P(), jax.tree_util.tree_map(lambda _: P("pod"), batch), P("pod")),
            out_specs=(P(), P(), P(), P("pod")),
            axis_names={"pod"},
            check_vma=False,
        )(params, batch, residual)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg
        )
        return params, opt_state, {**metrics, **opt_metrics, "loss": loss}, residual

    return train_step_compressed


def init_compression_residual(params: Any, n_pods: int) -> Any:
    """Per-pod error-feedback buffers: leading pod dim, sharded over "pod"."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros((n_pods, *p.shape), jnp.float32), params
    )
