"""Trainer: the fault-tolerant training loop.

Wires together: token pipeline -> jitted train_step -> checkpoint every N
steps (atomic) -> failure injection/restart -> straggler monitoring ->
optional DROP gradient-compression basis refresh. This is the loop
examples/train_lm.py and the fault-tolerance tests drive.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import ModelConfig
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.fault.faults import FailureInjector, NodeFailure, StragglerMonitor
from repro.models.model import init_model
from repro.sharding.specs import ShardCtx
from repro.train.grad_compress import GradCompressConfig, refresh_bases
from repro.train.optimizer import OptimizerConfig, init_optimizer
from repro.train.train_step import make_train_step


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    log_every: int = 10
    microbatches: int = 1
    remat: str = "none"
    seed: int = 0
    failure_prob: float = 0.0  # failure injection for restart testing
    grad_compress: GradCompressConfig | None = None


@dataclass
class TrainerReport:
    steps_run: int = 0
    restarts: int = 0
    losses: list = field(default_factory=list)
    straggler_steps: list = field(default_factory=list)
    ckpt_steps: list = field(default_factory=list)


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        opt_cfg: OptimizerConfig,
        tcfg: TrainerConfig,
        ctx: ShardCtx | None = None,
        log: Callable[[str], None] = print,
    ):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.ctx = ctx or ShardCtx(mesh=None)
        self.log = log
        self.pipeline = TokenPipeline(
            TokenPipelineConfig(
                vocab_size=cfg.vocab_size,
                seq_len=None or self._seq_len(),
                global_batch=self._batch(),
                seed=tcfg.seed,
            )
        )
        self.injector = FailureInjector(tcfg.failure_prob, seed=tcfg.seed)
        self.monitor = StragglerMonitor()
        self.report = TrainerReport()
        self._bases: dict | None = None
        self._step_fn = None

    # small-model defaults; launchers override by building Trainer subclasses
    def _seq_len(self) -> int:
        return 128

    def _batch(self) -> int:
        return 8

    def _build_step(self):
        return jax.jit(
            make_train_step(
                self.cfg,
                self.opt_cfg,
                self.ctx,
                remat=self.tcfg.remat,
                microbatches=self.tcfg.microbatches,
                compress_bases=self._bases,
            )
        )

    def _init_state(self):
        params = init_model(self.cfg, jax.random.PRNGKey(self.tcfg.seed))
        return params, init_optimizer(params)

    def run(self) -> TrainerReport:
        """Train with restart-on-failure until total_steps."""
        tc = self.tcfg
        while True:
            try:
                self._run_from_checkpoint()
                return self.report
            except NodeFailure as e:
                self.report.restarts += 1
                self.log(f"[fault] {e} -> restarting from last checkpoint")
                if self.report.restarts > 50:
                    raise

    def _run_from_checkpoint(self):
        tc = self.tcfg
        params, opt_state = self._init_state()
        start = 0
        last = ckpt.latest_step(tc.ckpt_dir)
        if last is not None:
            (params, opt_state), start = ckpt.restore(
                tc.ckpt_dir, (params, opt_state)
            )
            self.log(f"[ckpt] restored step {start}")
        self._step_fn = self._build_step()

        step = start
        while step < tc.total_steps:
            batch = {
                k: jax.numpy.asarray(v) for k, v in self.pipeline.batch(step).items()
            }
            t0 = time.perf_counter()
            self.injector.maybe_fail(step)
            params, opt_state, metrics = self._step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            if self.monitor.observe(step, dt):
                self.report.straggler_steps.append(step)
                self.log(f"[straggler] step {step} took {dt:.2f}s")
            self.report.losses.append(loss)
            self.report.steps_run += 1
            step += 1

            if tc.grad_compress and step % tc.grad_compress.refresh_every == 0:
                self._refresh_compression(params, opt_state, batch)

            if step % tc.ckpt_every == 0 or step == tc.total_steps:
                ckpt.save(tc.ckpt_dir, step, (params, opt_state))
                ckpt.prune(tc.ckpt_dir, keep=tc.ckpt_keep)
                self.report.ckpt_steps.append(step)
            if step % tc.log_every == 0:
                self.log(f"step {step}: loss={loss:.4f} ({dt*1e3:.0f} ms)")

        self._final = (params, opt_state)

    def _refresh_compression(self, params, opt_state, batch):
        """Host-side DROP pass over current gradients -> new bases."""
        from repro.models.model import loss_fn

        grads = jax.grad(
            lambda p: loss_fn(p, batch, self.cfg, self.ctx, remat="none")[0]
        )(params)
        self._bases = refresh_bases(grads, self.tcfg.grad_compress)
        self._step_fn = self._build_step()
        self.log(f"[drop-compress] refreshed {len(self._bases)} bases")
