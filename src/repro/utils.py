"""Shared small utilities: timing, rng plumbing, tree helpers."""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np


class Clock:
    """Wall-clock timer matching the paper's CLOCK.RESTART / CLOCK.ELAPSED."""

    def __init__(self) -> None:
        self._t0 = time.perf_counter()

    def restart(self) -> None:
        self._t0 = time.perf_counter()

    def elapsed(self) -> float:
        return time.perf_counter() - self._t0


def block(x: Any) -> Any:
    """Block until all arrays in a pytree are ready (for honest timing)."""
    jax.tree_util.tree_map(
        lambda a: a.block_until_ready() if hasattr(a, "block_until_ready") else a, x
    )
    return x


def time_fn(fn, *args, warmup: int = 1, iters: int = 3, **kwargs) -> tuple[float, Any]:
    """Return (best seconds, last result) of fn(*args, **kwargs), jit-warmed."""
    out = None
    for _ in range(max(warmup, 0)):
        out = block(fn(*args, **kwargs))
    best = float("inf")
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        out = block(fn(*args, **kwargs))
        best = min(best, time.perf_counter() - t0)
    return best, out


@dataclass
class RngStream:
    """Deterministic per-purpose numpy RNG fan-out from a single seed."""

    seed: int
    _streams: dict = field(default_factory=dict)

    def get(self, name: str) -> np.random.Generator:
        if name not in self._streams:
            # stable per-name child seed
            child = np.random.SeedSequence([self.seed, abs(hash(name)) % (2**31)])
            self._streams[name] = np.random.default_rng(child)
        return self._streams[name]


def tree_bytes(tree: Any) -> int:
    """Total bytes of all arrays (or ShapeDtypeStructs) in a pytree."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
    return total


def tree_count(tree: Any) -> int:
    """Total parameter count of a pytree of arrays/structs."""
    return sum(
        int(np.prod(leaf.shape))
        for leaf in jax.tree_util.tree_leaves(tree)
        if hasattr(leaf, "shape")
    )
