"""Baseline operators (PAA/FFT/JL) + downstream analytics (kNN/DBSCAN/KDE)."""

import numpy as np
import pytest

from repro.analytics import dbscan, gaussian_kde, knn_retrieval_accuracy, nearest_neighbors
from repro.baselines import fft_min_k, fft_transform, jl_transform, paa_min_k, paa_transform
from repro.baselines.fft import fft_real_expansion
from repro.baselines.jl import jl_dimension_bound
from repro.baselines.svd_pca import pca_min_k
from repro.data import ecg_like, sinusoid_mixture


@pytest.fixture(scope="module")
def ecg():
    return ecg_like(800, 128, seed=0)


def _pair_dists(x, n=300, seed=0):
    rng = np.random.default_rng(seed)
    i = rng.integers(0, x.shape[0], n)
    j = rng.integers(0, x.shape[0], n)
    return i, j, np.linalg.norm(x[i] - x[j], axis=1)


def test_paa_contractive(ecg):
    x, _ = ecg
    t = paa_transform(x, 16)
    i, j, d_hi = _pair_dists(x)
    d_lo = np.linalg.norm(t[i] - t[j], axis=1)
    assert np.all(d_lo <= d_hi + 1e-3)


def test_paa_full_k_close_to_identity_distances(ecg):
    x, _ = ecg
    t = paa_transform(x, x.shape[1])  # one point per segment: exact
    i, j, d_hi = _pair_dists(x)
    d_lo = np.linalg.norm(t[i] - t[j], axis=1)
    np.testing.assert_allclose(d_lo, d_hi, rtol=1e-4)


def test_fft_expansion_is_isometry(ecg):
    x, _ = ecg
    e = fft_real_expansion(x)
    np.testing.assert_allclose(
        np.linalg.norm(e, axis=1), np.linalg.norm(x, axis=1), rtol=1e-4
    )


def test_fft_contractive(ecg):
    x, _ = ecg
    t = fft_transform(x, 9)
    i, j, d_hi = _pair_dists(x)
    d_lo = np.linalg.norm(t[i] - t[j], axis=1)
    assert np.all(d_lo <= d_hi + 1e-3)


def test_pca_needs_fewer_dims_than_fft_and_paa(ecg):
    """The paper's headline measurement-study result (Table 6 / Fig 1)."""
    x, _ = ecg
    k_pca = pca_min_k(x, 0.90)
    k_fft = fft_min_k(x, 0.90)
    k_paa = paa_min_k(x, 0.90)
    assert k_pca <= k_fft
    assert k_pca <= k_paa


def test_jl_shape_and_bound():
    x = np.random.default_rng(0).normal(size=(100, 64)).astype(np.float32)
    t = jl_transform(x, 8, seed=1)
    assert t.shape == (100, 8)
    # §1: JL needs ~137 dims for 5000 points at 25% distortion
    assert 120 <= jl_dimension_bound(5000, 0.25) <= 160


def test_knn_nearest_neighbor_correct_small():
    x = np.array([[0.0, 0], [0.1, 0], [5, 5], [5.1, 5]], dtype=np.float32)
    nn = nearest_neighbors(x, block=4)
    assert nn.tolist() == [1, 0, 3, 2]


def test_knn_accuracy_on_separable_classes():
    x, y = sinusoid_mixture(400, 64, rank=4, n_classes=2, noise=0.01, seed=5)
    assert knn_retrieval_accuracy(x, y) > 0.8


def test_knn_top_k_path_matches_argmin_path():
    """The accelerator self-exclusion (top_k(2)) must return the same
    neighbors as the CPU mask+argmin path, including the padded tail block
    and near-duplicate rows (self may or may not be the top hit)."""
    import jax.numpy as jnp

    from repro.analytics.knn import _nn_block

    rng = np.random.default_rng(3)
    x = rng.normal(size=(130, 8)).astype(np.float32)
    x[7] = x[3] + 1e-4  # near-duplicate pair: top-2 ordering is exercised
    xj = jnp.asarray(x)
    block = 64
    for a in range(0, x.shape[0], block):
        xq = xj[a : a + block]
        if xq.shape[0] < block:
            xq = jnp.pad(xq, ((0, block - xq.shape[0]), (0, 0)))
        ref, _ = _nn_block(xq, xj, jnp.int32(a), block, False)
        top, _ = _nn_block(xq, xj, jnp.int32(a), block, True)
        n = min(block, x.shape[0] - a)
        np.testing.assert_array_equal(
            np.asarray(ref)[:n], np.asarray(top)[:n]
        )


def test_dbscan_finds_two_blobs():
    rng = np.random.default_rng(0)
    a = rng.normal(0, 0.1, size=(50, 2))
    b = rng.normal(5, 0.1, size=(50, 2))
    labels = dbscan(np.concatenate([a, b]).astype(np.float32), eps=0.5, min_samples=4)
    assert len(set(labels[:50])) == 1 and len(set(labels[50:])) == 1
    assert labels[0] != labels[50]


def test_kde_higher_density_near_cluster():
    rng = np.random.default_rng(0)
    x = rng.normal(0, 0.5, size=(200, 4)).astype(np.float32)
    q = np.array([[0, 0, 0, 0], [10, 10, 10, 10]], dtype=np.float32)
    dens = gaussian_kde(x, q, bandwidth=1.0)
    assert dens[0] > dens[1] * 100
