"""Decode-path correctness: token-by-token serve_step must reproduce the
training/prefill forward pass logits (per family), including caches, rope
positions, ring buffers, SSM state carry-over, and cross attention."""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models.model import forward, init_model
from repro.serve.decode import serve_step
from repro.serve.kvcache import cache_bytes, plan_cache, zeros_cache
from repro.sharding.specs import ShardCtx

CTX = ShardCtx(mesh=None)
B, S = 2, 12

PARITY_ARCHS = [
    "tinyllama_1_1b",
    "qwen3_32b",       # qk_norm
    "qwen2_vl_2b",     # M-RoPE
    "granite_3_8b",
    "deepseek_67b",
    "mixtral_8x7b",    # MoE + SWA ring buffer
    "granite_moe_3b_a800m",
    "mamba2_2_7b",     # SSM recurrent decode
    "zamba2_1_2b",     # hybrid
]


def _decode_all(cfg, params, toks, extra=2):
    cache = zeros_cache(cfg, plan_cache(cfg, B, toks.shape[1] + extra))
    lengths = jnp.zeros((B,), jnp.int32)
    step = jax.jit(lambda p, t, c, l: serve_step(p, t, c, l, cfg, CTX))
    logits = None
    for s in range(toks.shape[1]):
        logits, cache = step(params, toks[:, s : s + 1], cache, lengths)
        lengths = lengths + 1
    return logits, cache, lengths


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_decode_matches_forward_last_token(arch):
    # capacity_factor high so MoE token-dropping (a train-path batching
    # artifact) does not differ between the two code paths
    cfg = replace(get_smoke_config(arch), capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32)
    logits_full = forward(params, {"inputs": toks}, cfg, CTX)
    logits_dec, _, _ = _decode_all(cfg, params, toks)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full[:, -1, :], np.float32),
        atol=2e-2, rtol=2e-2,  # bf16 accumulation-order noise
    )


def test_swa_ring_buffer_bounds_cache():
    """Mixtral's sliding window means the cache never exceeds the window."""
    cfg = get_smoke_config("mixtral_8x7b")  # window = 32
    plan = plan_cache(cfg, batch=4, context_len=500_000)
    assert plan.attn_len == cfg.sliding_window
    small = cache_bytes(cfg, plan)
    dense_cfg = replace(cfg, sliding_window=None)
    big = cache_bytes(dense_cfg, plan_cache(dense_cfg, 4, 500_000))
    assert small * 1000 < big


def test_swa_ring_decode_matches_forward_beyond_window():
    """Decode past the window: ring buffer must evict correctly."""
    cfg = replace(get_smoke_config("mixtral_8x7b"), sliding_window=8,
                  capacity_factor=8.0)
    key = jax.random.PRNGKey(1)
    params = init_model(cfg, key)
    toks = jax.random.randint(key, (B, 20), 0, cfg.vocab_size, jnp.int32)
    logits_full = forward(params, {"inputs": toks}, cfg, CTX)
    logits_dec, _, _ = _decode_all(cfg, params, toks)
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full[:, -1, :], np.float32),
        atol=3e-2, rtol=3e-2,
    )


def test_ssm_state_is_constant_memory():
    cfg = get_smoke_config("mamba2_2_7b")
    b_short = cache_bytes(cfg, plan_cache(cfg, 4, 1_000))
    b_long = cache_bytes(cfg, plan_cache(cfg, 4, 500_000))
    assert b_short == b_long  # attention-free: O(1) in context length


def test_whisper_decode_runs_with_cross_cache():
    cfg = get_smoke_config("whisper_tiny")
    key = jax.random.PRNGKey(2)
    params = init_model(cfg, key)
    cache = zeros_cache(cfg, plan_cache(cfg, B, S + 2))
    # fill the cross cache with encoder-derived K/V
    from repro.models.whisper import encode

    frames = jax.random.normal(key, (B, cfg.encoder_ctx, cfg.d_model))
    enc = encode(params, frames.astype(jnp.bfloat16), cfg, CTX, remat="none")
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    ck, cv = [], []
    for li in range(cfg.num_layers):
        lp = jax.tree_util.tree_map(lambda a: a[li], params["dec_layers"])
        ck.append((enc @ lp["cross"]["wk"]).reshape(B, -1, kv, hd))
        cv.append((enc @ lp["cross"]["wv"]).reshape(B, -1, kv, hd))
    cache["cross"]["k"] = jnp.stack(ck)
    cache["cross"]["v"] = jnp.stack(cv)

    lengths = jnp.zeros((B,), jnp.int32)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, t, c, l: serve_step(p, t, c, l, cfg, CTX)
    )(params, tok, cache, lengths)
    assert logits.shape == (B, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
