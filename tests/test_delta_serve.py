"""Streaming delta-serving subsystem: pub/sub subscriptions with O(suffix)
appends (serve_drop.delta + analytics.incremental).

The protocol contract under test, layer by layer:

* **incremental analytics** — ``IncrementalAnalytics.append`` folds suffix
  rows in via rectangular suffix-x-all scans; kNN indices/distances and
  DBSCAN labels must be BIT-identical to a cold recompute over the same
  reduced rows at every (non-tile-aligned) cut, KDE densities equal to
  compensated-sum tolerance.
* **the delta ladder** — a subscription's first delta is the bootstrap
  rollback; drift-free appends ride the O(suffix) append path (TLB-gated,
  rotation-stable); injected drift forces a rollback whose restated state
  is parity-checked like any other.
* **ordering/termination** — deltas are sequence-numbered, delivered in
  order at most once; ``unsubscribe`` delivers a terminal ``closed`` delta
  after which every mutation raises ``SubscriptionClosed``.
* **transport** — the same subscription surface works through the threaded
  ingest front-end (blocking ``next_delta``) and the sharded scheduler.

Parity is stated in two layers on purpose: analytics are bit-exact against
a cold recompute over the rows the subscriber actually holds, while the
suffix-assembled transform matches a one-shot transform of the grown
matrix to float32 tolerance only (BLAS kernels are size-dependent, so the
piecewise and full products differ in ulps). The hypothesis sweep of the
same property lives in test_properties_serve.py (skipped without
hypothesis); the deterministic random-sequence sweep here covers
environments without it.
"""

import numpy as np
import pytest

from repro.analytics import (
    IncrementalAnalytics,
    dbscan,
    pairwise_kde,
    pairwise_knn,
)
from repro.core import DropConfig
from repro.data import sinusoid_mixture
from repro.serve_drop import (
    APPEND,
    CLOSED,
    ROLLBACK,
    DropService,
    IngestFrontend,
    ShardedDropService,
    SubscribeQuery,
    SubscriberState,
    SubscriptionClosed,
)

CFG = DropConfig(target_tlb=0.95, seed=0)
EPS = 1.0
MIN_SAMPLES = 5
BANDWIDTH = 1.0


def _stream(m_total=420, d=32, rank=3, seed=0):
    """One generative process; snapshots are prefixes (append-only)."""
    return sinusoid_mixture(m_total, d, rank=rank, seed=seed)[0]


def _drain(svc):
    while svc.poll():
        pass


def _query(x0, rotation_tol=0.25):
    return SubscribeQuery(
        x=x0, cfg=CFG, eps=EPS, min_samples=MIN_SAMPLES,
        bandwidth=BANDWIDTH, rotation_tol=rotation_tol,
    )


def _apply_all(svc, sid, client):
    """Drain the scheduler and fold every emitted delta into the client."""
    _drain(svc)
    got = svc.poll_deltas(sid)
    for d in got:
        client.apply(d)
    return got


def _assert_state_parity(client, grown):
    """The two-layer delta-parity contract (see module docstring)."""
    idx, d2 = pairwise_knn(client.rows)
    assert np.array_equal(client.knn_idx, np.asarray(idx))
    assert np.array_equal(client.knn_d2, np.asarray(d2))
    labels = dbscan(client.rows, EPS, MIN_SAMPLES)
    assert np.array_equal(client.labels, np.asarray(labels))
    dens = pairwise_kde(client.rows, None, BANDWIDTH)
    np.testing.assert_allclose(
        client.densities, np.asarray(dens), atol=1e-5
    )
    assert client.rows.dtype == np.float32
    np.testing.assert_allclose(
        client.rows, client.basis.transform(grown), atol=1e-4
    )


# ------------------------------------------ incremental analytics (unit)


@pytest.mark.parametrize("block", [64, 1024])
def test_incremental_analytics_bit_parity_at_awkward_cuts(block):
    """Appends at non-tile-aligned cuts: incremental kNN/DBSCAN state is
    bit-identical to a cold rebuild over the grown rows, KDE to f64-fold
    tolerance."""
    rng = np.random.default_rng(3)
    y = rng.normal(size=(301, 6)).astype(np.float32)
    inc = IncrementalAnalytics(
        y[:120], eps=EPS, min_samples=MIN_SAMPLES, bandwidth=BANDWIDTH,
        block=block,
    )
    for cut in (137, 181, 240, 301):
        inc.append(y[inc.rows: cut])
        snap = inc.snapshot()
        cold = IncrementalAnalytics(
            y[:cut], eps=EPS, min_samples=MIN_SAMPLES, bandwidth=BANDWIDTH,
            block=block,
        ).snapshot()
        assert np.array_equal(snap.knn_idx, cold.knn_idx)
        assert np.array_equal(snap.knn_d2, cold.knn_d2)
        assert np.array_equal(snap.labels, cold.labels)
        np.testing.assert_allclose(
            snap.densities, cold.densities, atol=1e-6
        )


def test_incremental_append_patch_is_o_suffix_shaped():
    """The append patch carries only changed old rows + the new rows —
    the O(suffix) wire contract SubscriberState folds in."""
    y = np.random.default_rng(0).normal(size=(200, 5)).astype(np.float32)
    inc = IncrementalAnalytics(y[:150], eps=EPS)
    patch = inc.append(y[150:])
    assert patch["append_idx"].shape == (50,)
    assert patch["append_d2"].shape == (50,)
    assert patch["changed"].shape == patch["idx"].shape
    assert patch["changed"].size <= 150  # only old rows whose NN moved


# --------------------------------------------------- service delta ladder


def test_bootstrap_then_stable_appends_with_parity():
    """Drift-free stream: one bootstrap rollback, then every append rides
    the O(suffix) path; subscriber state parity after every delta."""
    x = _stream(420)
    svc = DropService()
    sid = svc.subscribe(_query(x[:300]))
    client = SubscriberState()
    got = _apply_all(svc, sid, client)
    assert [d["kind"] for d in got] == [ROLLBACK]
    assert got[0]["reason"] == "subscribe"
    assert got[0]["seq"] == 0
    _assert_state_parity(client, x[:300])
    for lo, hi in ((300, 340), (340, 393), (393, 420)):
        svc.append(sid, x[lo:hi])
        got = _apply_all(svc, sid, client)
        assert [d["kind"] for d in got] == [APPEND]
        _assert_state_parity(client, x[:hi])
    assert client.appends == 3 and client.rollbacks == 1
    assert svc.stats.subscriptions == 1
    assert svc.stats.delta_serves == 3
    assert svc.stats.rollbacks == 0
    assert svc.stats.failures == 0


def test_drift_injection_forces_rollback_with_parity():
    """Rows from a different generative process (scaled novel directions)
    must rotate the basis past the gate: the subscriber sees a rollback
    (never a silently degraded append) and the restated state is
    parity-checked like any other."""
    x = _stream(360)
    drift = 5.0 * _stream(80, seed=9)[:, ::-1].copy()
    svc = DropService()
    sid = svc.subscribe(_query(x[:360], rotation_tol=0.2))
    client = SubscriberState()
    _apply_all(svc, sid, client)
    svc.append(sid, drift)
    got = _apply_all(svc, sid, client)
    assert [d["kind"] for d in got] == [ROLLBACK]
    assert got[0]["reason"] in ("drift", "headroom", "refit")
    grown = np.concatenate([x[:360], drift.astype(np.float32)])
    _assert_state_parity(client, grown)
    assert svc.stats.rollbacks == 1
    # the stream keeps going after a rollback: the refit state serves the
    # next (drift-free w.r.t. the NEW basis) append
    svc.append(sid, 5.0 * _stream(120, seed=9)[80:, ::-1].copy())
    got = _apply_all(svc, sid, client)
    assert len(got) == 1 and got[0]["kind"] in (APPEND, ROLLBACK)
    assert client.rows.shape[0] == 480


def test_deltas_are_ordered_at_most_once_and_replay_rejected():
    """poll pops (at-most-once); seq is contiguous; a replayed or reordered
    delta is a protocol violation the reference client rejects."""
    x = _stream(340)
    svc = DropService()
    sid = svc.subscribe(_query(x[:300]))
    _drain(svc)
    svc.append(sid, x[300:320])
    _drain(svc)
    svc.append(sid, x[320:340])
    _drain(svc)
    got = svc.poll_deltas(sid)
    assert [d["seq"] for d in got] == list(range(len(got)))
    assert svc.poll_deltas(sid) == []  # popped: delivered at most once
    client = SubscriberState()
    for d in got:
        client.apply(d)
    with pytest.raises(ValueError, match="out-of-order"):
        client.apply(got[-1])  # replay
    fresh = SubscriberState()
    with pytest.raises(ValueError, match="out-of-order"):
        fresh.apply(got[-1])  # skipped bootstrap


def test_unsubscribe_terminates_and_further_mutation_raises():
    x = _stream(320)
    svc = DropService()
    sid = svc.subscribe(_query(x[:300]))
    client = SubscriberState()
    _apply_all(svc, sid, client)
    svc.append(sid, x[300:320])
    svc.unsubscribe(sid)  # orderly: the queued suffix may drop, but the
    _drain(svc)           # terminal closed must still arrive
    got = svc.poll_deltas(sid)
    assert got and got[-1]["kind"] == CLOSED
    assert got[-1]["error"] is None
    for d in got:
        client.apply(d)
    assert client.closed
    assert sid not in svc.live_subscriptions()
    with pytest.raises(SubscriptionClosed):
        svc.append(sid, x[300:320])
    with pytest.raises(SubscriptionClosed):
        client.apply({"kind": APPEND, "seq": client._next_seq})


def test_pending_unsubscribe_before_bootstrap_still_closes():
    """Unsubscribing while the bootstrap reduction is still queued must
    not strand the subscription in pending."""
    x = _stream(300)
    svc = DropService()
    sid = svc.subscribe(_query(x))
    svc.unsubscribe(sid, force=True)
    _drain(svc)
    got = svc.poll_deltas(sid)
    assert got[-1]["kind"] == CLOSED
    assert sid not in svc.live_subscriptions()


# ------------------------------------------------ random-sequence sweep


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_random_append_sequence_matches_cold_recompute(seed):
    """Deterministic sweep (hypothesis mirror): random-size appends with a
    drift injection at a random step; after EVERY delta the subscriber
    state satisfies the two-layer parity contract — including across the
    forced rollback."""
    rng = np.random.default_rng(100 + seed)
    x = _stream(560, seed=seed)
    m0 = 300
    svc = DropService()
    sid = svc.subscribe(_query(x[:m0], rotation_tol=0.2))
    client = SubscriberState()
    got = _apply_all(svc, sid, client)
    assert [d["kind"] for d in got] == [ROLLBACK]
    grown = x[:m0]
    _assert_state_parity(client, grown)
    lo = m0
    drift_step = int(rng.integers(0, 4))
    for step in range(4):
        if step == drift_step:
            suffix = 4.0 * _stream(
                int(rng.integers(20, 60)), seed=77 + seed
            )[:, ::-1].copy()
        else:
            s = int(rng.integers(11, 64))
            suffix = x[lo: lo + s]
            lo += suffix.shape[0]
        svc.append(sid, suffix)
        grown = np.concatenate([grown, suffix.astype(np.float32)])
        got = _apply_all(svc, sid, client)
        assert len(got) == 1 and got[0]["kind"] in (APPEND, ROLLBACK)
        _assert_state_parity(client, grown)
    assert client.rows.shape[0] == grown.shape[0]
    assert client.rollbacks >= 2  # bootstrap + the drift injection
    assert svc.stats.failures == 0


# ------------------------------------------------------------ transports


def test_ingest_frontend_blocking_next_delta():
    """The threaded front-end: subscribe/append from the client thread,
    block on next_delta; unsubscribe delivers the terminal closed and
    subsequent waits raise SubscriptionClosed."""
    x = _stream(360)
    svc = DropService()
    with IngestFrontend(svc, queue_capacity=8) as fe:
        sid = fe.subscribe(x[:300], CFG, eps=EPS)
        client = SubscriberState()
        d = fe.next_delta(sid, timeout=120)
        client.apply(d)
        assert d["kind"] == ROLLBACK and d["reason"] == "subscribe"
        with pytest.raises(TimeoutError):
            fe.next_delta(sid, timeout=0.05)  # nothing pending
        fe.append(sid, x[300:360])
        d = fe.next_delta(sid, timeout=120)
        client.apply(d)
        assert d["kind"] in (APPEND, ROLLBACK)
        _assert_state_parity(client, x[:360])
        fe.unsubscribe(sid)
        d = fe.next_delta(sid, timeout=120)
        assert d["kind"] == CLOSED
        client.apply(d)
        with pytest.raises(SubscriptionClosed):
            fe.next_delta(sid, timeout=120)


def test_frontend_close_terminates_live_subscriptions():
    """close(drain=True) with a live subscription: the subscriber gets a
    terminal closed delta and no waiter is left stranded."""
    x = _stream(300)
    svc = DropService()
    fe = IngestFrontend(svc, queue_capacity=8).start()
    sid = fe.subscribe(x, CFG)
    fe.next_delta(sid, timeout=120)  # bootstrap landed
    fe.close(drain=True)
    got = svc.poll_deltas(sid)
    assert got and got[-1]["kind"] == CLOSED
    assert sid not in svc.live_subscriptions()


def test_sharded_single_device_subscription_parity():
    """The sharded scheduler's delta path (device-pinned compute) serves
    the same contract; with one device it degenerates to the base class."""
    x = _stream(360)
    svc = ShardedDropService(devices=1)
    sid = svc.subscribe(_query(x[:300]))
    client = SubscriberState()
    got = _apply_all(svc, sid, client)
    assert [d["kind"] for d in got] == [ROLLBACK]
    svc.append(sid, x[300:360])
    got = _apply_all(svc, sid, client)
    assert [d["kind"] for d in got] == [APPEND]
    _assert_state_parity(client, x[:360])
    svc.unsubscribe(sid)
    _drain(svc)
    assert svc.poll_deltas(sid)[-1]["kind"] == CLOSED


def test_subscriptions_and_queries_share_the_scheduler():
    """Plain request/response queries interleave with subscription deltas
    on the same scheduler without starving either."""
    from repro.core.cost import zero_cost

    x = _stream(380)
    other = _stream(240, seed=5)
    svc = DropService()
    sid = svc.subscribe(_query(x[:300]))
    qid = svc.submit(other, CFG, zero_cost())
    client = SubscriberState()
    _apply_all(svc, sid, client)
    svc.append(sid, x[300:380])
    qid2 = svc.submit(other, CFG, zero_cost())
    _apply_all(svc, sid, client)
    assert client.rows.shape[0] == 380
    for q in (qid, qid2):
        r = svc.take_result(q)
        assert r is not None and r.error is None
    assert svc.stats.subscriptions == 1
