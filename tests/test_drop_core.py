"""Unit + behaviour tests for the DROP optimizer core."""

import numpy as np
import pytest

from repro.core import drop, DropConfig
from repro.core.cost import knn_cost, linear_cost, zero_cost
from repro.core.tlb import exact_tlb
from repro.data import ecg_like, sinusoid_mixture, white_noise


@pytest.fixture(scope="module")
def structured():
    return sinusoid_mixture(1200, 96, rank=6, seed=0)


def test_drop_finds_low_dim_basis_on_structured_data(structured):
    x, _ = structured
    res = drop(x, DropConfig(target_tlb=0.95, seed=0), cost=zero_cost())
    assert res.satisfied
    # intrinsic rank is 6 (+noise): DROP should find a small basis, far below d
    assert res.k <= 16
    assert res.v.shape == (96, res.k)


def test_drop_result_tlb_matches_exact(structured):
    x, _ = structured
    res = drop(x, DropConfig(target_tlb=0.95, seed=0), cost=zero_cost())
    truth = exact_tlb(x[:300], res.v)
    assert abs(truth - res.tlb_estimate) < 0.03
    assert truth >= 0.93  # near target, sampling tolerance


def test_drop_transform_is_contractive(structured):
    x, _ = structured
    res = drop(x, DropConfig(target_tlb=0.9, seed=1), cost=zero_cost())
    xt = res.transform(x)
    rng = np.random.default_rng(0)
    i = rng.integers(0, x.shape[0], 200)
    j = rng.integers(0, x.shape[0], 200)
    d_hi = np.linalg.norm(x[i] - x[j], axis=1)
    d_lo = np.linalg.norm(xt[i] - xt[j], axis=1)
    assert np.all(d_lo <= d_hi + 1e-3)


def test_drop_processes_less_data_than_full_svd(structured):
    x, _ = structured
    res = drop(x, DropConfig(target_tlb=0.95, seed=0), cost=knn_cost(x.shape[0]))
    # progressive sampling should terminate well before scanning all data
    assert res.total_rows_processed < x.shape[0]


def test_drop_white_noise_needs_near_full_dimension():
    x, _ = white_noise(300, 48, seed=3)
    res = drop(x, DropConfig(target_tlb=0.9, seed=0), cost=zero_cost())
    # unstructured data has no low-dim TLB basis: k must stay near d
    assert res.k > 24


def test_drop_respects_tighter_target_with_larger_k(structured):
    x, _ = structured
    lo = drop(x, DropConfig(target_tlb=0.75, seed=0), cost=zero_cost())
    hi = drop(x, DropConfig(target_tlb=0.99, seed=0), cost=zero_cost())
    assert lo.k <= hi.k


def test_drop_prefix_and_binary_agree(structured):
    x, _ = structured
    rb = drop(x, DropConfig(target_tlb=0.9, search="binary", seed=0), cost=zero_cost())
    rp = drop(x, DropConfig(target_tlb=0.9, search="prefix", seed=0), cost=zero_cost())
    assert abs(rb.k - rp.k) <= 3  # same decision up to pair-sampling noise


def test_drop_full_svd_mode(structured):
    x, _ = structured
    res = drop(x, DropConfig(target_tlb=0.9, svd="full", seed=0), cost=zero_cost())
    assert res.satisfied


def test_linear_cost_terminates_earlier_than_zero_cost(structured):
    x, _ = structured
    eager = drop(
        x, DropConfig(target_tlb=0.9, seed=0), cost=linear_cost(x.shape[0], 1e-7)
    )
    patient = drop(x, DropConfig(target_tlb=0.9, seed=0), cost=zero_cost())
    assert len(eager.iterations) <= len(patient.iterations)


def test_iteration_records_are_consistent(structured):
    x, _ = structured
    res = drop(x, DropConfig(target_tlb=0.9, seed=0), cost=zero_cost())
    sizes = [r.sample_size for r in res.iterations]
    assert sizes == sorted(sizes)  # progressive schedule is nondecreasing
    assert res.runtime_s == pytest.approx(sum(r.runtime_s for r in res.iterations))
    assert all(r.pairs_used >= 0 for r in res.iterations)
