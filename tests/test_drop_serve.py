"""DropService behavior: parity with sequential drop(), the basis-reuse
cache's no-refit hit path, LRU bounds, and scheduler bookkeeping."""

import numpy as np
import pytest

from repro.core import DropConfig, DropRunner, drop
from repro.core import basis_search
from repro.core.cost import zero_cost
from repro.serve_drop import BasisReuseCache, DropService, dataset_fingerprint
from repro.serve_drop.cache import BasisCacheEntry
from repro.data import sinusoid_mixture


def _datasets(n, rows=500, dim=48):
    return [sinusoid_mixture(rows, dim, rank=4 + i, seed=10 + i)[0] for i in range(n)]


CFG = DropConfig(target_tlb=0.95, seed=0)

# Eq. 2 termination consults measured wall-clock runtimes, so iteration
# counts can differ between two runs of the same query when compile noise
# lands differently. Bit-exact parity tests pin min_iterations past the
# schedule length: every run walks the full schedule, timing-independent.
PARITY_CFG = DropConfig(target_tlb=0.95, seed=0, min_iterations=99)


# ------------------------------------------------------------------ parity


def test_concurrent_queries_match_sequential_drop():
    """N distinct in-flight queries, interleaved by the scheduler, must
    produce bit-identical results to sequential drop() on the same seeds."""
    datasets = _datasets(3, rows=300, dim=32)
    svc = DropService(max_inflight=3, enable_cache=False)
    for x in datasets:
        svc.submit(x, PARITY_CFG, zero_cost())
    served = svc.run()

    assert len(served) == len(datasets)
    for x, r in zip(datasets, served):
        ref = drop(x, PARITY_CFG, cost=zero_cost())
        assert r.result.k == ref.k
        assert r.result.satisfied == ref.satisfied
        np.testing.assert_array_equal(r.result.v, ref.v)
        np.testing.assert_array_equal(r.result.mean, ref.mean)
        assert len(r.result.iterations) == len(ref.iterations)


def test_runner_steps_equal_monolithic_drop():
    """The resumable DropRunner is the same algorithm as drop()."""
    (x,) = _datasets(1, rows=300, dim=32)
    runner = DropRunner(x, PARITY_CFG, zero_cost())
    steps = 0
    while runner.step():
        steps += 1
    res = runner.result()
    ref = drop(x, PARITY_CFG, cost=zero_cost())
    assert steps + 1 == len(ref.iterations)
    assert res.k == ref.k
    np.testing.assert_array_equal(res.v, ref.v)


# ------------------------------------------------------------- cache hits


def test_resubmitted_workload_skips_fit_basis(monkeypatch):
    """A repeat submission must be served from the basis cache with zero
    fit_basis calls — the §5 reuse path."""
    (x,) = _datasets(1)
    svc = DropService()
    svc.submit(x, CFG, zero_cost())
    first = svc.run()[0]
    assert not first.cache_hit and first.result.satisfied

    calls = []
    real_fit = basis_search.fit_basis
    monkeypatch.setattr(
        basis_search, "fit_basis", lambda *a, **k: calls.append(1) or real_fit(*a, **k)
    )
    svc.submit(x, CFG, zero_cost())
    second = svc.run()[0]
    assert second.cache_hit
    assert calls == []  # no fitting anywhere on the hit path
    assert second.result.satisfied
    assert second.result.k == first.result.k
    assert second.result.tlb_estimate >= CFG.target_tlb


def test_cache_hit_result_is_valid_basis():
    """The cached basis served on a hit must actually preserve distances on
    the re-submitted data (contractive + near-target sampled TLB)."""
    (x,) = _datasets(1)
    svc = DropService()
    svc.submit(x, CFG, zero_cost())
    svc.run()
    svc.submit(x, CFG, zero_cost())
    r = svc.run()[0].result
    xt = (x - r.mean) @ r.v
    rng = np.random.default_rng(0)
    i, j = rng.integers(0, x.shape[0], 100), rng.integers(0, x.shape[0], 100)
    d_hi = np.linalg.norm(x[i] - x[j], axis=1)
    d_lo = np.linalg.norm(xt[i] - xt[j], axis=1)
    assert np.all(d_lo <= d_hi + 1e-3)


def test_concurrent_repeats_deduplicated():
    """Repeats submitted concurrently with their first instance must not all
    run cold: the scheduler defers them onto the cache."""
    (x,) = _datasets(1)
    svc = DropService(max_inflight=4)
    for _ in range(4):
        svc.submit(x, CFG, zero_cost())
    served = svc.run()
    assert sum(r.cache_hit for r in served) == 3
    assert svc.stats.cache_misses == 1


def test_tighter_target_does_not_reuse_looser_basis():
    """A cached basis fitted at 0.90 must not short-circuit a 0.99 query
    (its k is no upper bound for the tighter target)."""
    (x,) = _datasets(1)
    svc = DropService()
    svc.submit(x, DropConfig(target_tlb=0.90, seed=0), zero_cost())
    loose = svc.run()[0]
    svc.submit(x, DropConfig(target_tlb=0.99, seed=0), zero_cost())
    tight = svc.run()[0]
    assert not tight.cache_hit
    assert tight.result.k >= loose.result.k
    # and the looser direction DOES reuse: a 0.90 query after a 0.99 fit
    svc.submit(x, DropConfig(target_tlb=0.90, seed=0), zero_cost())
    assert svc.run()[0].cache_hit


def test_stale_cache_entry_does_not_cap_fallback_run():
    """Fingerprint collision on drifted data: the cached basis fails
    revalidation, and the fallback cold run must not stay capped at the
    stale (too small) k — it has to find a satisfying basis on its own."""
    x, _ = sinusoid_mixture(200, 48, rank=3, seed=0)
    x = x.astype(np.float32)
    svc = DropService()
    cfg = DropConfig(target_tlb=0.9, seed=0)
    svc.submit(x, cfg, zero_cost())
    first = svc.run()[0]
    assert first.result.satisfied and first.result.k <= 8

    # drift every row the fingerprint does NOT hash (stride = m // 64 = 3:
    # rows 0,3,6,... and the last row are sampled) into white noise: same
    # fingerprint, but the old low-rank basis no longer preserves distances
    drifted = x.copy()
    rng = np.random.default_rng(1)
    for i in range(drifted.shape[0] - 1):
        if i % 3 != 0:
            drifted[i] = rng.normal(size=drifted.shape[1]).astype(np.float32)
    from repro.serve_drop import dataset_fingerprint as fp

    assert fp(drifted) == fp(x)  # collision is the premise of this test

    svc.submit(drifted, cfg, zero_cost())
    r = svc.run()[0]
    assert not r.cache_hit  # revalidation must reject the stale basis
    assert r.result.satisfied  # and the fallback must not stay rank-capped
    assert r.result.k > first.result.k  # noise needs far more dimensions


# -------------------------------------------------------------------- LRU


def test_lru_eviction_bound_respected():
    datasets = _datasets(5, rows=200, dim=24)
    svc = DropService(cache_entries=2)
    for x in datasets:
        svc.submit(x, CFG, zero_cost())
    svc.run()
    assert len(svc.cache) <= 2
    assert svc.cache.evictions >= 3


def test_lru_evicts_least_recently_used():
    cache = BasisReuseCache(capacity=2)
    entry = lambda k: BasisCacheEntry(  # noqa: E731
        v=np.eye(4)[:, :k], mean=np.zeros(4), k=k,
        target_tlb=0.9, tlb_estimate=0.99, satisfied=True,
    )
    cache.put("a", entry(1))
    cache.put("b", entry(2))
    assert cache.get_exact("a", 0.9) is not None  # refresh a
    cache.put("c", entry(3))  # evicts b, not a
    assert cache.get_exact("b", 0.9) is None
    assert cache.get_exact("a", 0.9) is not None
    assert cache.get_exact("c", 0.9) is not None
    assert len(cache) == 2


def test_fingerprint_sensitivity():
    x = np.random.default_rng(0).normal(size=(100, 8)).astype(np.float32)
    assert dataset_fingerprint(x) == dataset_fingerprint(x.copy())
    y = x.copy()
    y[-1, -1] += 1.0
    assert dataset_fingerprint(x) != dataset_fingerprint(y)
    assert dataset_fingerprint(x) != dataset_fingerprint(x[:99])


def test_fingerprint_append_and_distinct_data():
    """Appending rows always changes the fingerprint (shape is hashed and
    the stride re-lands); independently drawn data never collides."""
    x = np.random.default_rng(1).normal(size=(200, 8)).astype(np.float32)
    grown = np.concatenate([x, x[:1]], axis=0)
    assert dataset_fingerprint(grown) != dataset_fingerprint(x)
    y = np.random.default_rng(2).normal(size=(200, 8)).astype(np.float32)
    assert dataset_fingerprint(x) != dataset_fingerprint(y)


def test_fingerprint_unsampled_permutation_aliases():
    """Documented aliasing: permuting rows the strided subsample never reads
    keeps the fingerprint (this is the premise of the TTL staleness bound),
    while permuting a sampled row changes it."""
    m = 300  # stride = m // 64 = 4: rows 0, 4, 8, ... and the last are hashed
    x = np.random.default_rng(3).normal(size=(m, 8)).astype(np.float32)
    stride = max(1, m // 64)
    aliased = x.copy()
    aliased[[1, 2]] = aliased[[2, 1]]  # neither row is sampled
    assert dataset_fingerprint(aliased) == dataset_fingerprint(x)
    visible = x.copy()
    visible[[0, 1]] = visible[[1, 0]]  # row 0 is sampled
    assert dataset_fingerprint(visible) != dataset_fingerprint(x)
    assert stride > 2  # the construction above assumes rows 1,2 unsampled


# ---------------------------------------------------------------- TTL


def test_ttl_entries_expire_and_refresh():
    entry = lambda k: BasisCacheEntry(  # noqa: E731
        v=np.eye(4)[:, :k], mean=np.zeros(4), k=k,
        target_tlb=0.9, tlb_estimate=0.99, satisfied=True,
    )
    cache = BasisReuseCache(capacity=4, ttl_ticks=2)
    cache.put("a", entry(2))
    cache.tick()
    assert cache.get_exact("a", 0.9) is not None  # age 1 <= ttl
    cache.tick()
    assert cache.get_exact("a", 0.9) is not None  # age 2 == ttl: still fresh
    cache.tick()
    assert cache.get_exact("a", 0.9) is None  # age 3 > ttl: expired
    assert cache.expired_hits == 1
    assert cache.get_warm_k("a", 0.9) == 2  # warm starts survive expiry
    cache.put("a", entry(2))  # refit re-inserts: age restarts
    assert cache.get_exact("a", 0.9) is not None

    forever = BasisReuseCache(capacity=4, ttl_ticks=None)
    forever.put("a", entry(1))
    for _ in range(100):
        forever.tick()
    assert forever.get_exact("a", 0.9) is not None  # default: never expires


def test_ttl_expired_entry_with_degraded_basis_self_heals(monkeypatch):
    """The staleness hole + its TTL fix. The exact-hit revalidation samples
    pairs with a seed pinned by the query config, so drift the sampled pairs
    never see can keep serving a degraded basis forever. Simulate exactly
    that blind spot by degrading the cached entry (rank-1 truncation) while
    forcing the validation estimate to pass:

    * without a TTL the degraded entry is served as a cache hit forever;
    * with a TTL the aged entry is refused, the query refits cold, and the
      re-inserted entry (fresh basis AND fresh age) serves future hits.
    """
    from repro.core.tlb import TLBEstimate
    from repro.serve_drop import service as service_mod

    (x,) = _datasets(1)

    def poison(svc):
        ((key, entry),) = [(k, svc.cache._entries[k]) for k in svc.cache.keys()]
        entry.v = entry.v[:, :1]
        entry.k = 1
        return entry

    class _BlindEstimator:
        """Stands in for drift the seed-pinned validation pairs miss."""

        def __init__(self, *a, **k):
            pass

        def estimate_at_k(self, k, target, **kw):
            return TLBEstimate(mean=0.999, lo=0.99, hi=1.0, pairs_used=10)

    # -- the hole: no TTL, blind validation => stale k=1 served forever
    svc = DropService()
    svc.submit(x, CFG, zero_cost())
    k_good = svc.run()[0].result.k
    assert k_good > 1
    poison(svc)
    with monkeypatch.context() as m:
        m.setattr(service_mod, "TLBEstimator", _BlindEstimator)
        svc.submit(x, CFG, zero_cost())
        stale = svc.run()[0]
    assert stale.cache_hit and stale.result.k == 1  # degraded basis served

    # -- the fix: TTL expires the entry, forcing an honest refit
    svc = DropService(cache_ttl=3)
    svc.submit(x, CFG, zero_cost())
    assert svc.run()[0].result.k == k_good
    poison(svc)
    for _ in range(4):  # age the entry past the TTL
        svc.cache.tick()
    with monkeypatch.context() as m:
        m.setattr(service_mod, "TLBEstimator", _BlindEstimator)
        svc.submit(x, CFG, zero_cost())
        healed = svc.run()[0]
    # even with validation still blind, the expired entry cannot be served:
    # the cold refit recovers a real basis and re-inserts it (the refit's
    # exact k may differ from the first run's — the stale k=1 warm hint
    # perturbs the importance-sampling trajectory — but it must be a
    # satisfying, non-degenerate fit)
    assert not healed.cache_hit
    assert healed.result.satisfied and healed.result.k > 1
    svc.submit(x, CFG, zero_cost())
    again = svc.run()[0]  # fresh entry now serves hits again (self-healed)
    assert again.cache_hit and again.result.k == healed.result.k


# ------------------------------------------------- bucketing mirrors
# deterministic counterparts of the Hypothesis properties in
# test_properties_serve.py (those skip when hypothesis is absent)


def test_bucket_quantization_idempotent():
    from repro.core.bucketing import ShapeBucketCache, round_up

    bucket = ShapeBucketCache()
    for n in (1, 31, 32, 33, 100, 127, 128, 1000):
        assert round_up(round_up(n, 32), 32) == round_up(n, 32)
        assert bucket.bucket_pairs(bucket.bucket_pairs(n)) == bucket.bucket_pairs(n)
        assert bucket.bucket_rows(bucket.bucket_rows(n)) == bucket.bucket_rows(n)
        for hard in (n, n + 5, 2 * n):
            b = bucket.bucket_rank(n, hard)
            assert bucket.bucket_rank(b, hard) == b


def test_pair_bucketing_bit_matches_unbucketed():
    """Zero-padded pair batches, sliced back, must be bit-identical to the
    unpadded evaluation (padding never reaches the estimate)."""
    import jax.numpy as jnp

    from repro.core.bucketing import ShapeBucketCache
    from repro.core.tlb import TLBEstimator

    x = np.random.default_rng(5).normal(size=(80, 12)).astype(np.float32)
    v = np.linalg.svd(x - x.mean(0), full_matrices=False)[2].T[:, :6]
    identity = ShapeBucketCache(rank_quantum=1, pair_quantum=1, row_quantum=1)
    e1 = TLBEstimator(x, jnp.asarray(v), np.random.default_rng(7),
                      bucket=ShapeBucketCache(pair_quantum=128))
    e2 = TLBEstimator(x, jnp.asarray(v), np.random.default_rng(7),
                      bucket=identity)
    np.testing.assert_array_equal(e1.table(37), e2.table(37))


# -------------------------------------------------------------- bookkeeping


def test_stats_and_result_ordering():
    datasets = _datasets(2, rows=300, dim=24)
    svc = DropService(max_inflight=2)
    ids = [svc.submit(x, CFG, zero_cost()) for x in datasets + datasets]
    served = svc.run()
    assert [r.query_id for r in served] == sorted(ids)
    assert svc.stats.queries == 4
    assert svc.stats.cache_hits == 2
    assert svc.stats.fit_calls == svc.stats.iterations
    assert svc.stats.fit_calls > 0


# ------------------------------------------------------- use_kernels plumb


def test_served_query_use_kernels_interpret_parity(monkeypatch):
    """ReduceQuery carries cfg.use_kernels end-to-end: a served query with
    the kernel path forced through the Pallas interpreter must reach the
    same rank and a satisfying TLB as the plain served run (bit-exact k —
    the kernels compute the same tables; interpret mode only swaps the
    executor). Covers the launch/drop_serve.py --use-kernels plumbing."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    x = _datasets(1, rows=160, dim=16)[0]
    plain = DropService(enable_cache=False)
    plain.submit(x, PARITY_CFG, zero_cost())
    r_plain = plain.run()[0]

    kcfg = DropConfig(
        target_tlb=0.95, seed=0, min_iterations=99, use_kernels=True
    )
    svc = DropService(enable_cache=False)
    svc.submit(x, kcfg, zero_cost())
    r_kern = svc.run()[0]
    assert r_kern.error is None
    assert r_kern.result.satisfied
    assert r_kern.result.k == r_plain.result.k
    np.testing.assert_allclose(
        r_kern.result.tlb_estimate, r_plain.result.tlb_estimate, atol=5e-4
    )


# ------------------------------------------------- served downstream exec


def test_execute_downstream_attaches_parity_output():
    """execute_downstream=True runs the declared analytics task on the
    reduced data as a scheduled work item and attaches its output to the
    ServeResult — identical to calling run_downstream on the transform
    (the split decomposition is exact, so analytics_split changes
    nothing)."""
    from repro.pipeline.optimizer import run_downstream

    x = _datasets(1, rows=260, dim=24)[0]
    svc = DropService(enable_cache=False, analytics_split=2)
    svc.submit(x, CFG, zero_cost(), downstream="knn",
               execute_downstream=True)
    r = svc.run()[0]
    assert r.error is None
    assert r.downstream is not None
    assert r.downstream_s > 0.0
    assert svc.stats.downstream_runs == 1
    xt = r.result.transform(x)
    assert np.array_equal(r.downstream, run_downstream("knn", xt))


def test_execute_downstream_on_cache_hit():
    """A cache-hit query still gets its analytics leg: the basis is
    reused, the downstream task runs on the reused transform."""
    x = _datasets(1, rows=260, dim=24)[0]
    svc = DropService()
    svc.submit(x, CFG, zero_cost(), downstream="kde",
               execute_downstream=True)
    svc.submit(x, CFG, zero_cost(), downstream="kde",
               execute_downstream=True)
    r1, r2 = svc.run()
    assert r2.cache_hit and not r1.cache_hit
    assert r1.downstream is not None and r2.downstream is not None
    assert svc.stats.downstream_runs == 2
    np.testing.assert_allclose(r1.downstream, r2.downstream, rtol=1e-5)


def test_execute_downstream_error_contained(monkeypatch):
    """A downstream failure must not lose the reduction: the result (and
    its basis) commit with the error recorded, and the scheduler keeps
    draining."""
    import repro.pipeline.optimizer as opt_mod

    def boom(*a, **k):
        raise RuntimeError("analytics exploded")

    monkeypatch.setattr(opt_mod, "run_downstream", boom)
    x = _datasets(1, rows=260, dim=24)[0]
    svc = DropService(enable_cache=False)
    svc.submit(x, CFG, zero_cost(), downstream="knn",
               execute_downstream=True)
    r = svc.run()[0]
    assert r.error is not None and "downstream" in r.error
    assert r.result is not None  # the reduction itself survived
    assert r.downstream is None
    assert svc.stats.downstream_failures == 1
    assert svc.stats.downstream_runs == 0


def test_execute_downstream_requires_task():
    svc = DropService()
    x = _datasets(1, rows=120, dim=12)[0]
    with pytest.raises(ValueError, match="downstream"):
        svc.submit(x, CFG, zero_cost(), execute_downstream=True)
