"""Sharded multi-device DropService + async ingest behavior.

Fast, in-process: single-device fallback parity (the sharded scheduler with
one device degenerates to the base service), ingest backpressure
(reject-with-retry-after, never deadlock), and async completion.

Slow, subprocess: a forced 2-device host platform (XLA_FLAGS must precede
jax init, so it cannot run in the suite's process) checks that the threaded
2-device drain returns bit-identical per-query results vs the single-device
path, spreads iterations across both devices, and work-steals.
"""

import json
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.core import DropConfig, drop
from repro.core.cost import zero_cost
from repro.data import sinusoid_mixture
from repro.serve_drop import (
    DropService,
    IngestFrontend,
    RetryLater,
    ShardedDropService,
)
from repro.sharding.specs import serve_devices


def _datasets(n, rows=300, dim=32):
    return [
        sinusoid_mixture(rows, dim, rank=4 + i, seed=10 + i)[0] for i in range(n)
    ]


# Eq. 2 termination is wall-clock-adaptive; bit-exact parity pins
# min_iterations past the schedule length (see test_drop_serve.py)
PARITY_CFG = DropConfig(target_tlb=0.95, seed=0, min_iterations=99)
CFG = DropConfig(target_tlb=0.95, seed=0)


# ------------------------------------------------- single-device fallback


def test_serve_devices_clamps_and_defaults():
    devs = serve_devices()
    assert len(devs) >= 1
    assert serve_devices(1) == devs[:1]
    assert serve_devices(10_000) == devs  # clamped to availability
    assert serve_devices(0) == devs[:1]  # floor of one device


def test_sharded_single_device_matches_base_service():
    """With one device the sharded scheduler must be the base scheduler:
    bit-identical results, no steals, occupancy booked on that device."""
    datasets = _datasets(3)
    base = DropService(max_inflight=3, enable_cache=False)
    shard = ShardedDropService(devices=1, max_inflight=3, enable_cache=False)
    for x in datasets:
        base.submit(x, PARITY_CFG, zero_cost())
        shard.submit(x, PARITY_CFG, zero_cost())
    ref, out = base.run(), shard.run()
    assert len(out) == len(ref)
    for r, s in zip(ref, out):
        assert s.result.k == r.result.k
        np.testing.assert_array_equal(s.result.v, r.result.v)
        np.testing.assert_array_equal(s.result.mean, r.result.mean)
    assert shard.stats.steals == 0
    assert len(shard.stats.device_iterations) == 1
    assert sum(shard.stats.device_iterations.values()) == shard.stats.iterations


def test_sharded_cache_and_stats_still_work():
    """The sharded subclass inherits the §5 reuse path unchanged."""
    (x,) = _datasets(1)
    svc = ShardedDropService(devices=1)
    svc.submit(x, CFG, zero_cost())
    first = svc.run()[0]
    assert not first.cache_hit and first.result.satisfied
    svc.submit(x, CFG, zero_cost())
    assert svc.run()[0].cache_hit


# ------------------------------------------------------- async ingest


def test_backpressure_rejects_rather_than_deadlocks():
    """An over-full ingest queue must reject with a retry-after hint —
    submission never blocks, and accepted queries still complete."""
    datasets = _datasets(1, rows=200, dim=24) * 6
    svc = DropService(max_inflight=2, enable_cache=False)
    fe = IngestFrontend(svc, queue_capacity=2)  # drain NOT started yet
    accepted, rejections = [], []
    for x in datasets:
        try:
            accepted.append(fe.submit(x, CFG, zero_cost()))
        except RetryLater as e:
            rejections.append(e)
    assert len(accepted) == 2  # capacity bound respected
    assert len(rejections) == 4
    assert all(e.retry_after_s > 0 for e in rejections)
    assert all(e.backlog >= 2 for e in rejections)
    assert svc.stats.rejected == 4

    fe.start()
    done = [fe.result(q, timeout=120) for q in accepted]
    fe.close()
    assert [r.query_id for r in done] == accepted
    assert all(r.result.k >= 1 for r in done)


def test_async_ingest_accepts_while_draining():
    """Queries submitted from several threads while the scheduler drains all
    complete, and capacity frees up as results are taken."""
    datasets = _datasets(3, rows=200, dim=24)
    svc = DropService(max_inflight=2, enable_cache=False)
    results, errors = {}, []

    def client(i: int) -> None:
        try:
            x = datasets[i % len(datasets)]
            while True:
                try:
                    qid = fe.submit(x, CFG, zero_cost())
                    break
                except RetryLater as e:
                    time.sleep(e.retry_after_s)
            results[i] = fe.result(qid, timeout=120)
        except Exception as exc:  # surfaces in the main thread's assert
            errors.append(exc)

    with IngestFrontend(svc, queue_capacity=4) as fe:
        threads = [threading.Thread(target=client, args=(i,)) for i in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=300)
    assert not errors
    assert sorted(results) == list(range(6))
    assert all(r.result.k >= 1 for r in results.values())


def test_closed_frontend_rejects_submissions():
    svc = DropService()
    fe = IngestFrontend(svc, queue_capacity=4)
    fe.start()
    fe.close()
    with pytest.raises(RetryLater):
        fe.submit(_datasets(1)[0], CFG, zero_cost())


def test_failing_runner_does_not_wedge_the_scheduler(monkeypatch):
    """A runner iteration that raises must finish its query with an error
    result (not hang run() or leak a max_inflight slot), and the other
    tenants must still be served."""
    from repro.core.drop import DropRunner

    datasets = _datasets(3, rows=200, dim=24)
    real_step = DropRunner.step
    calls = {"n": 0}

    def step_first_runner_fails(self):
        calls["n"] += 1
        if calls["n"] == 2:  # second iteration of the first admitted runner
            raise RuntimeError("injected device failure")
        return real_step(self)

    monkeypatch.setattr(DropRunner, "step", step_first_runner_fails)
    svc = DropService(max_inflight=1, enable_cache=False)
    ids = [svc.submit(x, CFG, zero_cost()) for x in datasets]
    out = svc.run()  # must terminate
    assert [r.query_id for r in out] == ids
    failed = [r for r in out if r.error]
    assert len(failed) == 1 and "injected device failure" in failed[0].error
    assert all(r.result.k >= 1 for r in out if not r.error)
    assert svc.stats.failures == 1
    assert svc.backlog() == 0  # no leaked slots or stepping entries


def test_wedged_scheduler_does_not_hang_close(monkeypatch):
    """Regression: an exception escaping ``_poll_once`` used to kill the
    drain thread silently, after which ``close(drain=True)`` busy-waited
    forever on a backlog nothing would drain. Now the drain thread
    survives (counting ``drain_failures``) and close()'s progress deadline
    bounds the wait."""
    svc = DropService()

    def always_raises():
        raise RuntimeError("wedged scheduler tick")

    monkeypatch.setattr(svc, "_poll_once", always_raises)
    fe = IngestFrontend(svc, queue_capacity=4)
    fe.start()
    fe.submit(_datasets(1)[0], CFG, zero_cost())
    time.sleep(0.05)  # let drain threads hit the raising tick a few times
    t0 = time.perf_counter()
    fe.close(drain=True, progress_deadline_s=0.3)  # must RETURN
    assert time.perf_counter() - t0 < 10.0
    assert svc.stats.drain_failures > 0
    assert not fe._threads  # drain threads joined, none died early


def test_wedged_scheduler_close_terminates_live_subscription(monkeypatch):
    """Satellite of the wedge regression above: close(drain=True) with a
    LIVE delta subscription must terminate deterministically even when
    every scheduler tick raises — the subscriber gets a final ``closed``
    delta and a blocked ``next_delta`` waiter is released, not stranded."""
    from repro.serve_drop import SubscriptionClosed

    svc = DropService()
    fe = IngestFrontend(svc, queue_capacity=4)
    fe.start()
    x = _datasets(1)[0]
    sid = fe.subscribe(x, CFG)
    boot = fe.next_delta(sid, timeout=120)  # subscription is live
    assert boot["kind"] == "rollback"

    def always_raises():
        raise RuntimeError("wedged scheduler tick")

    monkeypatch.setattr(svc, "_poll_once", always_raises)
    fe.append(sid, x[:16])  # queued work the wedged scheduler cannot serve

    seen = []

    def waiter():
        try:
            while True:
                seen.append(fe.next_delta(sid, timeout=30))
        except (SubscriptionClosed, TimeoutError) as exc:
            seen.append(type(exc).__name__)

    th = threading.Thread(target=waiter)
    th.start()
    time.sleep(0.05)  # waiter parks on the delta condition
    t0 = time.perf_counter()
    fe.close(drain=True, progress_deadline_s=0.3)  # must RETURN
    assert time.perf_counter() - t0 < 10.0
    th.join(timeout=10)
    assert not th.is_alive()  # the waiter was released
    kinds = [d["kind"] if isinstance(d, dict) else d for d in seen]
    # the terminal closed was either consumed by the waiter before it saw
    # SubscriptionClosed, or the close raced it and the waiter saw the
    # terminal state directly — both are deterministic termination
    assert "SubscriptionClosed" in kinds or "closed" in kinds
    assert sid not in svc.live_subscriptions()


def test_commit_failure_fails_query_with_error_result(monkeypatch):
    """A raise in the commit section (after compute, e.g. cache put /
    stats bookkeeping) must finish the query with a ``scheduler:`` error
    result instead of stranding it half-retired."""
    svc = DropService()
    real_finish = DropService._finish

    def finish_raises(self, fl):
        real_finish(self, fl)  # commit first: _abandon must keep the result
        if not hasattr(self, "_blew_up"):
            self._blew_up = True
            raise RuntimeError("injected commit failure")

    monkeypatch.setattr(DropService, "_finish", finish_raises)
    xs = _datasets(2, rows=200, dim=24)
    ids = [svc.submit(x, CFG, zero_cost()) for x in xs]
    out = svc.run()  # must terminate
    assert [r.query_id for r in out] == ids
    # the commit ran before the raise, so the committed result wins; the
    # point is termination with every query answered exactly once
    assert all(r.result.k >= 1 or r.error for r in out)

    # now a commit that raises BEFORE producing a result: the query is
    # answered by _abandon with a scheduler error
    svc2 = DropService()

    def finish_explodes(self, fl):
        raise RuntimeError("commit lost the result")

    monkeypatch.setattr(DropService, "_finish", finish_explodes)
    qid = svc2.submit(xs[0], CFG, zero_cost())
    out2 = svc2.run()
    assert [r.query_id for r in out2] == [qid]
    assert out2[0].error and out2[0].error.startswith("scheduler:")
    assert "commit lost the result" in out2[0].error
    assert svc2.stats.failures == 1
    assert svc2.backlog() == 0


# ------------------------------------------- forced 2-device host platform

PROG = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import numpy as np
import jax
from repro.core import DropConfig
from repro.core.cost import zero_cost
from repro.data import sinusoid_mixture
from repro.serve_drop import DropService, ShardedDropService

assert len(jax.devices()) == 2, jax.devices()
PARITY_CFG = DropConfig(target_tlb=0.95, seed=0, min_iterations=99)
datasets = [sinusoid_mixture(300, 32, rank=4 + i, seed=10 + i)[0] for i in range(4)]
# every reducer type must be placement-invariant, not just the PCA loop
queries = [(x, "pca") for x in datasets] + [
    (datasets[0], m) for m in ("fft", "paa", "dwt", "jl")
]

base = DropService(max_inflight=4, enable_cache=False)
for x, m in queries:
    base.submit(x, PARITY_CFG, zero_cost(), method=m)
ref = base.run()

svc = ShardedDropService(devices=2, max_inflight=4, enable_cache=False)
assert len(svc.devices) == 2
for x, m in queries:
    svc.submit(x, PARITY_CFG, zero_cost(), method=m)
out = svc.run()

bit_identical = all(
    s.result.k == r.result.k
    and s.result.method == r.result.method
    and np.array_equal(s.result.v, r.result.v)
    and np.array_equal(s.result.mean, r.result.mean)
    and len(s.result.iterations) == len(r.result.iterations)
    for r, s in zip(ref, out)
)
print(json.dumps({
    "bit_identical": bit_identical,
    "ks": [s.result.k for s in out],
    "ref_ks": [r.result.k for r in ref],
    "occupancy": svc.stats.device_iterations,
    "steals": svc.stats.steals,
    "iterations": svc.stats.iterations,
}))
'''


@pytest.mark.slow  # subprocess pays a fresh jax init + 2x cold compiles
def test_two_device_run_bit_matches_single_device():
    out = subprocess.run(
        [sys.executable, "-c", PROG],
        capture_output=True, text=True, timeout=1800,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["bit_identical"], res
    assert res["ks"] == res["ref_ks"]
    # the threaded drain must actually use both devices, and every
    # iteration must be accounted to exactly one device
    assert len(res["occupancy"]) == 2, res
    assert all(n > 0 for n in res["occupancy"].values()), res
    assert sum(res["occupancy"].values()) == res["iterations"]


SUFFIX_PROG = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import numpy as np
import jax
from repro.core import DropConfig
from repro.core.cost import zero_cost
from repro.data import sinusoid_mixture
from repro.serve_drop import DropService, ShardedDropService

assert len(jax.devices()) == 2, jax.devices()
PARITY_CFG = DropConfig(target_tlb=0.95, seed=0, min_iterations=99)
# append-only stream: snapshots are prefixes of one generative process
x_full = sinusoid_mixture(700, 48, rank=3, seed=3)[0]
snapshots = [x_full[:500], x_full[:600], x_full]

def drive(svc):
    out = []
    for snap in snapshots:  # sequential: prefix matching is submit-time
        svc.submit(np.ascontiguousarray(snap), PARITY_CFG, zero_cost())
        out.append(svc.run()[0])
    return out

# budget 0: every append takes the suffix-update path on both services
ref = drive(DropService(suffix_budget=0.0))
svc = ShardedDropService(devices=2, suffix_budget=0.0)
assert len(svc.devices) == 2
out = drive(svc)

bit_identical = all(
    s.result.k == r.result.k
    and s.suffix_update == r.suffix_update
    and np.array_equal(s.result.v, r.result.v)
    and np.array_equal(s.result.mean, r.result.mean)
    for r, s in zip(ref, out)
)
print(json.dumps({
    "bit_identical": bit_identical,
    "suffix_flags": [s.suffix_update for s in out],
    "ks": [s.result.k for s in out],
    "suffix_updates": svc.stats.suffix_updates,
    "suffix_update_failures": svc.stats.suffix_update_failures,
    "fit_calls": svc.stats.fit_calls,
}))
'''


@pytest.mark.slow  # subprocess pays a fresh jax init + cold compiles
def test_two_device_suffix_update_parity():
    """The incremental suffix-update path must be placement-invariant: a
    forced 2-device mesh serves the same append stream with bit-identical
    updated maps (the merge is host numpy; the TLB gate compiles the same
    executable per device class) and the same escalation decisions."""
    out = subprocess.run(
        [sys.executable, "-c", SUFFIX_PROG],
        capture_output=True, text=True, timeout=1800,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["bit_identical"], res
    assert res["suffix_flags"] == [False, True, True], res
    assert res["suffix_updates"] == 2, res
    assert res["suffix_update_failures"] == 0, res
