"""Dry-run CLI smoke: the launch/dryrun.py machinery (512 forced devices,
production mesh construction, lower+compile, roofline JSON) end to end for
one small cell, in a subprocess so the device-count flag stays contained."""

import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # 512-device mesh lower+compile: minutes

ROOT = __file__.rsplit("/tests/", 1)[0]


@pytest.fixture(scope="module")
def cell_record(tmp_path_factory):
    out = tmp_path_factory.mktemp("dryrun")
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", "whisper_tiny", "--shape", "decode_32k",
            "--mesh", "single", "--out", str(out), "--force",
        ],
        capture_output=True, text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    path = out / "whisper_tiny__decode_32k__single.json"
    with open(path) as f:
        return json.load(f)


def test_cell_compiles_on_production_mesh(cell_record):
    assert cell_record["status"] == "ok"
    assert cell_record["mesh"] == "single"


def test_roofline_terms_present_and_sane(cell_record):
    r = cell_record["roofline"]
    assert r["chips"] == 256
    assert r["compute_s"] >= 0 and r["memory_s"] > 0
    assert r["dominant"] in ("compute", "memory", "collective")
    assert 0 < r["useful_ratio"] <= 20  # decode: small but positive


def test_memory_analysis_recorded(cell_record):
    assert "CompiledMemoryStats" in cell_record["memory_analysis"]


def test_skip_rule_applied():
    """long_500k on a full-attention arch must be recorded as a skip."""
    from repro.configs.base import SHAPES, cell_is_runnable, get_config

    ok, why = cell_is_runnable(get_config("qwen3_32b"), SHAPES["long_500k"])
    assert not ok and "quadratic" in why
    ok2, _ = cell_is_runnable(get_config("mamba2_2_7b"), SHAPES["long_500k"])
    assert ok2
