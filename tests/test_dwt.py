"""Haar DWT baseline: isometry, contractivity, nesting, min-k behavior."""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.baselines.dwt import dwt_min_k, dwt_transform, haar_expansion
from repro.baselines.svd_pca import pca_min_k
from repro.data import ecg_like, sinusoid_mixture


def test_haar_is_isometry_pow2():
    x, _ = ecg_like(300, 128, seed=0)
    e = haar_expansion(x)
    np.testing.assert_allclose(
        np.linalg.norm(e, axis=1), np.linalg.norm(x, axis=1), rtol=1e-4
    )


def test_haar_isometry_with_padding():
    x, _ = ecg_like(200, 100, seed=1)  # pads 100 -> 128
    e = haar_expansion(x)
    assert e.shape[1] == 128
    np.testing.assert_allclose(
        np.linalg.norm(e, axis=1), np.linalg.norm(x, axis=1), rtol=1e-4
    )


@given(st.integers(2, 40), st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_haar_contractive_property(k, seed):
    x = np.random.default_rng(seed).normal(size=(30, 33)).astype(np.float32)
    t = dwt_transform(x, k)
    i, j = 0, 29
    assert np.linalg.norm(t[i] - t[j]) <= np.linalg.norm(x[i] - x[j]) + 1e-4


def test_smooth_signals_compress_well():
    """Coarse Haar coefficients capture smooth/periodic structure."""
    x, _ = sinusoid_mixture(800, 256, rank=4, seed=2)
    k = dwt_min_k(x, 0.90)
    assert k < 256 // 2


def test_pca_still_beats_dwt():
    """The paper's conclusion extends to the wavelet baseline too."""
    x, _ = ecg_like(800, 128, seed=3)
    assert pca_min_k(x, 0.90) <= dwt_min_k(x, 0.90)
