"""Unit tests for the fault module: injector determinism, straggler
windows, restart budget + backoff. These are the primitives the serving
fleet's supervisor composes, so they get direct coverage here (the
end-to-end chaos paths live in test_fleet.py)."""

import pytest

from repro.fault.faults import (
    FailureInjector,
    NodeFailure,
    RestartPolicy,
    StragglerMonitor,
)


# ------------------------------------------------------------- injector


def test_injector_deterministic_per_seed():
    """Same seed -> identical failure schedule; different seed -> (almost
    surely) a different one. The fleet relies on this to make chaos tests
    reproducible."""

    def schedule(seed, steps=200, p=0.1):
        inj = FailureInjector(failure_prob=p, seed=seed)
        failed = []
        for s in range(steps):
            try:
                inj.maybe_fail(s)
            except NodeFailure:
                failed.append(s)
        return failed

    a = schedule(7)
    b = schedule(7)
    c = schedule(8)
    assert a == b
    assert a, "p=0.1 over 200 steps should inject at least once"
    assert a != c


def test_injector_zero_prob_never_fires():
    inj = FailureInjector(failure_prob=0.0, seed=0)
    for s in range(100):
        inj.maybe_fail(s)
    assert inj.injected == 0


def test_injector_counts_injections():
    inj = FailureInjector(failure_prob=1.0, seed=0)
    with pytest.raises(NodeFailure):
        inj.maybe_fail(0)
    with pytest.raises(NodeFailure):
        inj.maybe_fail(1)
    assert inj.injected == 2


# ------------------------------------------------------------ straggler


def test_straggler_warmup_never_flags():
    """Fewer than 5 historical samples -> no flagging, no matter how slow."""
    mon = StragglerMonitor()
    for step in range(5):
        assert mon.observe(step, 100.0) is False
    assert mon.flagged_steps == []


def test_straggler_flags_and_escalates():
    mon = StragglerMonitor(deadline_factor=3.0, tolerance=3)
    for step in range(10):
        mon.observe(step, 1.0)
    # 10x the median: each observation flags and builds the streak
    flagged = [mon.observe(10 + i, 10.0) for i in range(3)]
    assert flagged == [True, True, True]
    assert mon.flagged_steps == [10, 11, 12]
    assert mon.should_escalate
    # one healthy step resets the streak (but not the flag history)
    assert mon.observe(13, 1.0) is False
    assert not mon.should_escalate
    assert mon.flagged_steps == [10, 11, 12]


def test_straggler_median_is_rolling():
    """The median comes from the trailing window only: a regime change
    (permanently slower steps) stops flagging once the window refills."""
    mon = StragglerMonitor(deadline_factor=3.0, window=8)
    for step in range(8):
        mon.observe(step, 1.0)
    assert mon.observe(8, 10.0) is True  # vs median 1.0
    for step in range(9, 9 + 8):
        mon.observe(step, 10.0)  # new normal fills the window
    assert mon.observe(17, 10.0) is False  # vs median 10.0


def test_straggler_times_bounded_by_window():
    """A long-lived supervisor observes forever; the sample list must not
    grow without bound."""
    mon = StragglerMonitor(window=16)
    for step in range(10_000):
        mon.observe(step, 1.0)
    assert len(mon._times) == 16


# -------------------------------------------------------------- restart


def test_restart_policy_counts_and_succeeds():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] <= 2:
            raise NodeFailure("boom")

    assert RestartPolicy(max_restarts=5).run(flaky) == 2
    assert calls["n"] == 3


def test_restart_policy_exhaustion_reraises():
    def always_fails():
        raise NodeFailure("boom")

    policy = RestartPolicy(max_restarts=2)
    with pytest.raises(NodeFailure):
        policy.run(always_fails)


def test_restart_policy_only_catches_node_failure():
    def other_error():
        raise ValueError("not a node failure")

    with pytest.raises(ValueError):
        RestartPolicy(max_restarts=5).run(other_error)


def test_restart_delay_doubles_and_caps():
    policy = RestartPolicy(backoff_s=0.5, backoff_cap_s=3.0)
    assert policy.delay(1) == 0.5
    assert policy.delay(2) == 1.0
    assert policy.delay(3) == 2.0
    assert policy.delay(4) == 3.0  # 4.0 capped
    assert policy.delay(10) == 3.0


def test_restart_delay_disabled_by_default():
    policy = RestartPolicy()
    assert policy.backoff_s == 0.0
    for n in range(1, 8):
        assert policy.delay(n) == 0.0
    assert policy.delay(0) == 0.0  # 0-based callers get no sleep either


def test_restart_run_sleeps_between_restarts(monkeypatch):
    """run() consumes delay(): the sleep sequence is the doubling ladder."""
    import repro.fault.faults as faults_mod

    slept = []
    monkeypatch.setattr(faults_mod.time, "sleep", slept.append)
    calls = {"n": 0}

    def fails_thrice():
        calls["n"] += 1
        if calls["n"] <= 3:
            raise NodeFailure("boom")

    policy = RestartPolicy(max_restarts=5, backoff_s=0.1, backoff_cap_s=0.15)
    assert policy.run(fails_thrice) == 3
    assert slept == [0.1, 0.15, 0.15]
