"""Process-worker serving fleet: protocol units, supervised round trips,
crash/restart fault handling, chaos injection, and measured placement.

Every blocking wait here carries a timeout — the whole point of the
supervisor is that a dead worker can never hang a client, so a hang IS
the failure mode under test."""

import io
import os
import pickle
import signal
import time

import numpy as np
import pytest

from repro.core import DropConfig
from repro.core.cost import CostModel, knn_cost
from repro.data import sinusoid_mixture
from repro.serve_drop import DropService, FleetSupervisor, IngestFrontend
from repro.serve_drop.fleet import (
    _cost_from_spec,
    _cost_spec,
    _recv_frame,
    _send_frame,
)

CFG = DropConfig(target_tlb=0.9, seed=0)


def _datasets(n, rows=96, dim=12):
    return [
        sinusoid_mixture(rows, dim, rank=3 + i, seed=10 + i)[0]
        for i in range(n)
    ]


def _wait(predicate, timeout_s=30.0, what="condition"):
    deadline = time.perf_counter() + timeout_s
    while not predicate():
        if time.perf_counter() > deadline:
            raise AssertionError(f"timed out waiting for {what}")
        time.sleep(0.02)


# ------------------------------------------------------------ pure units


def test_frame_round_trip():
    buf = io.BytesIO()
    msgs = [
        {"t": "q", "x": np.arange(6, dtype=np.float32).reshape(2, 3)},
        {"t": "hb"},
        {"t": "pong", "blob": b"\0" * 1000},
    ]
    for m in msgs:
        _send_frame(buf, m)
    buf.seek(0)
    out = [_recv_frame(buf) for _ in msgs]
    assert out[1] == {"t": "hb"}
    np.testing.assert_array_equal(out[0]["x"], msgs[0]["x"])
    assert out[2]["blob"] == msgs[2]["blob"]
    assert _recv_frame(buf) is None  # EOF, not an exception


def test_frame_truncation_is_eof():
    buf = io.BytesIO()
    _send_frame(buf, {"t": "q", "payload": b"\0" * 500})
    data = buf.getvalue()
    assert _recv_frame(io.BytesIO(data[: len(data) - 7])) is None


def test_cost_spec_named_and_rejected():
    assert _cost_spec(None) is None
    assert _cost_spec(knn_cost(64)) == "knn"
    rebuilt = _cost_from_spec("knn", 64)
    assert rebuilt.name == "knn"
    # an anonymous closure cannot cross the process boundary
    custom = CostModel(name="custom", fn=lambda k: 0.0)
    with pytest.raises(ValueError, match="downstream"):
        _cost_spec(custom)
    # but a genuinely picklable object rides along as-is
    kind, obj = _cost_spec((1, 2, 3))
    assert kind == "pickled"
    assert _cost_from_spec((kind, obj), 10) == (1, 2, 3)
    assert pickle.dumps(obj)


# ------------------------------------------------------- supervised serve


def test_fleet_round_trip_matches_inprocess():
    """Two workers serve three tenants; per-query k matches the in-process
    service (same scheduler inside every worker)."""
    datasets = _datasets(3)

    svc = DropService()
    for x in datasets:
        svc.submit(x, CFG, downstream="knn")
    expect = {r.query_id: r.result.k for r in svc.run()}

    with FleetSupervisor(workers=2, profile=False) as fleet:
        qids = [fleet.submit(x, CFG, downstream="knn") for x in datasets]
        results = fleet.run(timeout=180)
    assert [r.query_id for r in results] == sorted(qids)
    assert all(r.error is None for r in results)
    assert [r.result.k for r in results] == [expect[q] for q in sorted(expect)]
    assert {r.worker for r in results} <= {"worker-0", "worker-1"}
    assert fleet.stats.queries == 3
    assert fleet.stats.worker_deaths == 0


def test_fleet_worker_cache_serves_repeats():
    x = _datasets(1)[0]
    with FleetSupervisor(workers=1, profile=False) as fleet:
        first = fleet.result(fleet.submit(x, CFG), timeout=120)
        second = fleet.result(fleet.submit(x, CFG), timeout=120)
    assert first.error is None and second.error is None
    assert not first.cache_hit
    assert second.cache_hit  # the worker's own BasisReuseCache hit
    assert second.worker == first.worker  # sticky tenant home
    assert fleet.stats.cache_hits == 1


def test_fleet_kill9_requeues_restarts_and_completes():
    """The acceptance scenario: kill -9 a worker mid-serve. Its in-flight
    queries must finish on a survivor (retried, not errored), the slot must
    restart within the RestartPolicy bounds, and nothing may hang."""
    datasets = _datasets(4)
    with FleetSupervisor(
        workers=2,
        profile=False,
        placement="rr",
        worker_slowdowns=[2.0, 0.0],  # holds worker-0's queries in flight
    ) as fleet:
        qids = [fleet.submit(x, CFG) for x in datasets]
        w0 = fleet._workers[0]
        _wait(lambda: w0.assigned, what="worker-0 to hold in-flight work")
        time.sleep(0.3)  # let it enter its slowdown sleep
        os.kill(w0.proc.pid, signal.SIGKILL)

        results = {r.query_id: r for r in fleet.run(timeout=180)}
        assert sorted(results) == sorted(qids)
        assert all(r.error is None for r in results.values())
        assert any(r.retries > 0 for r in results.values())
        assert fleet.stats.worker_deaths == 1
        assert fleet.stats.requeued_queries >= 1

        # the slot comes back under the restart policy...
        _wait(
            lambda: fleet.stats.worker_restarts >= 1
            and fleet._workers[0].state == "ready",
            what="worker-0 restart",
        )
        # ...and serves again
        res = fleet.result(fleet.submit(_datasets(1)[0], CFG), timeout=120)
        assert res.error is None


def test_fleet_retry_exhaustion_errors_instead_of_hanging():
    """With no retry budget and no survivor to absorb the work, the killed
    worker's query must FINISH — with ServeResult.error — not hang."""
    x = _datasets(1)[0]
    with FleetSupervisor(
        workers=1,
        profile=False,
        max_query_retries=0,
        worker_slowdowns=[5.0],
    ) as fleet:
        qid = fleet.submit(x, CFG)
        w0 = fleet._workers[0]
        _wait(lambda: w0.assigned, what="query in flight")
        time.sleep(0.2)
        os.kill(w0.proc.pid, signal.SIGKILL)
        res = fleet.result(qid, timeout=60)
    assert res.error is not None
    assert "worker-0" in res.error and "retries exhausted" in res.error
    assert res.retries == 1
    assert res.result.k == 0 and not res.result.satisfied
    assert fleet.stats.failures == 1


def test_fleet_chaos_injected_failures_all_queries_complete():
    """FailureInjector-driven crashes (os._exit inside the worker) walk the
    same death->requeue->restart ladder as a real kill; every query still
    gets a result."""
    datasets = _datasets(4)
    with FleetSupervisor(
        workers=2,
        profile=False,
        placement="rr",
        failure_prob=0.6,
        failure_seed=0,
        restart_policy=None,  # default: 3 restarts, 50ms base backoff
    ) as fleet:
        qids = [fleet.submit(x, CFG) for x in datasets]
        results = {r.query_id: r for r in fleet.run(timeout=240)}
    assert sorted(results) == sorted(qids)  # nothing lost, nothing hung
    assert fleet.stats.worker_deaths >= 1  # p=0.6 x 4 queries: certain
    assert fleet.stats.worker_restarts >= 1
    # queries either survived a retry or were errored out by exhaustion —
    # both count as "finished"; a hang would have tripped the run timeout
    assert all(
        (r.error is None) or ("retries exhausted" in r.error)
        for r in results.values()
    )


# ------------------------------------------------------------- placement


def test_fleet_rebalance_moves_tenant_off_congested_slow_worker():
    """Measured-cost placement: a tenant whose home worker is slow (and
    holding a queue) moves to the faster idle worker; the supervisor's
    speed estimate for the slow worker degrades from observed serve
    times."""
    x = _datasets(1)[0]
    with FleetSupervisor(
        workers=2,
        profile=False,  # equal priors: placement starts index-tied
        placement="cost",
        worker_slowdowns=[1.0, 0.0],
    ) as fleet:
        # burst of one tenant: q1 homes on worker-0 (index tiebreak); with
        # q1 still queued there, worker-1 is decisively cheaper for q2
        q1 = fleet.submit(x, CFG)
        q2 = fleet.submit(x, CFG)
        r1 = fleet.result(q1, timeout=120)
        r2 = fleet.result(q2, timeout=120)
        assert fleet.stats.rebalances >= 1
        assert r1.worker == "worker-0"
        assert r2.worker == "worker-1"
        # home moved: later queries stay on the fast worker
        r3 = fleet.result(fleet.submit(x, CFG), timeout=120)
        assert r3.worker == "worker-1"
        speeds = fleet.worker_speeds()
        assert speeds["worker-0"] < speeds["worker-1"]
    assert r1.error is None and r2.error is None and r3.error is None


def test_fleet_reprofile_recovers_degraded_link_placement():
    """Stale link profiles: worker-0's link degrades right AFTER its
    startup probe (the echo delay kicks in once the probe's pings are
    spent), so the startup alpha stays optimistically tiny. The age-out
    reprofile re-fits the link off the hot path; with the refreshed
    alpha ~ delay/2, the measured-cost placement moves the tenant to the
    healthy worker. The serve also rides the downstream-execution
    protocol (``xds``) across the worker pipe."""
    from repro.serve_drop.cache import dataset_fingerprint

    x = _datasets(1)[0]
    with FleetSupervisor(
        workers=2,
        reprofile_interval_s=0.3,
        reprofile_after_serves=0,  # isolate the time-based age-out
        worker_link_delays=[0.25],  # worker-0 only, >> any serve cost
    ) as fleet:
        r1 = fleet.result(fleet.submit(x, CFG), timeout=120)
        assert r1.error is None
        _wait(
            lambda: fleet.link_profiles()["worker-0"].alpha_s > 0.05,
            timeout_s=60.0,
            what="reprofile to pick up worker-0's degraded RTT",
        )
        assert fleet.stats.reprofiles >= 1
        # make the cost comparison deterministic: home the tenant on the
        # degraded worker with a known serve estimate and unit speeds —
        # cost_0 ~ 0.125 + 0.05 vs cost_1 ~ 0.05 clears the 0.7 margin
        fp = dataset_fingerprint(np.ascontiguousarray(x, dtype=np.float32))
        with fleet._lock:
            fleet._tenant_home[fp] = 0
            fleet._tenant_ref_s[fp] = 0.05
            for w in fleet._workers:
                w.speed = 1.0
        r2 = fleet.result(
            fleet.submit(x, CFG, downstream="knn", execute_downstream=True),
            timeout=120,
        )
        assert r2.error is None
        assert r2.worker == "worker-1"
        assert fleet.stats.rebalances >= 1
        assert r2.downstream is not None  # xds crossed the pipe


# ---------------------------------------------------------- ingest bridge


def test_ingest_frontend_over_fleet():
    """The async front-end treats the supervisor as just another service:
    submit from the client thread, block on result, close() drains."""
    datasets = _datasets(2)
    fleet = FleetSupervisor(workers=2, profile=False)
    with fleet, IngestFrontend(fleet, queue_capacity=8) as fe:
        qids = [fe.submit(x, CFG) for x in datasets]
        results = [fe.result(q, timeout=120) for q in qids]
    assert all(r.error is None for r in results)
    assert all(r.worker in ("worker-0", "worker-1") for r in results)


def test_fleet_subscription_round_trip():
    """Pub/sub across the process boundary: subscribe homes the tenant on
    a worker, deltas stream back through the supervisor read loop with
    supervisor-stamped contiguous seq, and unsubscribe delivers the
    terminal closed — the same protocol the in-process service serves
    (test_delta_serve.py pins its parity)."""
    from repro.serve_drop import (
        SubscribeQuery,
        SubscriberState,
        SubscriptionClosed,
    )

    x = sinusoid_mixture(200, 16, rank=3, seed=4)[0]
    client = SubscriberState()
    with FleetSupervisor(workers=1, profile=False) as fleet:
        sid = fleet.subscribe(SubscribeQuery(x=x[:150], cfg=CFG, eps=1.0))

        def next_delta(timeout_s=120.0):
            out = []
            _wait(lambda: out.extend(fleet.poll_deltas(sid, max_n=1)) or out,
                  timeout_s, "delta")
            return out[0]

        boot = next_delta()
        client.apply(boot)
        assert boot["kind"] == "rollback" and boot["reason"] == "subscribe"
        assert client.rows.shape[0] == 150
        fleet.append(sid, x[150:])
        d = next_delta()
        client.apply(d)
        assert d["kind"] in ("append", "rollback")
        assert client.rows.shape[0] == 200
        np.testing.assert_allclose(
            client.rows, client.basis.transform(x), atol=1e-4
        )
        assert fleet.stats.subscriptions == 1
        fleet.unsubscribe(sid)
        d = next_delta()
        client.apply(d)
        assert d["kind"] == "closed" and client.closed
        assert sid not in fleet.live_subscriptions()
        with pytest.raises(SubscriptionClosed):
            fleet.append(sid, x[:8])


def test_fleet_worker_death_closes_homed_subscriptions():
    """A killed worker's subscription state is unrecoverable (it lives in
    the worker's process memory), so unlike stateless queries it cannot be
    requeued on a survivor: the supervisor must close every homed
    subscription with an error-carrying terminal delta instead of leaving
    waiters hanging."""
    from repro.serve_drop import SubscribeQuery, SubscriptionClosed

    x = sinusoid_mixture(160, 16, rank=3, seed=4)[0]
    with FleetSupervisor(workers=2, profile=False) as fleet:
        sid = fleet.subscribe(SubscribeQuery(x=x, cfg=CFG, eps=1.0))
        out = []
        _wait(lambda: out.extend(fleet.poll_deltas(sid)) or out,
              timeout_s=120.0, what="bootstrap delta")
        assert out[0]["kind"] == "rollback"
        home = fleet._subs[sid].worker
        os.kill(fleet._workers[home].proc.pid, signal.SIGKILL)
        term = []
        _wait(lambda: term.extend(fleet.poll_deltas(sid)) or term,
              timeout_s=120.0, what="terminal delta after worker death")
        assert term[-1]["kind"] == "closed"
        assert term[-1]["error"]  # the death reason travels to the client
        assert sid not in fleet.live_subscriptions()
        with pytest.raises(SubscriptionClosed):
            fleet.append(sid, x[:8])
        # the supervisor itself stays healthy: the slot restarts and the
        # fleet keeps serving plain queries
        res = fleet.result(fleet.submit(_datasets(1)[0], CFG), timeout=120)
        assert res.error is None
