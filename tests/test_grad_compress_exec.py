"""DROP-compressed cross-pod gradient reduction: EXECUTED validation on a
pod-only mesh (subprocess; 2 forced host devices).

Invariants: (1) full-rank orthonormal bases make the compressed step
numerically identical to the dense reduce (V Vᵀ = I, zero residual);
(2) reduced-rank bases cut the pod-wire bytes; (3) error-feedback residuals
are nonzero and carried. Also covers elastic re-mesh (fault/faults.remesh).
"""

import json
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # subprocess multi-device compile: minutes

PROG = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.configs.base import get_smoke_config
from repro.models.model import init_model
from repro.sharding.specs import ShardCtx
from repro.train.optimizer import OptimizerConfig, init_optimizer
from repro.train.train_step import make_train_step, init_compression_residual
from repro.train.grad_compress import _path_key
from repro.roofline.hlo_parse import analyze

mesh = Mesh(np.array(jax.devices()).reshape(2,), ("pod",))
cfg = get_smoke_config("tinyllama_1_1b")
ctx = ShardCtx(mesh=mesh, tuned=False)
params = init_model(cfg, jax.random.PRNGKey(0))
opt = init_optimizer(params)
B, S = 4, 32
key = jax.random.PRNGKey(1)
batch = {
    "inputs": jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32),
    "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32),
    "mask": jnp.ones((B, S), jnp.float32),
}
resid = init_compression_residual(params, 2)

def make_bases(rankdiv):
    bases = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(params):
        names = tuple(p.key if hasattr(p, "key") else str(p) for p in path)
        if any(n in names for n in ("wq","wk","wv","wo","w_gate","w_up","w_down")):
            cols = leaf.shape[-1]
            r = max(cols // rankdiv, 2)
            q, _ = np.linalg.qr(np.random.default_rng(0).normal(size=(cols, r)).astype(np.float32))
            bases[_path_key(path)] = jnp.asarray(q)
    return bases

out = {}
for tag, bases in (("dense", {}), ("fullrank", make_bases(1)), ("low", make_bases(8))):
    step = make_train_step(cfg, OptimizerConfig(), ctx, remat="none", compress_bases=bases)
    with mesh:
        jitted = jax.jit(step)
        compiled = jitted.lower(params, opt, batch, resid).compile()
        p2, o2, m, r2 = jitted(params, opt, batch, resid)
    t = analyze(compiled.as_text())
    resid_sum = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree_util.tree_leaves(r2))
    out[tag] = {"loss": float(m["loss"]), "wire": t.collective_bytes, "resid": resid_sum}

# elastic remesh: move params from the pod mesh to a 1x2 data mesh
from repro.fault.faults import remesh
from repro.sharding.specs import param_specs
mesh2 = Mesh(np.array(jax.devices()).reshape(1, 2), ("data", "model"))
specs = param_specs(params)
moved = remesh(params, mesh2, specs)
same = all(
    bool(jnp.allclose(a.astype(jnp.float32), b.astype(jnp.float32)))
    for a, b in zip(jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(moved))
)
out["remesh_values_preserved"] = same
out["remesh_sharded"] = any(
    len(l.sharding.device_set) == 2 for l in jax.tree_util.tree_leaves(moved)
)
print(json.dumps(out))
'''


@pytest.fixture(scope="module")
def results():
    proc = subprocess.run(
        [sys.executable, "-c", PROG],
        capture_output=True, text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_fullrank_compression_identical_to_dense(results):
    assert results["fullrank"]["loss"] == pytest.approx(
        results["dense"]["loss"], abs=1e-4
    )
    assert results["fullrank"]["resid"] == pytest.approx(0.0, abs=1e-3)


def test_low_rank_cuts_pod_wire_bytes(results):
    assert results["low"]["wire"] < 0.55 * results["dense"]["wire"]


def test_error_feedback_carried(results):
    assert results["low"]["resid"] > 1.0  # nonzero residual accumulates


def test_elastic_remesh_preserves_values(results):
    assert results["remesh_values_preserved"]
    assert results["remesh_sharded"]
