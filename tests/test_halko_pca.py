"""SVD-Halko vs exact PCA: subspace quality, spectrum capture, numpy oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.halko import svd_halko, svd_halko_np
from repro.core.pca import center, center_masked, explained_spectrum, pca_fit_svd
from repro.data import sinusoid_mixture, white_noise


@pytest.fixture(scope="module")
def data():
    x, _ = sinusoid_mixture(600, 100, rank=7, seed=0)
    return jnp.asarray(x)


def _subspace_overlap(v1, v2):
    """Largest principal angle cosine product: ||V1ᵀ V2||_F² / k."""
    v1, v2 = np.asarray(v1), np.asarray(v2)
    k = min(v1.shape[1], v2.shape[1])
    return np.linalg.norm(v1[:, :k].T @ v2[:, :k]) ** 2 / k


def test_halko_matches_exact_subspace(data):
    _, c = center(data)
    v_h, s_h = svd_halko(c, 7, jax.random.PRNGKey(0), power_iters=2)
    _, v_e, s_e = pca_fit_svd(data, k=7)
    assert _subspace_overlap(v_h, v_e) > 0.98
    np.testing.assert_allclose(np.asarray(s_h), np.asarray(s_e), rtol=0.05)


def test_halko_columns_orthonormal(data):
    _, c = center(data)
    v, _ = svd_halko(c, 10, jax.random.PRNGKey(1))
    g = np.asarray(v).T @ np.asarray(v)
    np.testing.assert_allclose(g, np.eye(10), atol=2e-3)


def test_halko_jax_matches_numpy_oracle_quality(data):
    """Same algorithm, independent implementations: captured variance agrees."""
    _, c = center(data)
    cn = np.asarray(c)
    v_j, _ = svd_halko(c, 7, jax.random.PRNGKey(2), power_iters=1)
    v_n, _ = svd_halko_np(cn, 7, seed=3, power_iters=1)
    var_j = np.linalg.norm(cn @ np.asarray(v_j)) ** 2
    var_n = np.linalg.norm(cn @ v_n) ** 2
    assert var_j == pytest.approx(var_n, rel=0.02)


def test_center_masked_matches_unpadded(data):
    x = np.asarray(data)[:50]
    pad = np.zeros((14, x.shape[1]), dtype=x.dtype)
    xp = jnp.asarray(np.concatenate([x, pad]))
    mask = jnp.asarray(np.concatenate([np.ones(50), np.zeros(14)]))
    mean_p, c_p = center_masked(xp, mask)
    mean_u, c_u = center(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(mean_p), np.asarray(mean_u), atol=1e-5)
    np.testing.assert_allclose(np.asarray(c_p)[:50], np.asarray(c_u), atol=1e-5)
    assert np.abs(np.asarray(c_p)[50:]).max() == 0.0


def test_padded_rows_do_not_change_right_singular_vectors(data):
    x = np.asarray(data)[:80]
    c = x - x.mean(0)
    cpad = np.concatenate([c, np.zeros((40, x.shape[1]), dtype=c.dtype)])
    _, _, vt1 = np.linalg.svd(c, full_matrices=False)
    _, _, vt2 = np.linalg.svd(cpad, full_matrices=False)
    assert _subspace_overlap(vt1[:5].T, vt2[:5].T) > 0.999


def test_spectrum_rapid_falloff_for_structured_slow_for_noise():
    xs, _ = sinusoid_mixture(400, 64, rank=4, seed=1)
    xn, _ = white_noise(400, 64, seed=1)
    spec_s = explained_spectrum(xs)
    spec_n = explained_spectrum(xn)
    # paper Fig 3: structured time series capture most variance in few PCs
    assert spec_s[:4].sum() > 0.9
    assert spec_n[:4].sum() < 0.2
