"""Flash-decode Pallas kernel: interpret-mode sweeps vs the jnp oracle, plus
agreement with the model-level decode_attention path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode.flash_decode import flash_decode_pallas
from repro.kernels.flash_decode.ref import flash_decode_ref


def _case(b, t, kv, g, hd, dtype, seed=0, fill=None):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, kv, g, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, t, kv, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, t, kv, hd), jnp.float32).astype(dtype)
    if fill is None:
        valid = jnp.ones((b, t), bool)
    else:
        valid = jnp.arange(t)[None, :] < jnp.asarray(fill)[:, None]
    return q, k, v, valid


@pytest.mark.parametrize(
    "b,t,kv,g,hd,bt",
    [
        (2, 64, 4, 2, 16, 32),   # multi-tile T (online-softmax carry)
        (1, 32, 2, 4, 8, 32),    # single tile
        (3, 50, 2, 2, 16, 16),   # ragged T -> padded tail masked
        (2, 16, 1, 8, 32, 8),    # MHA-as-GQA degenerate kv=1
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_matches_ref(b, t, kv, g, hd, bt, dtype):
    q, k, v, valid = _case(b, t, kv, g, hd, dtype)
    got = flash_decode_pallas(q, k, v, valid, block_t=bt, interpret=True)
    want = flash_decode_ref(q, k, v, valid)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_decode_respects_length_mask():
    """Entries beyond each sequence's filled length must not contribute."""
    b, t, kv, g, hd = 2, 64, 2, 2, 16
    q, k, v, _ = _case(b, t, kv, g, hd, jnp.float32, seed=1)
    fill = [10, 40]
    valid = jnp.arange(t)[None, :] < jnp.asarray(fill)[:, None]
    got = flash_decode_pallas(q, k, v, valid, block_t=16, interpret=True)
    # reference computed on the truncated caches directly
    for i, f in enumerate(fill):
        want_i = flash_decode_ref(
            q[i : i + 1], k[i : i + 1, :f], v[i : i + 1, :f],
            jnp.ones((1, f), bool),
        )
        np.testing.assert_allclose(
            np.asarray(got[i : i + 1]), np.asarray(want_i), rtol=2e-5, atol=2e-5
        )


def test_flash_decode_matches_model_decode_attention():
    """Kernel vs the model-level decode path (layout differences included)."""
    from repro.models.attention import decode_attention

    b, t, kv, g, hd = 2, 48, 4, 2, 16
    q, k, v, valid = _case(b, t, kv, g, hd, jnp.float32, seed=2)
    got = flash_decode_pallas(q, k, v, valid, block_t=16, interpret=True)
    want = decode_attention(q[:, None].transpose(0, 1, 2, 3, 4), k, v,
                            length_mask=valid)  # (B,1,KV,G,hd)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want[:, 0]), rtol=1e-4, atol=1e-4
    )


def test_flash_decode_online_softmax_stability():
    """Large score magnitudes must not overflow (the running-max rescale)."""
    b, t, kv, g, hd = 1, 64, 2, 2, 8
    q, k, v, valid = _case(b, t, kv, g, hd, jnp.float32, seed=3)
    q = q * 100.0  # extreme logits
    got = flash_decode_pallas(q, k, v, valid, block_t=16, interpret=True)
    assert bool(jnp.isfinite(got).all())
    want = flash_decode_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-4)
