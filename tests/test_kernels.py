"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle, swept
over shapes (divisible, ragged, degenerate) and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.center_gram.center_gram import center_gram_pallas
from repro.kernels.center_gram.ref import center_gram_ref
from repro.kernels.matmul.matmul import matmul_pallas
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.pairwise_tlb.pairwise_tlb import pairwise_tlb_pallas
from repro.kernels.pairwise_tlb.ref import pairwise_tlb_ref

# interpret-mode kernels run the kernel body in python; keep blocks small so
# the sweep stays fast while still exercising multi-tile grids + padding
MM_BLOCKS = dict(block_m=16, block_n=16, block_k=16)
TLB_BLOCKS = dict(block_p=16, block_k=16)
CG_BLOCKS = dict(block_d=16, block_m=32)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, dtype=jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (32, 32, 32),     # exact tiles
        (48, 16, 64),     # multi-tile
        (33, 17, 19),     # ragged -> padding path
        (5, 40, 3),       # blocks larger than dims
        (16, 1, 16),      # degenerate contraction
        (1, 16, 1),       # single row/col
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_kernel_matches_ref(m, k, n, dtype):
    a = _rand(jax.random.PRNGKey(0), (m, k), dtype)
    b = _rand(jax.random.PRNGKey(1), (k, n), dtype)
    got = matmul_pallas(a, b, interpret=True, **MM_BLOCKS)
    want = matmul_ref(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize(
    "p,d,kdim",
    [
        (16, 32, 16),    # exact tiles
        (32, 64, 48),    # multi-tile K (prefix carry across tiles)
        (19, 33, 21),    # ragged
        (4, 8, 1),       # single component
        (1, 16, 16),     # single pair
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_tlb_kernel_matches_ref(p, d, kdim, dtype):
    kx, ky, kv = jax.random.split(jax.random.PRNGKey(2), 3)
    xi = _rand(kx, (p, d), dtype)
    xj = _rand(ky, (p, d), dtype)
    # orthonormal-ish basis so the table is meaningful
    v = jnp.linalg.qr(_rand(kv, (d, d), jnp.float32).astype(jnp.float32))[0][:, :kdim]
    v = v.astype(dtype)
    got = pairwise_tlb_pallas(xi, xj, v, interpret=True, **TLB_BLOCKS)
    want = pairwise_tlb_ref(xi, xj, v)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_pairwise_tlb_kernel_coincident_pair_is_one():
    x = jnp.ones((8, 16), jnp.float32)
    v = jnp.eye(16)[:, :8]
    got = pairwise_tlb_pallas(x, x, v, interpret=True, **TLB_BLOCKS)
    np.testing.assert_allclose(np.asarray(got), 1.0)


def test_pairwise_tlb_kernel_monotone_and_bounded():
    kx, ky, kv = jax.random.split(jax.random.PRNGKey(3), 3)
    xi = jax.random.normal(kx, (24, 48))
    xj = jax.random.normal(ky, (24, 48))
    v = jnp.linalg.qr(jax.random.normal(kv, (48, 48)))[0]
    got = np.asarray(pairwise_tlb_pallas(xi, xj, v, interpret=True, **TLB_BLOCKS))
    assert (np.diff(got, axis=1) >= -1e-5).all()
    assert got.min() >= 0 and got.max() <= 1 + 1e-5
    np.testing.assert_allclose(got[:, -1], 1.0, atol=1e-4)  # full basis: isometry


@pytest.mark.parametrize(
    "m,d",
    [
        (64, 32),    # exact tiles
        (96, 48),    # multi-tile
        (37, 23),    # ragged
        (8, 50),     # d > m
        (200, 5),    # skinny
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_center_gram_kernel_matches_ref(m, d, dtype):
    x = _rand(jax.random.PRNGKey(4), (m, d), dtype)
    got = center_gram_pallas(x, interpret=True, **CG_BLOCKS)
    want = center_gram_ref(x)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=tol, atol=tol * m
    )


def test_center_gram_is_psd_and_symmetric():
    x = jax.random.normal(jax.random.PRNGKey(5), (60, 24))
    g = np.asarray(center_gram_pallas(x, interpret=True, **CG_BLOCKS))
    np.testing.assert_allclose(g, g.T, atol=1e-3)
    ev = np.linalg.eigvalsh(g)
    assert ev.min() > -1e-2


def test_gram_eigvecs_match_svd_right_vectors():
    """Covariance-path PCA (via the fused kernel) agrees with SVD-path PCA."""
    x = jax.random.normal(jax.random.PRNGKey(6), (128, 20))
    g = np.asarray(center_gram_pallas(x, interpret=True, **CG_BLOCKS))
    w, vecs = np.linalg.eigh(g)
    v_gram = vecs[:, ::-1][:, :5]
    c = np.asarray(x) - np.asarray(x).mean(0)
    _, _, vt = np.linalg.svd(c, full_matrices=False)
    v_svd = vt[:5].T
    overlap = np.linalg.norm(v_gram.T @ v_svd) ** 2 / 5
    assert overlap > 0.999


# --------------------------------------------------- pairwise_reduce sweeps

from repro.kernels.pairwise_reduce.pairwise_reduce import (  # noqa: E402
    pairwise_dbscan_pallas,
    pairwise_kde_pallas,
    pairwise_knn_pallas,
)
from repro.kernels.pairwise_reduce.ref import (  # noqa: E402
    pairwise_dbscan_ref,
    pairwise_kde_ref,
    pairwise_knn_ref,
)

PR_BLOCKS = dict(block_q=16, block_k=32)

PR_SHAPES = [
    (32, 32, 8),   # exact tiles
    (48, 80, 16),  # multi-tile carry across dataset tiles
    (33, 61, 7),   # ragged -> padding path on both axes
    (1, 16, 4),    # single query row
    (3, 3, 2),     # blocks larger than dims
]


@pytest.mark.parametrize("mq,mk,d", PR_SHAPES)
def test_pairwise_knn_kernel_matches_ref(mq, mk, d):
    x = _rand(jax.random.PRNGKey(7), (mk, d), jnp.float32)
    xq = x[:mq]  # kNN queries ARE dataset rows (self-exclusion contract)
    gi, gd = pairwise_knn_pallas(xq, x, mk, interpret=True, **PR_BLOCKS)
    ri, rd = pairwise_knn_ref(xq, x, mk)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(ri))
    np.testing.assert_allclose(
        np.asarray(gd), np.asarray(rd), rtol=1e-5, atol=1e-5
    )


def test_pairwise_knn_kernel_near_duplicates_tie_break():
    """First-occurrence argmin across tiles: the kernel's strict-< carry
    must match the ref's global argmin on (near-)duplicate rows."""
    x = np.array(_rand(jax.random.PRNGKey(8), (70, 6), jnp.float32))
    x[40] = x[3]          # exact duplicate across tiles
    x[41] = x[3] + 1e-4   # near duplicate
    x = jnp.asarray(x)
    gi, _ = pairwise_knn_pallas(x, x, 70, interpret=True, **PR_BLOCKS)
    ri, _ = pairwise_knn_ref(x, x, 70)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(ri))


@pytest.mark.parametrize("mq,mk,d", PR_SHAPES)
def test_pairwise_dbscan_kernel_matches_ref(mq, mk, d):
    x = _rand(jax.random.PRNGKey(9), (mk, d), jnp.float32)
    xq = x[:mq]
    eps2 = 1.5 ** 2
    gc, gp = pairwise_dbscan_pallas(xq, x, mk, eps2, interpret=True, **PR_BLOCKS)
    rc, rp = pairwise_dbscan_ref(xq, x, mk, eps2)
    np.testing.assert_array_equal(np.asarray(gc), np.asarray(rc))
    # widths differ by padding; the extra words must be all-zero
    gp, rp = np.asarray(gp), np.asarray(rp)
    w = min(gp.shape[1], rp.shape[1])
    np.testing.assert_array_equal(gp[:, :w], rp[:, :w])
    assert not gp[:, w:].any() and not rp[:, w:].any()


@pytest.mark.parametrize("mq,mk,d", PR_SHAPES)
def test_pairwise_kde_kernel_matches_ref(mq, mk, d):
    x = _rand(jax.random.PRNGKey(10), (mk, d), jnp.float32)
    xq = x[:mq]
    sums, comps = pairwise_kde_pallas(xq, x, mk, 0.5, interpret=True, **PR_BLOCKS)
    got = np.asarray(sums, np.float64) + np.asarray(comps, np.float64)
    want = pairwise_kde_ref(xq, x, mk, 0.5)
    np.testing.assert_allclose(
        got, np.asarray(want), rtol=2e-5, atol=1e-6
    )


# ------------------------------------------------- split-variant sweeps
# The grid-parallel shard decomposition: per-shard partials from one
# pallas_call must merge to exactly the sequential kernel's answer.

from repro.kernels.pairwise_reduce.pairwise_reduce import (  # noqa: E402
    pairwise_dbscan_split_pallas,
    pairwise_kde_split_pallas,
    pairwise_knn_split_pallas,
)


def _shard_pad(x, shards, bk):
    """Tile-aligned shard padding, mirroring analytics.split._split_prepare."""
    mk = x.shape[0]
    nk = -(-mk // bk)
    tps = -(-nk // shards)
    rows = shards * tps * bk
    return jnp.pad(x, ((0, rows - mk), (0, 0)))


@pytest.mark.parametrize("shards", [1, 2, 3])
def test_pairwise_knn_split_kernel_merges_to_sequential(shards):
    from repro.analytics.split import merge_knn_partials

    x = np.array(_rand(jax.random.PRNGKey(11), (70, 6), jnp.float32))
    x[40] = x[3]  # cross-shard duplicate: tie must keep the earlier shard
    x = jnp.asarray(x)
    xp = _shard_pad(x, shards, PR_BLOCKS["block_k"])
    gi, gd = pairwise_knn_split_pallas(
        x, xp, 70, shards, interpret=True, **PR_BLOCKS
    )
    idx, d2 = merge_knn_partials(np.asarray(gi), np.asarray(gd))
    ri, rd = pairwise_knn_ref(x, x, 70)
    np.testing.assert_array_equal(idx, np.asarray(ri))
    np.testing.assert_allclose(d2, np.asarray(rd), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shards", [1, 2, 3])
def test_pairwise_dbscan_split_kernel_merges_to_sequential(shards):
    from repro.analytics.split import merge_dbscan_partials

    x = _rand(jax.random.PRNGKey(12), (61, 7), jnp.float32)
    xp = _shard_pad(x, shards, PR_BLOCKS["block_k"])
    gc, gp = pairwise_dbscan_split_pallas(
        x, xp, 61, 1.5 ** 2, shards, interpret=True, **PR_BLOCKS
    )
    counts, packed = merge_dbscan_partials(np.asarray(gc), np.asarray(gp))
    rc, rp = pairwise_dbscan_ref(x, x, 61, 1.5 ** 2)
    np.testing.assert_array_equal(counts, np.asarray(rc))
    rp = np.asarray(rp)
    w = min(packed.shape[1], rp.shape[1])
    np.testing.assert_array_equal(packed[:, :w], rp[:, :w])
    assert not packed[:, w:].any() and not rp[:, w:].any()


@pytest.mark.parametrize("shards", [1, 2, 3])
def test_pairwise_kde_split_kernel_merges_to_sequential(shards):
    from repro.analytics.split import merge_kde_partials

    x = _rand(jax.random.PRNGKey(13), (80, 5), jnp.float32)
    xp = _shard_pad(x, shards, PR_BLOCKS["block_k"])
    gs, gc = pairwise_kde_split_pallas(
        x, xp, 80, 0.5, shards, interpret=True, **PR_BLOCKS
    )
    dens = merge_kde_partials(np.asarray(gs), np.asarray(gc), 80)
    want = np.asarray(pairwise_kde_ref(x, x, 80, 0.5)) / 80.0
    np.testing.assert_allclose(dens, want, rtol=2e-5, atol=1e-6)
