"""DROP KV-cache compression: algebra, rank discovery, attention accuracy."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import decode_attention
from repro.serve.kv_compress import (
    KVCompressConfig,
    compress_cache_layer,
    decode_attention_compressed,
    discover_kv_basis,
)


@pytest.fixture(scope="module")
def cache():
    """Structured keys/values: low-rank + noise (attention-sink-like)."""
    rng = np.random.default_rng(0)
    b, t, kv, hd = 2, 64, 4, 32
    u = rng.normal(size=(b * t * kv, 6)).astype(np.float32)
    wk = rng.normal(size=(6, hd)).astype(np.float32)
    wv = rng.normal(size=(6, hd)).astype(np.float32)
    k = (u @ wk + 0.05 * rng.normal(size=(b * t * kv, hd))).reshape(b, t, kv, hd)
    v = (u @ wv + 0.05 * rng.normal(size=(b * t * kv, hd))).reshape(b, t, kv, hd)
    return jnp.asarray(k), jnp.asarray(v)


def test_discover_basis_finds_low_rank(cache):
    k, _ = cache
    rows = np.asarray(k).reshape(-1, k.shape[-1])
    basis = discover_kv_basis(rows, KVCompressConfig(target_tlb=0.95), seed=0)
    assert basis.shape[0] == k.shape[-1]
    assert basis.shape[1] <= 16  # true rank is 6 (+noise)


def test_full_rank_compression_is_exact(cache):
    k, v = cache
    hd = k.shape[-1]
    eye = jnp.eye(hd)
    ck, cv = compress_cache_layer(k, v, eye, eye)
    q = jax.random.normal(jax.random.PRNGKey(1), (2, 1, 4, 2, hd))
    valid = jnp.ones((2, k.shape[1]), bool)
    exact = decode_attention(q, k, v, length_mask=valid)
    comp = decode_attention_compressed(q, ck, cv, eye, eye, valid)
    np.testing.assert_allclose(
        np.asarray(comp, np.float32), np.asarray(exact, np.float32), atol=1e-3
    )


def test_compressed_attention_tracks_exact(cache):
    k, v = cache
    hd = k.shape[-1]
    # default target 0.98: softmax amplifies score distortion, so the basis
    # must capture the keys' full intrinsic rank (see KVCompressConfig note)
    kc = KVCompressConfig()
    bk = discover_kv_basis(np.asarray(k).reshape(-1, hd), kc, seed=0)
    bv = discover_kv_basis(np.asarray(v).reshape(-1, hd), kc, seed=1)
    ck, cv = compress_cache_layer(k, v, jnp.asarray(bk), jnp.asarray(bv))
    q = jax.random.normal(jax.random.PRNGKey(2), (2, 1, 4, 2, hd))
    valid = jnp.ones((2, k.shape[1]), bool)
    exact = np.asarray(decode_attention(q, k, v, length_mask=valid), np.float32)
    comp = np.asarray(
        decode_attention_compressed(q, ck, cv, jnp.asarray(bk), jnp.asarray(bv), valid),
        np.float32,
    )
    rel = np.linalg.norm(exact - comp) / np.linalg.norm(exact)
    assert rel < 0.05


def test_sub_rank_compression_degrades_sharply(cache):
    """The sensitivity the config documents: one rank below the intrinsic
    rank, softmax amplification blows the error up by >10x."""
    k, v = cache
    hd = k.shape[-1]
    cfg = KVCompressConfig()
    bk = discover_kv_basis(np.asarray(k).reshape(-1, hd), cfg, seed=0)
    bv = discover_kv_basis(np.asarray(v).reshape(-1, hd), cfg, seed=1)
    bk_sub = bk[:, :-2]  # drop below the keys' intrinsic rank
    q = jax.random.normal(jax.random.PRNGKey(3), (2, 1, 4, 2, hd))
    valid = jnp.ones((2, k.shape[1]), bool)
    exact = np.asarray(decode_attention(q, k, v, length_mask=valid), np.float32)

    def err(basis_k):
        ck, cv = compress_cache_layer(k, v, jnp.asarray(basis_k), jnp.asarray(bv))
        a = np.asarray(
            decode_attention_compressed(
                q, ck, cv, jnp.asarray(basis_k), jnp.asarray(bv), valid
            ),
            np.float32,
        )
        return np.linalg.norm(exact - a) / np.linalg.norm(exact)

    assert err(bk_sub) > 5 * err(bk)


def test_compression_reduces_bytes(cache):
    k, v = cache
    hd = k.shape[-1]
    bk = discover_kv_basis(
        np.asarray(k).reshape(-1, hd), KVCompressConfig(target_tlb=0.9), seed=0
    )
    assert bk.shape[1] < hd // 2  # at least 2x cache shrink on structured keys
