"""Per-architecture smoke tests: reduced config of the same family, one
forward + one gradient step on CPU, asserting shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, get_config, get_smoke_config
from repro.models.model import forward, init_model, loss_fn
from repro.sharding.specs import ShardCtx

pytestmark = pytest.mark.slow  # per-arch forward+grad: minutes, not CI-fast

CTX = ShardCtx(mesh=None)
B, S = 2, 32


def _batch(cfg, key):
    b = {
        "inputs": jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32),
        "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.is_encoder_decoder:
        b["frames"] = jax.random.normal(
            key, (B, cfg.encoder_ctx, cfg.d_model), jnp.float32
        )
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    batch = _batch(cfg, key)
    logits = jax.jit(lambda p, b: forward(p, b, cfg, CTX))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_one_train_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = init_model(cfg, key)
    batch = _batch(cfg, key)

    def loss(p):
        return loss_fn(p, batch, cfg, CTX, remat="none")[0]

    l0, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert bool(jnp.isfinite(l0))
    # every parameter receives a finite gradient
    for path, g in jax.tree_util.tree_leaves_with_path(grads):
        assert bool(jnp.isfinite(g).all()), path
    # sgd step decreases loss on the same batch (sanity of grad direction)
    lr = 0.5
    new_params = jax.tree_util.tree_map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
    l1 = jax.jit(loss)(new_params)
    assert float(l1) < float(l0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_remat_matches_no_remat(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(2)
    params = init_model(cfg, key)
    batch = _batch(cfg, key)
    l_plain = loss_fn(params, batch, cfg, CTX, remat="none")[0]
    l_remat = loss_fn(params, batch, cfg, CTX, remat="full")[0]
    np.testing.assert_allclose(float(l_plain), float(l_remat), rtol=1e-5)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_is_published_size(arch):
    """Guard the exact assigned hyperparameters (full configs never allocate)."""
    cfg = get_config(arch)
    expected = {
        "qwen2_vl_2b": (28, 1536, 12, 2, 8960, 151936),
        "qwen3_32b": (64, 5120, 64, 8, 25600, 151936),
        "tinyllama_1_1b": (22, 2048, 32, 4, 5632, 32000),
        "granite_3_8b": (40, 4096, 32, 8, 12800, 49155),
        "deepseek_67b": (95, 8192, 64, 8, 22016, 102400),
        "mixtral_8x7b": (32, 4096, 32, 8, 14336, 32000),
        "granite_moe_3b_a800m": (32, 1536, 24, 8, 512, 49155),
        "mamba2_2_7b": (64, 2560, 0, 0, 0, 50280),
        "zamba2_1_2b": (38, 2048, 32, 32, 8192, 32000),
        "whisper_tiny": (4, 384, 6, 6, 1536, 51865),
    }[arch]
    got = (
        cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
        cfg.d_ff, cfg.vocab_size,
    )
    assert got == expected
    # param-count plausibility vs the advertised model size
    approx_b = {
        "qwen2_vl_2b": 1.5, "qwen3_32b": 32.8, "tinyllama_1_1b": 1.1,
        "granite_3_8b": 8.2, "deepseek_67b": 67.4, "mixtral_8x7b": 46.7,
        "granite_moe_3b_a800m": 3.3, "mamba2_2_7b": 2.7, "zamba2_1_2b": 1.1,
        "whisper_tiny": 0.039,
    }[arch]
    assert cfg.param_count() / 1e9 == pytest.approx(approx_b, rel=0.12)


def test_shapes_registry():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert SHAPES["train_4k"].seq_len == 4096 and SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768 and SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288 and SHAPES["long_500k"].global_batch == 1
