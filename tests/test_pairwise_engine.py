"""Fused pairwise-analytics engine: fused-vs-legacy parity for all three
tasks (kNN indices, DBSCAN labels, KDE densities) over padded tails,
near-duplicate rows, and the m=1 degenerate input; both the CPU
(mask+argmin) and interpret-mode Pallas kernel paths; the packed-bitmask
neighbor-set property; the legacy DBSCAN remainder-recompile regression;
and the cost-model extension."""

import numpy as np
import pytest

import sys

from repro.analytics import (
    dbscan,
    dbscan_legacy,
    gaussian_kde,
    gaussian_kde_legacy,
    nearest_neighbors,
    nearest_neighbors_legacy,
    pairwise_dbscan,
    pairwise_kde,
    pairwise_knn,
    unpack_neighbors,
)
from repro.analytics.dbscan import _neighbor_lists

# the package re-exports dbscan the FUNCTION; the module object is needed
# for monkeypatching the jitted radius scan
dbscan_mod = sys.modules["repro.analytics.dbscan"]


@pytest.fixture(scope="module")
def xdup():
    """Seeded data with a padded tail (131 % 32 != 0) and a near-duplicate
    pair (rows 3/7 differ by 1e-4: exercises top-2 ordering and tie-ish
    argmin behavior)."""
    rng = np.random.default_rng(3)
    x = rng.normal(size=(131, 8)).astype(np.float32)
    x[7] = x[3] + 1e-4
    return x


# ------------------------------------------------------------------- kNN


@pytest.mark.parametrize("use_top_k", [False, True])
def test_knn_fused_matches_legacy(xdup, use_top_k):
    """Both engine reductions (CPU mask+argmin, accelerator top_k(2))
    reproduce the legacy host loop exactly, padded tail included."""
    ref = nearest_neighbors_legacy(xdup, block=64)
    idx, d2 = pairwise_knn(xdup, 32, 32, use_top_k=use_top_k)
    np.testing.assert_array_equal(idx, ref)
    true_d2 = np.sum((xdup - xdup[idx]) ** 2, axis=1)
    np.testing.assert_allclose(d2, true_d2, rtol=1e-3, atol=1e-4)


def test_knn_single_point_returns_self(xdup):
    """m=1 keeps the legacy degenerate behavior (self index) on every path."""
    one = xdup[:1]
    assert nearest_neighbors_legacy(one).tolist() == [0]
    assert nearest_neighbors(one).tolist() == [0]
    idx, _ = pairwise_knn(one, 32, 32)
    assert idx.tolist() == [0]


def test_knn_default_block_padding(xdup):
    """m far below the default 1024 block: one padded tile, exact parity."""
    np.testing.assert_array_equal(
        nearest_neighbors(xdup), nearest_neighbors_legacy(xdup)
    )


# ----------------------------------------------------------------- DBSCAN


@pytest.mark.parametrize("min_samples", [2, 4, 8])
def test_dbscan_fused_matches_legacy_blobs(min_samples):
    """Label parity on clustered data with a ragged tail. Both paths share
    the BFS, so parity is exact (border-point labels are traversal-order
    dependent — identical neighbor arrays mean identical traversal)."""
    rng = np.random.default_rng(0)
    x = np.concatenate(
        [
            rng.normal(0, 0.12, size=(61, 3)),
            rng.normal(4, 0.12, size=(49, 3)),
            rng.uniform(-8, 8, size=(20, 3)),  # sparse: noise candidates
        ]
    ).astype(np.float32)
    want = dbscan_legacy(x, eps=0.6, min_samples=min_samples, block=64)
    got = dbscan(x, eps=0.6, min_samples=min_samples, block=64)
    np.testing.assert_array_equal(got, want)


def test_dbscan_fused_matches_legacy_near_duplicates(xdup):
    want = dbscan_legacy(xdup, eps=1.5, min_samples=3, block=32)
    got = dbscan(xdup, eps=1.5, min_samples=3, block=32)
    np.testing.assert_array_equal(got, want)


def test_dbscan_single_point():
    one = np.zeros((1, 4), np.float32)
    np.testing.assert_array_equal(
        dbscan(one, eps=0.5, min_samples=2),
        dbscan_legacy(one, eps=0.5, min_samples=2),
    )


def test_packed_bitmask_equals_nonzero_sets(xdup):
    """The packed uint32 bitmask decodes to EXACTLY the neighbor sets the
    legacy per-row np.nonzero produced, and the fused degree counts are
    those set sizes + self."""
    for eps, block in ((0.8, 32), (1.5, 64), (3.0, 128)):
        counts, packed = pairwise_dbscan(xdup, eps, block, block)
        nbrs = _neighbor_lists(xdup, eps, block=block)
        for p in range(xdup.shape[0]):
            got = unpack_neighbors(packed[p], p, xdup.shape[0])
            np.testing.assert_array_equal(got, nbrs[p])
            assert counts[p] == nbrs[p].size + 1  # self included


def test_packed_bitmask_property_random_shapes():
    """Seeded sweep over ragged shapes/eps (the deterministic mirror of the
    hypothesis property below, always collected)."""
    for seed in range(4):
        rng = np.random.default_rng(seed)
        m = int(rng.integers(1, 90))
        d = int(rng.integers(1, 6))
        eps = float(rng.uniform(0.3, 3.0))
        x = rng.normal(size=(m, d)).astype(np.float32)
        counts, packed = pairwise_dbscan(x, eps, 32, 32)
        sq = np.sum(x * x, axis=1)
        d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
        want_mask = d2 <= np.float32(eps * eps)  # single rounding, as engine
        for p in range(m):
            want = np.flatnonzero(want_mask[p])
            np.testing.assert_array_equal(
                unpack_neighbors(packed[p], p, m), want[want != p]
            )
            assert counts[p] == want.size


def test_packed_bitmask_property_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        m=st.integers(1, 70),
        d=st.integers(1, 5),
        eps=st.floats(0.2, 4.0),
    )
    def check(seed, m, d, eps):
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(m, d)).astype(np.float32)
        _counts, packed = pairwise_dbscan(x, eps, 32, 32)
        sq = np.sum(x * x, axis=1)
        d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
        mask = d2 <= np.float32(eps * eps)
        for p in range(m):
            want = np.flatnonzero(mask[p])
            np.testing.assert_array_equal(
                unpack_neighbors(packed[p], p, m), want[want != p]
            )

    check()


def test_legacy_neighbor_lists_single_compiled_shape(monkeypatch):
    """Remainder-block regression: the legacy radius scan must pad the tail
    block instead of minting a fresh compile per distinct m % block."""
    shapes = []
    orig = dbscan_mod._radius_block

    def spy(xq, xs, eps2):
        shapes.append(tuple(xq.shape))
        return orig(xq, xs, eps2)

    monkeypatch.setattr(dbscan_mod, "_radius_block", spy)
    rng = np.random.default_rng(1)
    x = rng.normal(size=(130, 5)).astype(np.float32)  # 130 = 2*64 + 2
    nbrs = _neighbor_lists(x, 1.0, block=64)
    assert len(nbrs) == 130
    assert len(shapes) == 3
    assert set(shapes) == {(64, 5)}  # ONE compiled query shape, tail padded
    # and the padded tail rows still produce correct neighbor sets
    sq = np.sum(x * x, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    for p in (128, 129):
        want = np.flatnonzero(d2[p] <= 1.0)
        np.testing.assert_array_equal(nbrs[p], want[want != p])


# -------------------------------------------------------------------- KDE


def test_kde_fused_matches_legacy(xdup):
    """Only the summation tree differs between the paths (tile partials vs
    one row reduce), so parity is tight but not bitwise."""
    for queries in (None, xdup[:37] + 0.25):
        want = gaussian_kde_legacy(xdup, queries, bandwidth=0.8, block=64)
        got = gaussian_kde(xdup, queries, bandwidth=0.8, block=32)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-7)


def test_kde_single_point_and_row():
    one = np.ones((1, 3), np.float32)
    np.testing.assert_allclose(
        gaussian_kde(one), gaussian_kde_legacy(one), rtol=1e-6
    )
    q = np.zeros((1, 3), np.float32)
    np.testing.assert_allclose(
        gaussian_kde(one, q, bandwidth=0.5),
        gaussian_kde_legacy(one, q, bandwidth=0.5),
        rtol=1e-6,
    )


# ------------------------------------------- interpret-mode kernel path


def test_engine_kernel_path_parity_interpret(monkeypatch, xdup):
    """use_kernels=True under REPRO_PALLAS_INTERPRET=1 runs the Pallas
    pairwise_reduce kernels and must agree with the fused jnp scan on all
    three tasks (kNN bit-exact, KDE within summation-tree tolerance)."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    x = xdup[:70, :6]
    idx, _ = pairwise_knn(x, 32, 32, use_kernels=True)
    np.testing.assert_array_equal(idx, nearest_neighbors_legacy(x, block=32))
    got = dbscan(x, eps=1.2, min_samples=3, block=32, use_kernels=True)
    want = dbscan_legacy(x, eps=1.2, min_samples=3, block=32)
    np.testing.assert_array_equal(got, want)
    dens = gaussian_kde(x, bandwidth=0.9, block=32, use_kernels=True)
    np.testing.assert_allclose(
        dens, gaussian_kde_legacy(x, bandwidth=0.9, block=32),
        rtol=2e-5, atol=1e-7,
    )


def test_engine_kernel_flag_safe_without_backend(monkeypatch, xdup):
    """On a plain CPU backend (no interpret env) use_kernels=True must fall
    back to the fused jnp scan — same results, no kernel machinery."""
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    x = xdup[:50]
    np.testing.assert_array_equal(
        pairwise_knn(x, 32, 32, use_kernels=True)[0],
        pairwise_knn(x, 32, 32)[0],
    )


# ------------------------------------------------------------ cost model


def test_downstream_cost_mem_term_and_legacy_escape():
    from repro.core.cost import (
        DEFAULT_KNN_COEFF,
        DEFAULT_KNN_MEM_COEFF,
        downstream_cost,
    )

    m = 4000
    new = downstream_cost("knn", m)
    old = downstream_cost("knn", m, legacy_cost=True)
    # legacy model is the pure O(m^2 k): zero at k=0, and exactly the
    # k-term of the new model
    assert old(0) == 0.0
    assert new(0) == pytest.approx(DEFAULT_KNN_MEM_COEFF * m * m)
    for k in (1, 5, 50):
        assert new(k) - new(0) == pytest.approx(old(k))
        assert old(k) == pytest.approx(DEFAULT_KNN_COEFF * m * m * k)
    # the mem term is method-independent: it can never flip which k wins
    assert new(5) - new(3) == pytest.approx(old(5) - old(3))


def test_optimizer_choice_invariant_to_mem_term():
    """The k-independent term shifts every method's priced objective by the
    same amount, so re-pricing ONE run's outcomes under either model picks
    the same method (two separate runs would re-measure R with wall-clock
    noise, which is not what this asserts)."""
    from repro.core import DropConfig
    from repro.core.cost import downstream_cost
    from repro.data import sinusoid_mixture
    from repro.pipeline import WorkloadOptimizer

    x = sinusoid_mixture(300, 32, rank=3, seed=7)[0]
    cfg = DropConfig(target_tlb=0.9, seed=0)
    rep = WorkloadOptimizer(methods=("fft", "paa", "dwt"), cfg=cfg).optimize(
        x, "knn"
    )
    sat = [m for m, o in rep.outcomes.items() if o.result.satisfied]
    assert sat
    new_cost = downstream_cost("knn", x.shape[0])
    old_cost = downstream_cost("knn", x.shape[0], legacy_cost=True)
    pick = {
        c: min(
            sat,
            key=lambda m: rep.outcomes[m].reduce_s
            + c(rep.outcomes[m].result.k),
        )
        for c in (new_cost, old_cost)
    }
    assert pick[new_cost] == pick[old_cost]


def test_bucket_tile_rows_quantizes_to_tile():
    from repro.core.bucketing import ShapeBucketCache

    b = ShapeBucketCache()
    assert b.bucket_tile_rows(1, 32) == 32
    assert b.bucket_tile_rows(32, 32) == 32
    assert b.bucket_tile_rows(33, 32) == 64
    assert b.bucket_tile_rows(130, 64) == 192
    # recorded under the existing rows family: {32, 64, 192}, one repeat
    assert b.stats["rows"].requests == 4
    assert b.stats["rows"].hits == 1
    assert b.stats["rows"].sizes == {32, 64, 192}
