"""Hypothesis property-based tests on the system's core invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.baselines.fft import fft_real_expansion, fft_transform
from repro.baselines.paa import paa_transform
from repro.core.pca import center, pca_fit_svd
from repro.core.sampling import draw_sample, schedule_sizes
from repro.core.tlb import prefix_tlb_table, sample_pairs
from repro.core.progress import extrapolate
from repro.train.optimizer import clip_by_global_norm

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def matrices(draw, max_m=60, max_d=24):
    m = draw(st.integers(4, max_m))
    d = draw(st.integers(3, max_d))
    seed = draw(st.integers(0, 2**31 - 1))
    x = np.random.default_rng(seed).normal(size=(m, d)).astype(np.float32)
    return x


# --------------------------------------------------------------------------
# INVARIANT: every reduction operator we use in TLB contexts is CONTRACTIVE
# --------------------------------------------------------------------------

@given(matrices(), st.integers(1, 8))
@settings(**SETTINGS)
def test_pca_truncation_contractive(x, k):
    k = min(k, min(x.shape))
    _, v, _ = pca_fit_svd(jnp.asarray(x), k=k)
    t = x @ np.asarray(v)
    i, j = 0, x.shape[0] - 1
    assert np.linalg.norm(t[i] - t[j]) <= np.linalg.norm(x[i] - x[j]) + 1e-4


@given(matrices(), st.integers(1, 8))
@settings(**SETTINGS)
def test_paa_contractive(x, k):
    t = paa_transform(x, min(k, x.shape[1]))
    i, j = 0, x.shape[0] - 1
    assert np.linalg.norm(t[i] - t[j]) <= np.linalg.norm(x[i] - x[j]) + 1e-4


@given(matrices(), st.integers(1, 8))
@settings(**SETTINGS)
def test_fft_contractive_and_full_isometric(x, k):
    t = fft_transform(x, min(k, x.shape[1]))
    i, j = 0, x.shape[0] - 1
    assert np.linalg.norm(t[i] - t[j]) <= np.linalg.norm(x[i] - x[j]) + 1e-4
    e = fft_real_expansion(x)
    np.testing.assert_allclose(
        np.linalg.norm(e, axis=1), np.linalg.norm(x, axis=1), rtol=2e-3
    )


# --------------------------------------------------------------------------
# INVARIANT: the prefix-TLB table is in [0,1], monotone in k, and 1 at full
# rank (orthogonal basis preserves L2) — the properties DROP's search relies on
# --------------------------------------------------------------------------

@given(matrices(max_m=40, max_d=16), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_prefix_tlb_invariants(x, seed):
    d = x.shape[1]
    q = np.linalg.qr(np.random.default_rng(seed).normal(size=(d, d)))[0]
    pairs = sample_pairs(x.shape[0], 16, np.random.default_rng(seed))
    tab = np.asarray(
        prefix_tlb_table(
            jnp.asarray(x[pairs[:, 0]]),
            jnp.asarray(x[pairs[:, 1]]),
            jnp.asarray(q.astype(np.float32)),
        )
    )
    assert tab.min() >= 0 and tab.max() <= 1 + 1e-5
    assert (np.diff(tab, axis=1) >= -1e-4).all()
    np.testing.assert_allclose(tab[:, -1], 1.0, atol=5e-3)


# --------------------------------------------------------------------------
# INVARIANT: centering makes column means zero; zero-padding rows never
# changes the right singular space (the padded-bucket trick)
# --------------------------------------------------------------------------

@given(matrices())
@settings(**SETTINGS)
def test_centering_zero_mean(x):
    _, c = center(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(c).mean(axis=0), 0.0, atol=1e-4)


# --------------------------------------------------------------------------
# INVARIANT: sampling plumbing
# --------------------------------------------------------------------------

@given(st.integers(4, 5000), st.lists(st.floats(0.001, 1.0), min_size=1,
                                      max_size=12))
@settings(**SETTINGS)
def test_schedule_sizes_monotone_bounded(m, fracs):
    sizes = schedule_sizes(m, fracs)
    assert all(2 <= s <= m for s in sizes)
    assert sizes == sorted(set(sizes))


@given(st.integers(10, 500), st.integers(2, 100), st.integers(0, 1000))
@settings(**SETTINGS)
def test_draw_sample_no_duplicates_and_in_range(m, size, seed):
    rng = np.random.default_rng(seed)
    hard = rng.integers(0, m, size=min(7, m))
    idx = draw_sample(m, size, rng, hard_points=hard, reuse_fraction=0.2)
    assert len(np.unique(idx)) == len(idx)
    assert idx.min() >= 0 and idx.max() < m
    assert len(idx) <= min(size, m)


# --------------------------------------------------------------------------
# INVARIANT: progress extrapolation is exact on linear sequences
# --------------------------------------------------------------------------

@given(st.floats(-100, 100), st.floats(-10, 10),
       st.integers(1, 100), st.integers(1, 100))
@settings(**SETTINGS)
def test_linear_extrapolation_exact(intercept, slope, m1, dm):
    m2, m3 = m1 + dm, m1 + 2 * dm
    f = lambda m: intercept + slope * m
    got = extrapolate(f(m1), f(m2), m1, m2, m3)
    assert got == pytest.approx(f(m3), rel=1e-4, abs=1e-4)


# --------------------------------------------------------------------------
# INVARIANT: gradient clipping never increases the global norm, preserves
# direction
# --------------------------------------------------------------------------

@given(matrices(max_m=10, max_d=10), st.floats(0.01, 100.0))
@settings(**SETTINGS)
def test_clip_preserves_direction_bounds_norm(g, max_norm):
    tree = {"g": jnp.asarray(g)}
    clipped, norm = clip_by_global_norm(tree, max_norm)
    n2 = float(jnp.linalg.norm(clipped["g"]))
    assert n2 <= max_norm * (1 + 1e-3) + 1e-6
    if float(norm) > 1e-6:
        cos = float(
            jnp.sum(clipped["g"] * tree["g"])
            / (jnp.linalg.norm(clipped["g"]) * norm + 1e-12)
        )
        assert cos > 0.999


# --------------------------------------------------------------------------
# INVARIANT: MoE dispatch conserves tokens (no duplication; drops only at
# capacity) and is a convex combination per kept token
# --------------------------------------------------------------------------

@given(st.integers(4, 64), st.integers(2, 8), st.integers(1, 4),
       st.integers(0, 1000))
@settings(**SETTINGS)
def test_moe_identity_experts_reproduce_input(n, e, k, seed):
    """With every expert = identity-ish (w_down @ w_gate path), generous
    capacity, outputs must be a convex combination of expert outputs =
    bounded by input magnitudes."""
    from repro.models.moe import moe_ffn

    k = min(k, e)
    d, f = 8, 16
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    params = {
        "router": jnp.asarray(rng.normal(size=(d, e)).astype(np.float32)),
        "w_gate": jnp.ones((e, d, f), jnp.float32) * 0.1,
        "w_up": jnp.ones((e, d, f), jnp.float32) * 0.1,
        "w_down": jnp.ones((e, f, d), jnp.float32) * 0.1,
    }
    out, aux = moe_ffn(
        x, params, num_experts=e, experts_per_token=k, capacity_factor=8.0
    )
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    # balance loss ~1 near uniform routing; bounded away from 0 and inf
    assert 0.3 < float(aux) < float(e) + 1.0
