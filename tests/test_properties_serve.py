"""Hypothesis properties for the serve-layer quantization and fingerprint.

Deterministic mirrors of the core invariants live in test_drop_serve.py so
environments without hypothesis still cover them; this module sweeps random
shapes. Skipped (not failed) when hypothesis is absent, matching
test_properties.py.

What is (and is not) claimed about bucketing:

* padding is idempotent — quantizing a quantized size is the identity;
* pair-batch padding is BIT-exact — padded rows are sliced off before they
  can touch the estimate, and a row of a pairwise table depends only on its
  own pair;
* full ``compute_basis`` through buckets preserves the DECISION — k and
  satisfiability match an unbucketed run; the basis columns themselves may
  rotate within near-degenerate singular subspaces when row padding changes
  the SVD's floating-point path, which is why the service's bit-parity
  guarantees are always stated for a fixed quantization policy (and the
  shared-vs-private cache property below is bit-exact).
"""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.basis_search import compute_basis  # noqa: E402
from repro.core.bucketing import ShapeBucketCache, round_up  # noqa: E402
from repro.core.tlb import TLBEstimator  # noqa: E402
from repro.core.types import DropConfig  # noqa: E402
from repro.serve_drop import dataset_fingerprint  # noqa: E402

SETTINGS = dict(max_examples=20, deadline=None)

IDENTITY = dict(rank_quantum=1, pair_quantum=1, row_quantum=1)


@st.composite
def matrices(draw, min_m=8, max_m=60, max_d=16):
    m = draw(st.integers(min_m, max_m))
    d = draw(st.integers(4, max_d))
    seed = draw(st.integers(0, 2**31 - 1))
    return np.random.default_rng(seed).normal(size=(m, d)).astype(np.float32)


# ----------------------------------------------------------- idempotence


@given(st.integers(1, 4096), st.integers(1, 512))
@settings(**SETTINGS)
def test_round_up_idempotent_and_dominating(n, q):
    r = round_up(n, q)
    assert r >= n and r % q == 0
    assert round_up(r, q) == r


@given(st.integers(1, 512), st.integers(1, 512))
@settings(**SETTINGS)
def test_bucket_families_idempotent(n, hard):
    bucket = ShapeBucketCache()
    assert bucket.bucket_pairs(bucket.bucket_pairs(n)) == bucket.bucket_pairs(n)
    assert bucket.bucket_rows(bucket.bucket_rows(n)) == bucket.bucket_rows(n)
    b = bucket.bucket_rank(n, hard)
    assert bucket.bucket_rank(b, hard) == b
    assert b >= min(n, max(hard, 1))  # never truncates below the hard cap


# ------------------------------------------------------ bit-exactness


@given(matrices(), st.integers(1, 8), st.integers(1, 64))
@settings(**SETTINGS)
def test_pair_bucketing_bit_matches_unbucketed(x, k, p):
    """The padded pair batch, sliced back, is bit-identical to the unpadded
    one: each table row depends only on its own pair, and padded pairs are
    dropped before any reduction."""
    k = min(k, min(x.shape))
    v = np.linalg.svd(x - x.mean(0), full_matrices=False)[2].T[:, :k]
    p = min(p, x.shape[0] * (x.shape[0] - 1) // 2)
    e_bucketed = TLBEstimator(
        x, jnp.asarray(v), np.random.default_rng(11),
        bucket=ShapeBucketCache(pair_quantum=128),
    )
    e_plain = TLBEstimator(
        x, jnp.asarray(v), np.random.default_rng(11),
        bucket=ShapeBucketCache(**IDENTITY),
    )
    np.testing.assert_array_equal(e_bucketed.table(p), e_plain.table(p))


@given(matrices(min_m=12), st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_shared_and_private_bucket_caches_bit_match(x, seed):
    """Quantization is stateless: a tenant routed through a shared (already
    populated) bucket cache gets bit-identical results to one with a private
    cache of the same quanta — the property that lets the service share one
    cache per device class across tenants."""
    cfg = DropConfig(target_tlb=0.9, svd="full", seed=seed)
    sample = x[: max(4, x.shape[0] // 2)]
    shared = ShapeBucketCache()
    shared.bucket_rows(999)  # pre-populate: statefulness must not leak
    shared.bucket_pairs(7)
    r1 = compute_basis(x, sample, None, cfg, jax.random.PRNGKey(seed),
                       np.random.default_rng(seed + 1), bucket=shared)
    r2 = compute_basis(x, sample, None, cfg, jax.random.PRNGKey(seed),
                       np.random.default_rng(seed + 1),
                       bucket=ShapeBucketCache())
    assert r1.k == r2.k and r1.satisfied == r2.satisfied
    assert r1.tlb_mean == r2.tlb_mean
    np.testing.assert_array_equal(r1.v_full, r2.v_full)
    np.testing.assert_array_equal(r1.mean, r2.mean)


@given(matrices(min_m=12), st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_bucketed_compute_basis_preserves_decision(x, seed):
    """Bucketed vs unbucketed compute_basis: the returned decision (k,
    satisfiability) must match up to CI noise at the boundary; the docstring
    explains why the basis itself is only subspace-equal."""
    cfg = DropConfig(target_tlb=0.9, svd="full", seed=seed)
    sample = x[: max(4, x.shape[0] // 2)]
    r1 = compute_basis(x, sample, None, cfg, jax.random.PRNGKey(seed),
                       np.random.default_rng(seed + 1),
                       bucket=ShapeBucketCache())
    r2 = compute_basis(x, sample, None, cfg, jax.random.PRNGKey(seed),
                       np.random.default_rng(seed + 1),
                       bucket=ShapeBucketCache(**IDENTITY))
    assert r1.satisfied == r2.satisfied
    assert abs(r1.k - r2.k) <= 1  # boundary CI noise, as in test_search_parity


# ----------------------------------------------------------- fingerprint


@given(matrices(min_m=10), st.integers(1, 5))
@settings(**SETTINGS)
def test_fingerprint_appending_rows_changes_it(x, extra):
    grown = np.concatenate([x, x[:extra]], axis=0)
    assert dataset_fingerprint(grown) != dataset_fingerprint(x)
    assert dataset_fingerprint(x) == dataset_fingerprint(x.copy())


@given(st.integers(150, 400), st.integers(4, 12),
       st.integers(0, 2**31 - 1), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_fingerprint_unsampled_permutation_vs_distinct_data(m, d, seed, seed2):
    """Permuting rows beyond the strided subsample aliases (same
    fingerprint — the documented trust-domain trade-off the cache TTL
    bounds), while a truly different dataset of the same shape does not
    collide with it."""
    x = np.random.default_rng(seed).normal(size=(m, d)).astype(np.float32)
    stride = max(1, m // 64)
    if stride < 3:
        return  # all rows sampled: nothing to permute invisibly
    aliased = x.copy()
    aliased[[1, 2]] = aliased[[2, 1]]  # rows 1, 2 are never in x[::stride]
    assert dataset_fingerprint(aliased) == dataset_fingerprint(x)
    other = np.random.default_rng(seed2).normal(size=(m, d)).astype(np.float32)
    if not np.array_equal(other, x):  # seeds may coincide
        assert dataset_fingerprint(other) != dataset_fingerprint(x)


# ------------------------------------------------- incremental subspace


@given(st.integers(250, 420), st.sampled_from([3, 4, 5]),
       st.integers(1, 20), st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_suffix_update_tlb_matches_refit_property(m0, rank, pct, seed):
    """Across append sizes (1-20%), intrinsic ranks, and seeds: folding the
    suffix into the fitted basis (core.subspace) never loses more than
    0.005 TLB to a full refit on a shared evaluation sample — the claim
    that lets the serving layer replace cold refits with O(suffix) updates.
    The bound is ONE-sided: at near-degenerate k-vs-k+1 boundaries the
    refit's own CI-gated estimate can overshoot its true quality, so the
    update is sometimes the *better* map by far more than 0.005 — being
    better must not fail the property. The tracker is bootstrapped the way
    the service does it (``PcaDropReducer.tracker()``: fit basis + headroom
    columns), and ``min_iterations`` pins the full schedule — the repo's
    determinism convention, and what keeps the comparison about the MERGE
    rather than about how early the base fit happened to terminate.
    Deterministic mirrors live in test_suffix_update.py."""
    from repro.core.cost import zero_cost
    from repro.core.drop import PcaDropReducer
    from repro.core.reducer import reduce
    from repro.core.subspace import suffix_update
    from repro.core.tlb import sample_pairs, transform_tlb_sampled
    from repro.data import sinusoid_mixture

    ms = max(1, m0 * pct // 100)
    x = sinusoid_mixture(m0 + ms, 48, rank=rank, seed=seed % 1000)[0]
    base, grown = x[:m0], x
    cfg = DropConfig(target_tlb=0.95, seed=seed % 97, min_iterations=99)

    runner = PcaDropReducer(base, cfg, zero_cost())
    while runner.step():
        pass
    _, res, _ = suffix_update(runner.tracker(), grown, cfg)
    rr = reduce(grown, "pca", cfg, zero_cost())

    pairs = sample_pairs(grown.shape[0], 4000, np.random.default_rng(7))
    tlb_upd, _, _ = transform_tlb_sampled(grown, res.transform(grown), pairs)
    tlb_fit, _, _ = transform_tlb_sampled(grown, rr.transform(grown), pairs)
    assert res.v.dtype == np.float32  # float32 contract under sweep too
    assert tlb_upd >= tlb_fit - 0.005, (m0, rank, pct, tlb_upd, tlb_fit)


# ------------------------------------------------ incremental analytics


@given(
    st.integers(30, 90),
    st.lists(st.integers(1, 25), min_size=1, max_size=4),
    st.integers(2, 8),
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=10, deadline=None)
def test_incremental_analytics_matches_cold_after_every_append(
    m0, cuts, k, seed
):
    """The delta protocol's downstream half, swept over random append
    sequences: after EVERY append, the incrementally maintained kNN
    indices/distances and DBSCAN labels are BIT-identical to a cold
    rebuild over the grown rows, and KDE densities match to the f64
    compensated-fold tolerance. Block size 17 forces non-tile-aligned
    suffix boundaries on every step. The deterministic mirror (through
    the full DropService subscription ladder, rollbacks included) lives
    in test_delta_serve.py."""
    from repro.analytics import IncrementalAnalytics

    rng = np.random.default_rng(seed)
    total = m0 + sum(cuts)
    y = rng.normal(size=(total, k)).astype(np.float32)
    inc = IncrementalAnalytics(
        y[:m0], eps=1.0, min_samples=4, bandwidth=1.0, block=17
    )
    lo = m0
    for s in cuts:
        inc.append(y[lo: lo + s])
        lo += s
        snap = inc.snapshot()
        cold = IncrementalAnalytics(
            y[:lo], eps=1.0, min_samples=4, bandwidth=1.0, block=17
        ).snapshot()
        assert np.array_equal(snap.knn_idx, cold.knn_idx)
        assert np.array_equal(snap.knn_d2, cold.knn_d2)
        assert np.array_equal(snap.labels, cold.labels)
        np.testing.assert_allclose(
            snap.densities, cold.densities, atol=1e-6
        )
