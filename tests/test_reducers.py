"""Reducer protocol contracts: baseline min-k properties (contractivity,
monotonicity in the target) and bit-for-bit parity between each one-step
Reducer's result() and the legacy function API it wraps."""

import numpy as np
import pytest

from repro.baselines import (
    dwt_min_k,
    fft_min_k,
    fft_transform,
    jl_min_k,
    jl_transform,
    paa_min_k,
    paa_transform,
)
from repro.baselines.dwt import dwt_transform, haar_expansion
from repro.baselines.fft import fft_real_expansion
from repro.core import DropConfig, drop, make_reducer, reduce
from repro.core.tlb import nested_prefix_tlb, sample_pairs
from repro.data import ecg_like, sinusoid_mixture


@pytest.fixture(scope="module")
def ecg():
    return ecg_like(500, 96, seed=0)[0]


# ------------------------------------------------- contractivity properties


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize(
    "expansion", [fft_real_expansion, haar_expansion], ids=["fft", "dwt"]
)
def test_nested_prefix_tlb_is_contractive(expansion, seed):
    """Every prefix of a nested orthonormal expansion lower-bounds distances:
    the sampled TLB curve never exceeds 1 and is nondecreasing in k."""
    x = np.random.default_rng(seed).normal(size=(120, 37)).astype(np.float32)
    pairs = sample_pairs(x.shape[0], 200, np.random.default_rng(seed + 10))
    curve = nested_prefix_tlb(x, expansion(x), pairs)
    assert np.all(curve <= 1.0 + 1e-6)
    assert np.all(np.diff(curve) >= -1e-9)  # prefixes only add energy


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize(
    "transform", [fft_transform, paa_transform, dwt_transform],
    ids=["fft", "paa", "dwt"],
)
def test_prefix_transforms_contractive_on_raw_pairs(transform, seed, ecg):
    """Direct distance check (no TLB machinery): transformed distances never
    exceed originals for any k, per method."""
    rng = np.random.default_rng(seed)
    i = rng.integers(0, ecg.shape[0], 150)
    j = rng.integers(0, ecg.shape[0], 150)
    d_hi = np.linalg.norm(ecg[i] - ecg[j], axis=1)
    for k in (1, 5, 17, 50, ecg.shape[1]):
        t = transform(ecg, k)
        d_lo = np.linalg.norm(t[i] - t[j], axis=1)
        assert np.all(d_lo <= d_hi + 1e-3), (transform, k)


@pytest.mark.parametrize(
    "min_k", [fft_min_k, paa_min_k, dwt_min_k, jl_min_k],
    ids=["fft", "paa", "dwt", "jl"],
)
def test_min_k_monotone_in_target(min_k, ecg):
    """A tighter TLB target can never need FEWER dimensions."""
    ks = [min_k(ecg, t) for t in (0.80, 0.90, 0.95, 0.99)]
    assert ks == sorted(ks), ks


# -------------------------------------------------- reducer/legacy parity


LEGACY = {
    "fft": (fft_min_k, fft_transform),
    "paa": (paa_min_k, paa_transform),
    "dwt": (dwt_min_k, dwt_transform),
    "jl": (jl_min_k, jl_transform),
}


@pytest.mark.parametrize("method", sorted(LEGACY))
@pytest.mark.parametrize("target", [0.90, 0.98])
def test_single_shot_reducer_matches_legacy(method, target, ecg):
    """One-step Reducers are the legacy functions behind the protocol:
    identical seeded pair sample => bit-identical min-k, and the
    materialized operator reproduces the legacy transform (bit-for-bit for
    JL, whose operator is drawn rather than computed; float32-roundoff for
    the FFT/PAA/DWT matrix forms)."""
    min_k, transform = LEGACY[method]
    cfg = DropConfig(target_tlb=target, seed=0)
    runner = make_reducer(method, ecg, cfg)
    assert runner.step() is False  # single-shot: one step finishes it
    assert runner.done and runner.fit_calls == 1
    res = runner.result()
    assert res.method == method
    assert res.k == min_k(ecg, target)  # bit-for-bit contract
    assert len(res.iterations) == 1
    assert res.iterations[0].pairs_used == cfg.max_pairs
    got, want = res.transform(ecg), transform(ecg, res.k)
    if method == "jl":
        np.testing.assert_array_equal(got, want)
    else:
        np.testing.assert_allclose(got, want, atol=1e-4)


def test_reduce_pca_equals_drop(ecg):
    """reduce(x, "pca") is drop(): same Algorithm-2 trajectory bit-for-bit
    (min_iterations pinned past the schedule so Eq. 2 timing noise cannot
    change the iteration count)."""
    cfg = DropConfig(target_tlb=0.95, seed=0, min_iterations=99)
    a = reduce(ecg, "pca", cfg)
    b = drop(ecg, cfg)
    assert a.method == "pca" and a.k == b.k
    np.testing.assert_array_equal(a.v, b.v)
    np.testing.assert_array_equal(a.mean, b.mean)


def test_transform_dtype_stable_across_callers(ecg):
    """The float32 cast-through: a float64 caller sees bit-identical
    float32 outputs (the satellite dtype-drift fix), for every method."""
    cfg = DropConfig(target_tlb=0.9, seed=0)
    for method in ("pca", "fft", "paa", "dwt", "jl"):
        res = reduce(ecg, method, cfg)
        out32 = res.transform(ecg.astype(np.float32))
        out64 = res.transform(ecg.astype(np.float64))
        assert out32.dtype == np.float32 and out64.dtype == np.float32
        np.testing.assert_array_equal(out32, out64)


def test_make_reducer_rejects_unknown_method(ecg):
    with pytest.raises(KeyError, match="unknown reduction method"):
        make_reducer("tsne", ecg)


def test_reducers_satisfy_on_structured_data():
    """On low-rank data every contractive method eventually satisfies, and
    PCA needs the fewest dims (the paper's headline, via the new API)."""
    x, _ = sinusoid_mixture(600, 128, rank=4, seed=3)
    cfg = DropConfig(target_tlb=0.95, seed=0)
    ks = {m: reduce(x, m, cfg) for m in ("pca", "fft", "paa", "dwt")}
    for m, r in ks.items():
        assert r.satisfied, m
    assert ks["pca"].k <= min(ks["fft"].k, ks["paa"].k, ks["dwt"].k)
