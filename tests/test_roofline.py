"""Roofline HLO accounting: trip-count awareness, dot-FLOP reconstruction,
collective parsing — validated against analytic oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, get_config
from repro.roofline.analysis import model_flops
from repro.roofline.hlo_parse import analyze


def _scan_model(n_layers, b=16, d=64):
    w = jnp.ones((n_layers, d, d), jnp.float32)

    def f(x):
        def body(h, wl):
            return jnp.tanh(h @ wl), None

        return jax.lax.scan(body, x, w)[0].sum()

    return jax.jit(f).lower(jnp.ones((b, d))).compile()


def _cost_flops(compiled) -> float:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # jax<=0.4.x: one dict per device
        ca = ca[0]
    return ca["flops"]


def test_cost_analysis_misses_scan_trips():
    """The motivating defect: XLA's cost_analysis counts loop bodies once."""
    f2 = _cost_flops(_scan_model(2))
    f8 = _cost_flops(_scan_model(8))
    assert f2 == f8  # identical despite 4x the work


@pytest.mark.parametrize("n_layers", [2, 8, 31])
def test_parser_flops_exact_for_scans(n_layers):
    b, d = 16, 64
    t = analyze(_scan_model(n_layers, b, d).as_text())
    assert t.dot_flops == pytest.approx(n_layers * 2 * b * d * d, rel=1e-6)


def test_parser_counts_nested_scans():
    w = jnp.ones((4, 3, 8, 8), jnp.float32)  # outer 4, inner 3

    def f(x):
        def outer(h, wl):
            def inner(hh, wm):
                return jnp.tanh(hh @ wm), None

            return jax.lax.scan(inner, h, wl)[0], None

        return jax.lax.scan(outer, x, w)[0].sum()

    t = analyze(jax.jit(f).lower(jnp.ones((4, 8))).compile().as_text())
    assert t.dot_flops == pytest.approx(4 * 3 * 2 * 4 * 8 * 8, rel=1e-6)


def test_parser_unrolled_matches_scanned():
    b, d, n = 8, 32, 5
    ws = [jnp.eye(d) for _ in range(n)]

    def unrolled(x):
        for w in ws:
            x = jnp.tanh(x @ w)
        return x.sum()

    t_unrolled = analyze(jax.jit(unrolled).lower(jnp.ones((b, d))).compile().as_text())
    t_scanned = analyze(_scan_model(n, b, d).as_text())
    assert t_unrolled.dot_flops == t_scanned.dot_flops


def test_collectives_parsed_on_sharded_compile():
    import os

    if jax.device_count() < 2:
        pytest.skip("needs multiple devices (runs under forced-device tests)")
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    mesh = Mesh(np.array(jax.devices()[:2]).reshape(2), ("data",))
    xs = jax.ShapeDtypeStruct((8, 16), jnp.float32,
                              sharding=NamedSharding(mesh, P("data")))

    def f(x):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(None))
        ).sum()

    t = analyze(jax.jit(f).lower(xs).compile().as_text())
    assert t.collective_bytes > 0
    assert any("all-gather" in k for k in t.collective_ops)


def test_model_flops_conventions():
    cfg = get_config("deepseek_67b")
    n = cfg.active_param_count()
    assert model_flops(cfg, SHAPES["train_4k"]) == pytest.approx(
        6 * n * 256 * 4096
    )
    assert model_flops(cfg, SHAPES["prefill_32k"]) == pytest.approx(
        2 * n * 32 * 32768
    )
    assert model_flops(cfg, SHAPES["decode_32k"]) == pytest.approx(2 * n * 128)


def test_moe_uses_active_params():
    cfg = get_config("mixtral_8x7b")
    assert cfg.active_param_count() < cfg.param_count() * 0.35
    assert model_flops(cfg, SHAPES["train_4k"]) == pytest.approx(
        6 * cfg.active_param_count() * 256 * 4096
    )
