"""Parity of the two k-search modes (paper Alg. 4 binary vs TPU-native
prefix): on the same fitted basis and the same pair stream, both must find
the same smallest satisfying k, up to CI noise at the decision boundary."""

import jax
import numpy as np
import pytest

from repro.core.basis_search import _binary_search, _prefix_search, fit_basis
from repro.core.tlb import TLBEstimator
from repro.core.types import DropConfig
from repro.data import sinusoid_mixture

import jax.numpy as jnp

TARGETS = (0.80, 0.90, 0.95, 0.99)
CAPS = (8, 16, 48)


def _searches(x, target, cap, seed):
    """Run both searches on identical estimator state (same basis, and a
    fixed pair seed so the CI noise is shared)."""
    cfg = DropConfig(target_tlb=target, svd="full", seed=seed)
    mean, v = fit_basis(x[:400], cap, cfg, jax.random.PRNGKey(seed))
    out = {}
    for name, search in (("binary", _binary_search), ("prefix", _prefix_search)):
        est = TLBEstimator(
            x, jnp.asarray(v), np.random.default_rng(seed), confidence=cfg.confidence
        )
        out[name] = search(est, target, cap, cfg)
    return out


@pytest.mark.parametrize("target", TARGETS)
@pytest.mark.parametrize("cap", CAPS)
def test_binary_and_prefix_agree_on_low_rank_data(target, cap):
    x, _ = sinusoid_mixture(800, 64, rank=6, seed=0)
    res = _searches(x, target, min(cap, 64), seed=0)
    kb, mb, sb, _ = res["binary"]
    kp, mp, sp, _ = res["prefix"]
    assert sb == sp  # both reach the same satisfiability verdict
    if sb:
        assert abs(kb - kp) <= 1  # same smallest k up to boundary CI noise
        assert mb >= target and mp >= target


@pytest.mark.parametrize("seed", (1, 2, 3))
def test_parity_across_seeds(seed):
    x, _ = sinusoid_mixture(600, 48, rank=5, seed=seed)
    res = _searches(x, 0.95, 32, seed=seed)
    kb, _, sb, _ = res["binary"]
    kp, _, sp, _ = res["prefix"]
    assert sb and sp
    assert abs(kb - kp) <= 1


def test_unsatisfiable_cap_reported_by_both():
    """A cap far below the intrinsic rank: both searches must say so rather
    than return a bogus satisfying k."""
    rng = np.random.default_rng(4)
    x = rng.normal(size=(300, 40)).astype(np.float32)  # white noise: no low rank
    res = _searches(x, 0.99, cap=2, seed=4)
    _, _, sb, _ = res["binary"]
    _, _, sp, _ = res["prefix"]
    assert not sb and not sp


def test_prefix_never_uses_more_pair_batches_than_binary():
    """The prefix search decides from one fused table; its pair count can
    never exceed the binary search's worst probe."""
    x, _ = sinusoid_mixture(700, 64, rank=6, seed=5)
    res = _searches(x, 0.95, 48, seed=5)
    assert res["prefix"][3] <= res["binary"][3]
