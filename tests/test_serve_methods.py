"""Method-agnostic serving: FFT/PAA/DWT/JL queries scheduled and cached
like DROP, method-keyed cache isolation, append-only prefix-fingerprint
reuse, and TTL auto-tuning."""

import numpy as np
import pytest

from repro.core import DropConfig
from repro.core.cost import zero_cost
from repro.serve_drop import (
    BasisReuseCache,
    DropService,
    IngestFrontend,
    ShardedDropService,
)
from repro.serve_drop.cache import BasisCacheEntry
from repro.data import sinusoid_mixture

CFG = DropConfig(target_tlb=0.95, seed=0)
PARITY_CFG = DropConfig(target_tlb=0.95, seed=0, min_iterations=99)


def _data(rows=400, dim=48, rank=5, seed=11):
    return sinusoid_mixture(rows, dim, rank=rank, seed=seed)[0]


# -------------------------------------------------- multi-method serving


def test_baseline_methods_served_and_cached():
    """FFT/PAA queries flow through the same scheduler and basis cache as
    DROP: cold fit once, then validated cache hits with zero fitting."""
    x = _data()
    svc = DropService()
    for m in ("fft", "paa", "fft", "paa"):
        svc.submit(x, CFG, zero_cost(), method=m)
    out = svc.run()
    assert [r.result.method for r in out] == ["fft", "paa", "fft", "paa"]
    assert [r.cache_hit for r in out] == [False, False, True, True]
    assert out[0].result.k == out[2].result.k
    np.testing.assert_array_equal(out[0].result.v, out[2].result.v)
    assert svc.stats.cache_hits == 2 and svc.stats.fit_calls == 2


def test_cache_keyed_by_method():
    """A cached FFT map must never serve a PCA query on the same data (and
    vice versa): the key is fingerprint x method x target."""
    x = _data()
    svc = DropService()
    svc.submit(x, CFG, zero_cost(), method="fft")
    first = svc.run()[0]
    assert first.result.satisfied
    svc.submit(x, CFG, zero_cost(), method="pca")
    second = svc.run()[0]
    assert not second.cache_hit and second.result.method == "pca"
    assert second.result.k != first.result.k or not np.array_equal(
        second.result.v, first.result.v
    )
    # both entries coexist; each method hits its own
    for m in ("fft", "pca"):
        svc.submit(x, CFG, zero_cost(), method=m)
    assert all(r.cache_hit for r in svc.run())


def test_same_data_different_methods_not_deferred():
    """In-flight dedup is per (fingerprint, method): concurrent queries on
    the same data with different methods both run cold."""
    x = _data(rows=250, dim=32)
    svc = DropService(max_inflight=4)
    svc.submit(x, CFG, zero_cost(), method="fft")
    svc.submit(x, CFG, zero_cost(), method="dwt")
    out = svc.run()
    assert not any(r.cache_hit for r in out)
    assert svc.stats.fit_calls == 2


def test_ingest_frontend_serves_and_caches_baselines():
    """Acceptance: FFT and PAA queries are servable through IngestFrontend
    and cacheable in BasisReuseCache."""
    x = _data()
    svc = DropService()
    with IngestFrontend(svc, queue_capacity=8) as fe:
        cold = [fe.submit(x, CFG, zero_cost(), method=m) for m in ("fft", "paa")]
        cold_res = [fe.result(q, timeout=120) for q in cold]
        warm = [fe.submit(x, CFG, zero_cost(), method=m) for m in ("fft", "paa")]
        warm_res = [fe.result(q, timeout=120) for q in warm]
    assert [r.result.method for r in cold_res] == ["fft", "paa"]
    assert not any(r.cache_hit for r in cold_res)
    assert all(r.cache_hit for r in warm_res)
    for c, w in zip(cold_res, warm_res):
        assert c.result.k == w.result.k
        np.testing.assert_array_equal(c.result.v, w.result.v)


def test_sharded_single_device_parity_every_method():
    """Acceptance: sharded-vs-single per-query results bit-identical for
    every reducer type (the in-process leg; the forced 2-device leg lives in
    test_drop_serve_sharded's slow subprocess test)."""
    x = _data(rows=300, dim=32, rank=4, seed=10)
    methods = ("pca", "fft", "paa", "dwt", "jl")
    base = DropService(max_inflight=5, enable_cache=False)
    shard = ShardedDropService(devices=1, max_inflight=5, enable_cache=False)
    for m in methods:
        base.submit(x, PARITY_CFG, zero_cost(), method=m)
        shard.submit(x, PARITY_CFG, zero_cost(), method=m)
    for r, s in zip(base.run(), shard.run()):
        assert (r.result.method, r.result.k) == (s.result.method, s.result.k)
        np.testing.assert_array_equal(r.result.v, s.result.v)
        np.testing.assert_array_equal(r.result.mean, s.result.mean)


def test_jl_not_cached_and_never_poisons_ttl():
    """JL is data-independent (operator derivable from d/k/seed) and not
    contractive, so its results are never cached — a repeat runs cold
    instead of looping validation-fail -> refit, and the auto-TTL never
    sees a JL 'drift' verdict."""
    x = _data()
    svc = DropService(cache_ttl=8, cache_ttl_auto=True)
    for _ in range(2):
        svc.submit(x, DropConfig(target_tlb=0.98, seed=0), zero_cost(),
                   method="jl")
    out = svc.run()
    assert not any(r.cache_hit for r in out)
    assert out[0].result.k == out[1].result.k
    assert len(svc.cache) == 0  # nothing inserted
    assert svc.cache.validation_failures == 0
    assert svc.stats.effective_ttl == 8  # untouched by the repeats
    assert svc.stats.cache_misses == 0  # the cache was never in play


# ------------------------------------------------ prefix fingerprinting


def test_appended_rows_served_via_prefix_hit():
    """Append-only stream: growing a cached dataset hits via the prefix
    fingerprint, revalidates on the full grown data, and re-registers under
    the new fingerprint so the NEXT append's prefix matches again."""
    x = _data(rows=500)
    svc = DropService()
    svc.submit(x, CFG, zero_cost())
    first = svc.run()[0]
    assert first.result.satisfied and not first.cache_hit
    fits_after_cold = svc.stats.fit_calls

    rng = np.random.default_rng(0)
    noise = 0.01 * rng.normal(size=(60, x.shape[1]))
    grown = np.concatenate(  # same process: new rows from the same subspace
        [x, x[rng.integers(0, 500, 60)] + noise.astype(np.float32)]
    ).astype(np.float32)
    svc.submit(grown, CFG, zero_cost())
    r = svc.run()[0]
    assert r.cache_hit and r.prefix_hit
    assert r.result.k == first.result.k
    assert svc.stats.prefix_hits == 1
    assert svc.stats.fit_calls == fits_after_cold  # no refit anywhere

    grown2 = np.concatenate([grown, grown[:10]]).astype(np.float32)
    svc.submit(grown2, CFG, zero_cost())
    r2 = svc.run()[0]
    assert r2.cache_hit and r2.prefix_hit  # matched the re-registered entry
    assert svc.stats.prefix_hits == 2
    assert svc.stats.fit_calls == fits_after_cold


def test_drifted_suffix_fails_prefix_validation_and_warm_starts():
    """A grown dataset whose appended rows broke the subspace must NOT be
    served stale. With suffix updating disabled this is the PR 3 ladder:
    revalidation on the suffix-bearing data fails and the cold refit
    warm-starts from the prefix entry's rank. (With updating enabled the
    same workload escalates through the incremental update first — covered
    in test_suffix_update.py.)"""
    x = _data(rows=500, rank=3)
    svc = DropService(enable_suffix_update=False)
    cfg = DropConfig(target_tlb=0.95, seed=0)
    svc.submit(x, cfg, zero_cost())
    first = svc.run()[0]
    assert first.result.satisfied and first.result.k <= 6

    rng = np.random.default_rng(1)
    grown = np.concatenate(
        [x, rng.normal(size=(400, x.shape[1])).astype(np.float32)]
    ).astype(np.float32)  # 400 white-noise rows: old basis can't cover them
    svc.submit(grown, cfg, zero_cost())
    r = svc.run()[0]
    assert not r.cache_hit and not r.prefix_hit and not r.suffix_update
    assert r.warm_started  # the failed prefix entry still seeded the rank
    assert r.result.satisfied and r.result.k > first.result.k
    assert svc.cache.validation_failures == 1
    assert svc.stats.suffix_updates == 0


def test_prefix_requires_method_and_shape_match():
    """A prefix entry of a different method or width never matches."""
    x = _data(rows=500)
    svc = DropService()
    svc.submit(x, CFG, zero_cost(), method="fft")
    svc.run()
    grown = np.concatenate([x, x[:30]]).astype(np.float32)
    svc.submit(grown, CFG, zero_cost(), method="pca")  # different method
    r = svc.run()[0]
    assert not r.cache_hit and not r.prefix_hit


# ------------------------------------------------------ TTL auto-tuning


def test_cache_ttl_auto_tunes_on_verdicts():
    cache = BasisReuseCache(capacity=4, ttl_ticks=8, auto_ttl=True)
    assert cache.ttl_ticks == 8
    cache.note_validation(False)
    assert cache.ttl_ticks == 4  # observed drift: shrink
    cache.note_validation(False)
    cache.note_validation(False)
    cache.note_validation(False)
    assert cache.ttl_ticks == 1  # floored, never zero
    for _ in range(4):
        cache.note_validation(True)
    assert cache.ttl_ticks == 2  # sustained validated hits: grow back
    for _ in range(8):
        cache.note_validation(True)
    assert cache.ttl_ticks == 8  # capped at the configured budget
    for _ in range(4):
        cache.note_validation(True)
    assert cache.ttl_ticks == 8
    assert cache.validation_failures == 4

    fixed = BasisReuseCache(capacity=4, ttl_ticks=8, auto_ttl=False)
    fixed.note_validation(False)
    assert fixed.ttl_ticks == 8  # opt-in only
    assert fixed.validation_failures == 1


def test_service_exposes_effective_ttl():
    """A failing revalidation shrinks the live TTL (visible in
    ServiceStats.effective_ttl); validated hits grow it back."""
    x = _data()
    svc = DropService(cache_ttl=8, cache_ttl_auto=True)
    assert svc.stats.effective_ttl == 8
    svc.submit(x, CFG, zero_cost())
    k_good = svc.run()[0].result.k
    assert k_good > 1

    # degrade the cached entry so the next revalidation honestly fails
    ((key, entry),) = [(k, svc.cache._entries[k]) for k in svc.cache.keys()]
    entry.v = entry.v[:, :1]
    entry.k = 1
    svc.submit(x, CFG, zero_cost())
    healed = svc.run()[0]
    assert not healed.cache_hit and healed.result.k == k_good
    assert svc.stats.effective_ttl == 4  # drift halved the TTL

    for _ in range(4):  # fresh entry now validates: TTL earns its way back
        svc.submit(x, CFG, zero_cost())
        assert svc.run()[0].cache_hit
    assert svc.stats.effective_ttl == 8
