"""Sharded-execution integration tests on a forced 8-device host mesh:
the distributed code paths (constraints, shard_map MoE, flash-decode,
compressed pod reduce) must EXECUTE and match their single-device results.

Runs in a subprocess so the 8-device XLA_FLAGS does not leak into the rest of
the suite (which must see 1 device).
"""

import json
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # subprocess multi-device compile: minutes

PROG = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs.base import get_smoke_config
from repro.models.model import init_model, loss_fn
from repro.sharding.specs import ShardCtx, param_specs
from repro.serve.decode import serve_step
from repro.serve.kvcache import plan_cache, zeros_cache
out = {}

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))

for arch in ["tinyllama_1_1b", "mixtral_8x7b", "mamba2_2_7b"]:
    cfg = get_smoke_config(arch)
    params = init_model(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    B, S = 4, 32
    batch = {
        "inputs": jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32),
        "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size, jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    # single-device reference
    l_ref, _ = jax.jit(lambda p, b: loss_fn(p, b, cfg, ShardCtx(mesh=None)))(params, batch)
    # sharded execution with full constraints
    ctx = ShardCtx(mesh=mesh, tuned=True)
    shardings = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), param_specs(params),
                                       is_leaf=lambda x: isinstance(x, P))
    p_sh = jax.device_put(params, shardings)
    with mesh:
        l_sh, _ = jax.jit(lambda p, b: loss_fn(p, b, cfg, ctx))(p_sh, batch)
    out[arch] = [float(l_ref), float(l_sh)]

# sharded flash-decode parity
cfg = get_smoke_config("tinyllama_1_1b")
params = init_model(cfg, jax.random.PRNGKey(0))
B, S = 4, 8
toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size, jnp.int32)
def decode_all(ctx):
    cache = zeros_cache(cfg, plan_cache(cfg, B, S + 8))
    lengths = jnp.zeros((B,), jnp.int32)
    step = jax.jit(lambda p, t, c, l: serve_step(p, t, c, l, cfg, ctx))
    logits = None
    for s in range(S):
        logits, cache = step(params, toks[:, s:s+1], cache, lengths)
        lengths = lengths + 1
    return np.asarray(logits, np.float32)
ref = decode_all(ShardCtx(mesh=None))
with mesh:
    sh = decode_all(ShardCtx(mesh=mesh))
out["decode_maxdiff"] = float(np.abs(ref - sh).max())
print(json.dumps(out))
'''


@pytest.fixture(scope="module")
def results():
    proc = subprocess.run(
        [sys.executable, "-c", PROG],
        capture_output=True, text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("arch", ["tinyllama_1_1b", "mixtral_8x7b", "mamba2_2_7b"])
def test_sharded_loss_matches_single_device(results, arch):
    l_ref, l_sh = results[arch]
    assert l_sh == pytest.approx(l_ref, rel=0.02), (l_ref, l_sh)


def test_sharded_flash_decode_matches_reference(results):
    assert results["decode_maxdiff"] < 0.05
