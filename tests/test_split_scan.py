"""Split-dataset pairwise fan-out: shard-merge associativity properties.

The contract under test (``analytics/split.py``): for EVERY shard count,
the split scan's merged output bit-matches the sequential fused engine —
kNN indices and squared distances (including cross-shard duplicate
tie-breaks), DBSCAN counts/packed words (hence labels), and KDE densities
to ~f32 ulp (compensated partials folded in float64). Plus the two ride-
along regressions: the f32 exp-sum drift fix (S2) and the block-size
validation that replaced the opaque ``_pack_bits`` reshape crash (S3).

The slow leg forces a 2-device host platform in a subprocess (XLA_FLAGS
must precede the jax import) and checks the ``shard_map`` mesh fan-out
against the same sequential oracle at both mesh shapes."""

import json
import subprocess
import sys

import numpy as np
import pytest

from repro.analytics import dbscan, gaussian_kde, nearest_neighbors
from repro.analytics.pairwise import (
    pairwise_dbscan,
    pairwise_kde,
    pairwise_knn,
)
from repro.analytics.split import (
    merge_dbscan_partials,
    merge_kde_partials,
    merge_knn_partials,
    split_pairwise_dbscan,
    split_pairwise_kde,
    split_pairwise_knn,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # minimal installs: keep the module importable so the
    HAVE_HYPOTHESIS = False  # deterministic sweeps still run

    def given(**kw):  # noqa: D103 - inert stand-ins for the decorators
        return lambda f: f

    def settings(**kw):
        return lambda f: f

    class st:  # noqa: D101
        integers = staticmethod(lambda **kw: None)

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="property sweeps need hypothesis (see requirements-dev.txt)",
)
SETTINGS = dict(max_examples=25, deadline=None)

SHARDS = (1, 2, 3, 5, 7)
B = 64  # one word-width tile: many tiles per shard even at 131 rows


@pytest.fixture(scope="module")
def xdup():
    """131x8 with a duplicate pair straddling the shards>=3 boundary
    (rows 3 and 100 land in different bk=64 tiles): every row's nearest
    neighbor is tied between two columns somewhere in the sweep, so the
    strict-< first-occurrence tie-break is load-bearing, not incidental."""
    rng = np.random.default_rng(7)
    x = rng.normal(size=(131, 8)).astype(np.float32)
    x[100] = x[3]
    return x


def _rel(a, b):
    return float(
        np.max(np.abs(a - b) / np.maximum(np.abs(b), 1e-12))
    )


# ------------------------------------------------- merge primitives (unit)


def test_merge_knn_tie_keeps_lowest_shard():
    # shard 1 ties shard 0 on d2: the sequential scan would have seen
    # shard 0's column first, so the merge must keep it
    idx = np.array([[3, 9], [100, 4]], dtype=np.int32)
    d2 = np.array([[0.5, 2.0], [0.5, 1.0]], dtype=np.float32)
    gi, gd = merge_knn_partials(idx, d2)
    assert gi.tolist() == [3, 4]
    assert gd.tolist() == [0.5, 1.0]


def test_merge_dbscan_sums_counts_and_trims_words():
    counts = np.array([[2, 0], [1, 3]], dtype=np.int32)
    packed = np.arange(2 * 2 * 2, dtype=np.uint32).reshape(2, 2, 2)
    c, p = merge_dbscan_partials(counts, packed, words=3)
    assert c.tolist() == [3, 3]
    assert p.shape == (2, 3)  # shard-order concat, trailing pad dropped
    assert p.tolist() == [[0, 1, 4], [2, 3, 6]]


def test_merge_kde_folds_in_float64():
    # a compensation term far below f32 resolution of the sum must survive
    sums = np.array([[1.0e8], [1.0]], dtype=np.float32)
    comps = np.array([[0.25], [0.0]], dtype=np.float32)
    dens = merge_kde_partials(sums, comps, m=1)
    assert dens.dtype == np.float32
    assert dens[0] == np.float32((1.0e8 + 0.25 + 1.0) / 1.0)


# --------------------------------------------- deterministic parity sweeps


@pytest.mark.parametrize("shards", SHARDS)
def test_split_knn_bit_matches_sequential(xdup, shards):
    si, sd = pairwise_knn(xdup, B, B)
    mi, md = split_pairwise_knn(xdup, shards=shards, block_q=B, block_k=B)
    assert np.array_equal(si, mi)
    assert np.array_equal(sd, md)


def test_split_knn_cross_shard_duplicate_tie(xdup):
    # rows 3 and 100 are identical; at shards=3 they sit in different
    # shards, so the cross-shard merge decides their mutual tie
    mi, md = split_pairwise_knn(xdup, shards=3, block_q=B, block_k=B)
    assert mi[3] == 100 and mi[100] == 3
    assert md[3] == 0.0 and md[100] == 0.0


@pytest.mark.parametrize("shards", SHARDS)
def test_split_dbscan_bit_matches_sequential(xdup, shards):
    eps = 1.5
    sc, sp = pairwise_dbscan(xdup, eps, B, B)
    mc, mp = split_pairwise_dbscan(
        xdup, eps, shards=shards, block_q=B, block_k=B
    )
    assert np.array_equal(sc, mc)
    assert sp.shape == mp.shape  # same sequential word layout, no shifts
    assert np.array_equal(sp, mp)


@pytest.mark.parametrize("shards", (2, 5))
def test_split_dbscan_labels_through_wrapper(xdup, shards):
    # counts+packed parity implies label parity only if the BFS consumes
    # the merged outputs unchanged — pin the whole wrapper path
    seq = dbscan(xdup, eps=1.5, min_samples=3, block=B)
    spl = dbscan(xdup, eps=1.5, min_samples=3, block=B, split=shards)
    assert np.array_equal(seq, spl)


@pytest.mark.parametrize("shards", SHARDS)
def test_split_kde_matches_sequential(xdup, shards):
    seq = pairwise_kde(xdup, None, 1.0, B, B)
    spl = split_pairwise_kde(
        xdup, None, 1.0, shards=shards, block_q=B, block_k=B
    )
    assert _rel(spl, seq) <= 1e-5


def test_split_kde_distinct_queries(xdup):
    q = xdup[:17] + np.float32(0.25)
    seq = pairwise_kde(xdup, q, 0.8, B, B)
    for shards in (2, 3):
        spl = split_pairwise_kde(
            xdup, q, 0.8, shards=shards, block_q=B, block_k=B
        )
        assert spl.shape == (17,)
        assert _rel(spl, seq) <= 1e-5


# ----------------------------------------------------------- edge shapes


@pytest.mark.parametrize("rows", (1, 2, 63, 97))
def test_split_edge_shapes_padded_tails(rows):
    """m=1, m below a tile, non-tile-multiple m — and shards exceeding the
    tile count, so trailing shards are pure padding (inert partials)."""
    rng = np.random.default_rng(rows)
    x = rng.normal(size=(rows, 5)).astype(np.float32)
    si, sd = pairwise_knn(x, B, B)
    sc, sp = pairwise_dbscan(x, 1.0, B, B)
    sk = pairwise_kde(x, None, 1.0, B, B)
    for shards in (1, 4, 9):
        mi, md = split_pairwise_knn(x, shards=shards, block_q=B, block_k=B)
        assert np.array_equal(si, mi) and np.array_equal(sd, md)
        mc, mp = split_pairwise_dbscan(
            x, 1.0, shards=shards, block_q=B, block_k=B
        )
        assert np.array_equal(sc, mc) and np.array_equal(sp, mp)
        mk = split_pairwise_kde(
            x, None, 1.0, shards=shards, block_q=B, block_k=B
        )
        assert _rel(mk, sk) <= 1e-5


def test_public_wrappers_split_kwarg(xdup):
    assert np.array_equal(
        nearest_neighbors(xdup, block=B),
        nearest_neighbors(xdup, block=B, split=3),
    )
    assert _rel(
        gaussian_kde(xdup, block=B, split=3), gaussian_kde(xdup, block=B)
    ) <= 1e-5


# ------------------------------------------------- kernel (interpret) path


def test_split_kernel_path_parity_interpret(monkeypatch, xdup):
    """use_kernels=True under REPRO_PALLAS_INTERPRET=1 routes to the
    grid-parallel pairwise_reduce split variants; same merge, same bits."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    si, sd = pairwise_knn(xdup, B, B)
    mi, md = split_pairwise_knn(
        xdup, shards=3, block_q=B, block_k=B, use_kernels=True
    )
    assert np.array_equal(si, mi) and np.array_equal(sd, md)
    sc, sp = pairwise_dbscan(xdup, 1.5, B, B)
    mc, mp = split_pairwise_dbscan(
        xdup, 1.5, shards=3, block_q=B, block_k=B, use_kernels=True
    )
    assert np.array_equal(sc, mc) and np.array_equal(sp, mp)
    sk = pairwise_kde(xdup, None, 1.0, B, B)
    mk = split_pairwise_kde(
        xdup, None, 1.0, shards=3, block_q=B, block_k=B, use_kernels=True
    )
    assert _rel(mk, sk) <= 1e-5


# ------------------------------------------------------- property sweeps


@needs_hypothesis
@settings(**SETTINGS)
@given(
    rows=st.integers(min_value=1, max_value=160),
    dim=st.integers(min_value=1, max_value=8),
    shards=st.integers(min_value=1, max_value=9),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_split_knn_dbscan_property(rows, dim, shards, seed):
    """Arbitrary (rows, dim, shard count): split bit-matches sequential.
    A planted duplicate keeps tie-breaks in play at every size."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(rows, dim)).astype(np.float32)
    if rows >= 4:
        x[rows // 2] = x[1]
    si, sd = pairwise_knn(x, B, B)
    mi, md = split_pairwise_knn(x, shards=shards, block_q=B, block_k=B)
    assert np.array_equal(si, mi) and np.array_equal(sd, md)
    eps = 0.5 * float(dim) ** 0.5
    sc, sp = pairwise_dbscan(x, eps, B, B)
    mc, mp = split_pairwise_dbscan(
        x, eps, shards=shards, block_q=B, block_k=B
    )
    assert np.array_equal(sc, mc) and np.array_equal(sp, mp)


@needs_hypothesis
@settings(**SETTINGS)
@given(
    rows=st.integers(min_value=1, max_value=160),
    dim=st.integers(min_value=1, max_value=8),
    shards=st.integers(min_value=1, max_value=9),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_split_kde_property(rows, dim, shards, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(rows, dim)).astype(np.float32)
    seq = pairwise_kde(x, None, 1.0, B, B)
    spl = split_pairwise_kde(x, None, 1.0, shards=shards, block_q=B, block_k=B)
    assert _rel(spl, seq) <= 1e-5


# ------------------------------------------- S2: f32 exp-sum drift fix


def test_kde_compensated_sum_resists_f32_drift():
    """64 near points (exp ~ 1) plus 20k shell points (exp ~ 1e-7): the
    old plain-f32 tile carry loses the small terms against the large
    accumulator (the simulated pre-fix fold drifts ~1e-6 relative); the
    compensated carry stays at f32-ulp agreement with a float64 host
    reference, independent of the split point."""
    rng = np.random.default_rng(0)
    d = 4
    near = (rng.normal(size=(64, d)) * 1e-3).astype(np.float32)
    far = rng.normal(size=(20000, d)).astype(np.float32)
    far *= 5.68 / np.linalg.norm(far, axis=1, keepdims=True)
    x = np.concatenate([near, far]).astype(np.float32)
    q = np.zeros((1, d), dtype=np.float32)

    d2 = ((q.astype(np.float64) - x.astype(np.float64)) ** 2).sum(1)
    ref = np.exp(-d2 / 2.0).sum() / x.shape[0]

    dens = gaussian_kde(x, q, 1.0, block=256)
    err_comp = abs(float(dens[0]) - ref) / ref
    assert err_comp < 5e-7

    # simulate the pre-fix algorithm: per-tile f32 sums folded into a
    # plain (uncompensated) running f32 scalar, tile by tile
    terms = np.exp(-d2.astype(np.float32) / np.float32(2.0))
    acc = np.float32(0.0)
    for i in range(0, terms.size, 256):
        acc = np.float32(acc + terms[i : i + 256].sum(dtype=np.float32))
    err_naive = abs(float(acc) / x.shape[0] - ref) / ref
    assert err_naive > 5 * err_comp  # the fix is what closes the gap

    # split-point independence: every shard count lands on the same value
    vals = {
        float(gaussian_kde(x, q, 1.0, block=256, split=s)[0])
        for s in (1, 2, 3, 5)
    }
    assert all(abs(v - ref) / ref < 5e-7 for v in vals)


# ------------------------------------------ S3: block-size validation


def test_block_size_rejects_unusable_values():
    x = np.zeros((8, 3), dtype=np.float32)
    with pytest.raises(ValueError, match="block size"):
        pairwise_dbscan(x, 0.5, 0, B)
    with pytest.raises(ValueError, match="block size"):
        pairwise_knn(x, B, -3)
    with pytest.raises(ValueError, match="block size"):
        pairwise_kde(x, None, 1.0, 2.5, B)
    with pytest.raises(ValueError, match="block size"):
        split_pairwise_dbscan(x, 0.5, shards=2, block_q=B, block_k=0)


def test_block_size_rounds_up_and_matches():
    """bk=100 used to crash in the bitmask packer's (bq, bk//32, 32)
    reshape; it now quantizes to 128 and produces identical outputs."""
    rng = np.random.default_rng(3)
    x = rng.normal(size=(150, 4)).astype(np.float32)
    c1, p1 = pairwise_dbscan(x, 1.0, 100, 100)
    c2, p2 = pairwise_dbscan(x, 1.0, 128, 128)
    assert np.array_equal(c1, c2) and np.array_equal(p1, p2)
    assert np.array_equal(
        dbscan(x, eps=1.0, min_samples=3, block=33),
        dbscan(x, eps=1.0, min_samples=3, block=64),
    )


# ------------------------------------------------- mesh fan-out (slow)


PROG = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import numpy as np
import jax
from repro.analytics.pairwise import (
    pairwise_dbscan, pairwise_kde, pairwise_knn,
)
from repro.analytics.split import (
    split_pairwise_dbscan, split_pairwise_kde, split_pairwise_knn,
)

rng = np.random.default_rng(0)
x = rng.normal(size=(131, 6)).astype(np.float32)
x[100] = x[3]  # cross-shard duplicate tie at the 64-row tile boundary
eps = 1.2
out = {"devices": jax.device_count()}
si, sd = pairwise_knn(x, 64, 64)
sc, sp = pairwise_dbscan(x, eps, 64, 64)
sk = pairwise_kde(x, None, 1.0, 64, 64)
for shape in ((1, 2), (2, 1)):
    tag = "%dx%d" % shape
    mi, md = split_pairwise_knn(
        x, block_q=64, block_k=64, fanout="mesh", mesh_shape=shape)
    mc, mp = split_pairwise_dbscan(
        x, eps, block_q=64, block_k=64, fanout="mesh", mesh_shape=shape)
    mk = split_pairwise_kde(
        x, None, 1.0, block_q=64, block_k=64, fanout="mesh",
        mesh_shape=shape)
    out["knn_" + tag] = bool(
        np.array_equal(si, mi) and np.array_equal(sd, md))
    out["dbscan_" + tag] = bool(
        np.array_equal(sc, mc) and np.array_equal(sp, mp))
    out["kde_rel_" + tag] = float(
        np.max(np.abs(mk - sk) / np.maximum(np.abs(sk), 1e-12)))
print(json.dumps(out))
"""


@pytest.mark.slow
def test_mesh_fanout_parity_forced_two_devices():
    """shard_map fan-out on a forced 2-device host platform, both mesh
    shapes (dataset-split 1x2 and query-split 2x1), against the
    sequential oracle. Subprocess because XLA_FLAGS must be set before
    jax initializes."""
    proc = subprocess.run(
        [sys.executable, "-c", PROG],
        capture_output=True,
        text=True,
        timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["devices"] == 2
    for tag in ("1x2", "2x1"):
        assert out[f"knn_{tag}"], out
        assert out[f"dbscan_{tag}"], out
        assert out[f"kde_rel_{tag}"] <= 1e-5, out
