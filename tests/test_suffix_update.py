"""Incremental subspace tracking (core/subspace.py) and the serve-layer
suffix-update escalation ladder: prefix hit -> revalidate -> suffix update
-> cold refit as last resort.

Deterministic mirrors of the hypothesis property in test_properties_serve.py
live here (environments without hypothesis still cover the TLB-parity
claim), plus the service wiring: budget routing, failure fallback, the
raising-update regression, and the float32 served-transform contract."""

import numpy as np
import pytest

from repro.core import DropConfig, reduce
from repro.core.cost import zero_cost
from repro.core.drop import PcaDropReducer
from repro.core.reducer import FftReducer, make_reducer
from repro.core.subspace import TRACK_HEADROOM, SubspaceTracker, suffix_update
from repro.core.tlb import sample_pairs, transform_tlb_sampled
from repro.data import sinusoid_mixture
from repro.serve_drop import DropService

CFG = DropConfig(target_tlb=0.95, seed=0)


def _stream(m_total=700, d=64, rank=3, seed=0):
    """One generative process; snapshots are prefixes (append-only)."""
    return sinusoid_mixture(m_total, d, rank=rank, seed=seed)[0]


def _staged_rank_stream(m0=500, ms=80, d=48, r_base=3, r_full=5, seed=0):
    """Base rows span r_base sinusoid directions; appended rows open
    r_full - r_base NEW ones — the rank-growth case subspace tracking must
    handle without a refit."""
    rng = np.random.default_rng(seed)
    t = np.linspace(0.0, 1.0, d)
    freqs = rng.uniform(1.0, 12.0, r_full)
    phases = rng.uniform(0.0, 2 * np.pi, r_full)
    basis = np.stack(
        [np.sin(2 * np.pi * f * t + p) for f, p in zip(freqs, phases)]
    )
    amps = rng.normal(size=(m0 + ms, r_full))
    amps[:m0, r_base:] = 0.0
    x = (amps @ basis + 0.02 * rng.normal(size=(m0 + ms, d))).astype(
        np.float32
    )
    return np.ascontiguousarray(x[:m0]), np.ascontiguousarray(x)


# ------------------------------------------------------- tracker algebra


def test_tracker_merge_invariants():
    """Merged state stays an orthonormal, singular-value-ordered basis with
    an exact running mean and row count — float32 end-to-end."""
    x = _stream(600)
    base, grown = x[:500], x
    r = reduce(base, "pca", CFG, zero_cost())
    tr = SubspaceTracker.from_fit(base, r.v)
    assert (tr.v.dtype, tr.s.dtype, tr.mean.dtype) == (np.float32,) * 3
    assert tr.rows == 500

    merged = tr.merge(grown[500:], max_rank=tr.width + TRACK_HEADROOM)
    assert merged.rows == 600
    assert (merged.v.dtype, merged.s.dtype, merged.mean.dtype) == (
        np.float32,
    ) * 3
    np.testing.assert_allclose(
        merged.v.T @ merged.v, np.eye(merged.width), atol=1e-4
    )
    assert (np.diff(merged.s) <= 1e-4).all()  # singular-value ordered
    np.testing.assert_allclose(  # mean update is exact algebra
        merged.mean, grown.mean(axis=0), atol=1e-4
    )
    # float64 input must not leak float64 out (the satellite contract)
    m64 = tr.merge(grown[500:].astype(np.float64), max_rank=tr.width + 2)
    assert m64.v.dtype == np.float32 and m64.mean.dtype == np.float32


def test_empty_and_malformed_suffix():
    x = _stream(400)
    r = reduce(x, "pca", CFG, zero_cost())
    tr = SubspaceTracker.from_fit(x, r.v)
    assert tr.merge(x[:0], max_rank=8) is tr  # no rows: identity
    with pytest.raises(ValueError):
        tr.merge(np.zeros((4, x.shape[1] + 1), np.float32), max_rank=8)
    with pytest.raises(ValueError):
        suffix_update(tr, x[: tr.rows - 10], CFG)  # shrunk, not grown


@pytest.mark.parametrize(
    "frac,rank", [(0.01, 3), (0.05, 4), (0.10, 5)]
)
def test_suffix_update_tlb_matches_refit(frac, rank):
    """Deterministic mirror of the hypothesis property: across append sizes
    and ranks, the updated map's TLB on a shared evaluation sample matches a
    full refit's within 0.005 (the bench asserts the same on its stream; on
    these pinned structured combos the two-sided bound holds — the sweep
    property is one-sided because the refit itself is the noisier map at
    degenerate rank boundaries). Tracker bootstrapped as the service does
    it; min_iterations pinned (the determinism convention — and the
    comparison is about the merge, not about how early the base fit
    terminated)."""
    m0 = 600
    ms = max(1, int(m0 * frac))
    x = _stream(m0 + ms, d=64, rank=rank, seed=rank)
    base, grown = x[:m0], x
    cfg = DropConfig(target_tlb=0.97, seed=0, min_iterations=99)

    runner = PcaDropReducer(base, cfg, zero_cost())
    while runner.step():
        pass
    _, res, _ = suffix_update(runner.tracker(), grown, cfg)
    rr = reduce(grown, "pca", cfg, zero_cost())

    pairs = sample_pairs(grown.shape[0], 4000, np.random.default_rng(7))
    tlb_upd, _, _ = transform_tlb_sampled(grown, res.transform(grown), pairs)
    tlb_fit, _, _ = transform_tlb_sampled(grown, rr.transform(grown), pairs)
    assert res.satisfied and rr.satisfied
    assert abs(tlb_upd - tlb_fit) <= 0.005, (frac, rank, tlb_upd, tlb_fit)


# ------------------------------------------------------ reducer protocol


def test_reducer_update_folds_suffix():
    """PcaDropReducer.update(): the Reducer protocol's incremental path —
    O(suffix) fold, telemetry appended, result float32-satisfying."""
    x = _stream(660)
    runner = PcaDropReducer(x[:600], CFG, zero_cost())
    while runner.step():
        pass
    n_rec = len(runner.records)
    res = runner.update(x[600:])
    assert runner.supports_update
    assert res.satisfied and res.v.dtype == np.float32
    assert runner.x.shape[0] == 660  # suffix folded into the runner's view
    assert len(res.iterations) == n_rec + 1
    assert res.iterations[-1].sample_size == 60  # only the suffix processed
    assert runner.result().k == res.k  # result() agrees with update()


def test_single_shot_reducers_keep_refit_semantics():
    x = _stream(200, d=32)
    runner = make_reducer("fft", x, CFG, zero_cost())
    assert not FftReducer.supports_update
    while runner.step():
        pass
    with pytest.raises(NotImplementedError):
        runner.update(x[:10])


# --------------------------------------------------- service escalation


def test_revalidation_failure_escalates_to_suffix_update():
    """The ladder's middle rung: a small append (under the drift budget)
    whose new rows open NEW directions fails revalidation — and is then
    served by the TLB-gated incremental update with a GROWN rank, not by a
    cold refit."""
    base, grown = _staged_rank_stream()
    cfg = DropConfig(target_tlb=0.97, seed=0)
    svc = DropService()
    svc.submit(base, cfg, zero_cost())
    first = svc.run()[0]
    assert first.result.satisfied and first.result.k == 3
    fits_after_cold = svc.stats.fit_calls

    svc.submit(grown, cfg, zero_cost())
    r = svc.run()[0]
    assert r.suffix_update and not r.cache_hit and not r.warm_started
    assert r.result.satisfied and r.result.k > first.result.k  # rank grew
    assert svc.cache.validation_failures == 1  # revalidation ran and failed
    assert svc.stats.suffix_updates == 1
    assert svc.stats.suffix_update_failures == 0
    assert svc.stats.fit_calls == fits_after_cold  # NO refit anywhere

    # the updated entry re-registered under the grown fingerprint: an exact
    # repeat is now a plain validated hit
    svc.submit(grown, cfg, zero_cost())
    again = svc.run()[0]
    assert again.cache_hit and not again.suffix_update
    assert again.result.k == r.result.k


def test_large_append_skips_revalidation():
    """Past the drift budget the service does not waste a validation that
    will mostly fail: the prefix match goes straight to the update."""
    x = _stream(700)
    svc = DropService(suffix_budget=0.25)
    svc.submit(x[:500], CFG, zero_cost())
    svc.run()
    svc.submit(x, CFG, zero_cost())  # +40% > 25% budget
    r = svc.run()[0]
    assert r.suffix_update and not r.cache_hit
    assert svc.stats.suffix_updates == 1
    assert svc.cache.validation_failures == 0  # no revalidation ran
    assert svc.stats.cache_hits == 0


def test_small_append_still_prefers_revalidation():
    """Under the budget, a drift-free append is served by the cheaper
    revalidation (prefix hit) — the update never runs."""
    x = _stream(550)
    svc = DropService(suffix_budget=0.25)
    svc.submit(x[:500], CFG, zero_cost())
    svc.run()
    svc.submit(x, CFG, zero_cost())  # +10% < 25% budget, same process
    r = svc.run()[0]
    assert r.cache_hit and r.prefix_hit and not r.suffix_update
    assert svc.stats.suffix_updates == 0


def test_unsatisfiable_suffix_falls_back_to_cold_refit():
    """Last rung: a suffix that outgrows the tracked headroom (white noise
    needs ~d directions) fails the TLB gate; the query refits cold,
    warm-started, and is still served satisfied."""
    x = sinusoid_mixture(500, 48, rank=3, seed=11)[0]
    rng = np.random.default_rng(1)
    grown = np.ascontiguousarray(
        np.concatenate([x, rng.normal(size=(400, 48)).astype(np.float32)]),
        dtype=np.float32,
    )
    svc = DropService()
    svc.submit(x, CFG, zero_cost())
    first = svc.run()[0]
    assert first.result.satisfied and first.result.k <= 6

    svc.submit(grown, CFG, zero_cost())  # +80% > budget: direct update
    r = svc.run()[0]
    assert not r.suffix_update and not r.cache_hit
    assert r.warm_started  # the failed update still seeded the rank hint
    assert r.result.satisfied and r.result.k > first.result.k
    assert svc.stats.suffix_updates == 0
    assert svc.stats.suffix_update_failures == 1


def test_suffix_update_disabled_restores_refit_behavior():
    """enable_suffix_update=False is the PR 3 service: no tracker state is
    kept and a drifted append revalidates then refits cold."""
    base, grown = _staged_rank_stream()
    cfg = DropConfig(target_tlb=0.97, seed=0)
    svc = DropService(enable_suffix_update=False)
    svc.submit(base, cfg, zero_cost())
    svc.run()
    assert all(e.tracker is None for e in svc.cache._entries.values())
    svc.submit(grown, cfg, zero_cost())
    r = svc.run()[0]
    assert not r.suffix_update and not r.cache_hit and r.warm_started
    assert svc.stats.suffix_updates == 0
    assert svc.cache.validation_failures == 1


def test_raising_suffix_update_finishes_query_with_error(monkeypatch):
    """Regression: a _SuffixUpdate that raises mid-step must finish the
    query with ServeResult.error — not wedge the drain or leak a slot."""
    x = _stream(700)
    svc = DropService(suffix_budget=0.0)
    svc.submit(x[:500], CFG, zero_cost())
    svc.run()

    def boom(self, upd):
        raise RuntimeError("injected updater failure")

    monkeypatch.setattr(DropService, "_apply_suffix_update", boom)
    qid = svc.submit(x, CFG, zero_cost())
    out = svc.run()  # must terminate
    assert [r.query_id for r in out] == [qid]
    assert "injected updater failure" in out[0].error
    assert not out[0].result.satisfied
    assert svc.stats.failures == 1
    assert svc.stats.suffix_update_failures == 1
    assert svc.backlog() == 0  # no leaked slots or stepping entries

    # the service keeps serving after the failure
    monkeypatch.undo()
    svc.submit(x, CFG, zero_cost())
    healed = svc.run()[0]
    assert healed.error is None and healed.result.satisfied


def test_errored_validation_keeps_cold_refit_fallback(monkeypatch):
    """A prefix validation that RAISES (broken entry / infra error — not a
    drift verdict) must not escalate to the suffix update: the same broken
    state would break the merge too. It keeps PR 3's guaranteed warm cold
    refit, and the query is served without an error."""
    x = _stream(550)
    svc = DropService()  # suffix <= budget: the revalidate-first path
    svc.submit(x[:500], CFG, zero_cost())
    first = svc.run()[0]
    assert first.result.satisfied

    def broken_validate(self, val):
        raise RuntimeError("injected validation infrastructure failure")

    monkeypatch.setattr(DropService, "_validate", broken_validate)
    svc.submit(x, CFG, zero_cost())
    r = svc.run()[0]
    assert r.error is None and r.result.satisfied
    assert not r.cache_hit and not r.suffix_update
    assert r.warm_started  # the prefix entry still seeded the rank bound
    assert svc.stats.suffix_updates == 0
    assert svc.stats.suffix_update_failures == 0
    assert svc.cache.validation_failures == 0  # infra error != drift


def test_suffix_update_served_transform_is_float32():
    """Served-transform contract end-to-end: the updated (merged) map and
    its transforms stay float32 even for float64 callers — the augmented
    merge is an easy place to silently promote."""
    x = _stream(700)
    svc = DropService(suffix_budget=0.0)
    svc.submit(x[:500], CFG, zero_cost())
    svc.run()
    svc.submit(x, CFG, zero_cost())
    r = svc.run()[0]
    assert r.suffix_update
    assert r.result.v.dtype == np.float32
    assert r.result.mean.dtype == np.float32
    out32 = r.result.transform(x)
    out64 = r.result.transform(x.astype(np.float64))
    assert out32.dtype == np.float32 and out64.dtype == np.float32
    np.testing.assert_array_equal(out32, out64)  # bit-stable across dtypes
    ((_, entry),) = list(svc.cache._entries.items())[-1:]
    assert entry.tracker.v.dtype == np.float32
    assert entry.tracker.s.dtype == np.float32
    assert entry.tracker.mean.dtype == np.float32


# --------------------------------------------- headroom exhaustion gate


def test_full_width_gate_clear_is_reported_unsatisfied(monkeypatch):
    """Regression: a gate that only clears the target at the FULL tracked
    width is serving the merge's least-converged trailing columns with
    zero margin — quality then degrades silently append over append. The
    update must report unsatisfied so callers fall back to a warm refit
    (and delta subscribers see a rollback), never serve the zero-margin
    map."""
    from repro.core import subspace as subspace_mod

    base, grown = _staged_rank_stream()
    r = reduce(base, "pca", CFG, zero_cost())
    tr = SubspaceTracker.from_fit(base, r.v)

    def clears_only_at_full_width(est, target, w, cfg):
        return w, 0.99, True, 64  # (k, tlb_mean, satisfied, pairs)

    monkeypatch.setattr(
        subspace_mod, "_binary_search", clears_only_at_full_width
    )
    _, res, _ = suffix_update(tr, grown, CFG)
    assert res.k >= 1
    assert not res.satisfied  # zero headroom left => treated as exhausted


def test_full_space_width_keeps_satisfied(monkeypatch):
    """The carve-out: when the tracked width already spans min(m, d), no
    refit could find more directions — a full-width clear IS the best
    answer and must stay satisfied."""
    from repro.core import subspace as subspace_mod

    x = _stream(m_total=300, d=6, rank=3)  # d=6 < k + TRACK_HEADROOM
    r = reduce(x[:240], "pca", CFG, zero_cost())
    tr = SubspaceTracker.from_fit(x[:240], r.v)

    def clears_only_at_full_width(est, target, w, cfg):
        return w, 0.99, True, 64

    monkeypatch.setattr(
        subspace_mod, "_binary_search", clears_only_at_full_width
    )
    _, res, _ = suffix_update(tr, x, CFG)
    assert res.k == min(x.shape)  # the stub clears only at full width
    assert res.satisfied


def test_novel_direction_stream_never_serves_degraded_map():
    """End-to-end: appended rows that open MORE novel directions than a
    zero-headroom tracker can absorb must end in a refit-quality result —
    the service path may not serve the saturated merge as satisfied."""
    base, grown = _staged_rank_stream()
    r = reduce(base, "pca", CFG, zero_cost())
    tr = SubspaceTracker.from_fit(base, r.v)
    _, res, _ = suffix_update(tr, grown, CFG, headroom=0)
    # cap_w == tracker.width: the novel directions cannot fit, so either
    # the gate fails outright or clears only at the saturated width —
    # both must surface as unsatisfied (the caller's refit trigger)
    assert not res.satisfied
    # the service ladder turns that verdict into a warm refit that DOES
    # satisfy the target on the grown data
    svc = DropService(suffix_budget=0.0)
    svc.submit(base, CFG, zero_cost())
    svc.run()
    svc.submit(grown, CFG, zero_cost())
    out = svc.run()[0]
    assert out.error is None
    assert out.result.satisfied
