"""End-to-end behaviour tests for the paper's system.

The full pipeline the paper describes: structured time series in -> DROP
(progressive sampling + sampled TLB + cost-based termination) -> low-dim
basis -> downstream analytics — plus the framework integration round-trip
(train with checkpointing, restore, serve).
"""

import numpy as np
import pytest

from repro.analytics import knn_retrieval_accuracy
from repro.baselines.svd_pca import svd_binary_search
from repro.core import DropConfig, drop
from repro.core.cost import knn_cost
from repro.core.tlb import exact_tlb
from repro.data import ecg_like


@pytest.fixture(scope="module")
def pipeline_result():
    x, y = ecg_like(1500, 140, seed=7)
    cfg = DropConfig(target_tlb=0.98, seed=0)
    res = drop(x, cfg, cost=knn_cost(x.shape[0]))
    return x, y, cfg, res


def test_end_to_end_drop_knn_pipeline(pipeline_result):
    """Paper 4.4: DROP as analytics pre-processor preserves k-NN accuracy
    while cutting dimensionality."""
    x, y, cfg, res = pipeline_result
    assert res.satisfied
    assert res.k < x.shape[1] // 2  # substantial reduction at TLB 0.98
    acc_raw = knn_retrieval_accuracy(x, y)
    acc_drop = knn_retrieval_accuracy(np.ascontiguousarray(res.transform(x)), y)
    assert acc_drop > acc_raw - 0.03  # paper: within ~1%


def test_drop_basis_meets_contract_exactly(pipeline_result):
    """The TLB contract holds under exact (non-sampled) evaluation."""
    x, _, cfg, res = pipeline_result
    truth = exact_tlb(x[:400], res.v)
    assert truth >= cfg.target_tlb - 0.02  # sampling confidence slack


def test_drop_beats_full_svd_on_data_touched(pipeline_result):
    """The paper's core economy: DROP touches a fraction of the rows."""
    x, _, cfg, res = pipeline_result
    assert res.total_rows_processed < 0.6 * x.shape[0]
    base = svd_binary_search(x, cfg)
    assert res.k <= int(base.k * 2.0) + 2  # modest k inflation (paper: 1.23x)


def test_trainer_to_serving_round_trip(tmp_path):
    """Framework round-trip: train a smoke LM (with checkpointing), restore,
    and serve greedily — the checkpointed params drive generation."""
    import jax

    from repro.checkpoint import ckpt
    from repro.configs.base import get_smoke_config
    from repro.serve.engine import Engine
    from repro.sharding.specs import ShardCtx
    from repro.train.optimizer import OptimizerConfig, init_optimizer
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_smoke_config("tinyllama_1_1b")
    tc = TrainerConfig(total_steps=8, ckpt_every=4, ckpt_dir=str(tmp_path),
                       log_every=100, seed=3)
    trainer = Trainer(cfg, OptimizerConfig(learning_rate=1e-3), tc,
                      log=lambda *_: None)
    trainer.run()

    # restore into fresh structures (as a new process would)
    from repro.models.model import init_model

    params0 = init_model(cfg, jax.random.PRNGKey(tc.seed))
    (params, _), step = ckpt.restore(
        str(tmp_path), (params0, init_optimizer(params0))
    )
    assert step == 8

    eng = Engine(params, cfg, ShardCtx(mesh=None), batch=2, context_len=24)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, size=(2, 8))
    out = eng.generate(prompts, max_new=4)
    assert out.tokens.shape[0] == 2
    assert (out.tokens < cfg.vocab_size).all()
