"""TLB estimator tests: CI behavior, prefix table correctness, exact oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pca import pca_fit_svd
from repro.core.tlb import (
    TLBEstimator,
    exact_tlb,
    gaussian_ci,
    prefix_tlb_table,
    sample_pairs,
)
from repro.data import sinusoid_mixture


@pytest.fixture(scope="module")
def fitted():
    x, _ = sinusoid_mixture(500, 64, rank=5, seed=0)
    _, v, _ = pca_fit_svd(jnp.asarray(x))
    return x, np.asarray(v)


def test_sample_pairs_no_self_pairs():
    rng = np.random.default_rng(0)
    pairs = sample_pairs(100, 5000, rng)
    assert (pairs[:, 0] != pairs[:, 1]).all()
    assert pairs.min() >= 0 and pairs.max() < 100


def test_prefix_table_matches_direct_computation(fitted):
    x, v = fitted
    pairs = sample_pairs(x.shape[0], 64, np.random.default_rng(1))
    tab = np.asarray(
        prefix_tlb_table(jnp.asarray(x[pairs[:, 0]]), jnp.asarray(x[pairs[:, 1]]), jnp.asarray(v))
    )
    # direct: for a few (pair, k) cells compute ||diff @ V_k|| / ||diff||
    for pi in (0, 17, 63):
        diff = x[pairs[pi, 0]] - x[pairs[pi, 1]]
        for k in (1, 5, 32, 64):
            want = np.linalg.norm(diff @ v[:, :k]) / np.linalg.norm(diff)
            assert tab[pi, k - 1] == pytest.approx(min(want, 1.0), abs=2e-4)


def test_prefix_table_monotone_in_k(fitted):
    x, v = fitted
    pairs = sample_pairs(x.shape[0], 128, np.random.default_rng(2))
    tab = np.asarray(
        prefix_tlb_table(jnp.asarray(x[pairs[:, 0]]), jnp.asarray(x[pairs[:, 1]]), jnp.asarray(v))
    )
    assert (np.diff(tab, axis=1) >= -1e-5).all()  # more components never hurt
    assert (tab >= 0).all() and (tab <= 1 + 1e-5).all()


def test_full_basis_tlb_is_one(fitted):
    x, v = fitted
    pairs = sample_pairs(x.shape[0], 64, np.random.default_rng(3))
    tab = np.asarray(
        prefix_tlb_table(jnp.asarray(x[pairs[:, 0]]), jnp.asarray(x[pairs[:, 1]]), jnp.asarray(v))
    )
    # full orthogonal basis preserves L2 distance exactly (paper §3.4.3)
    assert tab[:, -1] == pytest.approx(np.ones(64), abs=1e-3)


def test_estimator_ci_narrows_with_pairs(fitted):
    x, v = fitted
    est = TLBEstimator(x, jnp.asarray(v), np.random.default_rng(4))
    few = est.table(50)[:, 9]
    many = est.table(1600)[:, 9]
    _, lo1, hi1 = gaussian_ci(few, 0.95)
    _, lo2, hi2 = gaussian_ci(many, 0.95)
    assert (hi2 - lo2) < (hi1 - lo1)


def test_estimate_at_k_terminates_quickly_when_far_from_target(fitted):
    x, v = fitted
    est = TLBEstimator(x, jnp.asarray(v), np.random.default_rng(5))
    e = est.estimate_at_k(64, target=0.5, initial_pairs=100, max_pairs=6400)
    assert e.pairs_used == 100  # CI clears 0.5 immediately at full rank


def test_sampled_estimate_agrees_with_exact(fitted):
    x, v = fitted
    est = TLBEstimator(x[:200], jnp.asarray(v), np.random.default_rng(6))
    vals = est.table(3200)[:, 4]
    truth = exact_tlb(x[:200], v[:, :5])
    assert vals.mean() == pytest.approx(truth, abs=0.02)


def test_point_scores_identify_worst_fit(fitted):
    x, v = fitted
    est = TLBEstimator(x, jnp.asarray(v), np.random.default_rng(7))
    est.table(400)
    pts, scores = est.point_scores(3)
    assert pts.size > 0
    assert (scores >= 0).all() and (scores <= 1 + 1e-5).all()
    assert np.unique(pts).size == pts.size
