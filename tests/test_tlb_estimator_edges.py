"""TLBEstimator edge cases: coincident pairs, pair-budget clamping, k=0,
and worst-first point scores (§3.3.2 / §3.4.2 corner behavior)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bucketing import ShapeBucketCache
from repro.core.tlb import TLBEstimator, sample_pairs


def _orthonormal(d: int, seed: int = 0) -> np.ndarray:
    q = np.linalg.qr(np.random.default_rng(seed).normal(size=(d, d)))[0]
    return q.astype(np.float32)


def test_coincident_pairs_contribute_tlb_one():
    """Duplicate rows give zero pair distance: the ratio is defined as 1
    (any projection preserves a zero distance exactly)."""
    d = 8
    x = np.ones((20, d), dtype=np.float32)  # every pair is coincident
    est = TLBEstimator(x, jnp.asarray(_orthonormal(d)), np.random.default_rng(0))
    tab = est.table(64)
    np.testing.assert_allclose(tab, 1.0)
    e = est.estimate_at_k(3, target=0.9)
    assert e.mean == pytest.approx(1.0)


def test_mixed_coincident_rows_stay_in_unit_interval():
    d = 6
    rng = np.random.default_rng(1)
    x = rng.normal(size=(30, d)).astype(np.float32)
    x[10:] = x[0]  # big block of duplicates → many coincident pairs
    est = TLBEstimator(x, jnp.asarray(_orthonormal(d, 1)), np.random.default_rng(2))
    tab = est.table(200)
    assert np.isfinite(tab).all()
    assert tab.min() >= 0.0 and tab.max() <= 1.0 + 1e-5


def test_pair_budget_clamped_to_population():
    """max_pairs beyond m(m-1)/2 must clamp: the estimator never claims more
    pairs than the population holds."""
    d, m = 5, 6  # only 15 distinct pairs
    x = np.random.default_rng(3).normal(size=(m, d)).astype(np.float32)
    est = TLBEstimator(x, jnp.asarray(_orthonormal(d, 3)), np.random.default_rng(4))
    e = est.estimate_at_k(2, target=0.5, initial_pairs=100, max_pairs=10_000)
    assert est.num_pairs_total == m * (m - 1) // 2
    assert e.pairs_used <= est.num_pairs_total


def test_estimate_at_k_zero_dimensions():
    """k=0 projects everything to the origin: TLB 0, no pairs spent."""
    x = np.random.default_rng(5).normal(size=(40, 7)).astype(np.float32)
    est = TLBEstimator(x, jnp.asarray(_orthonormal(7, 5)), np.random.default_rng(6))
    e = est.estimate_at_k(0, target=0.9)
    assert (e.mean, e.lo, e.hi, e.pairs_used) == (0.0, 0.0, 0.0, 0)


def test_point_scores_are_per_point_minimum_and_worst_first():
    """score(point) = min TLB over its evaluated pairs; sorting by score must
    surface the worst-fit points first (they seed the next sample)."""
    d, k = 10, 3
    x = np.random.default_rng(7).normal(size=(60, d)).astype(np.float32)
    est = TLBEstimator(x, jnp.asarray(_orthonormal(d, 7)), np.random.default_rng(8))
    est.table(300)
    pts, scores = est.point_scores(k)
    assert pts.size > 0 and pts.size == np.unique(pts).size

    vals = est._table[:300, k - 1]
    pairs = est._pairs[:300]
    for p, s in zip(pts[:20], scores[:20]):
        touching = vals[(pairs[:, 0] == p) | (pairs[:, 1] == p)]
        assert s == pytest.approx(float(touching.min()), abs=1e-6)

    # worst-first: the bottom-quantile cut used for importance sampling must
    # select exactly the points at or below the score cutoff
    from repro.core.sampling import hard_points_from_scores

    hard = hard_points_from_scores(pts, scores, quantile=0.2)
    cutoff = np.quantile(scores, 0.2)
    np.testing.assert_array_equal(np.sort(hard), np.sort(pts[scores <= cutoff]))
    assert scores[np.isin(pts, hard)].max() <= cutoff + 1e-12


def test_point_scores_empty_before_any_pairs():
    x = np.random.default_rng(9).normal(size=(10, 4)).astype(np.float32)
    est = TLBEstimator(x, jnp.asarray(_orthonormal(4, 9)), np.random.default_rng(10))
    pts, scores = est.point_scores(2)
    assert pts.size == 0 and scores.size == 0
    pts0, scores0 = est.point_scores(0)
    assert pts0.size == 0 and scores0.size == 0


def test_bucketed_extension_matches_unbucketed():
    """Zero-padding pair batches to shape buckets must not change the table:
    padding rows are sliced off before they reach any estimate."""
    d = 12
    x = np.random.default_rng(11).normal(size=(80, d)).astype(np.float32)
    v = jnp.asarray(_orthonormal(d, 11))
    plain = TLBEstimator(x, v, np.random.default_rng(12))
    bucketed = TLBEstimator(
        x, v, np.random.default_rng(12), bucket=ShapeBucketCache()
    )
    np.testing.assert_allclose(plain.table(100), bucketed.table(100), atol=1e-6)
    np.testing.assert_allclose(plain.table(333), bucketed.table(333), atol=1e-6)


def test_sample_pairs_within_range_small_m():
    pairs = sample_pairs(2, 50, np.random.default_rng(13))
    assert (pairs[:, 0] != pairs[:, 1]).all()
    assert pairs.min() >= 0 and pairs.max() < 2
