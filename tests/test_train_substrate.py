"""Training substrate: optimizer, checkpointing, fault-tolerant trainer,
gradient compression math, token pipeline determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs.base import get_smoke_config
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.fault.faults import FailureInjector, NodeFailure, StragglerMonitor
from repro.train import grad_compress as gc
from repro.train.optimizer import (
    OptimizerConfig,
    adamw_update,
    clip_by_global_norm,
    init_optimizer,
    lr_at,
)
from repro.train.trainer import Trainer, TrainerConfig


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    params = {"w": jnp.ones((8, 8)) * 3.0}
    state = init_optimizer(params)
    cfg = OptimizerConfig(learning_rate=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=300, schedule="constant")
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-3


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 100
    n2 = float(jnp.linalg.norm(clipped["a"]))
    assert n2 == pytest.approx(1.0, rel=1e-5)


def test_lr_schedule_warmup_and_decay():
    cfg = OptimizerConfig(learning_rate=1.0, warmup_steps=10, total_steps=100)
    assert float(lr_at(jnp.asarray(5), cfg)) == pytest.approx(0.5, rel=1e-5)
    assert float(lr_at(jnp.asarray(10), cfg)) == pytest.approx(1.0, rel=1e-5)
    assert float(lr_at(jnp.asarray(100), cfg)) < 1e-6


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones(5)}}
    ckpt.save(str(tmp_path), 7, tree)
    restored, step = ckpt.restore(str(tmp_path), tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))


def test_checkpoint_latest_and_prune(tmp_path):
    tree = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), s, tree)
    assert ckpt.latest_step(str(tmp_path)) == 4
    ckpt.prune(str(tmp_path), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    assert len(os.listdir(tmp_path)) == 2


def test_checkpoint_structure_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"a": jnp.zeros(3), "b": jnp.zeros(2)})


def test_checkpoint_atomicity_no_partial_state(tmp_path):
    """A .tmp directory must never be considered a restore point."""
    tree = {"x": jnp.zeros(3)}
    ckpt.save(str(tmp_path), 1, tree)
    os.makedirs(tmp_path / "step_00000002.tmp")  # simulated crash mid-write
    assert ckpt.latest_step(str(tmp_path)) == 1


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_trainer_restarts_after_injected_failures(tmp_path):
    cfg = get_smoke_config("tinyllama_1_1b")
    tc = TrainerConfig(
        total_steps=12, ckpt_every=4, ckpt_dir=str(tmp_path), log_every=100,
        failure_prob=0.15, seed=0,
    )
    trainer = Trainer(cfg, OptimizerConfig(learning_rate=1e-3), tc,
                      log=lambda *_: None)
    report = trainer.run()
    assert report.restarts == trainer.injector.injected > 0
    assert report.ckpt_steps and max(report.ckpt_steps) == 12
    assert ckpt.latest_step(str(tmp_path)) == 12


def test_trainer_loss_decreases(tmp_path):
    cfg = get_smoke_config("tinyllama_1_1b")
    tc = TrainerConfig(total_steps=30, ckpt_every=30, ckpt_dir=str(tmp_path),
                       log_every=100, seed=1)
    trainer = Trainer(cfg, OptimizerConfig(learning_rate=3e-3, warmup_steps=5,
                                           total_steps=30), tc,
                      log=lambda *_: None)
    report = trainer.run()
    first = np.mean(report.losses[:5])
    last = np.mean(report.losses[-5:])
    assert last < first - 0.1  # synthetic markov data is learnable


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(deadline_factor=3.0)
    for i in range(10):
        mon.observe(i, 0.1)
    assert mon.observe(10, 1.0) is True
    assert mon.flagged_steps == [10]


def test_failure_injector_deterministic():
    a = FailureInjector(0.3, seed=5)
    b = FailureInjector(0.3, seed=5)
    fa = [s for s in range(50) if _fails(a, s)]
    fb = [s for s in range(50) if _fails(b, s)]
    assert fa == fb and len(fa) > 0


def _fails(inj, step):
    try:
        inj.maybe_fail(step)
        return False
    except NodeFailure:
        return True


# ---------------------------------------------------------------------------
# DROP gradient compression math
# ---------------------------------------------------------------------------

def test_compression_roundtrip_identity_when_full_rank():
    g = np.random.default_rng(0).normal(size=(64, 32)).astype(np.float32)
    v = np.linalg.qr(np.random.default_rng(1).normal(size=(32, 32)))[0].astype(
        np.float32
    )
    approx = (g @ v) @ v.T
    np.testing.assert_allclose(approx, g, atol=1e-4)


def test_discover_basis_on_low_rank_gradients():
    rng = np.random.default_rng(0)
    u = rng.normal(size=(512, 4)).astype(np.float32)
    w = rng.normal(size=(4, 256)).astype(np.float32)
    g = u @ w  # rank-4 gradient matrix
    v = gc.discover_basis(g, gc.GradCompressConfig(target_tlb=0.95))
    assert v is not None
    assert v.shape[0] == 256 and v.shape[1] <= 16  # found the low rank
    rel_err = np.linalg.norm(g - (g @ v) @ v.T) / np.linalg.norm(g)
    assert rel_err < 0.35


def test_discover_basis_skips_full_rank_noise():
    g = np.random.default_rng(0).normal(size=(400, 300)).astype(np.float32)
    v = gc.discover_basis(g, gc.GradCompressConfig(target_tlb=0.99, max_rank=512))
    assert v is None  # no useful compression on isotropic noise


def test_compressed_bytes_ratio():
    grads = {"layer": {"w_gate": jnp.zeros((512, 256))}}
    leaf_path = jax.tree_util.tree_leaves_with_path(grads)[0][0]
    name = gc._path_key(leaf_path)
    bases = {name: jnp.zeros((256, 16))}
    ratio = gc.compressed_bytes_ratio(grads, bases)
    assert ratio == pytest.approx(16 / 256)


# ---------------------------------------------------------------------------
# token pipeline
# ---------------------------------------------------------------------------

def test_token_pipeline_deterministic_and_restartable():
    cfg = TokenPipelineConfig(vocab_size=1000, seq_len=64, global_batch=4, seed=3)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    b1, b2 = p1.batch(17), p2.batch(17)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    assert b1["inputs"].shape == (4, 64)
    assert b1["inputs"].max() < 1000
    # shifted-by-one language modeling targets
    np.testing.assert_array_equal(b1["inputs"][:, 1:], b1["targets"][:, :-1])


def test_token_pipeline_host_sharding():
    full = TokenPipeline(
        TokenPipelineConfig(vocab_size=100, seq_len=8, global_batch=8, seed=0)
    ).batch(0)
    h0 = TokenPipeline(
        TokenPipelineConfig(vocab_size=100, seq_len=8, global_batch=8, seed=0,
                            n_hosts=2, host_id=0)
    ).batch(0)
    assert h0["inputs"].shape == (4, 8)
