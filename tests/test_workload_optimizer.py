"""WorkloadOptimizer: per-method outcomes, objective-based selection, and
the §4.4 end-to-end demo (slow: DROP chosen and faster than forced FFT/PAA
at matched TLB on a structured workload)."""

import time

import numpy as np
import pytest

from repro.core import DropConfig, reduce
from repro.core.cost import downstream_cost
from repro.data import sinusoid_mixture
from repro.pipeline import WorkloadOptimizer, run_downstream


@pytest.fixture(scope="module")
def small():
    return sinusoid_mixture(300, 32, rank=3, seed=7)[0]


def test_optimizer_reports_every_method(small):
    opt = WorkloadOptimizer(
        methods=("pca", "fft", "paa", "dwt", "jl"),
        cfg=DropConfig(target_tlb=0.9, seed=0),
    )
    rep = opt.optimize(small, "knn")
    assert set(rep.outcomes) == {"pca", "fft", "paa", "dwt", "jl"}
    for m, o in rep.outcomes.items():
        assert o.result.method == m
        assert o.reduce_s > 0
        assert o.downstream_est_s == downstream_cost("knn", 300)(o.result.k)
        assert o.objective == o.reduce_s + o.downstream_est_s
        assert o.downstream_s is None  # execute defaults to "none"
    assert rep.chosen in rep.outcomes
    assert rep.chosen in rep.summary()


def test_chosen_minimizes_objective_among_satisfied(small):
    opt = WorkloadOptimizer(
        methods=("fft", "paa", "dwt"), cfg=DropConfig(target_tlb=0.9, seed=0)
    )
    rep = opt.optimize(small, "kde")
    sat = {m: o for m, o in rep.outcomes.items() if o.result.satisfied}
    assert sat  # contractive methods always satisfy at full width
    assert rep.chosen == min(sat, key=lambda m: sat[m].objective)


def test_all_failing_falls_back_to_best_tlb(small):
    """When no method reaches the (impossible) target, the caller still
    gets a map — the closest-TLB one, not the cheapest failure."""
    opt = WorkloadOptimizer(
        methods=("fft", "jl"), cfg=DropConfig(target_tlb=1.5, seed=0)
    )
    rep = opt.optimize(small, "knn")
    assert not any(o.result.satisfied for o in rep.outcomes.values())
    best_tlb = max(
        rep.outcomes, key=lambda m: rep.outcomes[m].result.tlb_estimate
    )
    assert rep.chosen == best_tlb


def test_execute_chosen_runs_only_the_winner(small):
    opt = WorkloadOptimizer(
        methods=("fft", "paa"), cfg=DropConfig(target_tlb=0.9, seed=0)
    )
    rep = opt.optimize(small, "knn", execute="chosen")
    assert rep.best.downstream_s is not None
    assert rep.best.end_to_end_s == rep.best.reduce_s + rep.best.downstream_s
    others = [o for m, o in rep.outcomes.items() if m != rep.chosen]
    assert all(o.downstream_s is None for o in others)


def test_plan_orders_cheap_methods_first(small):
    opt = WorkloadOptimizer(methods=("pca", "fft", "paa"))
    assert opt.plan(small) == ["paa", "fft", "pca"]  # DROP last


def test_optimizer_rejects_unknowns(small):
    with pytest.raises(KeyError):
        WorkloadOptimizer(methods=("pca", "umap"))
    opt = WorkloadOptimizer(methods=("fft",))
    with pytest.raises(KeyError):
        opt.optimize(small, "regression")
    with pytest.raises(ValueError):
        opt.optimize(small, "knn", execute="some")


def test_run_downstream_registry(small):
    assert run_downstream("knn", small[:, :4]).shape == (small.shape[0],)
    assert run_downstream("kde", small[:, :4]).shape == (small.shape[0],)
    labels = run_downstream("dbscan", small[:, :4])
    assert labels.shape == (small.shape[0],)


@pytest.mark.slow  # full-scale §4.4 demo: DROP + analytics at m=8000
def test_e2e_demo_drop_chosen_and_faster(tmp_path):
    """Acceptance demo: on a structured synthetic workload at matched
    TLB >= 0.98, the optimizer picks DROP(PCA) and its measured end-to-end
    (DR + k-NN) beats forced FFT and PAA. Timing follows the harness
    convention (jit warm, best-of-N) — see benchmarks/bench_e2e_workload.py
    for the standalone version."""
    x, _ = sinusoid_mixture(8000, 384, rank=3, seed=0)
    cfg = DropConfig(target_tlb=0.98, seed=0)
    cost = downstream_cost("knn", x.shape[0])
    methods = ("pca", "fft", "paa")
    for m in methods:  # warm DR + per-k downstream kernels
        res = reduce(x, m, cfg, cost)
        if m == "pca":  # the adaptive schedule needs two runs to stabilize
            res = reduce(x, m, cfg, cost)
        run_downstream("knn", res.transform(x))

    opt = WorkloadOptimizer(methods=methods, cfg=cfg)
    rep = opt.optimize(x, "knn", execute="all")
    for m, o in rep.outcomes.items():  # best-of-3 warm downstream
        xt = o.result.transform(x)
        for _ in range(2):
            t0 = time.perf_counter()
            run_downstream("knn", xt)
            o.downstream_s = min(o.downstream_s, time.perf_counter() - t0)
        o.end_to_end_s = o.reduce_s + o.downstream_s

    o = rep.outcomes
    assert rep.chosen == "pca", rep.summary()
    for m in methods:  # matched TLB: every method hit the 0.98 target
        assert o[m].result.satisfied and o[m].result.tlb_estimate >= 0.98
    assert o["pca"].result.k < o["fft"].result.k < o["paa"].result.k
    assert o["pca"].objective < o["fft"].objective
    assert o["pca"].objective < o["paa"].objective
    # measured end-to-end: strict vs PAA (wide margin); 5% tolerance vs FFT.
    # The slack exists because the k-NN block pays a k-INDEPENDENT O(m^2)
    # term — building the (b, m) distance matrix is memory-bound and
    # identical at k=3 and k=25 — so on CPU the pca-vs-fft e2e gap is only
    # the O(m^2 k) matmul delta, thin enough for container timing noise to
    # straddle. analytics/knn.py removes the second k-independent pass
    # (self-exclusion) with top_k(2) on ACCELERATORS only: measured on
    # XLA:CPU, lax.top_k is a 20-40x pessimization while where+argmin fuses
    # into one pass anyway — the distance-matrix build itself is
    # irreducible on every backend. The objective margin (cost-model
    # ranking) stays wide and is asserted strictly above.
    assert o["pca"].end_to_end_s < o["paa"].end_to_end_s, rep.summary()
    assert o["pca"].end_to_end_s < o["fft"].end_to_end_s * 1.05, rep.summary()
